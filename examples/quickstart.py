"""Quickstart: the MemEC store + the coding kernels in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import MemECCluster, make_cluster
from repro.core.codes import RSCode
from repro.kernels import ops
import jax.numpy as jnp


def main():
    # --- 1. an erasure-coded in-memory KV cluster (paper §4) ---
    cluster = MemECCluster(num_servers=16, scheme="rs", n=10, k=8, c=16,
                           chunk_size=512, max_unsealed=2)
    print("cluster: 16 servers, RS(10,8), 16 stripe lists")
    rng = np.random.default_rng(0)
    for i in range(3000):
        cluster.set(b"user%08d" % i, rng.bytes(24))
    print("loaded 3000 objects;",
          sum(s.seals for s in cluster.servers), "chunks sealed+encoded")

    cluster.update(b"user%08d" % 7, b"B" * 24)         # delta parity update
    print("GET after UPDATE:", cluster.get(b"user%08d" % 7)[:8], "...")

    # --- 2. kill a server; reads keep working (degraded mode, §5) ---
    t = cluster.fail_server(3)
    print(f"server 3 failed; transition T_N->D = {t['T_N_to_D']*1e3:.2f} ms")
    v = cluster.get(b"user%08d" % 7)
    assert v is not None
    print("degraded GET served;",
          cluster.stats["reconstructions"], "chunks reconstructed on demand")
    t = cluster.restore_server(3)
    print(f"server 3 restored; T_D->N = {t['T_D_to_N']*1e3:.2f} ms")

    # --- 3. scale out: sharded cluster, pipelined cross-shard batches ---
    sc = make_cluster(shards=4, num_servers=16, scheme="rs", n=10, k=8,
                      c=4, chunk_size=512, max_unsealed=1)
    items = [(b"batch%07d" % i, rng.bytes(24)) for i in range(6000)]
    for i in range(0, len(items), 64):
        sc.multi_set(items[i:i + 64])       # scatter/gather across shards
    got = sc.multi_get([k for k, _ in items[:64]])
    assert got == [v for _, v in items[:64]]
    print(f"sharded x4: {sc.stats['pipelined_batches']} pipelined batches, "
          f"{sc.stats['pipeline_overlap_saved_s']*1e3:.1f} modeled ms saved "
          "by overlapping shards")
    # fail a chunk-owning server in shard 2 only; others stay untouched
    victim = max(range(16),
                 key=lambda s: sum(sc.shards[2].servers[s].sealed))
    t = sc.fail_server(victim, shard=2)
    print(f"shard {t['shard']} recovered {t['recovered_chunks']} chunks in "
          f"{t['T_recovery']*1e3:.2f} modeled ms; "
          "other shards stayed decentralized")
    sc.restore_server(victim, shard=2)

    # --- 3a. async pipeline: hide coding behind the network (PR 4) ---
    # coding now has a modeled cost; async_engine=True submits engine
    # work as futures while netsim legs are in flight (max(coding, net)
    # per phase), overlaps seal fan-out with SET acks, and spreads
    # multi-key batches across proxies — contents stay byte-identical
    pair = {}
    for mode in (False, True):
        cl2 = make_cluster(shards=1, num_servers=16, scheme="rs", n=10,
                           k=8, c=4, chunk_size=512, max_unsealed=2,
                           async_engine=mode)
        for i in range(0, 3000, 64):
            cl2.multi_set(items[i:i + 64], proxy_id=None)
        pair[mode] = cl2
    assert (pair[True].multi_get([k for k, _ in items[:64]])
            == pair[False].multi_get([k for k, _ in items[:64]]))
    print(f"async S=1: saved "
          f"{pair[True].stats['intra_overlap_saved_s']*1e3:.1f} modeled ms "
          f"vs sync (coding {pair[True].stats['modeled_coding_s']*1e3:.1f} "
          f"ms hidden behind legs/acks), plus "
          f"{pair[True].stats['proxy_lane_saved_s']*1e3:.1f} ms vs serial "
          f"per-proxy calls across "
          f"{pair[True].stats['proxy_lane_batches']} lane batches; "
          "contents byte-identical to sync")

    # --- 3a'. plan/execute decode + the modeled engine queue (PR 5) ---
    # decode is a DecodePlan (host metadata: pattern group-by, cached
    # inversions) plus one batched device matmul per pattern group, so
    # jax/pallas dispatch it at submit; in async mode degraded
    # reconstruction overlaps decode with the recon fetches — the win is
    # stats["decode_overlap_saved_s"].  CostModel(engine_depth=d) bounds
    # how many engine calls one shard runs concurrently (default inf =
    # the historical no-contention merge); the extra wait a finite depth
    # induces lands in stats["engine_queue_wait_s"].
    from repro.core import CostModel
    deg = {}
    for depth in (float("inf"), 1):
        cl3 = make_cluster(shards=1, num_servers=16, scheme="rs", n=10,
                           k=8, c=4, chunk_size=512, max_unsealed=2,
                           num_proxies=1, async_engine=True,
                           cost=CostModel(coding_Bps=5e7,
                                          coding_fixed_s=2e-5,
                                          engine_depth=depth))
        for i in range(0, 2000, 32):
            cl3.multi_set(items[i:i + 32])
        cl3.fail_server(3, recover=False)   # §5.4 on-demand reconstruction
        cl3.multi_get([k for k, _ in items[:400]])
        deg[depth] = cl3
    inf_cl, d1 = deg[float("inf")], deg[1]
    print(f"eager decode: {inf_cl.stats['reconstructions']} on-demand "
          f"recons hid {inf_cl.stats['decode_overlap_saved_s']*1e3:.2f} "
          "modeled ms of decode behind recon fetches; "
          f"engine_depth=1 adds {d1.stats['engine_queue_wait_s']*1e3:.2f} "
          "ms of modeled queue wait (depth=inf adds "
          f"{inf_cl.stats['engine_queue_wait_s']*1e3:.2f})")

    # --- 3b. elastic placement: grow the cluster + escape a hot shard ---
    ec = make_cluster(shards=3, placement="ring", num_servers=16,
                      scheme="rs", n=10, k=8, c=4, chunk_size=512,
                      max_unsealed=1)
    items = [(b"el%07d" % i, rng.bytes(24)) for i in range(4000)]
    for i in range(0, len(items), 64):
        ec.multi_set(items[i:i + 64])
    rep = ec.add_shard()                    # live migration, ~1/S of keys
    print(f"add_shard: moved {rep['moved_keys']} keys "
          f"({rep['moved_bytes']} B, {rep['move_fraction']:.0%} of "
          "residents) — consistent hashing, not a reshuffle")
    ec.reset_load()
    hot = [k for k, _ in items if ec.shard_of(k) == 0][:400]
    for _ in range(4):
        ec.multi_get(hot)                   # hammer shard 0
    print(f"load skew before rebalance: {ec.load_skew():.2f} "
          f"(shard ops {ec.stats['shard_ops']})")
    rep = ec.rebalance(skew_threshold=1.2)  # shed the hot shard's arcs
    for _ in range(4):
        ec.multi_get(hot)
    print(f"rebalance moved {rep['moved_keys']} keys; "
          f"skew after: {ec.load_skew():.2f}")
    assert ec.multi_get([k for k, _ in items[:64]]) == \
        [v for _, v in items[:64]]          # nothing lost along the way

    # --- 3c. open-loop arrivals + tail percentiles (PR 7) ---
    # the phase algebra prices each request in isolation; an open-loop
    # ArrivalProcess (arrival= / $MEMEC_ARRIVAL) turns every recorded
    # request into a discrete event contending FCFS for admission slots,
    # per-endpoint link clocks, and CostModel.engine_depth coding lanes,
    # so p50/p99/p999 in stats["latency"] include queue wait.  Specs:
    #   MEMEC_ARRIVAL=poisson:5000:seed=1:inflight=4   seeded Poisson
    #   MEMEC_ARRIVAL=uniform:2000                     fixed 1/rate gaps
    #   MEMEC_ARRIVAL=trace:0.001,0.003,0.0035         explicit arrivals
    # The default "closed" keeps the historical closed loop (no event
    # machinery; rate->inf with inflight=1 reproduces it exactly), and
    # repro.core.telemetry.snapshot() exports the versioned dict schema
    # BENCH_ci.json and the benchmark harness consume.
    from repro.core import telemetry
    ol = MemECCluster(num_servers=16, scheme="rs", n=10, k=8, c=16,
                      chunk_size=512, max_unsealed=2,
                      arrival="poisson:2500:seed=1:inflight=4")
    for i in range(2000):
        ol.set(b"tail%06d" % i, rng.bytes(24))
    for i in range(4000):
        ol.get(b"tail%06d" % (i % 2000))
    lat = ol.stats["latency"]["GET"]
    snap = telemetry.validate(telemetry.snapshot(ol))
    print(f"open loop (poisson, inflight=4): GET p50 "
          f"{lat['p50_s']*1e3:.3f} ms, p99 {lat['p99_s']*1e3:.3f} ms, "
          f"p999 {lat['p999_s']*1e3:.3f} ms; queue wait "
          f"{ol.stats['queue_wait_s']*1e3:.1f} modeled ms "
          f"(telemetry schema {snap['schema']} v{snap['version']})")

    # --- 3d. span tracing + critical-path attribution (PR 8) ---
    # a bad p99 is opaque until you can see WHICH leg was slow.  With
    # trace=True (or MEMEC_TRACE=1) every recorded request grows a span
    # tree — admission wait, per-endpoint link legs, engine-lane queue +
    # service, seal/delta/decode phases tagged normal vs degraded — whose
    # max-weight root-to-leaf path equals the recorded latency.  Off by
    # default and zero-cost when off (no tracer state is allocated).
    #   trace.critical_paths(cl)  decomposes the p50/p99/p999 witness per
    #                             request kind into additive components
    #                             (telemetry v2 "critical_path" section)
    #   trace.export_chrome(cl, path="trace.json")  writes Chrome
    #                             trace-event JSON — open in Perfetto
    #                             (ui.perfetto.dev), one pid per shard,
    #                             one tid per server link / engine lane
    #   TraceCapture.from_cluster(cl)  records the run's arrivals + kinds;
    #                             replay it deterministically with
    #                             arrival=cap.arrival_spec() (or save()
    #                             and arrival="trace:@capture.json") to
    #                             reproduce a tail incident exactly
    from repro.core import TraceCapture, trace
    tr = MemECCluster(num_servers=16, scheme="rs", n=10, k=8, c=16,
                      chunk_size=512, max_unsealed=2,
                      arrival="poisson:2500:seed=1:inflight=4", trace=True)
    for i in range(400):
        tr.set(b"sp%06d" % i, rng.bytes(24))
    for i in range(800):
        tr.get(b"sp%06d" % (i % 400))
    cp = trace.critical_paths(tr)["GET"]["p99"]
    top, share = max(cp["components"].items(), key=lambda kv: kv[1]), \
        cp["latency_s"]
    print(f"GET p99 critical path: {top[0]} = {top[1]/share:.0%} of "
          f"{share*1e3:.3f} ms ({len(cp['components'])} components)")
    cap = TraceCapture.from_cluster(tr)
    print(f"captured {len(cap.arrivals)} arrivals; replay with "
          f"arrival=cap.arrival_spec() for a deterministic re-run")

    # --- 3e. straggler-tolerant reads: k-of-(k+Δ) + slow servers (PR 9) ---
    # one slow server ruins p99 for every read that touches it.  With
    # redundant_reads=Δ (or MEMEC_REDUNDANT_READS=Δ) a GET fans out to
    # the k-1+Δ least-loaded other stripe members alongside the data
    # server and completes at the k-th arrival; if the data server is
    # among the dropped Δ, the winners' chunks decode the value instead
    # (byte-identical, guarded by tests).  Dropped legs still occupy
    # links — later requests queue behind them — but show up as
    # cancelled spans, never on the critical path.  Inject a straggler
    # with inflate_server(sid, factor) (factor=1.0 restores):
    st = {}
    for delta in (0, 1):
        s = MemECCluster(num_servers=16, scheme="rs", n=10, k=8, c=4,
                         chunk_size=512, max_unsealed=2,
                         redundant_reads=delta)
        for i in range(1400):
            s.set(b"st%06d" % i, rng.bytes(24))
        s.inflate_server(3, 10.0)          # one server suddenly 10x slower
        for i in range(1400):
            s.get(b"st%06d" % i)
        st[delta] = s.stats["latency"]["GET"]["p99_s"] * 1e3
        if delta:
            print(f"straggler hidden: GET p99 {st[0]:.3f} -> {st[1]:.3f} ms "
                  f"({s.stats['redundant_decodes']} redundant decodes, "
                  f"{s.stats['redundant_cancelled']} cancelled fetches)")

    # --- 3f. hot-key update tier: version-buffered delta coding (PR 10) ---
    # every sealed UPDATE pays an engine delta + m parity legs; under a
    # Zipf write mix the few hottest keys dominate that cost.  With
    # hot_key_threshold=t (or MEMEC_HOT_KEYS=t) an EWMA tracker marks
    # sustained updaters hot and buffers their per-update XOR deltas
    # (bounded by hot_max_keys / hot_max_versions); data-server bytes
    # stay current — only parity lags, and only while buffered.  A flush
    # (eviction, a full entry, any parity-reading path: redundant-read
    # races, fail_server — or the explicit flush_hot_buffers()) folds
    # each key's V versions into ONE engine.submit_delta_collapse round,
    # so N buffered updates cost one parity round and the delta legs
    # carry just the union byte extent.  Byte-identical to a tier-off
    # twin (guarded by tests/test_hot_tier.py); stats land under
    # stats["hot_tier"] and the telemetry snapshot's "hot_tier" key:
    hot = MemECCluster(num_servers=16, scheme="rs", n=10, k=8, c=4,
                       chunk_size=512, max_unsealed=2,
                       hot_key_threshold=3.0)
    for i in range(1200):
        hot.set(b"hk%06d" % i, rng.bytes(64))
    for rep in range(200):
        hot.update(b"hk%06d" % (rep % 3), rng.bytes(64))
    folded = hot.flush_hot_buffers()
    ht = hot.stats["hot_tier"]
    print(f"hot tier: {ht['buffered_updates']} updates buffered, "
          f"{ht['saved_parity_rounds']} parity rounds saved "
          f"({ht['saved_parity_bytes']} delta bytes), "
          f"{folded} entries folded at the explicit drain")

    # --- 4. the compiled GF(2^8) data plane ---
    # kernels/dispatch picks the path per backend: compiled Pallas grids
    # on TPU/GPU, an XLA-jitted bit-plane formulation on CPU (faster
    # than both interpret-mode Pallas and the numpy oracle).  Knobs:
    #   MEMEC_INTERPRET=1   force interpret-mode Pallas everywhere (the
    #                       debugging escape hatch; the bench fails
    #                       loudly if interpret is entered WITHOUT it)
    #   MEMEC_TUNE_CACHE=f  use tuning cache f instead of the committed
    #                       kernels/tune_defaults.json; regenerate with
    #                       `python -m benchmarks.kernels_bench --tune`
    # `engine.describe()` / `engine.stats()` report the path actually
    # in use, so a run can always answer "did I actually compile?".
    from repro.kernels import dispatch
    code = RSCode(n=10, k=8)
    data = jnp.asarray(rng.integers(0, 256, (8, 4096), dtype=np.uint8))
    parity = ops.encode_stripe(code, data)           # dispatched kernel
    stripe = jnp.concatenate([data, parity])
    rec = ops.decode_stripe(code, {i: stripe[i] for i in range(10)
                                   if i not in (1, 9)}, [1, 9], 4096)
    assert np.array_equal(np.asarray(rec[1]), np.asarray(stripe[1]))
    print(f"kernel encode + decode-from-8-of-10 round trip: OK "
          f"(dispatch: {dispatch.describe()})")


if __name__ == "__main__":
    main()
