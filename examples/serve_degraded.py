"""Serve a small model with batched requests; EC-protect the KV-cache
pages and demonstrate a degraded read (reconstruct lost cache pages).

    PYTHONPATH=src python examples/serve_degraded.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.distributed import sharding as shd
from repro.distributed.ecstore import ECConfig
from repro.models import Model
from repro.serve.engine import ServeEngine


def main():
    cfg = get_reduced("recurrentgemma-2b")   # hybrid: RG-LRU + local attn
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, prompt_len, gen = 4, 24, 24
    eng = ServeEngine(model, params, max_len=prompt_len + gen, batch_size=B)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len),
                                 0, cfg.vocab_size)
    logits = eng.prefill({"tokens": prompts})
    print(f"prefilled {B}x{prompt_len} tokens")

    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    res = eng.decode(gen - 1, first_tokens=first)
    print("generated tokens (seq 0):", res.tokens[0][:12])

    # protect the serving state (KV window + recurrent states) with EC —
    # in production this runs continuously via delta parity updates
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 1), ("data", "model"))
    cspecs = shd.cache_specs(cfg, jax.eval_shape(lambda: eng.cache), mesh)
    eng.protect_cache(mesh, cspecs, ECConfig(k=2, m=1, page_size=256))
    print("cache pages erasure-coded")

    # degraded read drill: rebuild cache pages of data-axis position 0
    with mesh:
        pages = np.asarray(eng.ec_store.local_pages(eng.cache))
        rec = np.asarray(eng.recover_cache_pages(0))
    ok = np.array_equal(rec[0, 0], pages[0, 0])
    print("reconstructed cache pages match live cache:", ok,
          "(degraded GET at page granularity, paper §5.4)")
    assert ok


if __name__ == "__main__":
    main()
