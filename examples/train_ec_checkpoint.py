"""End-to-end driver: train a (reduced) LM with MemEC-style erasure-coded
in-memory checkpoints, kill a data-axis shard, reconstruct, keep training.

    PYTHONPATH=src python examples/train_ec_checkpoint.py \
        [--arch starcoder2-3b] [--steps 120]

Full-size runs use the same driver via repro.launch.train on a real mesh.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import sharding as shd
from repro.distributed.ecstore import ECConfig, ECStateStore
from repro.models import Model
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = Model(cfg)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 1), ("data", "model"))
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", lr=1e-3, warmup_steps=10,
                         total_steps=args.steps)
    opt_state = opt.init(params)
    pspecs = shd.param_specs(cfg, jax.eval_shape(lambda: params), mesh)
    # RS(3,2) over the 4-device data axis here; RS(10,8) on a real pod
    store = ECStateStore(mesh, pspecs, ECConfig(k=2, m=1, page_size=256))
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8,
                                  embed_dim=cfg.d_model
                                  if cfg.input_mode == "embeddings" else 0,
                                  mrope=cfg.rope_kind == "mrope"))
    with mesh:
        parity = store.encode(params)
        print("EC parity created:", parity.shape, parity.dtype)
        losses = []
        for i in range(args.steps):
            old = params
            params, opt_state, m = step(params, opt_state, data.batch(i))
            parity = store.delta_update(old, params, parity)  # paper UPDATE
            losses.append(float(m["loss"]))
            if i % 20 == 0:
                print(f"step {i:4d} loss {losses[-1]:.4f}")
        print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
        # --- failure drill: rebuild the shard from parity ---
        pages = np.asarray(store.local_pages(params))
        rec = np.asarray(store.reconstruct(params, parity, failed_index=0))
        ok = np.array_equal(rec[0, 0], pages[0, 0])
        print("reconstructed shard matches live state:", ok)
        assert ok


if __name__ == "__main__":
    main()
