"""Intra-shard async coding pipeline (PR 4).

The async pipeline (``async_engine=True`` / ``$MEMEC_ASYNC``) must be a
pure *scheduling* change: engine work is submitted as futures and overlaps
the shard's modeled netsim legs (``max(coding, network)`` per phase, seal
fan-out concurrent with SET acks, per-proxy lanes for multi-key batches),
but every stored byte and every served value stays identical to the
synchronous pipeline — in normal mode, degraded mode, and during
``fail_server`` batched recovery, for S=1 and S=4.  The modeled-latency
win is tracked in ``stats["intra_overlap_saved_s"]``.
"""
import os
import subprocess

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback
from conftest import subprocess_env

from repro.core import CostModel, EngineFuture, make_cluster, make_engine
from repro.core.codes import make_code
from repro.data.ycsb import YCSBConfig, YCSBWorkload, run_workload

KW = dict(num_servers=16, num_proxies=4, scheme="rs", n=10, k=8, c=4,
          chunk_size=512, max_unsealed=2)
# rs(4,2) small-cluster shape for the interleaving property (fast)
KW_SMALL = dict(num_servers=8, num_proxies=2, scheme="rs", n=4, k=2, c=6,
                chunk_size=256, max_unsealed=2, mapping_ckpt_every=16)


def sync_async_pair(shards=1, **kw):
    merged = dict(KW)
    merged.update(kw)
    return (make_cluster(shards=shards, async_engine=False, **merged),
            make_cluster(shards=shards, async_engine=True, **merged))


def all_keys(cfg):
    w = YCSBWorkload(cfg)
    return [w.key(i) for i in range(cfg.num_objects)]


# ---------------------------------------------------------------------------
# engine-level futures
# ---------------------------------------------------------------------------

class TestEngineFutures:
    BACKENDS = ("numpy", "jax")

    def _engine(self, backend, scheme="rs", n=6, k=4):
        return make_engine(backend, make_code(scheme, n, k))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_submit_matches_blocking_calls(self, backend, rng):
        eng = self._engine(backend)
        C = 64
        data = rng.integers(0, 256, (5, 4, C), dtype=np.uint8)
        assert np.array_equal(eng.submit_encode(data).result(),
                              eng.encode_batch(data))
        idx = np.array([0, 3, 1])
        xors = rng.integers(0, 256, (3, C), dtype=np.uint8)
        assert np.array_equal(eng.submit_delta(idx, xors).result(),
                              eng.delta_batch(idx, xors))
        parity = eng.encode_batch(data)
        avail = [{0: data[b, 0], 1: data[b, 1], 4: parity[b, 0],
                  5: parity[b, 1]} for b in range(5)]
        wanted = [[2, 3]] * 5
        got = eng.submit_decode(avail, wanted, C).result()
        want = eng.decode_batch(avail, wanted, C)
        for g, w in zip(got, want):
            assert sorted(g) == sorted(w)
            for pos in w:
                assert np.array_equal(g[pos], w[pos])

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_future_resolution_is_idempotent(self, backend, rng):
        eng = self._engine(backend)
        data = rng.integers(0, 256, (2, 4, 32), dtype=np.uint8)
        fut = eng.submit_encode(data)
        first = fut.result()
        assert fut.done
        assert first is fut.result()

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_work_bytes_deterministic_and_positive(self, backend):
        eng = self._engine(backend)
        f1 = eng.submit_encode(np.zeros((3, 4, 64), np.uint8))
        f2 = eng.submit_encode(np.zeros((3, 4, 64), np.uint8))
        assert f1.work_bytes == f2.work_bytes > 0
        assert eng.submit_delta(np.array([1]), np.zeros((1, 64), np.uint8)
                                ).work_bytes > 0

    def test_empty_batches(self):
        # numpy is lazy (work runs at result()); jax short-circuits to a
        # pre-resolved future — both return the empty shape
        fut = self._engine("numpy").submit_encode(
            np.zeros((0, 4, 64), np.uint8))
        assert fut.result().shape == (0, 2, 64)
        fut = self._engine("jax").submit_encode(
            np.zeros((0, 4, 64), np.uint8))
        assert fut.done and fut.result().shape == (0, 2, 64)

    def test_wrap_is_preresolved(self):
        fut = EngineFuture.wrap("x", work_bytes=7)
        assert fut.done and fut.result() == "x" and fut.work_bytes == 7

    def test_rdp_block_codes_supported(self, rng):
        eng = self._engine("jax", scheme="rdp", n=7, k=5)
        C = 64  # divisible by r = p-1 = 16
        data = rng.integers(0, 256, (3, 5, C), dtype=np.uint8)
        assert np.array_equal(eng.submit_encode(data).result(),
                              eng.encode_batch(data))


# ---------------------------------------------------------------------------
# sync/async byte equivalence on seeded YCSB runs
# ---------------------------------------------------------------------------

class TestSyncAsyncEquivalence:
    @pytest.mark.parametrize("shards", (1, 4))
    def test_normal_degraded_and_recovery(self, shards):
        n_obj = 1200 if shards == 1 else 1600
        cfg = YCSBConfig(num_objects=n_obj, seed=11)
        sync, asy = sync_async_pair(shards=shards)
        for cl in (sync, asy):
            run_workload(cl, "load", 0, cfg, batch_size=16)
            run_workload(cl, "A", 1200, cfg, batch_size=16)
        keys = all_keys(cfg)
        assert sync.multi_get(keys) == asy.multi_get(keys)
        # fail a server: batched recovery runs, then traffic lands on the
        # degraded shard *during* the outage
        sid = sync.global_sid(2, 3) if shards > 1 else 3
        ts, ta = sync.fail_server(sid), asy.fail_server(sid)
        assert ts["recovered_chunks"] == ta["recovered_chunks"]
        assert sync.multi_get(keys) == asy.multi_get(keys)
        wcfg = YCSBConfig(num_objects=n_obj, seed=12)
        for cl in (sync, asy):
            run_workload(cl, "A", 600, wcfg, batch_size=16)
        assert sync.multi_get(keys) == asy.multi_get(keys)
        for cl in (sync, asy):
            cl.restore_server(sid)
        assert sync.multi_get(keys) == asy.multi_get(keys)
        if shards == 1:
            assert asy.stats["degraded_requests"] == \
                sync.stats["degraded_requests"]

    def test_single_key_paths_identical(self, rng):
        sync, asy = sync_async_pair()
        kv = {}
        for i in range(600):
            k = b"sk%06d" % i
            v = bytes(rng.integers(0, 256, 8 if i % 2 else 24,
                                   dtype=np.uint8))
            assert sync.set(k, v) == asy.set(k, v) is True
            kv[k] = v
        for i, k in enumerate(sorted(kv)):
            if i % 3 == 0:
                nv = bytes(len(kv[k]))
                assert sync.update(k, nv) == asy.update(k, nv)
                kv[k] = nv
            elif i % 7 == 0:
                assert sync.delete(k) == asy.delete(k)
                kv[k] = None
        for k, v in kv.items():
            assert sync.get(k) == asy.get(k) == v

    def test_env_var_knob(self):
        env = subprocess_env()
        env["MEMEC_ASYNC"] = "1"
        out = subprocess.check_output(
            ["python", "-c",
             "from repro.core import make_cluster;"
             "print(make_cluster(shards=1, num_servers=8, scheme='rs',"
             " n=4, k=2, c=4).async_engine)"], env=env)
        assert out.strip() == b"True"
        env["MEMEC_ASYNC"] = "0"
        out = subprocess.check_output(
            ["python", "-c",
             "from repro.core import make_cluster;"
             "print(make_cluster(shards=1, num_servers=8, scheme='rs',"
             " n=4, k=2, c=4).async_engine)"], env=env)
        assert out.strip() == b"False"


# ---------------------------------------------------------------------------
# modeled-latency win
# ---------------------------------------------------------------------------

class TestOverlapAccounting:
    def test_overlap_saves_modeled_time_coding_bound(self):
        """With GF throughput slowed to be coding-bound, the async
        pipeline must both record savings and reduce total modeled time."""
        cost = CostModel(coding_Bps=5e7, coding_fixed_s=2e-5)
        cfg = YCSBConfig(num_objects=900, seed=21)
        sync, asy = sync_async_pair(cost=cost)
        for cl in (sync, asy):
            run_workload(cl, "load", 0, cfg, batch_size=16)
            run_workload(cl, "A", 800, cfg, batch_size=16)
        assert sync.stats["intra_overlap_saved_s"] == 0.0
        assert asy.stats["intra_overlap_saved_s"] > 0
        assert asy.stats["modeled_coding_s"] > 0
        assert asy.net.total_recorded_s < sync.net.total_recorded_s
        assert sync.multi_get(all_keys(cfg)) == asy.multi_get(all_keys(cfg))

    def test_single_key_seal_ack_overlap(self, rng):
        """Even without batching, async SETs overlap the seal fan-out
        with the acks — savings appear once chunks start sealing."""
        sync, asy = sync_async_pair()
        for i in range(900):
            v = rng.bytes(24)
            sync.set(b"ov%06d" % i, v)
            asy.set(b"ov%06d" % i, v)
        assert sum(s.seals for s in asy.servers) > 0
        assert asy.stats["intra_overlap_saved_s"] > 0
        assert asy.net.total_recorded_s < sync.net.total_recorded_s

    def test_recovery_merges_coding_with_fetches(self):
        cost = CostModel(coding_Bps=5e7, coding_fixed_s=2e-5)
        cfg = YCSBConfig(num_objects=1500, seed=23)
        sync, asy = sync_async_pair(cost=cost)
        for cl in (sync, asy):
            run_workload(cl, "load", 0, cfg, batch_size=32)
        ts, ta = sync.fail_server(3), asy.fail_server(3)
        assert ts["recovered_chunks"] == ta["recovered_chunks"] > 0
        # sync recovery pays coding + fetches serially; async the max
        assert ta["T_recovery"] < ts["T_recovery"]
        keys = all_keys(cfg)
        assert sync.multi_get(keys) == asy.multi_get(keys)
        sync.restore_server(3)
        asy.restore_server(3)
        assert sync.multi_get(keys) == asy.multi_get(keys)


# ---------------------------------------------------------------------------
# cross-proxy lanes
# ---------------------------------------------------------------------------

class TestProxyLanes:
    def test_spread_batches_merge_into_one_record(self, rng):
        _, asy = sync_async_pair()
        items = [(b"ln%06d" % i, rng.bytes(24)) for i in range(96)]
        assert all(asy.multi_set(items, proxy_id=None))
        assert asy.stats["proxy_lane_batches"] > 0
        # lane overlap is reported against the serial-lane baseline,
        # never folded into the sync-vs-async intra_overlap stat
        assert asy.stats["proxy_lane_saved_s"] > 0
        assert asy.net.ops_by_kind["MSET"] == 1   # one merged record
        assert sum(p.requests_begun for p in asy.proxies) >= len(items)
        got = asy.multi_get([k for k, _ in items], proxy_id=None)
        assert got == [v for _, v in items]

    def test_lane_assignment_keeps_per_key_order(self, rng):
        """Duplicate keys in one spread batch must upsert in request
        order (all occurrences of a key hash to the same lane)."""
        sync, asy = sync_async_pair()
        items = []
        for i in range(40):
            k = b"dup%04d" % (i % 10)
            items.append((k, rng.bytes(24)))
        assert all(sync.multi_set(items, proxy_id=0))
        assert all(asy.multi_set(items, proxy_id=None))
        for k in {k for k, _ in items}:
            assert sync.get(k) == asy.get(k)

    def test_sync_spread_runs_serially(self, rng):
        """proxy_id=None without async: lanes execute back to back (the
        conservative model) and must not record overlap savings."""
        sync, _ = sync_async_pair()
        items = [(b"ss%06d" % i, rng.bytes(24)) for i in range(64)]
        assert all(sync.multi_set(items, proxy_id=None))
        assert sync.stats["intra_overlap_saved_s"] == 0.0
        assert sync.stats["proxy_lane_saved_s"] == 0.0


# ---------------------------------------------------------------------------
# property-based interleavings (style of tests/test_transitions_prop.py):
# a sync and an async twin replay the same drawn op/failure sequence and
# must never diverge on a single served value
# ---------------------------------------------------------------------------

KEYSPACE = [b"ak%05d" % i for i in range(40)]


class TwinDriver:
    def __init__(self):
        self.sync = make_cluster(shards=1, async_engine=False, **KW_SMALL)
        self.asy = make_cluster(shards=1, async_engine=True, **KW_SMALL)
        self.failed: set[int] = set()
        self.version = 0

    def step(self, data):
        op = data.draw(st.sampled_from(
            ("mset", "set", "update", "mget", "get", "fail", "restore")),
            label="op")
        if op in ("set", "update"):
            key = data.draw(st.sampled_from(KEYSPACE), label="key")
            self.version += 1
            val = bytes((self.version + i) % 256
                        for i in range(8 if key[-1] % 2 else 24))
            if op == "set":
                assert self.sync.set(key, val) == self.asy.set(key, val)
            else:
                assert self.sync.update(key, val) == \
                    self.asy.update(key, val)
        elif op == "mset":
            ks = data.draw(st.lists(st.sampled_from(KEYSPACE),
                                    min_size=1, max_size=12), label="mkeys")
            self.version += 1
            items = [(k, bytes((self.version + j) % 256 for j in
                               range(8 if k[-1] % 2 else 24))) for k in ks]
            assert self.sync.multi_set(items, proxy_id=0) == \
                self.asy.multi_set(items, proxy_id=None)
        elif op == "mget":
            ks = data.draw(st.lists(st.sampled_from(KEYSPACE),
                                    min_size=1, max_size=12), label="gkeys")
            assert self.sync.multi_get(ks, proxy_id=0) == \
                self.asy.multi_get(ks, proxy_id=None)
        elif op == "get":
            key = data.draw(st.sampled_from(KEYSPACE), label="gkey")
            assert self.sync.get(key) == self.asy.get(key)
        elif op == "fail":
            live = [s for s in range(len(self.sync.servers))
                    if s not in self.failed]
            if len(self.failed) >= 2 or not live:  # rs(4,2): m = 2
                return
            sid = data.draw(st.sampled_from(live), label="fsid")
            self.sync.fail_server(sid)
            self.asy.fail_server(sid)
            self.failed.add(sid)
        elif op == "restore":
            if not self.failed:
                return
            sid = data.draw(st.sampled_from(sorted(self.failed)),
                            label="rsid")
            self.sync.restore_server(sid)
            self.asy.restore_server(sid)
            self.failed.discard(sid)

    def finish(self):
        for sid in sorted(self.failed):
            self.sync.restore_server(sid)
            self.asy.restore_server(sid)
        self.failed.clear()
        for key in KEYSPACE:
            assert self.sync.get(key) == self.asy.get(key), key


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_async_interleavings_track_sync(data):
    d = TwinDriver()
    for _ in range(40):
        d.step(data)
    d.finish()


@pytest.mark.slow
@settings(max_examples=16, deadline=None)
@given(st.data())
def test_async_interleavings_track_sync_long(data):
    """Longer soak variant (scripts/verify.sh --slow)."""
    d = TwinDriver()
    for _ in range(80):
        d.step(data)
    d.finish()
