"""Elastic ShardedCluster: live stripe migration (add/remove shard),
skew-aware rebalancing, forwarding-table routing, and migration x failure
interleavings — no key may ever be unreadable mid-rebalance."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # hypothesis or fallback
from repro.core import Rebalancer, make_cluster
from repro.data.ycsb import YCSBConfig, YCSBWorkload, hot_shard_id_map, \
    run_workload
from test_multikey import parity_invariant

KW = dict(num_servers=10, num_proxies=2, scheme="rs", n=4, k=2, c=8,
          chunk_size=256, max_unsealed=2)


def ring_cluster(shards=3, **kw):
    merged = dict(KW)
    merged.update(kw)
    return make_cluster(shards=shards, placement="ring", **merged)


def seeded_items(n, seed=0, sizes=(8, 32)):
    rng = np.random.default_rng(seed)
    return [(b"ek%06d" % i,
             bytes(rng.integers(0, 256, sizes[i % len(sizes)],
                                dtype=np.uint8)))
            for i in range(n)]


def load(cl, items, batch=32):
    for i in range(0, len(items), batch):
        assert all(cl.multi_set(items[i:i + batch]))


class TestScaleOut:
    def test_add_shard_minimal_movement_and_equivalence(self):
        cl = ring_cluster(shards=3)
        items = seeded_items(900, seed=1)
        load(cl, items)
        resident = cl.stored_payload_bytes()
        rep = cl.add_shard()
        assert rep["shard"] == 3 and cl.num_shards == 4
        # consistent hashing: ~1/(S+1) of resident bytes move, with slack
        assert rep["moved_bytes"] / resident <= 1 / 4 + 0.08
        assert rep["pending_left"] == 0
        keys = [k for k, _ in items]
        assert cl.multi_get(keys) == [v for _, v in items]
        # the new shard actually serves data, routed through the placement
        assert len(cl.shards[3].resident_keys()) == rep["moved_keys"] > 0
        assert all(cl.shard_of(k) == 3
                   for k in cl.shards[3].resident_keys())
        for sh in cl.shards:
            _, bad = parity_invariant(sh)
            assert bad == 0

    def test_sealed_objects_move_chunk_wise(self):
        cl = ring_cluster(shards=2)
        items = seeded_items(600, seed=2)
        load(cl, items)
        rep = cl.add_shard()
        # far fewer chunk fetches than moved keys: each source chunk is
        # fetched once and its movers extracted from the chunk bytes
        assert 0 < rep["chunks_fetched"] < rep["moved_keys"]
        assert rep["chunk_fetch_bytes"] == \
            rep["chunks_fetched"] * cl.chunk_size
        # migration traffic is accounted on the merged netsim view
        kinds = cl.net.bytes_by_kind
        assert kinds.get("mig_chunk", 0) == rep["chunk_fetch_bytes"] + \
            cl.net.cost.header_bytes * rep["chunks_fetched"]
        assert kinds.get("mig_obj", 0) > 0
        assert cl.net.latencies.get("MIGRATE")
        assert cl.stats["migration_bytes"] == rep["moved_bytes"]
        assert cl.stats["migrated_keys"] == rep["moved_keys"]

    def test_add_shard_without_migration_forwards(self):
        """migrate=False leaves data in place but must still install the
        forwarding table — the new placement already routes ~1/S of keys
        to the empty shard.  Nothing is ever unreadable in between."""
        cl = ring_cluster(shards=2)
        items = seeded_items(400, seed=3)
        load(cl, items)
        rep = cl.add_shard(migrate=False)
        assert rep["moved_keys"] == 0
        assert rep["pending_left"] == rep["mismatched"] == len(cl._pending) > 0
        keys = [k for k, _ in items]
        assert cl.multi_get(keys) == [v for _, v in items]  # forwarded
        # writes land at the forwarded location too, then migrate later
        assert cl.update(keys[0], items[0][1])
        rb = Rebalancer(cl)
        plan = rb.plan()
        assert plan.mismatched == rep["mismatched"]
        rep2 = rb.execute(plan)
        assert rep2["moved_keys"] == plan.mismatched
        assert rep2["pending_left"] == 0
        assert cl.multi_get(keys) == [v for _, v in items]


class TestScaleIn:
    def test_remove_shard_drains_fully(self):
        cl = ring_cluster(shards=3)
        items = seeded_items(700, seed=4)
        load(cl, items)
        rep = cl.remove_shard(1)
        assert rep["shard"] == 1 and rep["pending_left"] == 0
        assert cl.shards[1].resident_keys() == []
        assert 1 not in cl.placement.shard_ids and 1 in cl.retired
        keys = [k for k, _ in items]
        assert cl.multi_get(keys) == [v for _, v in items]
        assert all(cl.shard_of(k) != 1 for k in keys)
        with pytest.raises(ValueError):
            cl.remove_shard(1)   # already retired

    def test_scale_out_then_back_in(self):
        """Add a shard, then retire it again: the round trip must not
        lose or resurrect anything (the drain is physical)."""
        cl = ring_cluster(shards=2)
        items = seeded_items(500, seed=5)
        load(cl, items)
        dead = items[3][0]
        assert cl.delete(dead)
        cl.add_shard()
        cl.remove_shard(2)
        keys = [k for k, _ in items]
        got = cl.multi_get(keys)
        for (k, v), g in zip(items, got):
            assert g == (None if k == dead else v)


class TestLiveMigration:
    def test_requests_succeed_mid_migration(self):
        cl = ring_cluster(shards=2)
        items = seeded_items(600, seed=6)
        load(cl, items)
        state = dict(items)
        rng = np.random.default_rng(60)
        steps = 0

        def cb(p):
            nonlocal steps
            steps += 1
            probe = [k for k, _ in items[::5]]
            assert cl.multi_get(probe) == [state[k] for k in probe]
            # writes + deletes keep landing wherever the key lives now
            k_upd = items[(7 * p["batch"]) % len(items)][0]
            if state.get(k_upd) is not None:
                nv = bytes(rng.integers(0, 256, len(state[k_upd]),
                                        dtype=np.uint8))
                assert cl.update(k_upd, nv)
                state[k_upd] = nv
            k_new = b"live%05d" % p["batch"]
            v_new = bytes(rng.integers(0, 256, 16, dtype=np.uint8))
            assert cl.set(k_new, v_new)
            state[k_new] = v_new
            k_del = items[(11 * p["batch"]) % len(items)][0]
            if state.get(k_del) is not None:
                assert cl.delete(k_del)
                state[k_del] = None

        rep = cl.add_shard(batch_size=48, step_cb=cb)
        assert steps >= 2 and rep["moved_keys"] > 0
        for key, want in state.items():
            assert cl.get(key) == want
        for sh in cl.shards:
            _, bad = parity_invariant(sh)
            assert bad == 0

    def test_max_moves_cap_and_followup(self):
        cl = ring_cluster(shards=2)
        items = seeded_items(500, seed=7)
        load(cl, items)
        rep = cl.add_shard(max_moves=60)
        assert rep["moved_keys"] == 60
        assert rep["pending_left"] == rep["mismatched"] - 60 > 0
        keys = [k for k, _ in items]
        # uncapped remainder stays forwarded — everything readable
        assert cl.multi_get(keys) == [v for _, v in items]
        rep2 = Rebalancer(cl).run()
        assert rep2["pending_left"] == 0
        assert rep2["moved_keys"] == rep["mismatched"] - 60
        assert cl.multi_get(keys) == [v for _, v in items]

    def test_large_objects_move_logically(self):
        cl = ring_cluster(shards=2, chunk_size=256)
        items = seeded_items(150, seed=8)
        load(cl, items)
        rng = np.random.default_rng(80)
        big = {b"big%04d" % i: bytes(rng.integers(0, 256, 700,
                                                  dtype=np.uint8))
               for i in range(6)}
        for k, v in big.items():
            assert cl.set(k, v)
        cl.add_shard()
        Rebalancer(cl).run()   # idempotent follow-up: nothing mismatched
        for k, v in {**dict(items), **big}.items():
            assert cl.get(k) == v
        # fragments live with their manifest's shard, never alone
        for k in big:
            si = cl.shard_of(k)
            assert cl.shards[si].get(k) == big[k]


class TestMigrationFailureInterleaving:
    def test_seeded_failure_mid_migration(self):
        """The satellite scenario: fail_server lands in the middle of a
        live migration; movers on the lost server resolve through the
        batched-decode reconstruction cache and every key stays readable
        at every step."""
        cl = ring_cluster(shards=2)
        items = seeded_items(600, seed=9)
        load(cl, items)
        keys = [k for k, _ in items]
        expect = [v for _, v in items]
        events = []

        def cb(p):
            if p["batch"] == 1:
                # fail the source server with the most sealed chunks
                victim = max(range(cl.servers_per_shard),
                             key=lambda s: sum(cl.shards[0].servers[s].sealed))
                cl.fail_server(victim, shard=0)
                events.append(("fail", victim))
            if p["batch"] == 3 and events:
                cl.restore_server(events[0][1], shard=0)
                events.append(("restore",))
            assert cl.multi_get(keys) == expect, \
                f"key unreadable mid-rebalance at step {p}"

        rep = cl.add_shard(batch_size=24, step_cb=cb)
        assert [e[0] for e in events] == ["fail", "restore"]
        assert rep["moved_keys"] > 0 and rep["pending_left"] == 0
        assert cl.multi_get(keys) == expect
        assert cl.failed == set()

    def test_failure_in_destination_shard(self):
        cl = ring_cluster(shards=2)
        items = seeded_items(400, seed=10)
        load(cl, items)
        keys = [k for k, _ in items]
        expect = [v for _, v in items]

        def cb(p):
            if p["batch"] == 1:
                cl.fail_server(1, shard=2)   # new shard degraded mid-move
            assert cl.multi_get(keys) == expect

        rep = cl.add_shard(batch_size=32, step_cb=cb)
        assert rep["pending_left"] == 0
        assert cl.multi_get(keys) == expect
        cl.restore_server(1, shard=2)
        assert cl.multi_get(keys) == expect

    def test_migration_of_already_degraded_shard(self):
        """fail first, migrate second: movers come out of the redirected
        server's recon cache (batched decode at fail time)."""
        cl = ring_cluster(shards=2)
        items = seeded_items(500, seed=11)
        load(cl, items)
        victim = max(range(cl.servers_per_shard),
                     key=lambda s: sum(cl.shards[0].servers[s].sealed))
        t = cl.fail_server(victim, shard=0)
        assert t["recovered_chunks"] > 0
        rep = cl.add_shard()
        keys = [k for k, _ in items]
        assert cl.multi_get(keys) == [v for _, v in items]
        assert rep["pending_left"] == 0
        cl.restore_server(victim, shard=0)
        assert cl.multi_get(keys) == [v for _, v in items]

    @settings(max_examples=5, deadline=None)
    @given(st.data())
    def test_interleaving_property(self, data):
        """Property: random interleavings of {fail, restore, update, add
        traffic} with migration batches never make a key unreadable."""
        cl = ring_cluster(shards=2)
        items = seeded_items(300, seed=12)
        load(cl, items)
        state = dict(items)
        rng = np.random.default_rng(120)
        failed = []

        def cb(p):
            act = data.draw(st.sampled_from(
                ["none", "fail", "restore", "update"]), label="act")
            if act == "fail" and not failed:
                sh = data.draw(st.integers(min_value=0, max_value=2),
                               label="shard")
                if sh < cl.num_shards:
                    sid = data.draw(st.integers(
                        min_value=0, max_value=cl.servers_per_shard - 1),
                        label="sid")
                    cl.fail_server(sid, shard=sh)
                    failed.append((sh, sid))
            elif act == "restore" and failed:
                sh, sid = failed.pop()
                cl.restore_server(sid, shard=sh)
            elif act == "update":
                k = items[data.draw(st.integers(
                    min_value=0, max_value=len(items) - 1), label="i")][0]
                nv = bytes(rng.integers(0, 256, len(state[k]),
                                        dtype=np.uint8))
                assert cl.update(k, nv)
                state[k] = nv
            probe = [k for k, _ in items[::9]]
            assert cl.multi_get(probe) == [state[k] for k in probe], \
                "key unreadable mid-rebalance"

        cl.add_shard(batch_size=40, step_cb=cb)
        while failed:
            sh, sid = failed.pop()
            cl.restore_server(sid, shard=sh)
        assert cl.multi_get([k for k, _ in items]) == \
            [state[k] for k, _ in items]


class TestSkewRebalance:
    def _hot_loaded(self, seed=13):
        cl = ring_cluster(shards=3)
        cfg = YCSBConfig(num_objects=900, seed=seed)
        run_workload(cl, "load", 0, cfg, batch_size=16)
        return cl, cfg

    def test_skew_metric_and_snapshot(self):
        cl, cfg = self._hot_loaded()
        cl.reset_load()
        assert cl.load_skew() == 1.0   # no traffic -> neutral
        run_workload(cl, "B", 400, cfg, batch_size=16, hot_shard=0)
        snap = cl.net.snapshot()
        assert snap["shard_ops"] == cl.shard_ops
        assert snap["load_skew"] == cl.load_skew() == \
            cl.stats["load_skew"] > 1.0
        assert max(cl.shard_ops) == cl.shard_ops[0]

    def test_rebalance_reduces_skew(self):
        cl, cfg = self._hot_loaded(seed=14)
        id_map = hot_shard_id_map(cl, cfg, hot_shard=1)
        cl.reset_load()
        run_workload(cl, "B", 500, cfg, batch_size=16, id_map=id_map)
        before = cl.load_skew()
        assert before > 1.25
        rep = cl.rebalance(skew_threshold=1.25)
        assert rep["moved_keys"] > 0
        assert rep["weights"][1] < 1.0   # hot shard shed arcs
        run_workload(cl, "B", 500, cfg, batch_size=16, id_map=id_map)
        assert cl.load_skew() < before
        w = YCSBWorkload(cfg)
        keys = [w.key(i) for i in range(cfg.num_objects)]
        assert all(v is not None for v in cl.multi_get(keys))

    def test_rebalance_below_threshold_is_noop(self):
        cl, _ = self._hot_loaded(seed=15)
        cl.reset_load()
        rep = cl.rebalance(skew_threshold=1.25)
        assert rep["moved_keys"] == 0 and "skipped" in rep

    def test_mod_placement_reports_unsupported(self):
        cl = make_cluster(shards=2, placement="mod", **KW)
        items = seeded_items(200, seed=16)
        load(cl, items)
        hot = [k for k, _ in items if cl.shard_of(k) == 0]
        cl.reset_load()
        for _ in range(10):
            cl.multi_get(hot)
        rep = cl.rebalance(skew_threshold=1.1)
        assert rep["moved_keys"] == 0
        assert "does not support" in rep["skipped"]
        assert cl.multi_get(hot) == [dict(items)[k] for k in hot]


class TestDriverIntegration:
    def test_ycsb_under_scaling_matches_reference(self):
        """The verify.sh smoke's core: scale S=2 -> 3 under a running
        YCSB window; final contents byte-identical to an unscaled
        reference serving the same stream."""
        cfg = YCSBConfig(num_objects=500, seed=17)
        ref = ring_cluster(shards=2)
        cl = ring_cluster(shards=2)
        for c in (ref, cl):
            run_workload(c, "load", 0, cfg, batch_size=16)
            run_workload(c, "A", 400, cfg, batch_size=16)

        def cb(p):
            # the window keeps running against both clusters mid-move
            for c in (ref, cl):
                run_workload(c, "C", 60, YCSBConfig(num_objects=500,
                                                    seed=17 + p["batch"]),
                             batch_size=16)

        cl.add_shard(batch_size=32, step_cb=cb)
        w = YCSBWorkload(cfg)
        keys = [w.key(i) for i in range(cfg.num_objects)]
        assert cl.multi_get(keys) == ref.multi_get(keys)

    @pytest.mark.slow
    def test_soak_scale_out_in_under_churn(self):
        """Long soak: repeated add/remove/rebalance under workload A
        churn with a failure window, asserting byte-identity against an
        inelastic reference throughout."""
        cfg = YCSBConfig(num_objects=1200, seed=18)
        ref = ring_cluster(shards=2)
        cl = ring_cluster(shards=2)
        for c in (ref, cl):
            run_workload(c, "load", 0, cfg, batch_size=16)
        w = YCSBWorkload(cfg)
        keys = [w.key(i) for i in range(cfg.num_objects)]

        def churn(c, seed):
            run_workload(c, "A", 300, YCSBConfig(num_objects=1200,
                                                 seed=seed), batch_size=16)

        for round_i in range(3):
            for c in (ref, cl):
                churn(c, 100 + round_i)
            cl.add_shard(step_cb=lambda p: None)
            assert cl.multi_get(keys) == ref.multi_get(keys)
            cl.fail_server(2, shard=round_i % cl.num_shards)
            for c in (ref, cl):
                churn(c, 200 + round_i)
            cl.restore_server(2, shard=round_i % cl.num_shards)
            cl.remove_shard(cl.num_shards - 1)
            assert cl.multi_get(keys) == ref.multi_get(keys)
            rep = cl.rebalance(skew_threshold=1.05, max_moves=150)
            for c in (ref, cl):
                churn(c, 300 + round_i)
            assert cl.multi_get(keys) == ref.multi_get(keys)
        for sh in cl.shards:
            _, bad = parity_invariant(sh)
            assert bad == 0
