"""GF(2^8) arithmetic: field axioms + bit-plane lift correctness."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import gf256

byte = st.integers(0, 255)


@given(byte, byte, byte)
@settings(max_examples=50, deadline=None)
def test_field_axioms(a, b, c):
    mul = lambda x, y: int(gf256.MUL_TABLE[x, y])
    # commutativity / associativity
    assert mul(a, b) == mul(b, a)
    assert mul(a, mul(b, c)) == mul(mul(a, b), c)
    # distributivity over XOR (field addition)
    assert mul(a, b ^ c) == mul(a, b) ^ mul(a, c)
    # identities
    assert mul(a, 1) == a
    assert mul(a, 0) == 0


@given(st.integers(1, 255))
@settings(max_examples=50, deadline=None)
def test_inverse(a):
    inv = gf256.gf_inv_np(a)
    assert int(gf256.MUL_TABLE[a, inv]) == 1


def test_inverse_of_zero_raises():
    with pytest.raises(ZeroDivisionError):
        gf256.gf_inv_np(0)


@given(byte, byte)
@settings(max_examples=30, deadline=None)
def test_mul_matrix_lift(c, x):
    """Multiplication by c == its 8x8 GF(2) matrix acting on bit vectors."""
    M = gf256.gf_mul_matrix(c)
    bits = np.array([(x >> i) & 1 for i in range(8)], dtype=np.uint8)
    out_bits = (M @ bits) % 2
    out = int(sum(int(b) << i for i, b in enumerate(out_bits)))
    assert out == int(gf256.MUL_TABLE[c, x])


def test_matrix_inverse_roundtrip(rng):
    for _ in range(5):
        while True:
            M = rng.integers(0, 256, (6, 6), dtype=np.uint8)
            try:
                Minv = gf256.gf_mat_inv(M)
                break
            except np.linalg.LinAlgError:
                continue
        I = gf256.gf_matmul_np(M, Minv)
        assert np.array_equal(I, np.eye(6, dtype=np.uint8))


def test_device_tables_agree(rng):
    import jax.numpy as jnp
    a = rng.integers(0, 256, 128, dtype=np.uint8)
    b = rng.integers(0, 256, 128, dtype=np.uint8)
    dev = np.asarray(gf256.gf_mul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(dev, gf256.gf_mul_np(a, b))


def test_bytes_view_roundtrip(rng):
    import jax.numpy as jnp
    x = jnp.asarray(rng.standard_normal((4, 6)).astype(np.float32))
    b = gf256.bytes_view(x)
    y = gf256.from_bytes_view(b, jnp.float32, (4, 6))
    assert np.array_equal(np.asarray(x), np.asarray(y))
