"""Training substrate: optimizers, loss descent, checkpoints, serving."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import Model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import (adafactor, adamw, adamw8bit,
                                   apply_updates, clip_by_global_norm,
                                   make_optimizer)
from repro.train.train_step import make_train_step


def quadratic_fixture():
    params = {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.array(0.5)}
    target = {"w": jnp.array([1.0, 1.0, 1.0]), "b": jnp.array(-1.0)}

    def grads_of(p):
        return jax.tree.map(lambda a, t: 2 * (a - t), p, target)

    return params, target, grads_of


@pytest.mark.parametrize("name", ["adamw", "adamw8bit", "adafactor"])
def test_optimizer_converges_on_quadratic(name):
    params, target, grads_of = quadratic_fixture()
    opt = make_optimizer(name, lr=0.1, warmup_steps=1, schedule="constant",
                         total_steps=300, weight_decay=0.0)
    state = opt.init(params)
    for _ in range(300):
        g = grads_of(params)
        updates, state = opt.update(g, state, params)
        params = apply_updates(params, updates)
    err = sum(float(jnp.sum(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(params),
                              jax.tree.leaves(target)))
    assert err < 0.3, (name, params)


def test_adamw8bit_state_is_int8():
    params = {"w": jnp.zeros((1024,))}
    opt = adamw8bit()
    st = opt.init(params)
    assert st["m"]["w"]["q"].dtype == jnp.int8
    assert st["v"]["w"]["q"].dtype == jnp.int8


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000), rel=1e-5)
    cn = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert cn == pytest.approx(1.0, rel=1e-4)


def test_loss_decreases_small_lm():
    cfg = get_reduced("starcoder2-3b")
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    opt = make_optimizer("adamw", lr=1e-3, warmup_steps=5, total_steps=60)
    opt_state = opt.init(params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8))
    step = jax.jit(make_train_step(model, opt))
    losses = []
    for i in range(60):
        p = data.batch(i)
        params, opt_state, m = step(params, opt_state, p)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": jnp.array(7, jnp.int32)}}
    d = str(tmp_path / "ckpt")
    ckpt.save_checkpoint(d, 10, tree)
    ckpt.save_checkpoint(d, 20, jax.tree.map(lambda x: x * 0, tree))
    assert ckpt.latest_step(d) == 20
    restored = ckpt.restore_checkpoint(d, 10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(d, s, tree, keep_last=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("5".zfill(8))


def test_data_pipeline_deterministic():
    data = SyntheticLM(DataConfig(vocab_size=1000, seq_len=32,
                                  global_batch=4, seed=7))
    b1 = data.batch(3)
    b2 = data.batch(3)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = data.batch(4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    assert b1["tokens"].shape == (4, 32)
    assert b1["labels"].shape == (4, 32)


def test_greedy_generate_consistency():
    from repro.serve.engine import greedy_generate
    cfg = get_reduced("starcoder2-3b")
    model = Model(cfg)
    rng = jax.random.PRNGKey(3)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)
    out1 = greedy_generate(model, params, prompts, steps=8)
    out2 = greedy_generate(model, params, prompts, steps=8)
    assert np.array_equal(out1, out2)
    assert out1.shape == (2, 8)


def test_serve_engine_with_cache_protection():
    from repro.serve.engine import ServeEngine
    from repro.launch.mesh import make_host_mesh
    from repro.distributed import sharding as shd
    from repro.distributed.ecstore import ECConfig
    cfg = get_reduced("starcoder2-3b")
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    eng = ServeEngine(model, params, max_len=32, batch_size=2)
    prompts = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
    logits = eng.prefill({"tokens": prompts})
    assert logits.shape == (2, cfg.padded_vocab)
    mesh = make_host_mesh()
    cache_sh = jax.eval_shape(lambda: eng.cache)
    cspecs = shd.cache_specs(cfg, cache_sh, mesh)
    eng.protect_cache(mesh, cspecs, ECConfig(k=1, m=1, page_size=256))
    assert eng.ec_parity is not None
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    res = eng.decode(4, first_tokens=first)
    assert res.tokens.shape == (2, 4)
