"""Pallas kernels: shape/dtype sweeps vs the pure-jnp oracle (ref.py) and
the numpy host data plane.  Interpret mode on CPU."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.codes import RSCode
from repro.core.index import CuckooIndex
from repro.kernels import ops
from repro.kernels.gf256_matmul import build_apow, gf256_matmul
from repro.kernels.delta_update import delta_update
from repro.kernels import ref as kref


@pytest.mark.parametrize("m,k", [(2, 8), (4, 10), (1, 4), (8, 8)])
@pytest.mark.parametrize("C", [128, 1000, 4096, 5000])
def test_gf256_matmul_shapes(m, k, C, rng):
    A = rng.integers(0, 256, (m, k), dtype=np.uint8)
    D = rng.integers(0, 256, (k, C), dtype=np.uint8)
    got = np.asarray(gf256_matmul(A, jnp.asarray(D)))
    want = np.asarray(kref.gf256_matmul_ref(jnp.asarray(A), jnp.asarray(D)))
    assert np.array_equal(got, want)
    from repro.core.gf256 import gf_matmul_np
    assert np.array_equal(got, gf_matmul_np(A, D))


@given(st.integers(0, 2**31), st.sampled_from([64, 256, 2048]),
       st.sampled_from([256, 512, 4096]))
@settings(max_examples=10, deadline=None)
def test_gf256_matmul_block_sizes(seed, block_c, C):
    rng = np.random.default_rng(seed)
    A = rng.integers(0, 256, (2, 8), dtype=np.uint8)
    D = rng.integers(0, 256, (8, C), dtype=np.uint8)
    got = np.asarray(gf256_matmul(A, jnp.asarray(D), block_c=block_c))
    from repro.core.gf256 import gf_matmul_np
    assert np.array_equal(got, gf_matmul_np(A, D))


@pytest.mark.parametrize("m,C", [(2, 4096), (4, 1000), (1, 128)])
def test_delta_update_kernel(m, C, rng):
    parity = rng.integers(0, 256, (m, C), dtype=np.uint8)
    old = rng.integers(0, 256, C, dtype=np.uint8)
    new = rng.integers(0, 256, C, dtype=np.uint8)
    gammas = rng.integers(0, 256, m, dtype=np.uint8)
    got = np.asarray(delta_update(jnp.asarray(parity),
                                  jnp.asarray(gammas.astype(np.int32)),
                                  jnp.asarray(old), jnp.asarray(new)))
    want = np.asarray(kref.delta_update_ref(
        jnp.asarray(parity), jnp.asarray(gammas), jnp.asarray(old),
        jnp.asarray(new)))
    assert np.array_equal(got, want)


def test_encode_decode_stripe_via_kernels(rng):
    code = RSCode(n=10, k=8)
    data = rng.integers(0, 256, (8, 4096), dtype=np.uint8)
    par = np.asarray(ops.encode_stripe(code, jnp.asarray(data)))
    assert np.array_equal(par, code.encode(data))
    stripe = np.concatenate([data, par])
    avail = {i: jnp.asarray(stripe[i]) for i in range(10) if i not in (0, 5)}
    rec = ops.decode_stripe(code, avail, [0, 5], 4096)
    assert np.array_equal(np.asarray(rec[0]), stripe[0])
    assert np.array_equal(np.asarray(rec[5]), stripe[5])


def test_apply_parity_delta_matches_host(rng):
    code = RSCode(n=10, k=8)
    data = rng.integers(0, 256, (8, 4096), dtype=np.uint8)
    par = code.encode(data)
    new3 = data[3].copy()
    new3[10:200] = rng.integers(0, 256, 190, dtype=np.uint8)
    got = np.asarray(ops.apply_parity_delta(
        code, jnp.asarray(par), 3, jnp.asarray(data[3]), jnp.asarray(new3)))
    d2 = data.copy()
    d2[3] = new3
    assert np.array_equal(got, code.encode(d2))


@pytest.mark.parametrize("nbuckets,n_keys", [(64, 100), (256, 800)])
def test_cuckoo_lookup_kernel(nbuckets, n_keys, rng):
    idx = CuckooIndex(num_buckets=nbuckets)
    keys = [b"obj%06d" % i for i in range(n_keys)]
    for i, k in enumerate(keys):
        idx.insert(k, i)
    probe = keys[::3] + [b"nope%04d" % i for i in range(40)]
    fk, sk = ops.batched_index_lookup(idx, probe)
    fr, sr = ops.batched_index_lookup(idx, probe, use_ref=True)
    fk, sk, fr, sr = map(np.asarray, (fk, sk, fr, sr))
    assert np.array_equal(fk, fr) and np.array_equal(sk, sr)
    expect = np.array([k in idx for k in probe])
    assert np.array_equal(fk, expect)
    for k, f, s in zip(probe, fk, sk):
        if f:
            b, sl = divmod(int(s), 4)
            assert idx.slot_data[(b, sl)][0] == k


def test_apow_table():
    from repro.core.gf256 import MUL_TABLE
    A = np.array([[3, 7], [11, 200]], dtype=np.uint8)
    ap = build_apow(A)
    assert ap.shape == (2, 2, 8)
    for r in range(2):
        for i in range(2):
            for b in range(8):
                assert ap[r, i, b] == MUL_TABLE[A[r, i], 1 << b]


# ---------------------------------------------------------------------------
# large-matrix batched matmul (PR 5): the RDP block representation —
# (m*r, k*r) 0/1 matrices and their (k*r, k*r) decode inverses — routes
# through the column-loop kernel bodies instead of the per-element unroll
# ---------------------------------------------------------------------------

def test_gf256_matmul_batched_large_binary_matrix(rng):
    from repro.core.gf256 import gf_matmul_np
    from repro.kernels.gf256_matmul import MAX_UNROLL_OPS, gf256_matmul_batched
    O, J, B, Cb = 32, 128, 3, 96          # RDP(10,8)@p=17 block shapes
    assert O * J * 8 > MAX_UNROLL_OPS     # really takes the 0/1 kernel
    A = (rng.integers(0, 4, (O, J)) == 0).astype(np.uint8)
    D = rng.integers(0, 256, (B, J, Cb), dtype=np.uint8)
    got = np.asarray(gf256_matmul_batched(A, jnp.asarray(D)))
    want = np.stack([gf_matmul_np(A, d) for d in D])
    assert np.array_equal(got, want)


def test_gf256_matmul_batched_large_dense_matrix(rng):
    from repro.core.gf256 import gf_matmul_np
    from repro.kernels.gf256_matmul import MAX_UNROLL_OPS, gf256_matmul_batched
    O, J, B, Cb = 12, 20, 2, 200          # big AND non-0/1: column loop
    assert O * J * 8 > MAX_UNROLL_OPS
    A = rng.integers(0, 256, (O, J), dtype=np.uint8)
    D = rng.integers(0, 256, (B, J, Cb), dtype=np.uint8)
    got = np.asarray(gf256_matmul_batched(A, jnp.asarray(D)))
    want = np.stack([gf_matmul_np(A, d) for d in D])
    assert np.array_equal(got, want)
