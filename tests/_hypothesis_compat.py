"""Deterministic fallback for ``hypothesis`` when it is not installed.

Offline environments lack hypothesis; importing it at module scope used to
fail collection for five test modules.  This shim re-exports the real
library when available and otherwise provides a miniature, deterministic
implementation of the subset the test-suite uses:

* ``given(*strategies)`` — runs the test body over a fixed number of
  pseudo-random examples drawn from a per-test seeded ``random.Random``
  (seeded by the test name, so runs are reproducible and order-independent);
* ``settings(max_examples=..., deadline=...)`` — honours ``max_examples``;
* ``strategies``/``st`` — integers, binary, lists, tuples, sets,
  sampled_from, and data() with ``.draw``.

No shrinking, no database, no health checks — just deterministic example
sweeps so the properties still get meaningful coverage offline.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # type: ignore

    st = strategies
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A strategy is just a seeded-sampler; boundaries are favoured."""

        def __init__(self, sample):
            self._sample = sample

        def sample(self, rnd: random.Random):
            return self._sample(rnd)

    class _DataObject:
        """Stand-in for hypothesis's ``data()`` draw object."""

        def __init__(self, rnd: random.Random):
            self._rnd = rnd

        def draw(self, strategy: _Strategy, label: str | None = None):
            return strategy.sample(self._rnd)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            def sample(rnd):
                if rnd.random() < 0.15:  # bias toward the boundaries
                    return rnd.choice((min_value, max_value))
                return rnd.randint(min_value, max_value)
            return _Strategy(sample)

        @staticmethod
        def binary(min_size: int = 0, max_size: int = 16) -> _Strategy:
            def sample(rnd):
                n = rnd.randint(min_size, max_size)
                return bytes(rnd.getrandbits(8) for _ in range(n))
            return _Strategy(sample)

        @staticmethod
        def sampled_from(options) -> _Strategy:
            opts = list(options)
            return _Strategy(lambda rnd: opts[rnd.randrange(len(opts))])

        @staticmethod
        def tuples(*strategies_) -> _Strategy:
            return _Strategy(
                lambda rnd: tuple(s.sample(rnd) for s in strategies_))

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
                  unique_by=None) -> _Strategy:
            def sample(rnd):
                n = rnd.randint(min_size, max_size)
                out, seen, attempts = [], set(), 0
                while len(out) < n and attempts < n * 20 + 20:
                    attempts += 1
                    v = elements.sample(rnd)
                    if unique_by is not None:
                        k = unique_by(v)
                        if k in seen:
                            continue
                        seen.add(k)
                    out.append(v)
                return out
            return _Strategy(sample)

        @staticmethod
        def sets(elements: _Strategy, min_size: int = 0,
                 max_size: int = 10) -> _Strategy:
            def sample(rnd):
                n = rnd.randint(min_size, max_size)
                out, attempts = set(), 0
                while len(out) < n and attempts < n * 20 + 20:
                    attempts += 1
                    out.add(elements.sample(rnd))
                return out
            return _Strategy(sample)

        @staticmethod
        def data() -> _Strategy:
            return _Strategy(lambda rnd: _DataObject(rnd))

    strategies = _Strategies()
    st = strategies

    def settings(max_examples: int = 20, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strategies_):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*fixture_args, **fixture_kwargs):
                n = getattr(wrapper, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples", 20))
                rnd = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = tuple(s.sample(rnd) for s in strategies_)
                    fn(*fixture_args, *drawn, **fixture_kwargs)
            # pytest must not unwrap to the original signature (it would
            # treat the strategy-filled parameters as fixtures)
            wrapper.__dict__.pop("__wrapped__", None)
            # preserve the attribute if @settings is applied above @given
            wrapper._compat_max_examples = getattr(
                fn, "_compat_max_examples", None) or 20
            return wrapper
        return deco


__all__ = ["given", "settings", "strategies", "st", "HAVE_HYPOTHESIS"]
