"""Distributed layer: EC state store (subprocess with a multi-device mesh),
elastic fleet monitor, sharding rules, analysis formulas."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import subprocess_env


def run_sub(code: str, devices: int = 12) -> subprocess.CompletedProcess:
    env = subprocess_env()
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)


@pytest.mark.slow
def test_ecstore_encode_delta_reconstruct_vs_oracle():
    """Distributed parity (rotational stripe lists over the data axis)
    matches the RS oracle; reconstruction recovers a zeroed device."""
    p = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        import jax.sharding as jshard
        from repro.distributed._compat import shard_map
        from repro.distributed.ecstore import (ECConfig, parity_delta_update,
                                               reconstruct_failed, encode_parity)
        from repro.core.codes import RSCode
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((12, 1), ("data", "model"))
        from jax.sharding import PartitionSpec as P
        cfg = ECConfig(k=8, m=2, page_size=64)
        A, Pn = 12, 16
        rng = np.random.default_rng(0)
        state = rng.integers(0, 256, (A, 1, Pn, cfg.page_size), dtype=np.uint8)
        sspec = P("data", "model", None, None)
        pspec = P("data", "model", None, None, None)
        wrap = lambda f, i, o: shard_map(f, mesh=mesh, in_specs=i, out_specs=o,
                                         check_rep=False)
        def enc(pages):
            def f(pg):
                out = encode_parity(pg.reshape(pg.shape[2:]), cfg)
                return out.reshape((1, 1) + out.shape)
            return wrap(f, (sspec,), pspec)(pages)
        with mesh:
            parity = np.asarray(jax.jit(enc)(jnp.asarray(state)))
        code = RSCode(n=10, k=8)
        def oracle():
            out = np.zeros((A, 1, cfg.m, Pn // cfg.k, cfg.page_size), np.uint8)
            for l in range(A):
                for s in range(Pn // cfg.k):
                    data = np.stack([state[(l + j) % A, 0, s * cfg.k + j]
                                     for j in range(cfg.k)])
                    par = code.encode(data)
                    for r in range(cfg.m):
                        out[(l + cfg.k + r) % A, 0, r, s] = par[r]
            return out
        assert np.array_equal(parity, oracle()), "encode"
        new = state.copy()
        new[3, 0, 5] ^= rng.integers(0, 256, cfg.page_size, dtype=np.uint8)
        xor = state ^ new
        def upd(xp, par):
            def f(x, p):
                out = parity_delta_update(x.reshape(x.shape[2:]),
                                          p.reshape(p.shape[2:]), cfg)
                return out.reshape((1, 1) + out.shape)
            return wrap(f, (sspec, pspec), pspec)(xp, par)
        with mesh:
            parity2 = np.asarray(jax.jit(upd)(jnp.asarray(xor),
                                              jnp.asarray(parity)))
        state = new
        assert np.array_equal(parity2, oracle()), "delta"
        # systolic chain variant (§Perf C1) is byte-exact vs direct
        from repro.distributed.ecstore import parity_delta_update_chain
        def upd_chain(xp, par):
            def f(x, p):
                out = parity_delta_update_chain(x.reshape(x.shape[2:]),
                                                p.reshape(p.shape[2:]), cfg)
                return out.reshape((1, 1) + out.shape)
            return wrap(f, (sspec, pspec), pspec)(xp, par)
        with mesh:
            parity2c = np.asarray(jax.jit(upd_chain)(jnp.asarray(xor),
                                                     jnp.asarray(parity)))
        assert np.array_equal(parity2c, parity2), "chain variant"
        def rec(pages, par):
            def f(pg, p):
                out = reconstruct_failed(pg.reshape(pg.shape[2:]),
                                         p.reshape(p.shape[2:]),
                                         jnp.int32(3), cfg)
                return out.reshape((1, 1) + out.shape)
            return wrap(f, (sspec, pspec), sspec)(pages, par)
        holed = state.copy(); holed[3] = 0
        with mesh:
            got = np.asarray(jax.jit(rec)(jnp.asarray(holed),
                                          jnp.asarray(parity2)))
        assert np.array_equal(got[0, 0], state[3, 0]), "reconstruct"
        # double failure: both pages AND parity of the failed pair lost
        from repro.distributed.ecstore import reconstruct_failed_pair
        def recpair(f1, f2):
            def g(pages, par):
                def f(pg, p):
                    out = reconstruct_failed_pair(
                        pg.reshape(pg.shape[2:]), p.reshape(p.shape[2:]),
                        f1, f2, A, cfg)
                    return out.reshape((1, 1) + out.shape)
                return wrap(f, (sspec, pspec), sspec)(pages, par)
            return g
        for f1, f2 in [(3, 7), (2, 3), (0, 11)]:
            holed2 = state.copy(); holed2[f1] = 0; holed2[f2] = 0
            parz = parity2.copy(); parz[f1] = 0; parz[f2] = 0
            with mesh:
                r1 = np.asarray(jax.jit(recpair(f1, f2))(
                    jnp.asarray(holed2), jnp.asarray(parz)))
                r2 = np.asarray(jax.jit(recpair(f2, f1))(
                    jnp.asarray(holed2), jnp.asarray(parz)))
            assert np.array_equal(r1[0, 0], state[f1, 0]), (f1, f2)
            assert np.array_equal(r2[0, 0], state[f2, 0]), (f2, f1)
        print("ECSTORE_OK")
    """)
    assert "ECSTORE_OK" in p.stdout, p.stderr[-2000:]


@pytest.mark.slow
def test_ec_checkpoint_protects_training_state():
    """Train a few steps with per-step EC parity maintenance; reconstruct
    a lost data-axis shard and verify it matches the live state bytes."""
    p = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        import jax.sharding as jshard
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.models import Model
        from repro.distributed import sharding as shd
        from repro.distributed.ecstore import ECConfig, ECStateStore
        from repro.train.optimizer import make_optimizer
        from repro.train.train_step import make_train_step
        from repro.data.pipeline import DataConfig, SyntheticLM
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = get_reduced("starcoder2-3b")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        pspecs = shd.param_specs(cfg, jax.eval_shape(lambda: params), mesh)
        store = ECStateStore(mesh, pspecs, ECConfig(k=2, m=1, page_size=256))
        opt = make_optimizer("adamw", lr=1e-3, total_steps=10)
        opt_state = opt.init(params)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=4))
        step = jax.jit(make_train_step(model, opt))
        with mesh:
            parity = store.encode(params)
            for i in range(3):
                old = params
                params, opt_state, m = step(params, opt_state, data.batch(i))
                parity = store.delta_update(old, params, parity)
            pages = store.local_pages(params)
            rec = store.reconstruct(params, parity, failed_index=1)
        pages = np.asarray(pages)
        rec = np.asarray(rec)
        # reconstruction of data-axis position 1 (any model column)
        assert np.array_equal(rec[0, 0], pages[1, 0]), "model col 0"
        assert np.array_equal(rec[0, 1], pages[1, 1]), "model col 1"
        print("ECCKPT_OK")
    """, devices=8)
    assert "ECCKPT_OK" in p.stdout, p.stderr[-2000:]


class TestElastic:
    def test_heartbeat_miss_degrades(self):
        from repro.distributed.elastic import ElasticConfig, FleetMonitor
        from repro.core.coordinator import ServerState
        mon = FleetMonitor(4, ElasticConfig(heartbeat_interval=1.0,
                                            miss_threshold=3))
        for t in range(3):
            for h in range(4):
                mon.heartbeat(h, float(t))
        # host 2 goes silent
        for t in range(3, 8):
            for h in (0, 1, 3):
                mon.heartbeat(h, float(t))
        plan = mon.check(8.0)
        assert plan.kind == "reconstruct"
        assert plan.failed_hosts == [2]
        assert mon.states()[2] == ServerState.DEGRADED

    def test_straggler_detection(self):
        from repro.distributed.elastic import ElasticConfig, FleetMonitor
        mon = FleetMonitor(4, ElasticConfig(straggler_factor=2.0))
        for t in range(10):
            for h in range(4):
                mon.heartbeat(h, float(t))
                mon.report_step_time(h, 1.0 if h != 3 else 5.0)
        plan = mon.check(10.0)
        assert plan.kind == "reconstruct"
        assert 3 in plan.failed_hosts

    def test_restore_path(self):
        from repro.distributed.elastic import FleetMonitor
        from repro.core.coordinator import ServerState
        mon = FleetMonitor(3)
        for h in range(3):
            mon.heartbeat(h, 0.0)
        plan = mon.check(100.0)   # everyone missed -> rescale advice
        assert plan.kind == "rescale"
        mon.restore(0, 101.0)
        assert mon.states()[0] == ServerState.COORDINATED_NORMAL
        mon.migration_done(0, 102.0)
        assert mon.states()[0] == ServerState.NORMAL

    def test_below_min_hosts_requires_disk(self):
        from repro.distributed.elastic import ElasticConfig, FleetMonitor
        mon = FleetMonitor(2, ElasticConfig(min_hosts=2))
        mon.heartbeat(0, 0.0)
        mon.heartbeat(1, 0.0)
        plan = mon.check(50.0)
        assert plan.kind == "rescale"


class TestShardingRules:
    def test_param_specs_cover_all_archs(self):
        import jax
        import jax.sharding as jshard
        from repro.configs import ARCH_NAMES, get_reduced
        from repro.distributed import sharding as shd
        from repro.models import Model
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1, 1), ("data", "model"))
        for arch in ARCH_NAMES:
            cfg = get_reduced(arch)
            shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
            specs = shd.param_specs(cfg, shapes, mesh)
            n_spec = len(jax.tree.leaves(
                specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or
                x.__class__.__name__ == "PartitionSpec"))
            n_leaf = len(jax.tree.leaves(shapes))
            assert n_spec == n_leaf, arch

    def test_fit_spec_demotes_indivisible(self):
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import fit_spec
        from repro.distributed._compat import abstract_mesh
        mesh = abstract_mesh((4, 2), ("data", "model"))
        assert fit_spec(P("data", "model"), (8, 6), mesh) == P("data", "model")
        assert fit_spec(P("data", "model"), (7, 6), mesh) == P(None, "model")
        # unknown axis ("pod") dropped; remaining must divide
        assert fit_spec(P(("pod", "data"), None), (4, 3), mesh) == \
            P(("data",), None)


class TestAnalysis:
    def test_figure2_paper_claims(self):
        """Paper §3.3: K=8, V<10, (10,8): AllRep 4.1-4.8x, Hybrid 3.3-4.7x,
        AllEnc 1.7-1.9x (up to 60% / 58.9% reduction)."""
        from repro.core.analysis import (AnalysisParams,
                                         redundancy_all_encoding,
                                         redundancy_all_replication,
                                         redundancy_hybrid_encoding)
        for V in range(2, 10):
            p = AnalysisParams(K=8, V=V, n=10, k=8)
            ar = redundancy_all_replication(p)
            hy = redundancy_hybrid_encoding(p)
            ae = redundancy_all_encoding(p)
            assert 4.1 <= ar <= 4.81, (V, ar)
            assert 3.3 <= hy <= 4.71, (V, hy)
            assert 1.65 <= ae <= 1.91, (V, ae)  # 1.678@V=9 rounds to "1.7"
        # max reductions quoted by the paper
        p2 = AnalysisParams(K=8, V=2, n=10, k=8)
        red_ar = 1 - redundancy_all_encoding(p2) / redundancy_all_replication(p2)
        red_hy = 1 - redundancy_all_encoding(p2) / redundancy_hybrid_encoding(p2)
        assert red_ar == pytest.approx(0.60, abs=0.02)
        assert red_hy == pytest.approx(0.589, abs=0.02)

    def test_crossover_V180(self):
        """Paper: all-encoding < 1.3x for V>=180; hybrid needs V>=890."""
        from repro.core.analysis import crossover_value
        v_ae = crossover_value(8, (10, 8), 1.3, "all-encoding")
        v_hy = crossover_value(8, (10, 8), 1.3, "hybrid-encoding")
        assert 150 <= v_ae <= 200, v_ae
        assert 850 <= v_hy <= 930, v_hy


@pytest.mark.slow
def test_dryrun_cell_compiles():
    """Deliverable (e) guard: one full-config cell lowers + compiles on the
    production mesh machinery (16 virtual devices for CI speed)."""
    p = run_sub("""
        import os
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import jax.sharding as jshard
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 4), ("data", "model"))
        from repro.launch.dryrun import build_cell, collective_bytes
        built, why = build_cell("starcoder2-3b", "decode_32k", mesh)
        assert built is not None, why
        step, args, in_sh, out_sh, meta = built
        to_named = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        with mesh:
            compiled = jax.jit(step, in_shardings=to_named(in_sh),
                               out_shardings=to_named(out_sh)
                               ).lower(*args).compile()
        assert compiled.memory_analysis() is not None
        from repro.launch.hlo_analysis import analyze
        r = analyze(compiled.as_text())
        assert r["flops"] > 0 and r["bytes"] > 0
        print("DRYRUN_CELL_OK")
    """, devices=16)
    assert "DRYRUN_CELL_OK" in p.stdout, p.stderr[-2000:]
