"""Per-request span tracing + critical-path attribution (PR 8).

Properties pinned here:

* span trees are well-formed — children nest inside parents, seq
  children tile the parent, par duration is the max child — and the
  critical-path components of every request sum to its recorded latency
  within 1e-9, across closed-loop, poisson open-loop, normal and
  degraded modes, S=1 and S=4;
* tracing is provably zero-cost when off — no tracer state allocated,
  contents byte-identical and ``stats`` bit-identical to a traced twin;
* ``TraceCapture`` -> ``arrival="trace:..."`` replay reproduces the
  per-kind latency summaries exactly (the ROADMAP's capture/replay
  loop);
* the Chrome trace-event exporter emits structurally valid JSON (one
  pid per shard) and ``validate_chrome`` rejects malformed documents;
* telemetry v2 carries the ``trace`` + ``critical_path`` sections and
  rejects v1 snapshots loudly;
* satellite fix: ``engine_queue_wait_s`` no longer double-counts lane
  contention already forwarded to the event runtime via ``note_coding``
  (the ``queue_wait_s_by_resource["engine"]`` side).
"""
import json

import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import (CostModel, MemECCluster, TraceCapture, Tracer,
                        critical_paths, export_chrome, make_cluster,
                        resolve_trace, telemetry, validate_chrome)
from repro.core.trace import Span, components

KW = dict(num_servers=16, scheme="rs", n=10, k=8, c=4,
          chunk_size=512, max_unsealed=2)

POISSON = "poisson:4000:seed=9:inflight=2"


def cluster(shards=1, arrival=None, trace=None, **kw):
    merged = dict(KW)
    merged.update(kw)
    return make_cluster(shards=shards, arrival=arrival, trace=trace,
                        **merged)


def drive(cl, n_obj=18, degraded=False, sharded=False):
    """Deterministic mixed workload; optionally fail a server mid-way so
    the read half runs degraded.  Returns the keys written."""
    keys = [b"tr%06d" % i for i in range(n_obj)]
    for i, k in enumerate(keys):
        cl.set(k, bytes((i * 7 + j) % 256 for j in range(48)))
    if degraded:
        if sharded:
            victim = cl.shards[0].mapper.data_server_for(keys[0])[1]
            cl.fail_server(cl.global_sid(0, victim))
        else:
            cl.fail_server(cl.mapper.data_server_for(keys[0])[1])
    for i in range(2 * n_obj):
        assert cl.get(keys[(i * 5) % n_obj]) is not None
    cl.update(keys[1], bytes(48))
    cl.delete(keys[2])
    if sharded:
        cl.multi_get(keys)
        cl.multi_set([(b"mm%04d" % i, bytes(32)) for i in range(8)])
    return keys


def all_roots(cl):
    roots = list(cl.tracer.requests)
    for sh in getattr(cl, "shards", []) or []:
        if sh.tracer is not None:
            roots.extend(sh.tracer.requests)
    return roots


# ---------------------------------------------------------------------------
# span invariants: nesting + critical-path sum == recorded latency
# ---------------------------------------------------------------------------

class TestSpanInvariants:
    @pytest.mark.parametrize("shards", [1, 4])
    @pytest.mark.parametrize("arrival", [None, POISSON])
    @pytest.mark.parametrize("degraded", [False, True])
    def test_nesting_and_component_sums(self, shards, arrival, degraded):
        cl = cluster(shards=shards, arrival=arrival, trace=True)
        drive(cl, degraded=degraded, sharded=shards > 1)
        roots = all_roots(cl)
        assert roots, "traced run recorded no requests"
        for root in roots:
            root.check(eps=1e-9)  # nesting + seq-tiling + par-max
            comps = components(root)
            assert abs(sum(comps.values()) - root.dur) <= 1e-9, \
                f"{root.name}: components do not sum to recorded latency"
        if degraded:
            assert any(r.meta.get("degraded") for r in roots), \
                "degraded workload produced no degraded-tagged roots"
            assert any(r.name.endswith("_DEG") for r in roots)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2 ** 16),
           st.integers(min_value=500, max_value=20000))
    def test_open_loop_sums_property(self, seed, rate):
        cl = cluster(arrival=f"poisson:{rate}:seed={seed}:inflight=2",
                     trace=True)
        drive(cl, n_obj=8)
        for root in cl.tracer.requests:
            root.check(eps=1e-9)
            assert abs(sum(components(root).values()) - root.dur) <= 1e-9

    def test_critical_path_witness_sums(self):
        cl = cluster(arrival=POISSON, trace=True)
        drive(cl)
        cp = critical_paths(cl)
        assert cp, "no critical-path rows"
        for kind, row in cp.items():
            for pct in ("p50", "p99", "p999"):
                w = row[pct]
                assert abs(sum(w["components"].values())
                           - w["latency_s"]) <= 1e-9, (kind, pct)

    def test_open_loop_spans_name_waits(self):
        # saturate so queueing actually appears in the spans
        cl = cluster(arrival="poisson:100000:seed=2:inflight=8", trace=True)
        drive(cl)
        names = {s.name for r in cl.tracer.requests for s in r.walk()}
        assert any(n.startswith("wait:") for n in names), \
            f"no wait spans under saturation: {sorted(names)[:10]}"


# ---------------------------------------------------------------------------
# zero-cost when off
# ---------------------------------------------------------------------------

class TestZeroCostOff:
    def test_no_tracer_state_by_default(self, monkeypatch):
        monkeypatch.delenv("MEMEC_TRACE", raising=False)
        cl = cluster()
        assert cl.tracer is None and cl.net.tracer is None

    def test_env_zero_disables(self, monkeypatch):
        monkeypatch.setenv("MEMEC_TRACE", "0")
        assert resolve_trace() is None
        cl = cluster()
        assert cl.tracer is None

    def test_env_enables(self, monkeypatch):
        monkeypatch.setenv("MEMEC_TRACE", "1")
        tr = resolve_trace()
        assert isinstance(tr, Tracer)
        cl = cluster()
        assert cl.tracer is not None
        monkeypatch.delenv("MEMEC_TRACE")

    @pytest.mark.parametrize("shards", [1, 4])
    @pytest.mark.parametrize("arrival", [None, POISSON])
    def test_on_off_bit_identical(self, shards, arrival):
        def run(trace):
            cl = cluster(shards=shards, arrival=arrival, trace=trace)
            keys = drive(cl, degraded=(shards == 1),
                         sharded=shards > 1)
            contents = [cl.get(k) for k in keys if cl.get(k) is not None]
            return contents, cl.stats, dict(cl.net.latencies)

        c_on, s_on, l_on = run(True)
        c_off, s_off, l_off = run(False)
        assert c_on == c_off, "tracing changed served contents"
        assert json.dumps(s_on, sort_keys=True, default=str) == \
            json.dumps(s_off, sort_keys=True, default=str), \
            "tracing changed stats"
        assert l_on == l_off, "tracing changed recorded latencies"

    def test_sharded_off_allocates_nothing(self):
        cl = cluster(shards=2)
        assert cl.tracer is None
        assert all(sh.tracer is None for sh in cl.shards)


# ---------------------------------------------------------------------------
# capture -> replay
# ---------------------------------------------------------------------------

class TestCaptureReplay:
    def _run(self, arrival):
        cl = cluster(arrival=arrival)
        drive(cl, n_obj=12)
        return cl

    def test_replay_reproduces_summaries_exactly(self):
        cl = self._run(POISSON)
        cap = TraceCapture.from_cluster(cl)
        rep = self._run(cap.arrival_spec())
        assert cl.net.latency_summary() == rep.net.latency_summary()

    def test_replay_via_file(self, tmp_path):
        cl = self._run(POISSON)
        path = tmp_path / "capture.json"
        TraceCapture.from_cluster(cl).save(str(path))
        rep = self._run(f"trace:@{path}")
        assert cl.net.latency_summary() == rep.net.latency_summary()

    def test_capture_round_trips_kinds(self):
        cl = self._run(POISSON)
        cap = TraceCapture.from_cluster(cl)
        cap2 = TraceCapture.from_json(cap.to_json())
        assert cap2.arrivals == cap.arrivals
        assert cap2.kinds == cap.kinds
        assert len(cap.kinds) == len(cap.arrivals) > 0

    def test_capture_requires_open_loop(self):
        cl = cluster()  # closed loop: no event log to capture
        drive(cl, n_obj=4)
        with pytest.raises(ValueError):
            TraceCapture.from_cluster(cl)


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------

class TestChromeExport:
    def test_export_is_valid_and_loadable(self, tmp_path):
        cl = cluster(shards=2, arrival=POISSON, trace=True)
        drive(cl, sharded=True)
        path = tmp_path / "trace.json"
        doc = export_chrome(cl, path=str(path))
        validate_chrome(doc)
        on_disk = json.loads(path.read_text())
        validate_chrome(on_disk)
        pids = {e["pid"] for e in doc["traceEvents"]}
        assert 0 in pids, "facade pid missing"
        assert pids - {0}, "no per-shard pids"
        metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
        assert any(e["name"] == "process_name" for e in metas)
        assert any(e["name"] == "thread_name" for e in metas)

    def test_validate_rejects_malformed(self):
        validate_chrome({"traceEvents": []})
        with pytest.raises(ValueError):
            validate_chrome({})
        with pytest.raises(ValueError):
            validate_chrome({"traceEvents": [{"ph": "B", "name": "x"}]})
        with pytest.raises(ValueError):
            validate_chrome({"traceEvents": [
                {"ph": "X", "name": "x", "pid": 0, "tid": 0,
                 "ts": -1.0, "dur": 1.0}]})


# ---------------------------------------------------------------------------
# telemetry v2
# ---------------------------------------------------------------------------

class TestTelemetryV2:
    def test_trace_sections_present(self):
        cl = cluster(trace=True)
        drive(cl, n_obj=6)
        snap = telemetry.validate(telemetry.snapshot(cl))
        assert snap["version"] == 2
        assert snap["trace"]["enabled"]
        assert snap["trace"]["requests"] > 0
        assert snap["trace"]["spans"] > snap["trace"]["requests"]
        assert set(snap["critical_path"]) >= {"GET", "SET"}

    def test_off_sections_empty(self):
        cl = cluster()
        drive(cl, n_obj=4)
        snap = telemetry.validate(telemetry.snapshot(cl))
        assert snap["trace"] == {"enabled": False, "requests": 0, "spans": 0}
        assert snap["critical_path"] == {}

    def test_v1_rejected_loudly(self):
        cl = cluster()
        drive(cl, n_obj=4)
        snap = telemetry.snapshot(cl)
        with pytest.raises(ValueError, match="version"):
            telemetry.validate(dict(snap, version=1))
        with pytest.raises(ValueError, match="missing"):
            bad = dict(snap)
            del bad["critical_path"]
            telemetry.validate(bad)


# ---------------------------------------------------------------------------
# satellite fix: engine wait no longer double-counted
# ---------------------------------------------------------------------------

class TestEngineWaitDedup:
    def test_intra_phase_wait_split_from_lane_demand(self):
        # depth=1: two calls of 2ms and 1ms serialize -> the 1ms makespan
        # excess is intra-phase wait (engine_queue_wait_s), while only the
        # pure max(durations) demand is forwarded to the event runtime's
        # engine lanes (note_coding) — previously the full makespan was,
        # double-counting the wait in queue_wait_s_by_resource["engine"].
        cl = cluster(arrival="poisson:1000:seed=1",
                     cost=CostModel(engine_depth=1))
        cl._stats["engine_queue_wait_s"] = 0.0
        cl.net._pending_coding_s = 0.0
        cl._merge_coding_calls([2e-3, 1e-3], 0.0)
        assert cl._stats["engine_queue_wait_s"] == 1e-3
        assert cl.net._pending_coding_s == 2e-3

    def test_infinite_depth_forwards_pure_demand(self):
        cl = cluster(arrival="poisson:1000:seed=1")  # depth=inf: no wait
        cl._stats["engine_queue_wait_s"] = 0.0
        cl.net._pending_coding_s = 0.0
        cl._merge_coding_calls([2e-3, 1e-3], 0.0)
        assert cl._stats["engine_queue_wait_s"] == 0.0
        assert cl.net._pending_coding_s == 2e-3


# ---------------------------------------------------------------------------
# Span primitive sanity (pure, no cluster)
# ---------------------------------------------------------------------------

class TestSpanPrimitive:
    def test_seq_tiling_check(self):
        root = Span("r", "request", 3.0, "seq", children=[
            Span("a", "leaf", 1.0), Span("b", "leaf", 2.0)])
        root.children[0].t0 = 0.0
        root.children[1].t0 = 1.0
        root.check()
        assert components(root) == {"a": 1.0, "b": 2.0}

    def test_par_max_and_slack(self):
        root = Span("p", "phase", 2.0, "par", children=[
            Span("a", "leaf", 2.0), Span("b", "leaf", 0.5)])
        root.check()
        comps = components(root)
        assert comps == {"a": 2.0}
        assert sum(comps.values()) == root.dur

    def test_check_rejects_bad_nesting(self):
        root = Span("r", "request", 1.0, "seq",
                    children=[Span("a", "leaf", 2.0)])
        with pytest.raises(AssertionError):
            root.check()
