"""Placement subsystem (core/ring.py): cross-process determinism, ~1/S
minimal key movement on membership changes (property-based), weights,
and factory/env-var selection."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # hypothesis or fallback
from conftest import subprocess_env
from repro.core import (ModPlacement, Placement, RingPlacement,
                        make_placement, shard_for_key)


def keys(n, prefix=b"rk"):
    return [prefix + b"%07d" % i for i in range(n)]


class TestModPlacement:
    def test_matches_historical_shard_for_key(self):
        """The default placement must stay bit-identical with the
        pre-elasticity FNV-mod routing."""
        p = ModPlacement(4)
        for k in keys(500):
            assert p.shard_for(k) == shard_for_key(k, 4)
        assert ModPlacement(1).shard_for(b"x") == 0

    def test_membership_changes(self):
        p = ModPlacement(3)
        assert p.shard_ids == (0, 1, 2)
        p.add_shard(3)
        assert p.shard_ids == (0, 1, 2, 3)
        for k in keys(200):
            assert p.shard_for(k) == shard_for_key(k, 4)
        p.remove_shard(1)
        assert p.shard_ids == (0, 2, 3)
        assert all(p.shard_for(k) in (0, 2, 3) for k in keys(200))
        with pytest.raises(ValueError):
            p.add_shard(0)
        with pytest.raises(ValueError):
            p.remove_shard(9)
        with pytest.raises(NotImplementedError):
            p.set_weight(0, 2.0)

    def test_mod_is_a_full_reshuffle(self):
        """The baseline placement the ring must beat: adding a shard
        remaps the vast majority of keys."""
        p = ModPlacement(3)
        ks = keys(2000)
        before = [p.shard_for(k) for k in ks]
        p.add_shard(3)
        moved = sum(a != p.shard_for(k) for a, k in zip(before, ks))
        assert moved > len(ks) * 0.5


class TestRingDeterminism:
    def test_rebuild_identical(self):
        ks = keys(1000)
        a = RingPlacement(4, vnodes=64)
        b = RingPlacement(4, vnodes=64)
        assert [a.shard_for(k) for k in ks] == [b.shard_for(k) for k in ks]
        # membership history does not matter, only the final membership
        c = RingPlacement(3, vnodes=64)
        c.add_shard(3)
        assert [a.shard_for(k) for k in ks] == [c.shard_for(k) for k in ks]

    def test_deterministic_across_processes(self):
        """Routing is pure hashing: a fresh interpreter must compute the
        exact same assignment (proxies/tools agree without coordination)."""
        ks = keys(300)
        local = [RingPlacement(4, vnodes=32).shard_for(k) for k in ks]
        prog = textwrap.dedent("""
            from repro.core import RingPlacement
            ks = [b"rk%07d" % i for i in range(300)]
            p = RingPlacement(4, vnodes=32)
            print(",".join(str(p.shard_for(k)) for k in ks))
        """)
        out = subprocess.check_output([sys.executable, "-c", prog],
                                      env=subprocess_env(), text=True)
        assert [int(x) for x in out.strip().split(",")] == local

    def test_spread_roughly_uniform(self):
        p = RingPlacement(4, vnodes=64)
        counts = np.bincount([p.shard_for(k) for k in keys(4000)],
                             minlength=4)
        assert (counts > 0).all()
        assert counts.max() < 3 * counts.min()


class TestRingMinimalMovement:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=2, max_value=6),
           st.integers(min_value=0, max_value=5))
    def test_add_moves_only_to_new_shard(self, s, salt):
        """Property: adding a shard only moves keys *onto* the new shard,
        and moves ~1/(S+1) of them (the consistent-hashing guarantee)."""
        ks = keys(1200, prefix=b"mv%d-" % salt)
        p = RingPlacement(s, vnodes=64)
        before = {k: p.shard_for(k) for k in ks}
        new = p.add_shard(s)
        moved = [k for k in ks if p.shard_for(k) != before[k]]
        assert all(p.shard_for(k) == new for k in moved)
        frac = len(moved) / len(ks)
        ideal = 1.0 / (s + 1)
        assert frac <= ideal + 0.10, f"moved {frac:.3f}, ideal {ideal:.3f}"

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=3, max_value=6),
           st.integers(min_value=0, max_value=5))
    def test_remove_moves_only_from_removed_shard(self, s, salt):
        ks = keys(1200, prefix=b"rm%d-" % salt)
        p = RingPlacement(s, vnodes=64)
        before = {k: p.shard_for(k) for k in ks}
        victim = salt % s
        p.remove_shard(victim)
        for k in ks:
            now = p.shard_for(k)
            if before[k] != victim:
                assert now == before[k], "untouched shard's key moved"
            else:
                assert now != victim

    def test_weight_shrink_sheds_arcs(self):
        p = RingPlacement(3, vnodes=64)
        ks = keys(3000)
        before = {k: p.shard_for(k) for k in ks}
        n0 = sum(1 for v in before.values() if v == 0)
        p.set_weight(0, 0.25)
        after = [p.shard_for(k) for k in ks]
        n0_after = sum(1 for v in after if v == 0)
        assert n0_after < n0 * 0.6
        # only shard-0 keys moved (its arcs shrank; nobody else's changed)
        moved = [k for k in ks if p.shard_for(k) != before[k]]
        assert moved and all(before[k] == 0 for k in moved)
        fr = p.arc_fractions()
        assert fr[0] < fr[1] and fr[0] < fr[2]
        assert abs(sum(fr.values()) - 1.0) < 1e-9


class TestFactory:
    def test_make_placement_specs(self):
        assert isinstance(make_placement("mod", 3), ModPlacement)
        r = make_placement("ring:16", 3)
        assert isinstance(r, RingPlacement) and r.vnodes == 16
        assert make_placement(None, 2).kind == "mod"  # historical default
        inst = RingPlacement(3)
        assert make_placement(inst, 3) is inst
        with pytest.raises(ValueError):
            make_placement(inst, 4)   # membership mismatch
        with pytest.raises(ValueError):
            make_placement("spiral", 2)

    def test_memec_placement_env(self, monkeypatch):
        monkeypatch.setenv("MEMEC_PLACEMENT", "ring:8")
        p = make_placement(None, 3)
        assert isinstance(p, RingPlacement) and p.vnodes == 8
        monkeypatch.delenv("MEMEC_PLACEMENT")
        assert make_placement(None, 3).kind == "mod"

    def test_describe(self):
        assert "ring" in RingPlacement(2).describe()
        assert isinstance(ModPlacement(2), Placement)
