"""Erasure codes: MDS property, delta-update linearity, RDP double-failure."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.codes import NoCode, RDPCode, RSCode, XORCode, make_code

C = 256  # small chunk size for test speed (divisible by p-1=16)


def _stripe(code, rng, chunk=C):
    data = rng.integers(0, 256, (code.k, chunk), dtype=np.uint8)
    parity = code.encode(data)
    return data, parity, np.concatenate([data, parity])


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_rs_mds_property(data):
    """Any n-k erasures are recoverable (MDS)."""
    n, k = data.draw(st.sampled_from([(10, 8), (14, 10), (6, 4), (5, 3)]))
    code = RSCode(n=n, k=k)
    seed = data.draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    d, p, stripe = _stripe(code, rng)
    erased = data.draw(st.sets(st.integers(0, n - 1), min_size=1,
                               max_size=n - k))
    avail = {i: stripe[i] for i in range(n) if i not in erased}
    rec = code.decode(avail, sorted(erased), C)
    for i in erased:
        assert np.array_equal(rec[i], stripe[i]), f"position {i}"


@given(st.integers(0, 2**31), st.integers(0, 7), st.integers(1, C))
@settings(max_examples=25, deadline=None)
def test_rs_delta_equals_reencode(seed, idx, span):
    """P' = P xor gamma*(D xor D') == encode of the updated stripe (§2)."""
    code = RSCode(n=10, k=8)
    rng = np.random.default_rng(seed)
    d, p, _ = _stripe(code, rng)
    new = d.copy()
    off = rng.integers(0, C - span + 1)
    new[idx, off:off + span] = rng.integers(0, 256, span, dtype=np.uint8)
    delta = code.parity_delta(idx, d[idx], new[idx])
    assert np.array_equal(p ^ delta, code.encode(new))


@given(st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_rdp_double_erasure(seed):
    code = make_code("rdp", 10, 8)
    rng = np.random.default_rng(seed)
    d, p, stripe = _stripe(code, rng)
    i, j = rng.choice(10, size=2, replace=False)
    avail = {x: stripe[x] for x in range(10) if x not in (i, j)}
    rec = code.decode(avail, [int(i), int(j)], C)
    assert np.array_equal(rec[int(i)], stripe[i])
    assert np.array_equal(rec[int(j)], stripe[j])


@given(st.integers(0, 2**31), st.integers(0, 7))
@settings(max_examples=15, deadline=None)
def test_rdp_delta_equals_reencode(seed, idx):
    code = make_code("rdp", 10, 8)
    rng = np.random.default_rng(seed)
    d, p, _ = _stripe(code, rng)
    new = d.copy()
    new[idx, 5:37] = rng.integers(0, 256, 32, dtype=np.uint8)
    delta = code.parity_delta(idx, d[idx], new[idx])
    assert np.array_equal(p ^ delta, code.encode(new))


def test_xor_code(rng):
    code = XORCode(n=9, k=8)
    d, p, stripe = _stripe(code, rng)
    rec = code.decode({i: stripe[i] for i in range(9) if i != 3}, [3], C)
    assert np.array_equal(rec[3], d[3])
    delta = code.parity_delta(2, d[2], d[2] ^ 0xFF)
    new = d.copy()
    new[2] = d[2] ^ 0xFF
    assert np.array_equal(p ^ delta, code.encode(new))


def test_nocode(rng):
    code = NoCode(n=10)
    d = rng.integers(0, 256, (10, C), dtype=np.uint8)
    assert code.encode(d).shape == (0, C)
    with pytest.raises(ValueError):
        code.decode({i: d[i] for i in range(9)}, [9], C)


def test_beyond_tolerance_raises(rng):
    code = RSCode(n=10, k=8)
    d, p, stripe = _stripe(code, rng)
    avail = {i: stripe[i] for i in range(7)}  # only 7 < k
    with pytest.raises(ValueError):
        code.decode(avail, [8], C)


def test_make_code_dispatch():
    assert isinstance(make_code("rs", 10, 8), RSCode)
    assert isinstance(make_code("rdp", 10, 8), RDPCode)
    assert isinstance(make_code("xor", 9, 8), XORCode)
    assert isinstance(make_code("none", 10, 10), NoCode)
    with pytest.raises(ValueError):
        make_code("zfec", 10, 8)


def test_rdp_block_matrix_matches_basis_probe():
    """The analytic RDP block matrix (codes.RDPCode.block_matrix, the
    one engine.block_rep now uses) must equal the matrix probed out of
    ``encode`` with k*r basis vectors — and stay 0/1 (pure XOR)."""
    for k, p in ((8, 17), (5, 7), (3, 5)):
        code = RDPCode(n=k + 2, k=k, p=p)
        r = p - 1
        E = code.block_matrix()
        assert E.shape == (2 * r, k * r) and int(E.max()) <= 1
        probed = np.zeros_like(E)
        for j in range(k * r):
            basis = np.zeros((k, r), dtype=np.uint8)
            basis[j // r, j % r] = 1
            probed[:, j] = code.encode(basis).reshape(2 * r)
        assert np.array_equal(E, probed), (k, p)
