"""Hot-key update tier (PR 10): version-buffered delta coding.

The one invariant everything here pins: the tier is *pure deferral* —
a cluster with the hot tier on must end byte-identical (returned values
AND raw server chunk bytes) to its tier-off twin, across engines,
schemes (r=1 RS and r>1 RDP), sharding, straggler races, degraded mode,
failures injected mid-buffer, and flush-ordering interleavings.  On top
of that: the fold-back barriers actually fire, the facade aggregates
the tier's counters, the per-op dispatch provenance is loud about jnp
fallbacks, and the r>1 per-item delta entry consults the tuning cache.
"""
import json

import numpy as np
import pytest

from repro.core import gf256, make_cluster
from repro.core.codes import make_code
from repro.core.engine import make_engine
from repro.core.hotkey import HotKeyTracker, VersionBuffer, resolve_hot_keys
from repro.data.ycsb import YCSBConfig, YCSBWorkload, run_workload
from repro.kernels import dispatch, tune
from repro.kernels.delta_update import delta_apply_per_item_batched

KW = dict(num_servers=16, scheme="rs", n=10, k=8, c=4,
          chunk_size=512, max_unsealed=2)


def twin_pair(engine="numpy", shards=1, threshold=3.0, **kw):
    """Layout-identical clusters: hot tier on (threshold) vs off (0.0 —
    explicit, so $MEMEC_HOT_KEYS can't switch the baseline on)."""
    merged = dict(KW, engine=engine, shards=shards)
    merged.update(kw)
    on = make_cluster(hot_key_threshold=threshold, **merged)
    off = make_cluster(hot_key_threshold=0.0, **merged)
    return on, off


def seed(cl, n_obj=800, s=5):
    """Load enough 64-byte objects that chunks actually seal (the tier
    only touches sealed updates)."""
    cfg = YCSBConfig(num_objects=n_obj, value_sizes=(64, 64), seed=s)
    run_workload(cl, "load", 0, cfg, batch_size=1)
    return cfg


def drive(cl, cfg, n_ops, workload="U", batch=1, s=6):
    rcfg = YCSBConfig(num_objects=cfg.num_objects, value_sizes=(64, 64),
                      seed=s)
    run_workload(cl, workload, n_ops, rcfg, batch_size=batch)


def contents(cl, cfg):
    w = YCSBWorkload(cfg)
    return cl.multi_get([w.key(i) for i in range(cfg.num_objects)])


def regions(cl):
    """Raw chunk bytes of every server — the strongest identity check
    (catches stale parity that value reads would never surface)."""
    stores = cl.shards if hasattr(cl, "shards") else [cl]
    out = []
    for st in stores:
        for srv in st.servers:
            out.extend(bytes(np.asarray(c)) for c in srv.region)
    return out


def assert_twins_equal(on, off, cfg):
    assert on.flush_hot_buffers() >= 0
    assert contents(on, cfg) == contents(off, cfg), \
        "hot tier changed returned bytes"
    assert regions(on) == regions(off), \
        "hot tier left divergent raw chunk bytes after flush"


def hot_stats(cl):
    return cl.stats["hot_tier"]


# ---------------------------------------------------------------------------
# byte identity across engines x schemes (incl. the r>1 RDP shape)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,scheme", [
    ("numpy", "rs"), ("jax", "rs"), ("pallas", "rs"),
    ("numpy", "rdp"), ("pallas", "rdp"),
])
def test_twin_byte_identity(engine, scheme):
    on, off = twin_pair(engine=engine, scheme=scheme)
    cfgs = []
    for cl in (on, off):
        cfg = seed(cl)
        drive(cl, cfg, 600, batch=1)          # single-op sealed updates
        drive(cl, cfg, 400, workload="A", batch=8, s=9)  # multi_update path
        cfgs.append(cfg)
    st = hot_stats(on)
    assert st["buffered_updates"] > 0, "workload never buffered an update"
    assert st["flushes"] > 0 or len(on.hot.buffer) > 0
    assert "hot_tier" not in off.stats, "tier-off twin allocated tier state"
    assert_twins_equal(on, off, cfgs[0])
    # after the explicit drain the buffer is empty and counters moved
    assert hot_stats(on)["flushed_versions"] >= st["buffered_updates"] - \
        sum(len(e.versions) for e in on.hot.buffer.entries.values())


# ---------------------------------------------------------------------------
# failures and degraded mode: mid-buffer injection, fold-back barriers
# ---------------------------------------------------------------------------

def _victim(cl, parity_side):
    """Server owning the most sealed data (or parity) chunks."""
    def count(srv):
        return sum(1 for idx, cid in enumerate(srv.chunk_ids)
                   if cid is not None and srv.sealed[idx]
                   and (cid.position >= cl.k) == parity_side)
    sid = max(range(len(cl.servers)), key=lambda s: count(cl.servers[s]))
    assert count(cl.servers[sid]) > 0
    return sid


@pytest.mark.parametrize("parity_side", (False, True),
                         ids=("data-victim", "parity-victim"))
def test_fail_during_buffer(parity_side):
    """fail_server mid-buffer: the top-of-fail barrier folds everything
    back before recovery reads any parity; buffering stays paused while
    the failure exists and resumes after restore."""
    on, off = twin_pair()
    cfg = seed(on)
    seed(off)
    for cl in (on, off):
        drive(cl, cfg, 500)
    assert len(on.hot.buffer) > 0, "no entries buffered before the failure"
    victim = _victim(on, parity_side)
    for cl in (on, off):
        cl.fail_server(victim)            # recover=True: rebuild + redirect
        drive(cl, cfg, 300, s=7)          # paused: failure still declared
    assert len(on.hot.buffer) == 0 and hot_stats(on)["barrier_flushes"] > 0
    for cl in (on, off):
        cl.restore_server(victim)
        drive(cl, cfg, 300, s=8)          # buffering resumes
    assert hot_stats(on)["buffered_updates"] > 0
    assert_twins_equal(on, off, cfg)


def test_degraded_reads_after_fail_no_recover():
    """recover=False (§5.4 on-demand): every sealed GET through the
    failed server decodes from parity — which the fail barrier already
    made current."""
    on, off = twin_pair()
    cfg = seed(on)
    seed(off)
    for cl in (on, off):
        drive(cl, cfg, 500)
    victim = _victim(on, parity_side=False)
    for cl in (on, off):
        cl.fail_server(victim, recover=False)
    assert contents(on, cfg) == contents(off, cfg)
    assert on.stats["reconstructions"] > 0, "degraded path never decoded"
    for cl in (on, off):
        drive(cl, cfg, 200, s=7)          # updates while degraded: no buffer
        cl.restore_server(victim)
    assert_twins_equal(on, off, cfg)


def test_read_barrier_under_straggler_races():
    """Δ=1 redundant reads race the fan-out and may decode from parity:
    the pre-read stripe barrier must fold buffered deltas back first."""
    on, off = twin_pair(redundant_reads=1)
    cfg = seed(on)
    seed(off)
    for cl in (on, off):
        cl.inflate_server(0, 10.0)
        for i in range(6):                # interleave updates and reads
            drive(cl, cfg, 150, s=20 + i)
            drive(cl, cfg, 150, workload="C", batch=4, s=30 + i)
    assert hot_stats(on)["barrier_flushes"] > 0, \
        "straggler races never hit the read barrier"
    assert_twins_equal(on, off, cfg)


# ---------------------------------------------------------------------------
# flush-ordering interleavings and capacity pressure
# ---------------------------------------------------------------------------

def test_update_during_flush_interleavings():
    """Tiny buffer bounds force mid-stream flushes (full-entry and
    eviction) between updates to the same keys — every interleaving of
    buffer -> flush -> re-buffer must stay byte-identical."""
    on, off = twin_pair(hot_max_versions=2, hot_max_keys=3, threshold=1.5)
    cfg = seed(on)
    seed(off)
    for i in range(4):
        for cl in (on, off):
            drive(cl, cfg, 250, s=40 + i)
        on.flush_hot_buffers()            # explicit drain mid-stream...
        for cl in (on, off):
            drive(cl, cfg, 100, s=50 + i)  # ...then immediately re-buffer
    st = hot_stats(on)
    assert st["evictions"] > 0, "max_keys pressure never evicted"
    assert st["flushes"] > st["barrier_flushes"]
    assert_twins_equal(on, off, cfg)


def test_sharded_facade_aggregation_and_fail():
    """S=4: per-shard tiers behave independently; the facade sums the
    hot_tier counters, delegates flush_hot_buffers, and a mid-buffer
    failure in one shard doesn't disturb the others."""
    on, off = twin_pair(shards=4)
    cfg = seed(on, n_obj=1600)
    seed(off, n_obj=1600)
    for cl in (on, off):
        drive(cl, cfg, 800)
    st = hot_stats(on)
    assert st["buffered_updates"] > 0
    assert st["buffered_updates"] == sum(
        sh.stats["hot_tier"]["buffered_updates"] for sh in on.shards)
    sid = on.global_sid(1, 2)
    for cl in (on, off):
        cl.fail_server(sid)
        drive(cl, cfg, 300, s=7)
        cl.restore_server(sid)
        drive(cl, cfg, 300, s=8)
    assert "hot_tier" not in off.stats
    assert_twins_equal(on, off, cfg)


# ---------------------------------------------------------------------------
# knobs, tracker, buffer units
# ---------------------------------------------------------------------------

def test_resolve_hot_keys_knob(monkeypatch):
    monkeypatch.delenv("MEMEC_HOT_KEYS", raising=False)
    assert resolve_hot_keys(None) == 0.0
    monkeypatch.setenv("MEMEC_HOT_KEYS", "2.5")
    assert resolve_hot_keys(None) == 2.5
    assert resolve_hot_keys(4.0) == 4.0      # ctor wins over env
    assert resolve_hot_keys(0.0) == 0.0      # explicit off beats env
    assert resolve_hot_keys(-3.0) == 0.0     # clamped


def test_tracker_deterministic_and_decaying():
    a, b = HotKeyTracker(3.0), HotKeyTracker(3.0)
    seq = [b"hot"] * 8 + [b"cold", b"hot"] * 8
    assert [a.touch(k) for k in seq] == [b.touch(k) for k in seq]
    assert a.touch(b"hot") is True
    assert a.touch(b"rare") is False      # first-ever touch: score 1.0
    # a long quiet gap decays the hot key back under threshold
    for i in range(HotKeyTracker.HALFLIFE_OPS * 8):
        a.touch(b"filler%d" % (i % 7))
    assert a.touch(b"hot") is False


def test_version_buffer_bounds():
    class _SL:                      # minimal stand-ins for the index keys
        parity_servers = (8, 9)

    class _CID:
        def __init__(self, stripe):
            self.stripe_id = stripe
            self.position = 0
    sl = _SL()
    vb = VersionBuffer(max_keys=2, max_versions=2)
    seg = np.ones(4, np.uint8)
    e1, ev = vb.append(b"k1", sl, _CID(0), 0, seg)
    assert ev is None and not vb.full(e1)
    e1b, _ = vb.append(b"k1", sl, _CID(0), 4, seg)
    assert e1b is e1 and vb.full(e1)
    vb.append(b"k2", sl, _CID(1), 0, seg)
    _, evicted = vb.append(b"k3", sl, _CID(0), 0, seg)  # over max_keys
    assert evicted is not None and evicted.key == b"k1"
    assert {e.key for e in vb.pop_stripe(sl, _CID(0))} == {b"k3"}
    assert {e.key for e in vb.pop_all()} == {b"k2"}
    assert len(vb) == 0


# ---------------------------------------------------------------------------
# provenance: op_paths must be loud about jnp fallbacks
# ---------------------------------------------------------------------------

def _collapse_once(eng, code, rng):
    B, V, C = 3, 4, 512
    data = rng.integers(0, 256, (B, code.k, C), dtype=np.uint8)
    parity = np.asarray(eng.encode_batch(data))
    idxs = [int(i) for i in rng.integers(0, code.k, B)]
    versions = [rng.integers(0, 256, (V, C), dtype=np.uint8)
                for _ in range(B)]
    got = np.asarray(eng.submit_delta_collapse(parity, idxs,
                                               versions).result())
    # oracle: one delta round with the XOR-fold of the versions
    for b in range(B):
        folded = np.bitwise_xor.reduce(versions[b], axis=0)
        d2 = np.array(data[b])
        d2[idxs[b]] ^= folded
        want = np.asarray(make_engine("numpy", code).encode_batch(
            d2[None])[0])
        assert np.array_equal(got[b], want), f"collapse diverged at {b}"
    return got


@pytest.mark.parametrize("scheme", ("rs", "rdp"))
def test_op_paths_provenance(scheme, rng):
    code = make_code(scheme, 10, 8)
    jax_eng = make_engine("jax", code)
    _collapse_once(jax_eng, code, rng)
    assert jax_eng.op_paths["delta_per_item"] == "jnp-fallback", \
        "jax engine must loudly report its jnp per-item fallback"
    pal = make_engine("pallas", code)
    _collapse_once(pal, code, rng)
    path = pal.op_paths["delta_per_item"]
    assert path in (dispatch.PALLAS, dispatch.XLA, dispatch.INTERPRET)
    if not dispatch.interpret_forced():
        assert path != dispatch.INTERPRET
    # the recorded paths surface through BOTH introspection seams
    for eng in (jax_eng, pal):
        assert eng.describe()["op_paths"] == eng.op_paths
        assert eng.stats()["op_paths"] == eng.op_paths


# ---------------------------------------------------------------------------
# r>1 per-item delta entry: tune-cache consultation
# ---------------------------------------------------------------------------

@pytest.fixture
def fresh_tune_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("MEMEC_TUNE_CACHE", str(tmp_path / "tune.json"))
    tune.load_cache(reload=True)
    yield tmp_path / "tune.json"
    monkeypatch.delenv("MEMEC_TUNE_CACHE")
    tune.load_cache(reload=True)


def test_delta_per_item_consults_tune_cache(fresh_tune_cache, rng):
    B, O, J, C = 2, 3, 4, 256
    Ms = rng.integers(0, 256, (B, O, J), dtype=np.uint8)
    blocks = rng.integers(0, 256, (B, J, C), dtype=np.uint8)
    parity = rng.integers(0, 256, (B, O, C), dtype=np.uint8)
    want = np.stack([parity[b] ^ gf256.gf_matmul_np(Ms[b], blocks[b])
                     for b in range(B)])
    # no entry: heuristic default, still the oracle's bytes
    got = np.asarray(delta_apply_per_item_batched(parity, Ms, blocks))
    assert np.array_equal(got, want)
    # a tuned entry for this exact shape must be honored byte-identically
    dec = dispatch.decide()
    entry = tune.candidates("delta_per_item", dec.path,
                            ops=O * J, is01=False)[-1]
    tune.record(tune.key("delta_per_item", dec.path, k=J, m=O, chunk=C,
                         batch=B, cls=tune.matrix_cls(Ms)), entry)
    got = np.asarray(delta_apply_per_item_batched(parity, Ms, blocks))
    assert np.array_equal(got, want), f"tuned entry {entry} broke bytes"


def test_autotune_delta_per_item_records_and_persists(fresh_tune_cache):
    rng = np.random.default_rng(3)
    M = rng.integers(0, 2, (4, 4), dtype=np.uint8)
    dec = dispatch.decide()
    won = tune.autotune_delta_per_item(M, chunk=128, batch=2, reps=1)
    assert "strategy" in won or "block_c" in won
    assert tune.lookup("delta_per_item", dec.path, k=4, m=4, chunk=128,
                       batch=2, cls="01") is not None
    path = tune.save()
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert any(k.startswith("delta_per_item/") for k in entries)
