"""Fused flash-attention Pallas kernel: shape/GQA sweeps vs plain softmax."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention


def oracle(q, k, v, causal=True):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    kv_idx = np.arange(H) // G
    ke = k[:, :, kv_idx].transpose(0, 2, 1, 3)
    ve = v[:, :, kv_idx].transpose(0, 2, 1, 3)
    qe = q.transpose(0, 2, 1, 3).astype(np.float32)
    s = np.einsum("bhqd,bhtd->bhqt", qe, ke.astype(np.float32)) / np.sqrt(hd)
    if causal:
        mask = np.arange(Sq)[:, None] >= np.arange(k.shape[1])[None, :]
        s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = np.einsum("bhqt,bhtd->bhqd", p, ve.astype(np.float32))
    return o.transpose(0, 2, 1, 3)


@pytest.mark.parametrize("B,S,H,KV,hd,bq,bkv", [
    (2, 256, 4, 2, 64, 128, 128),
    (1, 200, 8, 8, 32, 128, 64),     # MHA + ragged seq (padding path)
    (2, 384, 6, 3, 128, 128, 256),
    (1, 64, 2, 1, 16, 32, 32),       # MQA
])
def test_flash_matches_oracle(B, S, H, KV, hd, bq, bkv, rng):
    q = rng.standard_normal((B, S, H, hd)).astype(np.float32)
    k = rng.standard_normal((B, S, KV, hd)).astype(np.float32)
    v = rng.standard_normal((B, S, KV, hd)).astype(np.float32)
    got = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k),
                                     jnp.asarray(v), block_q=bq,
                                     block_kv=bkv))
    np.testing.assert_allclose(got, oracle(q, k, v), atol=2e-5, rtol=2e-5)


def test_flash_non_causal(rng):
    q = rng.standard_normal((1, 128, 4, 32)).astype(np.float32)
    k = rng.standard_normal((1, 128, 4, 32)).astype(np.float32)
    v = rng.standard_normal((1, 128, 4, 32)).astype(np.float32)
    got = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k),
                                     jnp.asarray(v), causal=False,
                                     block_q=64, block_kv=64))
    np.testing.assert_allclose(got, oracle(q, k, v, causal=False),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16(rng):
    q = rng.standard_normal((1, 128, 4, 64)).astype(np.float32)
    k = rng.standard_normal((1, 128, 2, 64)).astype(np.float32)
    v = rng.standard_normal((1, 128, 2, 64)).astype(np.float32)
    got = np.asarray(flash_attention(
        jnp.asarray(q, jnp.bfloat16), jnp.asarray(k, jnp.bfloat16),
        jnp.asarray(v, jnp.bfloat16), block_q=64, block_kv=64)
    ).astype(np.float32)
    np.testing.assert_allclose(got, oracle(q, k, v), atol=0.05, rtol=0.05)
