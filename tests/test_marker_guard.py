"""Marker hygiene guard (PR 4 CI satellite).

CI's fast job deselects ``slow``-marked tests.  A marker typo (e.g. a
module-level ``pytestmark = pytest.mark.slowtests``) or an accidental
blanket mark would silently deselect an entire suite and CI would pass
with zero coverage.  Guard: ``pytest -m "not slow"`` must still collect
a non-zero number of tests in every module the async-pipeline PR touches,
and the ``slow`` marker must be registered (no unknown-marker warnings).
"""
import os
import subprocess
from collections import Counter

import pytest

from conftest import subprocess_env

# every test module touched by (or load-bearing for) the async pipeline
GUARDED_MODULES = [
    "tests/test_async_engine.py",
    "tests/test_decode_plan.py",
    "tests/test_dispatch_tune.py",
    "tests/test_engine.py",
    "tests/test_event_runtime.py",
    "tests/test_hot_tier.py",
    "tests/test_multikey.py",
    "tests/test_shard.py",
    "tests/test_store.py",
    "tests/test_straggler.py",
    "tests/test_system.py",
    "tests/test_trace.py",
    "tests/test_transitions_prop.py",
]


def _collect(args):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        ["python", "-m", "pytest", "--collect-only", "-q", *args],
        cwd=root, env=subprocess_env(), capture_output=True, text=True)
    assert out.returncode in (0, 5), out.stdout + out.stderr
    per_module: Counter = Counter()
    for line in out.stdout.splitlines():
        if "::" in line and not line.startswith(("=", " ")):
            per_module[line.split("::", 1)[0]] += 1
    return per_module, out


@pytest.mark.guard
def test_not_slow_collects_tests_in_every_touched_module():
    per_module, out = _collect(["-m", "not slow", *GUARDED_MODULES])
    for mod in GUARDED_MODULES:
        assert per_module.get(mod, 0) > 0, (
            f"pytest -m 'not slow' collected 0 tests from {mod} — "
            f"a marker typo is deselecting the suite\n{out.stdout}")


@pytest.mark.guard
def test_slow_marker_is_registered_and_used():
    # the slow suite itself must be non-empty (the nightly job runs it)
    per_module, out = _collect(["-m", "slow", "tests"])
    assert sum(per_module.values()) > 0, \
        f"no slow-marked tests collected\n{out.stdout}"
    # registration: pytest must not warn about an unknown `slow` marker
    assert "Unknown pytest.mark.slow" not in out.stdout + out.stderr
