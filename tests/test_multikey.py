"""Batched multi-key requests + batched recovery: the multi_* API must be
semantically identical to sequential single-key requests, in normal AND
degraded mode, and `fail_server` must recover every lost chunk in one
batched decode."""
import numpy as np
import pytest

from repro.core import MemECCluster, ServerState
from repro.core.chunk import ChunkId
from repro.data.ycsb import YCSBConfig, run_workload


def make_cluster(**kw):
    defaults = dict(num_servers=16, scheme="rs", n=10, k=8, c=16,
                    chunk_size=512, max_unsealed=2, verify_rebuild=True)
    defaults.update(kw)
    return MemECCluster(**defaults)


def parity_invariant(cl):
    bad = checked = 0
    cs = cl.chunk_size
    for s in cl.servers:
        for idx, cid in enumerate(s.chunk_ids):
            if cid is None or not s.sealed[idx] or cid.position >= cl.k:
                continue
            sl = cl.stripe_lists[cid.stripe_list_id]
            avail = {}
            for i in range(cl.n):
                if i == cid.position:
                    continue
                c = cl.servers[sl.servers[i]].get_sealed_chunk(
                    ChunkId(cid.stripe_list_id, cid.stripe_id, i))
                avail[i] = c if c is not None else np.zeros(cs, np.uint8)
            rec = cl.code.decode(avail, [cid.position], cs)[cid.position]
            checked += 1
            bad += 0 if np.array_equal(rec, s.region[idx]) else 1
    return checked, bad


def batch_load(cl, n, batch=16, seed=0, vsizes=(8, 32)):
    rng = np.random.default_rng(seed)
    items = [(b"bk%08d" % i,
              bytes(rng.integers(0, 256, vsizes[i % len(vsizes)],
                                 dtype=np.uint8)))
             for i in range(n)]
    for i in range(0, n, batch):
        ok = cl.multi_set(items[i:i + batch], proxy_id=(i // batch) % 4)
        assert all(ok)
    return dict(items), rng


class TestMultiKeyNormalMode:
    def test_multi_set_get_roundtrip(self):
        cl = make_cluster()
        kv, _ = batch_load(cl, 3000)
        keys = list(kv)
        for i in range(0, len(keys), 16):
            got = cl.multi_get(keys[i:i + 16])
            assert got == [kv[k] for k in keys[i:i + 16]]
        checked, bad = parity_invariant(cl)
        assert checked > 0 and bad == 0

    def test_multi_matches_sequential(self):
        """Batched and per-key execution must leave identical contents."""
        cl_b, cl_s = make_cluster(), make_cluster()
        rng = np.random.default_rng(7)
        items = [(b"eq%07d" % i,
                  bytes(rng.integers(0, 256, 16, dtype=np.uint8)))
                 for i in range(600)]
        cl_b.multi_set(items)
        for k, v in items:
            cl_s.set(k, v)
        upd = [(k, bytes(rng.integers(0, 256, 16, dtype=np.uint8)))
               for k, _ in items[::5]]
        cl_b.multi_update(upd)
        for k, v in upd:
            cl_s.update(k, v)
        keys = [k for k, _ in items]
        assert cl_b.multi_get(keys) == [cl_s.get(k) for k in keys]

    def test_multi_set_duplicates_and_upserts(self):
        cl = make_cluster()
        cl.set(b"old", b"XXXX")
        ok = cl.multi_set([(b"dup", b"AAAA"), (b"dup", b"BBBB"),
                           (b"old", b"YYYY"), (b"new", b"ZZZZ")])
        assert all(ok)
        assert cl.get(b"dup") == b"BBBB"     # last write wins
        assert cl.get(b"old") == b"YYYY"     # upsert through fallback
        assert cl.get(b"new") == b"ZZZZ"
        _, bad = parity_invariant(cl)
        assert bad == 0

    def test_multi_get_missing_and_update_missing(self):
        cl = make_cluster()
        cl.multi_set([(b"a", b"1234")])
        assert cl.multi_get([b"a", b"nope"]) == [b"1234", None]
        assert cl.multi_update([(b"a", b"5678"), (b"nope", b"0000")]) == \
            [True, False]
        assert cl.get(b"a") == b"5678"

    def test_multi_set_large_object_fallback(self):
        cl = make_cluster(chunk_size=512)
        big = bytes(range(256)) * 9
        ok = cl.multi_set([(b"small", b"abcd"), (b"bigkey", big)])
        assert all(ok)
        assert cl.get(b"bigkey") == big
        assert cl.multi_get([b"bigkey", b"small"]) == [big, b"abcd"]

    def test_batched_seal_identical_to_sequential(self):
        """Seal fan-out through fold_seal_batch must rebuild the exact
        chunk bytes (verify_rebuild asserts parity-side equality)."""
        cl = make_cluster(verify_rebuild=True, max_unsealed=1)
        batch_load(cl, 2000, batch=64)
        assert sum(s.seals for s in cl.servers) > 0
        _, bad = parity_invariant(cl)
        assert bad == 0


    def test_crash_hook_fires_in_multi_update(self):
        """Fault injection must behave exactly as in sequential mode."""
        from repro.core import PartialFailure
        cl = make_cluster(max_unsealed=1)
        kv, rng = batch_load(cl, 1500)
        target = None
        for k in kv:
            _, ds = cl.mapper.data_server_for(k)
            ref = cl.servers[ds].lookup(k)
            if ref is not None and cl.servers[ds].sealed[ref.chunk_local_idx]:
                target = k
                break
        assert target is not None
        cl.crash_hook = ("update", target, 1)
        with pytest.raises(PartialFailure):
            cl.multi_update([(target, bytes(len(kv[target])))])

    def test_crash_hook_mid_batch_matches_sequential_order(self):
        """Items before the crashing key complete; items after do not."""
        from repro.core import PartialFailure
        cl = make_cluster(max_unsealed=1)
        kv, rng = batch_load(cl, 1500)
        sealed = []
        for k in kv:
            _, ds = cl.mapper.data_server_for(k)
            ref = cl.servers[ds].lookup(k)
            if ref is not None and cl.servers[ds].sealed[ref.chunk_local_idx]:
                sealed.append(k)
            if len(sealed) == 3:
                break
        assert len(sealed) == 3
        before, target, after = sealed
        newvals = {k: bytes(rng.integers(0, 256, len(kv[k]),
                                         dtype=np.uint8)) for k in sealed}
        cl.crash_hook = ("update", target, 1)
        with pytest.raises(PartialFailure):
            cl.multi_update([(k, newvals[k]) for k in sealed])
        assert cl.get(before) == newvals[before]   # ran before the crash
        assert cl.get(after) == kv[after]          # never executed


class TestMultiKeyDegradedMode:
    def test_degraded_multi_roundtrip(self):
        cl = make_cluster()
        kv, rng = batch_load(cl, 2500)
        cl.fail_server(3)
        assert cl.coordinator.state_of(3) == ServerState.DEGRADED
        keys = list(kv)
        for i in range(0, len(keys), 16):
            got = cl.multi_get(keys[i:i + 16])
            assert got == [kv[k] for k in keys[i:i + 16]]
        upd = [(k, bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8)))
               for k in keys[:300]]
        for i in range(0, len(upd), 16):
            assert all(cl.multi_update(upd[i:i + 16]))
        kv.update(dict(upd))
        new = [(b"deg%05d" % i, bytes(rng.integers(0, 256, 16,
                                                   dtype=np.uint8)))
               for i in range(80)]
        for i in range(0, len(new), 16):
            assert all(cl.multi_set(new[i:i + 16]))
        kv.update(dict(new))
        for i in range(0, len(keys), 16):
            got = cl.multi_get(keys[i:i + 16])
            assert got == [kv[k] for k in keys[i:i + 16]]
        cl.restore_server(3)
        assert all(cl.get(k) == v for k, v in kv.items())
        _, bad = parity_invariant(cl)
        assert bad == 0


class TestBatchedRecovery:
    def test_fail_server_recovers_all_chunks_in_one_decode(self):
        cl = make_cluster()
        kv, _ = batch_load(cl, 3000)
        sealed_owned = sum(
            1 for idx, cid in enumerate(cl.servers[3].chunk_ids)
            if cid is not None and cl.servers[3].sealed[idx])
        t = cl.fail_server(3)
        assert t["recovered_chunks"] == sealed_owned > 0
        assert t["T_recovery"] > 0
        assert cl.stats["batch_recovered_chunks"] == sealed_owned
        # every sealed chunk is already reconstructed: a full GET sweep
        # must not trigger a single further per-chunk decode
        before = cl.stats["reconstructions"]
        for k in kv:
            cl.get(k)
        assert cl.stats["reconstructions"] == before
        assert cl.stats["recon_chunk_hits"] > 0
        cl.restore_server(3)
        assert all(cl.get(k) == v for k, v in kv.items())

    def test_recovery_timing_separate_from_transition(self):
        cl = make_cluster()
        batch_load(cl, 1500)
        t = cl.fail_server(5)
        assert set(t) >= {"T_N_to_D", "T_recovery", "recovered_chunks"}
        assert t["T_N_to_D"] < 1.0    # paper Exp 5: transitions stay fast
        cl.restore_server(5)

    def test_recovery_time_scales_with_volume(self):
        """T_recovery models link-serialized fetches per redirected
        server — more lost chunks must cost more modeled time."""
        times = {}
        for n_obj in (600, 4800):
            cl = make_cluster(max_unsealed=1)
            batch_load(cl, n_obj, batch=32)
            t = cl.fail_server(3)
            times[n_obj] = (t["recovered_chunks"], t["T_recovery"])
            cl.restore_server(3)
        assert times[4800][0] > times[600][0]
        assert times[4800][1] > times[600][1]

    def test_nocode_recovery_is_noop(self):
        cl = make_cluster(scheme="none", n=10, k=10)
        batch_load(cl, 500)
        t = cl.fail_server(2)
        assert t["recovered_chunks"] == 0
        cl.restore_server(2)


class TestBatchedYCSB:
    @pytest.mark.parametrize("fail", [False, True])
    def test_ycsb_batched_roundtrip(self, fail):
        """multi_get/multi_set round-trip YCSB in normal AND degraded mode:
        the batched driver must leave the store byte-identical with what a
        sequential verification sweep reads back."""
        cl = make_cluster()
        cfg = YCSBConfig(num_objects=800)
        ops, w = run_workload(cl, "load", 0, cfg, batch_size=16)
        assert ops == 800
        if fail:
            cl.fail_server(4)
        ops, _ = run_workload(cl, "A", 1200, cfg, batch_size=16)
        assert ops == 1200
        assert cl.net.ops_by_kind.get("MGET", 0) > 0
        assert cl.net.ops_by_kind.get("MUPDATE", 0) > 0 or fail
        if fail:
            cl.restore_server(4)
        # verify every object readable (updates in workload A move values;
        # GET correctness is checked against a sequentially-driven twin)
        cl2 = make_cluster()
        run_workload(cl2, "load", 0, cfg, batch_size=1)
        ops2, _ = run_workload(cl2, "A", 1200, cfg, batch_size=1)
        for i in range(cfg.num_objects):
            key = w.key(i)
            assert cl.get(key) == cl2.get(key), (i, fail)
        _, bad = parity_invariant(cl)
        assert bad == 0
