"""Shared pytest fixtures.

NOTE: no XLA_FLAGS here on purpose — smoke tests run on the single real
CPU device; tests needing a multi-device mesh spawn a subprocess with
their own --xla_force_host_platform_device_count (see test_ecstore.py).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def subprocess_env():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    return env
