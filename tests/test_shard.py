"""ShardedCluster: hash routing, S=1/S>1 byte-identity, pipelined
cross-shard batches, shard-scoped failures, and seeded fault injection on
the batched multi-key paths (degraded fallback must hit exactly the
affected keys)."""
import numpy as np
import pytest

from repro.core import (MemECCluster, ShardedCluster, engine_specs,
                        make_cluster, resolve_shards, shard_for_key)
from repro.core.engine import JaxEngine, NumpyEngine
from repro.data.ycsb import YCSBConfig, YCSBWorkload, run_workload
from test_multikey import parity_invariant

KW = dict(num_servers=10, num_proxies=2, scheme="rs", n=4, k=2, c=8,
          chunk_size=256, max_unsealed=2)


def sharded(shards=3, **kw):
    merged = dict(KW)
    merged.update(kw)
    return ShardedCluster(shards=shards, **merged)


def seeded_items(n, seed=0, sizes=(8, 32)):
    rng = np.random.default_rng(seed)
    return [(b"sk%06d" % i,
             bytes(rng.integers(0, 256, sizes[i % len(sizes)],
                                dtype=np.uint8)))
            for i in range(n)]


class TestConstructionAndRouting:
    def test_make_cluster_s1_is_plain_memec(self):
        cl = make_cluster(shards=1, **KW)
        assert isinstance(cl, MemECCluster)
        cl = make_cluster(shards=3, **KW)
        assert isinstance(cl, ShardedCluster) and cl.num_shards == 3

    def test_memec_shards_env(self, monkeypatch):
        monkeypatch.setenv("MEMEC_SHARDS", "4")
        assert resolve_shards(None) == 4
        cl = make_cluster(**KW)
        assert isinstance(cl, ShardedCluster) and cl.num_shards == 4
        monkeypatch.delenv("MEMEC_SHARDS")
        assert resolve_shards(None) == 1
        with pytest.raises(ValueError):
            resolve_shards(0)

    def test_shard_routing_deterministic_and_spread(self):
        keys = [b"rk%05d" % i for i in range(2000)]
        assign = [shard_for_key(k, 4) for k in keys]
        assert assign == [shard_for_key(k, 4) for k in keys]  # stable
        counts = np.bincount(assign, minlength=4)
        assert (counts > 0).all()          # every shard gets traffic
        assert counts.max() < 2 * counts.min()  # roughly uniform
        assert all(shard_for_key(k, 1) == 0 for k in keys[:10])

    def test_routing_flows_through_placement(self):
        """No caller may hard-code FNV-mod: shard_of/locate/_plan all go
        through the pluggable Placement (default: the historical mod)."""
        from repro.core import RingPlacement
        cl = sharded(shards=3)
        assert cl.placement.kind == "mod"
        keys = [b"pk%05d" % i for i in range(300)]
        assert [cl.shard_of(k) for k in keys] == \
            [shard_for_key(k, 3) for k in keys]        # default unchanged
        ring = sharded(shards=3, placement="ring")
        assert isinstance(ring.placement, RingPlacement)
        for k in keys[:50]:
            si, sl, ds = ring.locate(k)
            assert si == ring.placement.shard_for(k)
        groups = ring._plan(keys)
        for si, idxs in groups.items():
            assert all(ring.placement.shard_for(keys[i]) == si
                       for i in idxs)

    def test_mixed_engines_per_shard(self):
        assert engine_specs("pallas,numpy", 4) == \
            ["pallas", "numpy", "pallas", "numpy"]
        assert engine_specs(["jax"], 3) == ["jax", "jax", "jax"]
        cl = sharded(shards=3, engine="numpy,jax")
        assert isinstance(cl.shards[0].engine, NumpyEngine)
        assert isinstance(cl.shards[1].engine, JaxEngine)
        assert isinstance(cl.shards[2].engine, NumpyEngine)
        # every shard still serves the same data plane
        items = seeded_items(120, seed=5)
        assert all(cl.multi_set(items))
        assert cl.multi_get([k for k, _ in items]) == [v for _, v in items]


class TestShardEquivalence:
    def test_s3_matches_s1_seeded_workload(self):
        """Byte-identity: the sharded cluster must serve exactly what the
        unsharded one serves for the same seeded batched workload."""
        cl3, cl1 = sharded(shards=3), make_cluster(shards=1, **KW)
        items = seeded_items(900, seed=1)
        keys = [k for k, _ in items]
        for i in range(0, len(items), 32):
            assert all(cl3.multi_set(items[i:i + 32]))
            assert all(cl1.multi_set(items[i:i + 32]))
        rng = np.random.default_rng(2)
        upd = [(k, bytes(rng.integers(0, 256, len(v), dtype=np.uint8)))
               for k, v in items[::4]]
        assert all(cl3.multi_update(upd)) == all(cl1.multi_update(upd))
        assert cl3.multi_get(keys) == cl1.multi_get(keys)
        for sh in cl3.shards:
            checked, bad = parity_invariant(sh)
            assert bad == 0 and checked > 0

    def test_degraded_decode_matches_s1(self):
        """Decode byte-identity: degraded reads (reconstructed chunks) in
        every shard must equal the unsharded cluster's contents."""
        cl3, cl1 = sharded(shards=3), make_cluster(shards=1, **KW)
        items = seeded_items(600, seed=3)
        keys = [k for k, _ in items]
        for cl in (cl3, cl1):
            for i in range(0, len(items), 32):
                assert all(cl.multi_set(items[i:i + 32]))
        for si in range(cl3.num_shards):   # one failure per shard
            cl3.fail_server(cl3.global_sid(si, 1))
        assert cl3.multi_get(keys) == cl1.multi_get(keys)
        assert cl3.stats["degraded_requests"] > 0
        for si in range(cl3.num_shards):
            cl3.restore_server(cl3.global_sid(si, 1))
        assert cl3.multi_get(keys) == cl1.multi_get(keys)

    def test_ycsb_driver_sharded_matches_unsharded(self):
        cfg = YCSBConfig(num_objects=500)
        cl3, cl1 = sharded(shards=3), make_cluster(shards=1, **KW)
        for cl in (cl3, cl1):
            run_workload(cl, "load", 0, cfg, batch_size=16)
            run_workload(cl, "A", 800, cfg, batch_size=16)
        w = YCSBWorkload(cfg)
        keys = [w.key(i) for i in range(cfg.num_objects)]
        assert cl3.multi_get(keys) == cl1.multi_get(keys)
        assert cl3.net.ops_by_kind.get("MGET", 0) > 0


class TestPipelinedBatches:
    def test_overlap_saves_modeled_time(self):
        cl = sharded(shards=4)
        items = seeded_items(400, seed=7)
        for i in range(0, len(items), 64):
            cl.multi_set(items[i:i + 64])
        saved_after_load = cl.stats["pipeline_overlap_saved_s"]
        assert cl.stats["pipelined_batches"] > 0
        assert saved_after_load > 0
        cl.multi_get([k for k, _ in items[:128]])
        assert cl.stats["pipeline_overlap_saved_s"] > saved_after_load

    def test_merged_latency_is_slowest_shard(self):
        cl = sharded(shards=3, pipeline=True)
        items = seeded_items(96, seed=8)
        cl.multi_set(items)
        shard_t = [sh.net.latencies["MSET"][-1] for sh in cl.shards
                   if sh.net.latencies.get("MSET")]
        assert cl.net.local.latencies["MSET"][-1] == \
            pytest.approx(max(shard_t))

    def test_pipeline_off_is_byte_identical(self):
        cl_p = sharded(shards=3, pipeline=True)
        cl_s = sharded(shards=3, pipeline=False)
        items = seeded_items(300, seed=9)
        assert cl_p.multi_set(items) == cl_s.multi_set(items)
        keys = [k for k, _ in items]
        assert cl_p.multi_get(keys) == cl_s.multi_get(keys)
        assert cl_p.stats["degraded_requests"] == 0

    def test_planner_routes_through_per_shard_proxies(self):
        cl = sharded(shards=3)
        items = seeded_items(240, seed=13)
        for pid in range(cl.num_proxies):
            for i in range(0, len(items), 48):
                cl.multi_set(items[i:i + 48], proxy_id=pid)
        for sh in cl.shards:   # every shard's proxies carried requests
            assert sum(p.requests_begun for p in sh.proxies) > 0
        assert cl.multi_get([k for k, _ in items]) == \
            [v for _, v in items]

    def test_aggregate_net_view(self):
        cl = sharded(shards=2)
        items = seeded_items(64, seed=10)
        cl.multi_set(items)
        cl.multi_get([k for k, _ in items])
        lat = cl.net.latencies
        assert lat["MGET"] and lat["MSET"]
        # facade-merged entries, not per-shard duplicates
        assert len(lat["MGET"]) == cl.net.local.ops_by_kind["MGET"]
        eps = cl.net.bytes_by_endpoint
        assert any(ep.startswith("sh0:s") for ep in eps)
        assert any(ep.startswith("sh1:s") for ep in eps)
        assert set(cl.server_endpoint_names()) <= \
            {f"sh{i}:s{j}" for i in range(2) for j in range(10)}
        assert cl.net.total_bytes() > 0
        cl.net.reset()
        assert cl.net.latencies == {} and cl.net.total_bytes() == 0


class TestShardScopedFailures:
    def test_failure_isolated_to_owning_shard(self):
        cl = sharded(shards=3)
        items = seeded_items(600, seed=11)
        for i in range(0, len(items), 32):
            cl.multi_set(items[i:i + 32])
        t = cl.fail_server(cl.global_sid(1, 2))
        assert t["shard"] == 1 and t["recovered_chunks"] >= 0
        assert cl.failed == {cl.global_sid(1, 2)}
        keys = [k for k, _ in items]
        assert cl.multi_get(keys) == [v for _, v in items]
        assert cl.shards[1].stats["degraded_requests"] > 0
        assert cl.shards[0].stats["degraded_requests"] == 0
        assert cl.shards[2].stats["degraded_requests"] == 0
        # unaffected shards never left NORMAL: no coordinated traffic
        assert not cl.shards[0].coordinator.any_failure()
        assert not cl.shards[2].coordinator.any_failure()
        t = cl.restore_server(cl.global_sid(1, 2))
        assert t["shard"] == 1
        assert cl.failed == set()
        assert cl.multi_get(keys) == [v for _, v in items]

    def test_explicit_shard_kwarg(self):
        cl = sharded(shards=2)
        cl.multi_set(seeded_items(50, seed=12))
        t = cl.fail_server(3, shard=1)
        assert t["shard"] == 1 and cl.failed == {cl.global_sid(1, 3)}
        cl.restore_server(3, shard=1)
        with pytest.raises(ValueError):
            cl.fail_server(0, shard=5)


class TestSeededFaultInjectionBatched:
    """PR-1 fallback logic regression guards: batched requests with a
    failure in *some* shards degrade exactly the affected keys."""

    def _loaded(self, shards=2, n_items=500, seed=21):
        cl = sharded(shards=shards)
        items = seeded_items(n_items, seed=seed)
        for i in range(0, n_items, 32):
            assert all(cl.multi_set(items[i:i + 32]))
        return cl, items

    def test_multi_get_degrades_exactly_affected_keys(self):
        cl, items = self._loaded()
        fsid, fshard = 2, 0
        cl.fail_server(cl.global_sid(fshard, fsid))
        affected = [k for k, _ in items
                    if cl.shard_of(k) == fshard
                    and cl.locate(k)[2] == fsid]
        assert affected   # seed must actually hit the failed server
        base = cl.stats["degraded_requests"]
        got = cl.multi_get([k for k, _ in items])
        assert got == [v for _, v in items]
        assert cl.stats["degraded_requests"] - base == len(affected)
        assert cl.shards[1].stats["degraded_requests"] == 0
        cl.restore_server(cl.global_sid(fshard, fsid))

    def test_multi_update_degrades_exactly_affected_keys(self):
        cl, items = self._loaded(seed=22)
        fsid, fshard = 1, 1
        cl.fail_server(cl.global_sid(fshard, fsid))
        rng = np.random.default_rng(99)
        upd = [(k, bytes(rng.integers(0, 256, len(v), dtype=np.uint8)))
               for k, v in items]
        expected = 0
        for k, _ in upd:
            si, sl, ds = cl.locate(k)
            if si != fshard:
                continue
            if ds == fsid:
                expected += 2   # degraded head-probe GET + degraded UPDATE
            elif fsid in sl.parity_servers:
                expected += 1   # degraded UPDATE only
        assert expected > 0
        base = cl.stats["degraded_requests"]
        assert all(cl.multi_update(upd))
        assert cl.stats["degraded_requests"] - base == expected
        assert cl.shards[0].stats["degraded_requests"] == 0
        cl.restore_server(cl.global_sid(fshard, fsid))
        kv = dict(upd)
        assert cl.multi_get([k for k, _ in items]) == \
            [kv[k] for k, _ in items]
        for sh in cl.shards:
            _, bad = parity_invariant(sh)
            assert bad == 0

    def test_multi_set_degrades_only_affected_shard(self):
        cl, _ = self._loaded(seed=23)
        cl.fail_server(cl.global_sid(0, 4))
        fresh = seeded_items(120, seed=24)
        fresh = [(b"new" + k, v) for k, v in fresh]
        assert all(cl.multi_set(fresh))
        assert cl.multi_get([k for k, _ in fresh]) == [v for _, v in fresh]
        assert cl.shards[1].stats["degraded_requests"] == 0
        cl.restore_server(cl.global_sid(0, 4))
        assert cl.multi_get([k for k, _ in fresh]) == [v for _, v in fresh]
