"""End-to-end system behaviour: YCSB workloads over MemEC + baselines,
mirroring the paper's §7 evaluation setup at reduced scale."""
import numpy as np
import pytest

from repro.core import (AllReplicationCluster, HybridEncodingCluster,
                        MemECCluster)
from repro.data.ycsb import WORKLOADS, YCSBConfig, YCSBWorkload, run_workload


def test_ycsb_zipf_skew():
    cfg = YCSBConfig(num_objects=5000, seed=1)
    w = YCSBWorkload(cfg)
    ids = w.zipf.sample(20000)
    top = np.bincount(ids, minlength=cfg.num_objects)
    # zipf(0.99): the hottest key takes a few % of traffic
    assert top.max() / len(ids) > 0.02
    assert (ids < cfg.num_objects).all() and (ids >= 0).all()


def test_ycsb_mixes():
    assert WORKLOADS["A"] == {"get": 0.5, "update": 0.5}
    assert WORKLOADS["C"] == {"get": 1.0}
    w = YCSBWorkload(YCSBConfig(num_objects=100, seed=2))
    kinds = [k for k, _, _ in w.run_ops("B", 1000)]
    get_frac = kinds.count("get") / len(kinds)
    assert 0.9 < get_frac <= 1.0


@pytest.mark.parametrize("factory", [
    lambda: MemECCluster(num_servers=16, scheme="rs", n=10, k=8,
                         chunk_size=512, max_unsealed=2),
    lambda: AllReplicationCluster(num_servers=16, n=10, k=8),
    lambda: HybridEncodingCluster(num_servers=16, scheme="rs", n=10, k=8,
                                  chunk_size=512),
])
def test_workload_a_on_all_data_models(factory):
    cl = factory()
    cfg = YCSBConfig(num_objects=1200)
    run_workload(cl, "load", 0, cfg)
    ops, w = run_workload(cl, "A", 1500, cfg)
    assert ops == 1500
    # spot-check consistency: a value of the right size is served
    probe = YCSBWorkload(cfg)
    for i in (0, 1, 7, 42):
        v = cl.get(probe.key(i))
        assert v is not None and len(v) == probe.value_size(i)


def test_degraded_workload_end_to_end():
    """Exp 4 analogue: run A, fail a server mid-workload, finish, restore."""
    cl = MemECCluster(num_servers=16, scheme="rs", n=10, k=8,
                      chunk_size=512, max_unsealed=2)
    cfg = YCSBConfig(num_objects=1500)
    run_workload(cl, "load", 0, cfg)
    run_workload(cl, "A", 600, cfg)
    cl.fail_server(4)
    run_workload(cl, "A", 600, cfg)
    assert cl.stats["degraded_requests"] > 0
    cl.restore_server(4)
    run_workload(cl, "C", 400, cfg)
    assert cl.net.latencies["GET"]
    deg = (cl.net.latencies.get("GET_DEG") or
           cl.net.latencies.get("UPDATE_DEG"))
    assert deg


def test_hybrid_degraded_read():
    cl = HybridEncodingCluster(num_servers=16, scheme="rs", n=10, k=8,
                               chunk_size=512)
    cfg = YCSBConfig(num_objects=800)
    run_workload(cl, "load", 0, cfg)
    w = YCSBWorkload(cfg)
    sl, ds = cl.mapper.data_server_for(w.key(5))
    cl.fail_server(ds)
    v = cl.get(w.key(5))
    assert v == w.value(5)
    cl.restore_server(ds)


def test_allrep_survives_failures():
    cl = AllReplicationCluster(num_servers=16, n=10, k=8)
    cfg = YCSBConfig(num_objects=500)
    run_workload(cl, "load", 0, cfg)
    w = YCSBWorkload(cfg)
    cl.fail_server(0)
    cl.fail_server(1)
    for i in range(0, 100, 7):
        assert cl.get(w.key(i)) == w.value(i)
