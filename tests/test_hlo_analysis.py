"""Scan-aware HLO cost analysis: calibration against known graphs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.analysis import xla_cost_analysis
from repro.launch.hlo_analysis import analyze, parse_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_plain_matmul_exact():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, a)
    r = analyze(c.as_text())
    assert r["flops"] == pytest.approx(2 * 512**3, rel=0.01)


def test_scan_multiplies_body():
    """XLA cost_analysis counts while bodies once; ours multiplies by the
    known trip count — the property the whole roofline rests on."""
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def scanned(x, y):
        def body(c, _):
            return jnp.tanh(c @ y), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    c = _compile(scanned, a, a)
    raw = xla_cost_analysis(c).get("flops", 0)
    ours = analyze(c.as_text())["flops"]
    expect = 8 * 2 * 256**3
    assert raw < expect / 4          # XLA undercounts (1 body)
    assert ours == pytest.approx(expect, rel=0.05)


def test_nested_scan():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def nested(x, y):
        def outer(c, _):
            def inner(ci, _):
                return ci @ y, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return jnp.tanh(c2), None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    c = _compile(nested, a, a)
    ours = analyze(c.as_text())["flops"]
    assert ours == pytest.approx(12 * 2 * 128**3, rel=0.05)


def test_parse_handles_tuple_types_with_comments():
    hlo = """
HloModule m
ENTRY %main (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
  %t = (s32[], f32[4,4]{1,0}, /*index=2*/f32[4,4]{1,0}) tuple(%p)
  ROOT %r = f32[4,4]{1,0} get-tuple-element(%t), index=1
}
"""
    comps = parse_hlo(hlo)
    assert "__entry__" in comps
    ops = [i.op for i in comps["__entry__"].instrs]
    assert "tuple" in ops


def test_bytes_counts_dots_not_layout_ops():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(lambda x, y: (x @ y).T.reshape(-1), a, a)
    r = analyze(c.as_text())
    # dot reads 2 operands + writes 1 result (3 * 256KB); transpose/reshape
    # are layout ops and must not double the count
    assert r["bytes"] <= 4 * 256 * 256 * 4 * 2
