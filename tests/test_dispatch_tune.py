"""Compiled data plane: dispatch policy, cross-strategy byte equivalence,
fused-kernel compositions, and the tuning cache.

Everything the dispatch seam can pick (XLA bit-plane / select / table,
Pallas unroll / cols / gf01, single-stripe 2D jits, per-item matrices,
fused folds) must be byte-identical to the numpy GF(2^8) oracle, and a
missing or corrupt tuning cache must degrade to heuristics — never
crash.
"""
import json

import numpy as np
import pytest

from repro.core import gf256
from repro.core.codes import RSCode, make_code
from repro.core.engine import block_rep, make_engine
from repro.kernels import dispatch, tune, xla_gf256
from repro.kernels.delta_update import delta_apply_batched, delta_update
from repro.kernels.gf256_matmul import (PALLAS_STRATEGIES, gf256_matmul,
                                        gf256_matmul_batched,
                                        gf256_matmul_per_item_batched)

CPU = dispatch.backend() == "cpu"


@pytest.fixture(autouse=True)
def _fresh_tune_cache():
    """Tests monkeypatch $MEMEC_TUNE_CACHE; make sure the module cache is
    re-resolved both on entry and after the env is restored."""
    tune.load_cache(reload=True)
    yield
    tune.load_cache(reload=True)


# ---------------------------------------------------------------------------
# dispatch policy
# ---------------------------------------------------------------------------

def test_decide_explicit_overrides():
    assert dispatch.decide(True).path == dispatch.INTERPRET
    assert dispatch.decide(True).interpret is True
    assert dispatch.decide(False).path == dispatch.PALLAS
    assert dispatch.decide(False).interpret is False
    assert dispatch.decide(False).compiled is True


@pytest.mark.skipif(not CPU, reason="CPU-policy test")
def test_decide_cpu_defaults_to_xla(monkeypatch):
    monkeypatch.delenv("MEMEC_INTERPRET", raising=False)
    assert dispatch.decide().path == dispatch.XLA
    assert dispatch.decide().compiled is True
    # kernels with no XLA twin fall back to interpret on CPU
    assert dispatch.decide(xla_ok=False).path == dispatch.INTERPRET


def test_interpret_env_forces_interpret(monkeypatch):
    for val in ("1", "true", "YES", " on "):
        monkeypatch.setenv("MEMEC_INTERPRET", val)
        assert dispatch.interpret_forced(), val
        assert dispatch.decide().path == dispatch.INTERPRET
    for val in ("", "0", "no", "off"):
        monkeypatch.setenv("MEMEC_INTERPRET", val)
        assert not dispatch.interpret_forced(), val
        assert dispatch.decide().path != dispatch.INTERPRET
    # the env hatch loses to an explicit per-call interpret=False
    monkeypatch.setenv("MEMEC_INTERPRET", "1")
    assert dispatch.decide(False).path == dispatch.PALLAS


def test_describe_snapshot(monkeypatch):
    monkeypatch.delenv("MEMEC_INTERPRET", raising=False)
    d = dispatch.describe()
    assert d["backend"] == dispatch.backend()
    assert d["path"] == dispatch.decide().path
    assert d["interpret_forced"] is False


# ---------------------------------------------------------------------------
# cross-strategy byte equivalence vs the numpy oracle
# ---------------------------------------------------------------------------

def _matrices():
    rs = RSCode(n=10, k=8)
    # a small 0/1 matrix stands in for the RDP block class (the real
    # (m*r, k*r) block matrices are strategy-equivalent but too wide to
    # unroll in interpret mode; test_engine covers them end to end)
    rng01 = np.random.default_rng(7)
    A01 = rng01.integers(0, 2, (3, 6), dtype=np.uint8)
    A01[:, 0] = 1
    return [
        ("rs-parity", np.asarray(rs.parity_matrix, np.uint8)),
        ("block-01", A01),
    ]


@pytest.mark.parametrize("name,A", _matrices())
@pytest.mark.parametrize("C", (37, 129, 256))
@pytest.mark.parametrize("B", (0, 1, 3))
def test_all_strategies_match_oracle(name, A, C, B, rng):
    data = rng.integers(0, 256, (B, A.shape[1], C), dtype=np.uint8)
    want = np.stack([gf256.gf_matmul_np(A, d) for d in data]) if B else \
        np.zeros((0, A.shape[0], C), np.uint8)
    # XLA strategies (select32 demotes itself on dense matrices)
    for s in xla_gf256.STRATEGIES:
        got = np.asarray(xla_gf256.matmul_batched(A, data, strategy=s))
        assert np.array_equal(got, want), (name, s, C, B)
    # Pallas strategies in interpret mode, incl. a block_c that does not
    # divide C (forces the pad/slice path)
    for s in PALLAS_STRATEGIES:
        got = np.asarray(gf256_matmul_batched(
            A, data, strategy=s, block_c=128, interpret=True))
        assert np.array_equal(got, want), (name, s, C, B)
    # the dispatch default (whatever the policy + tune cache picked)
    got = np.asarray(gf256_matmul_batched(A, data))
    assert np.array_equal(got, want), (name, "default", C, B)


@pytest.mark.parametrize("C", (37, 208))
def test_single_stripe_matches_oracle(C, rng):
    A = np.asarray(RSCode(n=10, k=8).parity_matrix, np.uint8)
    d = rng.integers(0, 256, (A.shape[1], C), dtype=np.uint8)
    want = gf256.gf_matmul_np(A, d)
    assert np.array_equal(np.asarray(gf256_matmul(A, d)), want)
    assert np.array_equal(np.asarray(gf256_matmul(A, d, interpret=True)),
                          want)
    for s in xla_gf256.STRATEGIES:
        assert np.array_equal(
            np.asarray(xla_gf256.matmul(A, d, strategy=s)), want), s


def test_empty_matrix_rows(rng):
    A = np.zeros((0, 4), np.uint8)
    data = rng.integers(0, 256, (2, 4, 64), dtype=np.uint8)
    assert gf256_matmul_batched(A, data).shape == (2, 0, 64)


# ---------------------------------------------------------------------------
# per-item-matrix kernels (r > 1 deltas, fused folds)
# ---------------------------------------------------------------------------

def _per_item_oracle(Ms, blocks, parity=None):
    out = np.stack([gf256.gf_matmul_np(M, d) for M, d in zip(Ms, blocks)]) \
        if len(Ms) else np.zeros((0, Ms.shape[1], blocks.shape[2]), np.uint8)
    return out if parity is None else parity ^ out


@pytest.mark.parametrize("dense", (True, False))
@pytest.mark.parametrize("C", (37, 128))
@pytest.mark.parametrize("B", (0, 1, 3))
@pytest.mark.parametrize("fold", (False, True))
def test_per_item_matmul_matches_oracle(dense, C, B, fold, rng):
    O, J = 3, 4
    Ms = rng.integers(0, 256 if dense else 2, (B, O, J), dtype=np.uint8)
    blocks = rng.integers(0, 256, (B, J, C), dtype=np.uint8)
    parity = rng.integers(0, 256, (B, O, C), dtype=np.uint8) if fold else None
    want = _per_item_oracle(Ms, blocks, parity)
    got = np.asarray(gf256_matmul_per_item_batched(Ms, blocks, parity))
    assert np.array_equal(got, want), ("dispatch", dense, C, B, fold)
    got = np.asarray(gf256_matmul_per_item_batched(
        Ms, blocks, parity, block_c=128, interpret=True))
    assert np.array_equal(got, want), ("interpret", dense, C, B, fold)
    for s in xla_gf256.STRATEGIES:
        got = np.asarray(xla_gf256.matmul_per_item(
            Ms, blocks, parity, strategy=s))
        assert np.array_equal(got, want), (s, dense, C, B, fold)


@pytest.mark.parametrize("C", (37, 200))
def test_delta_kernels_match_oracle(C, rng):
    A = np.asarray(RSCode(n=10, k=8).parity_matrix, np.uint8)
    B, m = 3, A.shape[0]
    idxs = rng.integers(0, A.shape[1], B)
    gammas = A[:, idxs].T.astype(np.uint32)               # (B, m)
    xors = rng.integers(0, 256, (B, C), dtype=np.uint8)
    parity = rng.integers(0, 256, (B, m, C), dtype=np.uint8)
    want = parity ^ np.stack(
        [np.stack([gf256.gf_mul_np(np.full(C, g, np.uint8), x)
                   for g in gam]) for gam, x in zip(gammas, xors)])
    got = np.asarray(delta_apply_batched(parity, gammas, xors))
    assert np.array_equal(got, want)
    got = np.asarray(delta_apply_batched(parity, gammas, xors,
                                         interpret=True))
    assert np.array_equal(got, want)
    # single-row fused spelling
    old = rng.integers(0, 256, C, dtype=np.uint8)
    new = old ^ xors[0]
    want0 = parity[0] ^ np.stack(
        [gf256.gf_mul_np(np.full(C, g, np.uint8), xors[0])
         for g in gammas[0]])
    got0 = np.asarray(delta_update(parity[0], gammas[0].astype(np.int32),
                                   old, new))
    assert np.array_equal(got0, want0)


# ---------------------------------------------------------------------------
# fused engine ops == their two-call compositions
# ---------------------------------------------------------------------------

BACKENDS = ("numpy", "jax", "pallas")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("scheme,n,k", (("rs", 10, 8), ("rdp", 10, 8)))
def test_submit_fold_rows_equals_delta_then_pick(backend, scheme, n, k, rng):
    code = make_code(scheme, n, k)
    eng = make_engine(backend, code)
    oracle = make_engine("numpy", code)
    B, C = 5, 128
    idxs = rng.integers(0, code.k, B)
    xors = rng.integers(0, 256, (B, C), dtype=np.uint8)
    rows = rng.integers(0, code.m, B)
    parity_rows = rng.integers(0, 256, (B, C), dtype=np.uint8)
    want = parity_rows ^ oracle.delta_batch(idxs, xors)[np.arange(B), rows]
    got = eng.submit_fold_rows(idxs, xors, rows, parity_rows).result()
    assert np.array_equal(got, want), backend
    # empty batch: rows pass through untouched
    empty = eng.submit_fold_rows(np.zeros(0, int),
                                 np.zeros((0, C), np.uint8),
                                 np.zeros(0, int),
                                 np.zeros((0, C), np.uint8)).result()
    assert empty.shape == (0, C)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("scheme,n,k", (("rs", 10, 8), ("rdp", 10, 8)))
def test_submit_apply_delta_equals_delta_then_xor(backend, scheme, n, k, rng):
    code = make_code(scheme, n, k)
    eng = make_engine(backend, code)
    oracle = make_engine("numpy", code)
    B, C = 4, 128
    idxs = rng.integers(0, code.k, B)
    xors = rng.integers(0, 256, (B, C), dtype=np.uint8)
    parity = rng.integers(0, 256, (B, code.m, C), dtype=np.uint8)
    want = parity ^ oracle.delta_batch(idxs, xors)
    got = eng.submit_apply_delta(parity, idxs, xors).result()
    assert np.array_equal(got, want), backend


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_decode_matrix_equals_two_pass(backend, rng):
    """[inv ; G∘inv] applied once == decode matmul + re-encode pass."""
    code = make_code("rs", 10, 8)
    eng = make_engine(backend, code)
    C = 96
    data = rng.integers(0, 256, (code.k, C), dtype=np.uint8)
    parity = code.encode(data)
    stripe = np.concatenate([data, parity])
    erased = (0, 9)
    avail = {i: stripe[i] for i in range(code.n) if i not in erased}
    plan = eng.plan_decode([tuple(sorted(avail))], [list(erased)], C)
    (g,) = plan.groups
    M = eng._fused_decode_matrix(g)
    stacked = np.stack([avail[i] for i in g.use])
    fused = gf256.gf_matmul_np(M, stacked)
    inv_out = gf256.gf_matmul_np(g.inv, stacked)
    two_pass = np.concatenate(
        [inv_out, gf256.gf_matmul_np(g.par_rows, inv_out)])
    assert np.array_equal(fused, two_pass)
    # and end to end: the decoded positions match the original stripe
    out = eng.decode_batch([avail], [list(erased)], C)[0]
    for w in erased:
        assert np.array_equal(out[w], stripe[w]), (backend, w)


@pytest.mark.skipif(not CPU, reason="CPU dispatch surface")
def test_engine_describe_exposes_dispatch_path(monkeypatch):
    monkeypatch.delenv("MEMEC_INTERPRET", raising=False)
    code = make_code("rs", 10, 8)
    d = make_engine("pallas", code).describe()
    assert d["path"] == dispatch.XLA
    assert d["backend"] == "cpu"
    assert d["interpret_forced"] is False
    assert make_engine("numpy", code).describe()["path"] == "numpy-host"
    monkeypatch.setenv("MEMEC_INTERPRET", "1")
    assert make_engine("pallas", code).describe()["path"] == \
        dispatch.INTERPRET


def test_engine_stats_counts_device_dispatches(rng):
    code = make_code("rs", 10, 8)
    eng = make_engine("pallas", code)
    assert eng.stats()["device_dispatches"] == 0
    data = rng.integers(0, 256, (2, code.k, 64), dtype=np.uint8)
    eng.encode_batch(data)
    s = eng.stats()
    assert s["device_dispatches"] > 0
    assert s["path"] == eng.describe()["path"]


# ---------------------------------------------------------------------------
# tuning cache
# ---------------------------------------------------------------------------

def test_tune_cache_round_trip(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv("MEMEC_TUNE_CACHE", str(path))
    # a pointed-at-but-missing cache warns once and degrades to empty
    with pytest.warns(UserWarning, match="not found"):
        assert tune.load_cache(reload=True) == {}
    A = np.asarray(RSCode(n=6, k=4).parity_matrix, np.uint8)
    best = tune.autotune_matmul(A, chunk=64, batch=2, reps=1)
    assert best["strategy"]
    assert tune.save() == str(path)
    tune.load_cache(reload=True)
    ent = tune.lookup("matmul", dispatch.decide().path, k=4, m=2,
                      chunk=64, batch=2, cls=tune.matrix_cls(A))
    assert ent is not None and ent["strategy"] == best["strategy"]
    # the persisted JSON is the versioned {entries: ...} shape
    raw = json.loads(path.read_text())
    assert raw["version"] == 1 and raw["entries"]


@pytest.mark.parametrize("content", (None, "not json {", '{"entries": 3}',
                                     '["wrong shape"]'))
def test_corrupt_or_missing_cache_falls_back(tmp_path, monkeypatch, content,
                                             rng):
    path = tmp_path / "tune.json"
    if content is not None:
        path.write_text(content)
    monkeypatch.setenv("MEMEC_TUNE_CACHE", str(path))
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        cache = tune.load_cache(reload=True)
    assert cache == {}
    # dispatch still answers correctly with heuristics only
    A = np.asarray(RSCode(n=10, k=8).parity_matrix, np.uint8)
    data = rng.integers(0, 256, (2, 8, 100), dtype=np.uint8)
    want = np.stack([gf256.gf_matmul_np(A, d) for d in data])
    assert np.array_equal(np.asarray(gf256_matmul_batched(A, data)), want)


def test_malformed_entries_are_filtered(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    key = tune.key("matmul", dispatch.XLA, k=8, m=2, chunk=64, batch=1)
    path.write_text(json.dumps({"entries": {
        key: {"strategy": "bitplane32", "block_c": 0},
        "bad/one": {"block_c": 9},                      # no strategy
        "worse/one": "not a dict",
    }}))
    monkeypatch.setenv("MEMEC_TUNE_CACHE", str(path))
    cache = tune.load_cache(reload=True)
    assert list(cache) == [key]


def test_committed_defaults_parse():
    """The checked-in tune_defaults.json must always load cleanly."""
    raw = json.loads(open(tune.DEFAULTS_PATH).read())
    assert raw["entries"], "committed tune defaults are empty"
    for k, v in raw["entries"].items():
        assert "strategy" in v and "block_c" in v, k
