"""CodingEngine cross-backend properties: numpy / jax / pallas backends
must agree byte-for-byte with the numpy ``Code`` oracle for every scheme,
batch size, and (odd) chunk width."""
import numpy as np
import pytest

from repro.core.codes import make_code
from repro.core.engine import (ENGINES, JaxEngine, NumpyEngine, PallasEngine,
                               block_rep, make_engine)

# (scheme, n, k) x chunk widths.  RDP views chunks as (p-1)=16 sub-blocks,
# so its widths must be multiples of 16 (non-powers-of-two still exercise
# padding); the dense codes get genuinely odd widths.
SCHEMES = {
    ("rs", 10, 8): (37, 129),
    ("xor", 9, 8): (41, 160),
    ("rdp", 10, 8): (64, 208),
    ("none", 10, 10): (33,),
}
BATCHES = (1, 3, 16)
BACKENDS = ("numpy", "jax", "pallas")


def _cases():
    for (scheme, n, k), widths in SCHEMES.items():
        for C in widths:
            yield scheme, n, k, C


@pytest.fixture(scope="module")
def engines():
    cache = {}

    def get(scheme, n, k):
        if (scheme, n, k) not in cache:
            code = make_code(scheme, n, k)
            cache[(scheme, n, k)] = {b: make_engine(b, code)
                                     for b in BACKENDS}
        return cache[(scheme, n, k)]

    return get


@pytest.mark.parametrize("scheme,n,k,C", _cases())
@pytest.mark.parametrize("B", BATCHES)
def test_encode_batch_matches_oracle(scheme, n, k, C, B, engines, rng):
    code = make_code(scheme, n, k)
    data = rng.integers(0, 256, (B, code.k, C), dtype=np.uint8)
    want = np.stack([code.encode(d) for d in data])
    for backend, eng in engines(scheme, n, k).items():
        got = eng.encode_batch(data)
        assert got.shape == (B, code.m, C), backend
        assert np.array_equal(got, want), (backend, scheme, C, B)


@pytest.mark.parametrize("scheme,n,k,C", _cases())
@pytest.mark.parametrize("B", BATCHES)
def test_decode_batch_matches_oracle(scheme, n, k, C, B, engines, rng):
    code = make_code(scheme, n, k)
    if code.m == 0:
        pytest.skip("nothing to erase under NoCode")
    data = rng.integers(0, 256, (B, code.k, C), dtype=np.uint8)
    stripes = np.concatenate(
        [data, np.stack([code.encode(d) for d in data])], axis=1)
    avail, wanted = [], []
    for b in range(B):  # erasure patterns deliberately vary across items
        n_erase = int(rng.integers(1, code.m + 1))
        erased = set(rng.choice(code.n, size=n_erase, replace=False).tolist())
        avail.append({i: stripes[b, i] for i in range(code.n)
                      if i not in erased})
        wanted.append(sorted(erased))
    want = [code.decode(a, w, C) for a, w in zip(avail, wanted)]
    for backend, eng in engines(scheme, n, k).items():
        got = eng.decode_batch(avail, wanted, C)
        for b in range(B):
            for w in wanted[b]:
                assert np.array_equal(got[b][w], want[b][w]), \
                    (backend, scheme, C, B, b, w)


@pytest.mark.parametrize("scheme,n,k,C", _cases())
@pytest.mark.parametrize("B", BATCHES)
def test_apply_delta_batch_matches_oracle(scheme, n, k, C, B, engines, rng):
    code = make_code(scheme, n, k)
    data = rng.integers(0, 256, (B, code.k, C), dtype=np.uint8)
    parity = np.stack([code.encode(d) for d in data])
    idx = rng.integers(0, code.k, B)
    xors = np.zeros((B, C), np.uint8)
    for b in range(B):  # sparse spans, like real object updates
        span = int(rng.integers(1, C + 1))
        off = int(rng.integers(0, C - span + 1))
        xors[b, off: off + span] = rng.integers(0, 256, span, dtype=np.uint8)
    want_delta = np.stack([code.xor_delta(int(i), x)
                           for i, x in zip(idx, xors)])
    for backend, eng in engines(scheme, n, k).items():
        got = eng.delta_batch(idx, xors)
        assert np.array_equal(got, want_delta), (backend, scheme, C, B)
        applied = eng.apply_delta_batch(parity, idx, xors)
        assert np.array_equal(applied, parity ^ want_delta), \
            (backend, scheme, C, B)


@pytest.mark.parametrize("scheme,n,k,C", _cases())
def test_delta_equals_reencode_through_engine(scheme, n, k, C, engines, rng):
    """Linearity end-to-end: applying engine deltas == re-encoding."""
    code = make_code(scheme, n, k)
    if code.m == 0:
        pytest.skip("no parity under NoCode")
    B = 4
    data = rng.integers(0, 256, (B, code.k, C), dtype=np.uint8)
    idx = rng.integers(0, code.k, B)
    new = data.copy()
    for b in range(B):
        new[b, idx[b], : C // 2] ^= rng.integers(
            0, 256, C // 2, dtype=np.uint8)
    xors = np.stack([data[b, idx[b]] ^ new[b, idx[b]] for b in range(B)])
    for backend, eng in engines(scheme, n, k).items():
        parity = eng.encode_batch(data)
        updated = eng.apply_delta_batch(parity, idx, xors)
        assert np.array_equal(updated, eng.encode_batch(new)), backend


def test_block_rep_matches_rs_parity_matrix():
    code = make_code("rs", 10, 8)
    rep = block_rep(code)
    assert rep.r == 1
    assert np.array_equal(rep.encode, code.parity_matrix)


def test_decode_beyond_tolerance_raises(rng):
    code = make_code("rs", 10, 8)
    data = rng.integers(0, 256, (1, 8, 64), dtype=np.uint8)
    stripe = np.concatenate([data[0], code.encode(data[0])])
    avail = [{i: stripe[i] for i in range(7)}]  # 7 < k
    for backend in BACKENDS:
        with pytest.raises(ValueError):
            make_engine(backend, code).decode_batch(avail, [[8]], 64)


# ---------------------------------------------------------------------------
# full cross-backend parity grid: numpy / jax / pallas must be pairwise
# byte-identical for encode, decode, AND delta over every scheme at
# several chunk sizes and batch shapes — one parametrized matrix, the
# regression gate for any new backend or kernel change.
# ---------------------------------------------------------------------------
GRID_SCHEMES = {
    ("rs", 10, 8): (37, 64, 129),
    ("xor", 9, 8): (41, 96),
    ("rdp", 10, 8): (64, 160),
    ("none", 10, 10): (33, 64),
}
GRID_BATCHES = (1, 5)


def _grid_cases():
    for (scheme, n, k), widths in GRID_SCHEMES.items():
        for C in widths:
            for B in GRID_BATCHES:
                yield scheme, n, k, C, B


@pytest.mark.parametrize("scheme,n,k,C,B", _grid_cases())
def test_backends_pairwise_identical_grid(scheme, n, k, C, B, engines, rng):
    code = make_code(scheme, n, k)
    engs = engines(scheme, n, k)
    data = rng.integers(0, 256, (B, code.k, C), dtype=np.uint8)

    encoded = {b: e.encode_batch(data) for b, e in engs.items()}
    ref = encoded["numpy"]
    for b, got in encoded.items():
        assert got.dtype == np.uint8 and got.shape == (B, code.m, C), b
        assert np.array_equal(got, ref), ("encode", b, scheme, C, B)

    if code.m:
        idx = rng.integers(0, code.k, B)
        xors = rng.integers(0, 256, (B, C), dtype=np.uint8)
        deltas = {b: e.delta_batch(idx, xors) for b, e in engs.items()}
        applied = {b: e.apply_delta_batch(ref, idx, xors)
                   for b, e in engs.items()}
        for b in engs:
            assert np.array_equal(deltas[b], deltas["numpy"]), \
                ("delta", b, scheme, C, B)
            assert np.array_equal(applied[b], applied["numpy"]), \
                ("apply", b, scheme, C, B)

        stripes = np.concatenate([data, ref], axis=1)
        erased = sorted(rng.choice(code.n, size=code.m,
                                   replace=False).tolist())
        avail = [{i: stripes[b2, i] for i in range(code.n)
                  if i not in erased} for b2 in range(B)]
        wanted = [list(erased)] * B
        decoded = {b: e.decode_batch(avail, wanted, C)
                   for b, e in engs.items()}
        for b in engs:
            for b2 in range(B):
                for w in erased:
                    assert np.array_equal(decoded[b][b2][w],
                                          decoded["numpy"][b2][w]), \
                        ("decode", b, scheme, C, B, b2, w)


def test_make_engine_selection(monkeypatch):
    code = make_code("rs", 10, 8)
    assert isinstance(make_engine("numpy", code), NumpyEngine)
    assert isinstance(make_engine("jax", code), JaxEngine)
    assert isinstance(make_engine("pallas", code), PallasEngine)
    monkeypatch.setenv("MEMEC_ENGINE", "jax")
    assert isinstance(make_engine(None, code), JaxEngine)
    monkeypatch.delenv("MEMEC_ENGINE")
    assert isinstance(make_engine(None, code), NumpyEngine)
    # per-shard comma lists collapse to their first entry here
    assert isinstance(make_engine("jax,numpy", code), JaxEngine)
    with pytest.raises(ValueError):
        make_engine("isal", code)
    assert set(ENGINES) == {"numpy", "jax", "pallas"}
