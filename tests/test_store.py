"""MemEC cluster end-to-end behaviour: normal mode, seals, degraded mode,
transitions, consistency resolution, redundancy accounting."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import MemECCluster, PartialFailure, ServerState
from repro.core.chunk import ChunkId


def make_cluster(**kw):
    defaults = dict(num_servers=16, scheme="rs", n=10, k=8, c=16,
                    chunk_size=512, max_unsealed=2, verify_rebuild=True)
    defaults.update(kw)
    return MemECCluster(**defaults)


def load(cl, n, seed=0, vsizes=(8, 32)):
    rng = np.random.default_rng(seed)
    kv = {}
    for i in range(n):
        key = b"key%08d" % i
        val = bytes(rng.integers(0, 256, vsizes[i % len(vsizes)],
                                 dtype=np.uint8))
        cl.set(key, val, proxy_id=i % 4)
        kv[key] = val
    return kv, rng


def check_all(cl, kv):
    return sum(1 for k, v in kv.items() if cl.get(k) != v)


def parity_invariant(cl):
    """Every sealed data chunk must decode from the other stripe chunks."""
    bad = checked = 0
    cs = cl.chunk_size
    for s in cl.servers:
        for idx, cid in enumerate(s.chunk_ids):
            if cid is None or not s.sealed[idx] or cid.position >= cl.k:
                continue
            sl = cl.stripe_lists[cid.stripe_list_id]
            avail = {}
            for i in range(cl.n):
                if i == cid.position:
                    continue
                owner = sl.servers[i]
                c = cl.servers[owner].get_sealed_chunk(
                    ChunkId(cid.stripe_list_id, cid.stripe_id, i))
                avail[i] = c if c is not None else np.zeros(cs, np.uint8)
            rec = cl.code.decode(avail, [cid.position], cs)[cid.position]
            checked += 1
            bad += 0 if np.array_equal(rec, s.region[idx]) else 1
    return checked, bad


class TestNormalMode:
    def test_set_get_update_delete(self):
        cl = make_cluster()
        kv, rng = load(cl, 4000)
        assert check_all(cl, kv) == 0
        for k in list(kv)[::3]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            assert cl.update(k, nv)
            kv[k] = nv
        for k in list(kv)[::7]:
            assert cl.delete(k)
            del kv[k]
            assert cl.get(k) is None
        assert check_all(cl, kv) == 0
        checked, bad = parity_invariant(cl)
        assert checked > 0 and bad == 0

    def test_get_missing_returns_none(self):
        cl = make_cluster()
        assert cl.get(b"nothing") is None
        assert not cl.update(b"nothing", b"xx")
        assert not cl.delete(b"nothing")

    def test_upsert_same_key_never_duplicates(self):
        cl = make_cluster()
        cl.set(b"dup", b"AAAA")
        cl.set(b"dup", b"BBBB")           # same size -> update path
        assert cl.get(b"dup") == b"BBBB"
        cl.set(b"dup", b"C" * 10)         # different size -> delete+set
        assert cl.get(b"dup") == b"C" * 10
        _, bad = parity_invariant(cl)
        assert bad == 0

    def test_update_size_change_rejected(self):
        cl = make_cluster()
        cl.set(b"k", b"12345678")
        with pytest.raises(ValueError):
            cl.update(b"k", b"123")

    def test_large_objects(self):
        cl = make_cluster(chunk_size=512)
        big = bytes(range(256)) * 9       # 2304 bytes > chunk
        cl.set(b"bigkey", big)
        assert cl.get(b"bigkey") == big
        big2 = bytes(reversed(big))
        cl.update(b"bigkey", big2)
        assert cl.get(b"bigkey") == big2
        cl.delete(b"bigkey")
        assert cl.get(b"bigkey") is None

    def test_seal_message_carries_keys_only(self):
        cl = make_cluster()
        load(cl, 3000)
        seal_bytes = cl.net.bytes_by_kind.get("seal", 0)
        seals = sum(s.seals for s in cl.servers)
        assert seals > 0
        # keys are 11 bytes (+1 len +24 header): far below chunk size
        assert seal_bytes / seals < cl.chunk_size


class TestCodingSchemes:
    @pytest.mark.parametrize("scheme,n,k", [("rs", 10, 8), ("rdp", 10, 8),
                                            ("xor", 9, 8), ("none", 10, 10)])
    def test_scheme_end_to_end(self, scheme, n, k):
        cl = make_cluster(scheme=scheme, n=n, k=k)
        kv, rng = load(cl, 800)
        for key in list(kv)[::5]:
            nv = bytes(rng.integers(0, 256, len(kv[key]), dtype=np.uint8))
            cl.update(key, nv)
            kv[key] = nv
        assert check_all(cl, kv) == 0
        if scheme != "none":
            _, bad = parity_invariant(cl)
            assert bad == 0


class TestDegradedMode:
    def test_single_failure_cycle(self):
        cl = make_cluster()
        kv, rng = load(cl, 2500)
        t = cl.fail_server(3)
        assert t["T_N_to_D"] > 0
        assert cl.coordinator.state_of(3) == ServerState.DEGRADED
        assert check_all(cl, kv) == 0
        assert cl.stats["degraded_requests"] > 0
        # degraded mutations
        for k in list(kv)[:400]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            assert cl.update(k, nv)
            kv[k] = nv
        for i in range(100):
            key = b"newkey%05d" % i
            val = bytes(rng.integers(0, 256, 16, dtype=np.uint8))
            cl.set(key, val)
            kv[key] = val
        for k in list(kv)[::17][:40]:
            cl.delete(k)
            del kv[k]
        assert check_all(cl, kv) == 0
        t2 = cl.restore_server(3)
        assert t2["T_D_to_N"] > 0
        assert cl.coordinator.state_of(3) == ServerState.NORMAL
        assert check_all(cl, kv) == 0
        _, bad = parity_invariant(cl)
        assert bad == 0

    def test_double_failure_after_churn(self):
        cl = make_cluster()
        kv, rng = load(cl, 2000)
        cl.fail_server(2)
        for k in list(kv)[:300]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            cl.update(k, nv)
            kv[k] = nv
        cl.restore_server(2)
        for k in list(kv)[100:400]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            cl.update(k, nv)
            kv[k] = nv
        cl.fail_server(5)
        cl.fail_server(11)
        assert check_all(cl, kv) == 0
        for k in list(kv)[:200]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            assert cl.update(k, nv)
            kv[k] = nv
        assert check_all(cl, kv) == 0
        cl.restore_server(5)
        cl.restore_server(11)
        assert check_all(cl, kv) == 0
        _, bad = parity_invariant(cl)
        assert bad == 0

    def test_degraded_disabled_still_serves(self):
        cl = make_cluster(degraded_enabled=False)
        kv, _ = load(cl, 500)
        cl.fail_server(3)
        assert check_all(cl, kv) == 0       # slow (netem) but correct
        lat = cl.net.latencies["GET"]
        assert max(lat) > cl.net.cost.failed_delay_s

    def test_reconstruction_amortized_at_chunk_granularity(self):
        """Paper §5.4: later GETs to the same reconstructed chunk are free."""
        cl = make_cluster()
        kv, _ = load(cl, 2500)
        cl.fail_server(3)
        for k in kv:
            cl.get(k)
        assert cl.stats["recon_chunk_hits"] >= cl.stats["reconstructions"] * 0

        recons_after_one_pass = cl.stats["reconstructions"]
        for k in kv:
            cl.get(k)
        # second pass reconstructs nothing new
        assert cl.stats["reconstructions"] == recons_after_one_pass


class TestConsistencyResolution:
    def test_partial_update_revert_and_replay(self):
        """§5.3: a request interrupted mid-parity-fanout is reverted from
        the delta buffers and replayed as a degraded request."""
        cl = make_cluster()
        kv, rng = load(cl, 4000)
        # choose a key in a sealed chunk
        target = None
        for k in kv:
            sl, ds = cl.mapper.data_server_for(k)
            ref = cl.servers[ds].lookup(k)
            if ref is not None and cl.servers[ds].sealed[ref.chunk_local_idx]:
                target = (k, ds)
                break
        assert target is not None
        key, ds = target
        newval = bytes(rng.integers(0, 256, len(kv[key]), dtype=np.uint8))
        cl.crash_hook = ("update", key, 1)   # crash after 1 of 2 parity legs
        with pytest.raises(PartialFailure):
            cl.update(key, newval)
        # proxy still holds the request; now the data server fails
        assert any(p.pending for p in cl.proxies)
        cl.fail_server(ds)
        assert cl.stats["reverted_deltas"] >= 1
        # replayed as degraded update: new value visible
        assert cl.get(key) == newval
        kv[key] = newval
        cl.restore_server(ds)
        assert cl.get(key) == newval
        _, bad = parity_invariant(cl)
        assert bad == 0


class TestRedundancyAccounting:
    def test_measured_redundancy_tracks_formula(self):
        """Loaded-store byte accounting approaches the §3.3 analysis."""
        from repro.core.analysis import AnalysisParams, redundancy_all_encoding
        cl = make_cluster(chunk_size=4096, max_unsealed=1, c=16)
        K, V = 24, 32
        n_obj = 12000
        rng = np.random.default_rng(0)
        for i in range(n_obj):
            cl.set(b"%023d!" % i, bytes(rng.integers(0, 256, V,
                                                     dtype=np.uint8)))
        sealed = sum(1 for s in cl.servers for i, c in enumerate(s.chunk_ids)
                     if c is not None and s.sealed[i] and c.position < cl.k)
        assert sealed > 50
        # count chunk bytes of sealed data + their parity (m/k ratio)
        payload = n_obj * (K + V + 4)
        chunk_bytes = sum(len(s.region) * cl.chunk_size for s in cl.servers)
        measured = chunk_bytes / payload
        formula = redundancy_all_encoding(
            AnalysisParams(K=K, V=V, n=10, k=8))
        # unsealed slack + index overhead keep measured within ~40%
        assert measured == pytest.approx(formula, rel=0.4)


class TestStateTransitions:
    def test_transition_timings_shape(self):
        """Exp 5 shape: T_N->D with pending requests > without; both < 1s."""
        cl = make_cluster()
        kv, rng = load(cl, 1500)
        t_idle = cl.fail_server(3)["T_N_to_D"]
        cl.restore_server(3)
        # leave an unacknowledged request hanging, then fail
        key = next(iter(kv))
        cl.crash_hook = ("update", key, 1)
        try:
            cl.update(key, bytes(rng.integers(0, 256, len(kv[key]),
                                              dtype=np.uint8)))
        except PartialFailure:
            pass
        sl, ds = cl.mapper.data_server_for(key)
        t_busy = cl.fail_server(ds)["T_N_to_D"]
        assert t_idle < 1.0 and t_busy < 1.0
        assert t_busy >= t_idle * 0.5  # busy path includes revert work


class TestReSetInstanceHardening:
    """Delete-then-re-SET churn (heavy under shard migration, but
    reachable with plain requests): the superseded instance's tombstone
    may still sit in an unsealed chunk when the key is re-added, so
    parity replicas and recovery mappings must be matched by *instance*,
    not by key alone."""

    def _churn_reset(self, cl, kv, rng, frac=3):
        """Delete then immediately re-SET every frac-th key."""
        for i, key in enumerate(list(kv)):
            if i % frac:
                continue
            assert cl.delete(key)
            nv = bytes(rng.integers(0, 256, len(kv[key]), dtype=np.uint8))
            assert cl.set(key, nv)
            kv[key] = nv

    def test_zombie_seal_uses_tombstoned_replica(self):
        """Sealing a chunk that holds a superseded tombstone must consume
        that instance's frozen replica (verify_rebuild cross-checks the
        rebuilt bytes), leaving the live instance's replica intact."""
        cl = make_cluster(chunk_size=256)
        kv, rng = load(cl, 60)
        self._churn_reset(cl, kv, rng)
        # force every chunk to seal by appending filler traffic
        filler, _ = load(cl, 400, seed=7)
        kv.update(filler)
        assert check_all(cl, kv) == 0
        _, bad = parity_invariant(cl)
        assert bad == 0
        # updating/deleting re-set keys still finds their live replicas
        for i, key in enumerate(list(kv)[:30]):
            nv = bytes(rng.integers(0, 256, len(kv[key]), dtype=np.uint8))
            assert cl.update(key, nv)
            kv[key] = nv
        assert check_all(cl, kv) == 0

    def test_degraded_reads_resolve_newest_instance(self):
        """Multiple proxies buffer mappings for different instances of a
        re-SET key; the failure-time merge must resolve the newest one,
        whatever order the proxies push in."""
        cl = make_cluster(chunk_size=256)
        kv, rng = load(cl, 300)
        # rotate proxies so old/new instances land in different buffers
        for i, key in enumerate(list(kv)[:80]):
            assert cl.delete(key, proxy_id=i % 4)
            nv = bytes(rng.integers(0, 256, len(kv[key]), dtype=np.uint8))
            assert cl.set(key, nv, proxy_id=(i + 1) % 4)
            kv[key] = nv
        for sid in (2, 9):
            cl.fail_server(sid)
            assert check_all(cl, kv) == 0, \
                f"stale instance served after fail({sid})"
            cl.restore_server(sid)
        assert check_all(cl, kv) == 0

    def test_restore_keeps_reset_keys(self):
        """A pre-failure tombstone in a dirty reconstructed chunk must not
        evict the re-SET instance's index entry at restore time."""
        cl = make_cluster(chunk_size=256)
        kv, rng = load(cl, 300)
        self._churn_reset(cl, kv, rng, frac=4)
        sl, ds = cl.mapper.data_server_for(next(iter(kv)))
        cl.fail_server(ds)
        # degraded churn dirties reconstructed chunks
        for key in list(kv)[:40]:
            nv = bytes(rng.integers(0, 256, len(kv[key]), dtype=np.uint8))
            assert cl.update(key, nv)
            kv[key] = nv
        assert check_all(cl, kv) == 0
        cl.restore_server(ds)
        assert check_all(cl, kv) == 0
        _, bad = parity_invariant(cl)
        assert bad == 0

    def test_shadowed_delete_survives_parity_outage_seal(self):
        """Delete (and delete/re-SET) of unsealed objects while a parity
        server is down: the shadow must preserve the tombstone's value
        extent and its instance, so chunks sealing after the restore
        rebuild byte-identically (verify_rebuild asserts it)."""
        cl = make_cluster(chunk_size=256)
        kv, rng = load(cl, 120)
        # pick a parity server of some unsealed object and fail it
        key0 = next(iter(kv))
        sl, ds = cl.mapper.data_server_for(key0)
        parity = sl.parity_servers[0]
        cl.fail_server(parity)
        dropped, reset = [], []
        for i, key in enumerate(list(kv)):
            sl2, _ = cl.mapper.data_server_for(key)
            if parity not in sl2.parity_servers:
                continue
            if i % 2:
                assert cl.delete(key)      # shadowed tombstone
                kv[key] = None
                dropped.append(key)
            else:                           # delete + re-SET: new instance
                assert cl.delete(key)
                nv = bytes(rng.integers(0, 256, 8, dtype=np.uint8))
                assert cl.set(key, nv)
                kv[key] = nv
                reset.append(key)
        assert dropped and reset
        cl.restore_server(parity)
        # filler traffic forces every touched chunk to seal + rebuild
        filler, _ = load(cl, 500, seed=11)
        kv.update(filler)
        assert sum(1 for k, v in kv.items() if cl.get(k) != v) == 0
        _, bad = parity_invariant(cl)
        assert bad == 0


class TestLargeObjectUpsert:
    def test_small_over_large_removes_fragments(self):
        """SET of a small value over an existing large object must tear
        the old fragments down, not just overwrite the manifest head."""
        cl = make_cluster(chunk_size=256)
        key = b"biggie"
        rng = np.random.default_rng(1)
        big = bytes(rng.integers(0, 256, 900, dtype=np.uint8))
        assert cl.set(key, big)
        assert cl.get(key) == big
        frag_keys = [k for s in cl.servers for k in s.object_index.keys()
                     if k.startswith(key) and k != key]
        assert frag_keys   # fragments exist
        small = b"tiny"
        assert cl.set(key, small)
        assert cl.get(key) == small
        for fk in frag_keys:   # no orphaned fragment survives
            assert all(s.lookup(fk) is None for s in cl.servers)

    def test_large_over_large_shrink(self):
        """Re-SET of a large object with fewer fragments must not leave
        stale tail fragments that a later read or migration could see."""
        cl = make_cluster(chunk_size=256)
        key = b"shrinker"
        rng = np.random.default_rng(2)
        big = bytes(rng.integers(0, 256, 1200, dtype=np.uint8))
        smaller = bytes(rng.integers(0, 256, 400, dtype=np.uint8))
        assert cl.set(key, big)
        assert cl.set(key, smaller)
        assert cl.get(key) == smaller
        live_frags = [k for s in cl.servers for k in s.object_index.keys()
                      if k.startswith(key) and k != key]
        from repro.core.chunk import fragment_count
        assert len(live_frags) == fragment_count(len(smaller), len(key),
                                                 cl.chunk_size)

    def test_small_over_large_during_data_server_outage(self):
        """Upsert teardown must resolve the manifest through the degraded
        view: a large object SET while its data server is down lives in
        the redirect store, not the frozen server memory."""
        cl = make_cluster(chunk_size=256)
        kv, rng = load(cl, 60)
        key = b"deg-big"
        sl, ds = cl.mapper.data_server_for(key)
        cl.fail_server(ds)
        big = bytes(rng.integers(0, 256, 700, dtype=np.uint8))
        assert cl.set(key, big)            # degraded large SET
        assert cl.get(key) == big
        assert cl.set(key, b"tiny")        # upsert over it, still degraded
        assert cl.get(key) == b"tiny"
        cl.restore_server(ds)
        assert cl.get(key) == b"tiny"
        # no orphaned fragment keys survive anywhere
        for s in cl.servers:
            assert not [k for k in s.object_index.keys()
                        if k.startswith(key) and k != key]
        assert sum(1 for k, v in kv.items() if cl.get(k) != v) == 0
