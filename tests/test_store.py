"""MemEC cluster end-to-end behaviour: normal mode, seals, degraded mode,
transitions, consistency resolution, redundancy accounting."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import MemECCluster, PartialFailure, ServerState
from repro.core.chunk import ChunkId


def make_cluster(**kw):
    defaults = dict(num_servers=16, scheme="rs", n=10, k=8, c=16,
                    chunk_size=512, max_unsealed=2, verify_rebuild=True)
    defaults.update(kw)
    return MemECCluster(**defaults)


def load(cl, n, seed=0, vsizes=(8, 32)):
    rng = np.random.default_rng(seed)
    kv = {}
    for i in range(n):
        key = b"key%08d" % i
        val = bytes(rng.integers(0, 256, vsizes[i % len(vsizes)],
                                 dtype=np.uint8))
        cl.set(key, val, proxy_id=i % 4)
        kv[key] = val
    return kv, rng


def check_all(cl, kv):
    return sum(1 for k, v in kv.items() if cl.get(k) != v)


def parity_invariant(cl):
    """Every sealed data chunk must decode from the other stripe chunks."""
    bad = checked = 0
    cs = cl.chunk_size
    for s in cl.servers:
        for idx, cid in enumerate(s.chunk_ids):
            if cid is None or not s.sealed[idx] or cid.position >= cl.k:
                continue
            sl = cl.stripe_lists[cid.stripe_list_id]
            avail = {}
            for i in range(cl.n):
                if i == cid.position:
                    continue
                owner = sl.servers[i]
                c = cl.servers[owner].get_sealed_chunk(
                    ChunkId(cid.stripe_list_id, cid.stripe_id, i))
                avail[i] = c if c is not None else np.zeros(cs, np.uint8)
            rec = cl.code.decode(avail, [cid.position], cs)[cid.position]
            checked += 1
            bad += 0 if np.array_equal(rec, s.region[idx]) else 1
    return checked, bad


class TestNormalMode:
    def test_set_get_update_delete(self):
        cl = make_cluster()
        kv, rng = load(cl, 4000)
        assert check_all(cl, kv) == 0
        for k in list(kv)[::3]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            assert cl.update(k, nv)
            kv[k] = nv
        for k in list(kv)[::7]:
            assert cl.delete(k)
            del kv[k]
            assert cl.get(k) is None
        assert check_all(cl, kv) == 0
        checked, bad = parity_invariant(cl)
        assert checked > 0 and bad == 0

    def test_get_missing_returns_none(self):
        cl = make_cluster()
        assert cl.get(b"nothing") is None
        assert not cl.update(b"nothing", b"xx")
        assert not cl.delete(b"nothing")

    def test_upsert_same_key_never_duplicates(self):
        cl = make_cluster()
        cl.set(b"dup", b"AAAA")
        cl.set(b"dup", b"BBBB")           # same size -> update path
        assert cl.get(b"dup") == b"BBBB"
        cl.set(b"dup", b"C" * 10)         # different size -> delete+set
        assert cl.get(b"dup") == b"C" * 10
        _, bad = parity_invariant(cl)
        assert bad == 0

    def test_update_size_change_rejected(self):
        cl = make_cluster()
        cl.set(b"k", b"12345678")
        with pytest.raises(ValueError):
            cl.update(b"k", b"123")

    def test_large_objects(self):
        cl = make_cluster(chunk_size=512)
        big = bytes(range(256)) * 9       # 2304 bytes > chunk
        cl.set(b"bigkey", big)
        assert cl.get(b"bigkey") == big
        big2 = bytes(reversed(big))
        cl.update(b"bigkey", big2)
        assert cl.get(b"bigkey") == big2
        cl.delete(b"bigkey")
        assert cl.get(b"bigkey") is None

    def test_seal_message_carries_keys_only(self):
        cl = make_cluster()
        load(cl, 3000)
        seal_bytes = cl.net.bytes_by_kind.get("seal", 0)
        seals = sum(s.seals for s in cl.servers)
        assert seals > 0
        # keys are 11 bytes (+1 len +24 header): far below chunk size
        assert seal_bytes / seals < cl.chunk_size


class TestCodingSchemes:
    @pytest.mark.parametrize("scheme,n,k", [("rs", 10, 8), ("rdp", 10, 8),
                                            ("xor", 9, 8), ("none", 10, 10)])
    def test_scheme_end_to_end(self, scheme, n, k):
        cl = make_cluster(scheme=scheme, n=n, k=k)
        kv, rng = load(cl, 800)
        for key in list(kv)[::5]:
            nv = bytes(rng.integers(0, 256, len(kv[key]), dtype=np.uint8))
            cl.update(key, nv)
            kv[key] = nv
        assert check_all(cl, kv) == 0
        if scheme != "none":
            _, bad = parity_invariant(cl)
            assert bad == 0


class TestDegradedMode:
    def test_single_failure_cycle(self):
        cl = make_cluster()
        kv, rng = load(cl, 2500)
        t = cl.fail_server(3)
        assert t["T_N_to_D"] > 0
        assert cl.coordinator.state_of(3) == ServerState.DEGRADED
        assert check_all(cl, kv) == 0
        assert cl.stats["degraded_requests"] > 0
        # degraded mutations
        for k in list(kv)[:400]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            assert cl.update(k, nv)
            kv[k] = nv
        for i in range(100):
            key = b"newkey%05d" % i
            val = bytes(rng.integers(0, 256, 16, dtype=np.uint8))
            cl.set(key, val)
            kv[key] = val
        for k in list(kv)[::17][:40]:
            cl.delete(k)
            del kv[k]
        assert check_all(cl, kv) == 0
        t2 = cl.restore_server(3)
        assert t2["T_D_to_N"] > 0
        assert cl.coordinator.state_of(3) == ServerState.NORMAL
        assert check_all(cl, kv) == 0
        _, bad = parity_invariant(cl)
        assert bad == 0

    def test_double_failure_after_churn(self):
        cl = make_cluster()
        kv, rng = load(cl, 2000)
        cl.fail_server(2)
        for k in list(kv)[:300]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            cl.update(k, nv)
            kv[k] = nv
        cl.restore_server(2)
        for k in list(kv)[100:400]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            cl.update(k, nv)
            kv[k] = nv
        cl.fail_server(5)
        cl.fail_server(11)
        assert check_all(cl, kv) == 0
        for k in list(kv)[:200]:
            nv = bytes(rng.integers(0, 256, len(kv[k]), dtype=np.uint8))
            assert cl.update(k, nv)
            kv[k] = nv
        assert check_all(cl, kv) == 0
        cl.restore_server(5)
        cl.restore_server(11)
        assert check_all(cl, kv) == 0
        _, bad = parity_invariant(cl)
        assert bad == 0

    def test_degraded_disabled_still_serves(self):
        cl = make_cluster(degraded_enabled=False)
        kv, _ = load(cl, 500)
        cl.fail_server(3)
        assert check_all(cl, kv) == 0       # slow (netem) but correct
        lat = cl.net.latencies["GET"]
        assert max(lat) > cl.net.cost.failed_delay_s

    def test_reconstruction_amortized_at_chunk_granularity(self):
        """Paper §5.4: later GETs to the same reconstructed chunk are free."""
        cl = make_cluster()
        kv, _ = load(cl, 2500)
        cl.fail_server(3)
        for k in kv:
            cl.get(k)
        assert cl.stats["recon_chunk_hits"] >= cl.stats["reconstructions"] * 0

        recons_after_one_pass = cl.stats["reconstructions"]
        for k in kv:
            cl.get(k)
        # second pass reconstructs nothing new
        assert cl.stats["reconstructions"] == recons_after_one_pass


class TestConsistencyResolution:
    def test_partial_update_revert_and_replay(self):
        """§5.3: a request interrupted mid-parity-fanout is reverted from
        the delta buffers and replayed as a degraded request."""
        cl = make_cluster()
        kv, rng = load(cl, 4000)
        # choose a key in a sealed chunk
        target = None
        for k in kv:
            sl, ds = cl.mapper.data_server_for(k)
            ref = cl.servers[ds].lookup(k)
            if ref is not None and cl.servers[ds].sealed[ref.chunk_local_idx]:
                target = (k, ds)
                break
        assert target is not None
        key, ds = target
        newval = bytes(rng.integers(0, 256, len(kv[key]), dtype=np.uint8))
        cl.crash_hook = ("update", key, 1)   # crash after 1 of 2 parity legs
        with pytest.raises(PartialFailure):
            cl.update(key, newval)
        # proxy still holds the request; now the data server fails
        assert any(p.pending for p in cl.proxies)
        cl.fail_server(ds)
        assert cl.stats["reverted_deltas"] >= 1
        # replayed as degraded update: new value visible
        assert cl.get(key) == newval
        kv[key] = newval
        cl.restore_server(ds)
        assert cl.get(key) == newval
        _, bad = parity_invariant(cl)
        assert bad == 0


class TestRedundancyAccounting:
    def test_measured_redundancy_tracks_formula(self):
        """Loaded-store byte accounting approaches the §3.3 analysis."""
        from repro.core.analysis import AnalysisParams, redundancy_all_encoding
        cl = make_cluster(chunk_size=4096, max_unsealed=1, c=16)
        K, V = 24, 32
        n_obj = 12000
        rng = np.random.default_rng(0)
        for i in range(n_obj):
            cl.set(b"%023d!" % i, bytes(rng.integers(0, 256, V,
                                                     dtype=np.uint8)))
        sealed = sum(1 for s in cl.servers for i, c in enumerate(s.chunk_ids)
                     if c is not None and s.sealed[i] and c.position < cl.k)
        assert sealed > 50
        # count chunk bytes of sealed data + their parity (m/k ratio)
        payload = n_obj * (K + V + 4)
        chunk_bytes = sum(len(s.region) * cl.chunk_size for s in cl.servers)
        measured = chunk_bytes / payload
        formula = redundancy_all_encoding(
            AnalysisParams(K=K, V=V, n=10, k=8))
        # unsealed slack + index overhead keep measured within ~40%
        assert measured == pytest.approx(formula, rel=0.4)


class TestStateTransitions:
    def test_transition_timings_shape(self):
        """Exp 5 shape: T_N->D with pending requests > without; both < 1s."""
        cl = make_cluster()
        kv, rng = load(cl, 1500)
        t_idle = cl.fail_server(3)["T_N_to_D"]
        cl.restore_server(3)
        # leave an unacknowledged request hanging, then fail
        key = next(iter(kv))
        cl.crash_hook = ("update", key, 1)
        try:
            cl.update(key, bytes(rng.integers(0, 256, len(kv[key]),
                                              dtype=np.uint8)))
        except PartialFailure:
            pass
        sl, ds = cl.mapper.data_server_for(key)
        t_busy = cl.fail_server(ds)["T_N_to_D"]
        assert t_idle < 1.0 and t_busy < 1.0
        assert t_busy >= t_idle * 0.5  # busy path includes revert work
