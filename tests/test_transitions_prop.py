"""Property-based graceful-transition tests (paper §5).

Random interleavings of ``fail_server``/``restore_server`` with
set/get/update traffic — across shards, with up to m concurrent failures
per shard — must never lose an acknowledged write, and once every server
is restored all reads must converge back to decentralized normal-mode
handling.  Plus targeted regressions for the transition hardening the
interleavings exposed: redirect-target handoff on cascading failures,
sticky degraded routing, degraded upserts, and shadow-replica migration
under double parity failure.
"""
import zlib

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import MemECCluster, ServerState, ShardedCluster

# rs(4,2): m = 2 concurrent failures tolerated per shard
KW = dict(num_servers=8, num_proxies=2, scheme="rs", n=4, k=2, c=6,
          chunk_size=256, max_unsealed=2, mapping_ckpt_every=16)
M = 2
KEYSPACE = [b"pk%05d" % i for i in range(48)]


def value_for(key: bytes, version: int) -> bytes:
    """Deterministic value; size fixed per key (paper §4.2 fixed-size
    updates), content varies with the version."""
    size = 8 if key[-1] % 2 else 24
    # crc32, not hash(): stable across interpreters so failing examples
    # replay with identical bytes regardless of PYTHONHASHSEED
    rng = np.random.default_rng(zlib.crc32(key + b"|%d" % version))
    return bytes(rng.integers(0, 256, size, dtype=np.uint8))


class Driver:
    """Applies a drawn op sequence to a cluster while tracking the model
    state (acked writes) and per-shard failure sets."""

    def __init__(self, num_shards: int):
        self.cl = ShardedCluster(shards=num_shards, **KW)
        self.num_shards = num_shards
        self.model: dict[bytes, bytes] = {}
        self.failed: dict[int, set[int]] = {s: set()
                                            for s in range(num_shards)}
        self.version = 0

    def step(self, data):
        op = data.draw(st.sampled_from(
            ("set", "set", "update", "update", "get", "get",
             "fail", "restore")), label="op")
        if op == "set":
            key = data.draw(st.sampled_from(KEYSPACE), label="key")
            self.version += 1
            val = value_for(key, self.version)
            assert self.cl.set(key, val) is True  # acked
            self.model[key] = val
        elif op == "update":
            if not self.model:
                return
            key = data.draw(st.sampled_from(sorted(self.model)),
                            label="ukey")
            self.version += 1
            val = value_for(key, self.version)
            assert self.cl.update(key, val) is True  # acked
            self.model[key] = val
        elif op == "get":
            key = data.draw(st.sampled_from(KEYSPACE), label="gkey")
            assert self.cl.get(key) == self.model.get(key)
        elif op == "fail":
            shard = data.draw(st.integers(0, self.num_shards - 1),
                              label="fshard")
            live = [s for s in range(self.cl.servers_per_shard)
                    if s not in self.failed[shard]]
            if len(self.failed[shard]) >= M or not live:
                return
            sid = data.draw(st.sampled_from(live), label="fsid")
            self.cl.fail_server(sid, shard=shard)
            self.failed[shard].add(sid)
        elif op == "restore":
            down = [(sh, s) for sh, ss in self.failed.items() for s in ss]
            if not down:
                return
            shard, sid = data.draw(st.sampled_from(down), label="rsid")
            self.cl.restore_server(sid, shard=shard)
            self.failed[shard].discard(sid)

    def finish(self):
        """Restore everything, then check convergence + no lost writes."""
        for shard, ss in self.failed.items():
            for sid in sorted(ss):
                self.cl.restore_server(sid, shard=shard)
            ss.clear()
        for sh in self.cl.shards:
            for s in range(self.cl.servers_per_shard):
                assert sh.coordinator.state_of(s) == ServerState.NORMAL
        degraded_before = self.cl.stats["degraded_requests"]
        for key in KEYSPACE:
            assert self.cl.get(key) == self.model.get(key), key
        # normal-mode convergence: the verification sweep must not have
        # needed a single coordinated (degraded) request
        assert self.cl.stats["degraded_requests"] == degraded_before


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_random_interleavings_never_lose_acked_writes_sharded(data):
    d = Driver(num_shards=2)
    for _ in range(50):
        d.step(data)
    d.finish()


@settings(max_examples=6, deadline=None)
@given(st.data())
def test_random_interleavings_never_lose_acked_writes_unsharded(data):
    d = Driver(num_shards=1)
    for _ in range(40):
        d.step(data)
    d.finish()


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(st.data())
def test_random_interleavings_long_sharded(data):
    """Longer soak variant (scripts/verify.sh --slow)."""
    d = Driver(num_shards=3)
    for _ in range(90):
        d.step(data)
    d.finish()


# ---------------------------------------------------------------------------
# targeted hardening regressions (single unsharded cluster = one shard)
# ---------------------------------------------------------------------------

def make_store(**kw):
    merged = dict(KW)
    merged.update(kw)
    return MemECCluster(**merged)


def load_some(cl, n=300, seed=0, prefix=b"hk"):
    rng = np.random.default_rng(seed)
    kv = {}
    for i in range(n):
        k = prefix + b"%05d" % i
        v = bytes(rng.integers(0, 256, 8 if i % 2 else 24, dtype=np.uint8))
        assert cl.set(k, v)
        kv[k] = v
    return kv, rng


class TestRedirectHandoff:
    def _key_on(self, cl, sid):
        for i in range(10 ** 4):
            k = b"nk%05d" % i
            if cl.mapper.data_server_for(k)[1] == sid and \
                    cl.servers[sid].lookup(k) is None:
                return k
        raise AssertionError("no key found")

    def test_degraded_set_survives_redirect_target_failure(self):
        """fail(A) -> degraded SET lands at A's redirect target -> the
        target itself fails: the acked write must be handed off, not
        stranded (the cascading-failure interleaving)."""
        cl = make_store()
        load_some(cl)
        ds = 0
        cl.fail_server(ds)
        key = self._key_on(cl, ds)
        sl, _ = cl.mapper.data_server_for(key)
        assert cl.set(key, b"degraded!") is True          # acked
        r = cl.coordinator.redirected_server(sl, ds)
        assert key in cl.redirect[r].temp_objects
        cl.fail_server(r)                                  # cascade
        assert cl.stats["redirect_handoffs"] > 0
        assert cl.get(key) == b"degraded!"                 # still served
        cl.restore_server(ds)
        cl.restore_server(r)
        assert cl.get(key) == b"degraded!"                 # migrated back

    def test_recon_chunks_hand_off_with_updates(self):
        """Dirty reconstructed chunks (degraded updates applied) follow
        the redirect reassignment when their host fails."""
        cl = make_store(max_unsealed=1)
        kv, rng = load_some(cl, 400, seed=1)
        ds = 1
        sealed_key = next(
            k for k in kv
            if cl.mapper.data_server_for(k)[1] == ds
            and cl.servers[ds].sealed[cl.servers[ds].lookup(k).chunk_local_idx])
        sl, _ = cl.mapper.data_server_for(sealed_key)
        cl.fail_server(ds)
        newval = bytes(rng.integers(0, 256, len(kv[sealed_key]),
                                    dtype=np.uint8))
        assert cl.update(sealed_key, newval) is True
        r = cl.coordinator.redirected_server(sl, ds)
        cl.fail_server(r)
        assert cl.get(sealed_key) == newval
        cl.restore_server(ds)
        cl.restore_server(r)
        assert cl.get(sealed_key) == newval
        for k, v in kv.items():
            if k != sealed_key:
                assert cl.get(k) == v


class TestStickyRedirect:
    def test_restore_of_bystander_does_not_move_redirect(self):
        """Restoring an unrelated server must not re-rank the redirect
        choice for a still-failed server (state would be stranded)."""
        cl = make_store()
        load_some(cl)
        a, b = 0, 1
        cl.fail_server(b)      # b down first
        cl.fail_server(a)      # a's redirect choice now avoids b
        key = None
        for i in range(10 ** 4):
            k = b"sr%05d" % i
            if cl.mapper.data_server_for(k)[1] == a:
                key = k
                break
        sl, _ = cl.mapper.data_server_for(key)
        assert cl.set(key, b"sticky") is True
        r_before = cl.coordinator.redirected_server(sl, a)
        cl.restore_server(b)   # bystander comes back
        assert cl.coordinator.redirected_server(sl, a) == r_before
        assert cl.get(key) == b"sticky"
        cl.restore_server(a)
        assert cl.get(key) == b"sticky"
        assert not cl.coordinator.redirect_assignments


class TestDegradedUpsert:
    def test_set_existing_key_with_failed_parity_is_upsert(self):
        """SET of an existing key while a parity server is down must not
        leave the key in two chunk slots (parity-rebuild corruption)."""
        cl = make_store(verify_rebuild=True)
        kv, _ = load_some(cl, 200, seed=2)
        key = next(iter(kv))
        sl, ds = cl.mapper.data_server_for(key)
        p = sl.parity_servers[0]
        cl.fail_server(p)
        newval = bytes(len(kv[key]))
        assert cl.set(key, newval) is True     # upsert, degraded
        assert cl.get(key) == newval
        cl.restore_server(p)
        assert cl.get(key) == newval
        # force remaining chunks sealed via fresh traffic; rebuild checks
        # (verify_rebuild) assert parity equality throughout
        load_some(cl, 150, seed=3, prefix=b"up")
        assert cl.get(key) == newval


class TestDoubleParityFailure:
    def test_shadow_replicas_reach_both_restored_parities(self):
        """With both parity servers of a list down, one shadow replica
        entry must migrate to each on restore — else the later seal
        rebuild finds a missing replica."""
        cl = make_store()
        kv, rng = load_some(cl, 120, seed=4)
        # find an unsealed object => its replica lives on parity servers
        key = next(k for k in kv
                   if not cl.servers[cl.mapper.data_server_for(k)[1]].sealed[
                       cl.servers[cl.mapper.data_server_for(k)[1]]
                       .lookup(k).chunk_local_idx])
        sl, ds = cl.mapper.data_server_for(key)
        p1, p2 = sl.parity_servers
        cl.fail_server(p1)
        cl.fail_server(p2)
        newval = bytes(rng.integers(0, 256, len(kv[key]), dtype=np.uint8))
        assert cl.update(key, newval) is True   # shadow at redirect target
        cl.restore_server(p1)
        cl.restore_server(p2)
        assert cl.servers[p1].get_replica(key) is not None
        assert cl.servers[p2].get_replica(key) is not None
        assert cl.servers[p1].get_replica(key)[0] == newval
        assert cl.servers[p2].get_replica(key)[0] == newval
        assert cl.get(key) == newval
        # now seal the chunk: rebuild must find every replica
        load_some(cl, 200, seed=5, prefix=b"xs")
        assert cl.get(key) == newval
