"""Plan/execute decode (PR 5).

``submit_decode`` is split into a ``DecodePlan`` built from host
metadata (erasure-pattern group-by, bounded-LRU cached inversions,
output scatter map) and an execute stage issuing one batched device
matmul per pattern group — so the jax/pallas backends dispatch decode
on-device at submit time, like encode/delta.  These tests pin down:

* cross-backend equivalence (numpy oracle vs jax vs pallas) for RS and
  RDP, single and double erasures, MIXED patterns in one batch —
  property-driven;
* dispatch-at-submit on the device backends, probed via the engines'
  ``device_dispatches`` counter (numpy stays lazy);
* the bounded decode-inverse LRU (``inv_cache_size`` /
  ``$MEMEC_INV_CACHE``) — rolling failures across many patterns must
  not grow it without limit;
* the modeled engine queue (``CostModel.engine_depth`` /
  ``stats["engine_queue_wait_s"]``): finite depth bounds hiding, the
  default infinite depth preserves every modeled latency.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import CostModel, make_cluster
from repro.core.codes import RDPCode, make_code
from repro.core.engine import DecodePlan, NumpyEngine, make_engine
from repro.data.ycsb import YCSBConfig, YCSBWorkload, run_workload

# (scheme, n, k, chunk sizes) — RDP widths must divide by r = p-1 = 16
CASES = {
    "rs": ("rs", 10, 8, (64, 129)),
    "rdp": ("rdp", 10, 8, (64, 208)),
}
BACKENDS = ("numpy", "jax", "pallas")


def _stripes(code, B, C, rng):
    data = rng.integers(0, 256, (B, code.k, C), dtype=np.uint8)
    parity = np.stack([code.encode(d) for d in data])
    return np.concatenate([data, parity], axis=1)


def _erasure_batch(code, stripes, patterns):
    """Per-item availability/wanted from a list of erased-position sets."""
    avail, wanted = [], []
    for b, erased in enumerate(patterns):
        avail.append({i: stripes[b, i] for i in range(code.n)
                      if i not in erased})
        wanted.append(sorted(erased))
    return avail, wanted


# ---------------------------------------------------------------------------
# cross-backend equivalence (property-driven, mixed patterns per batch)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.data())
def test_decode_plan_cross_backend_equivalence(data):
    scheme, n, k, widths = data.draw(st.sampled_from(list(CASES.values())),
                                     label="case")
    C = data.draw(st.sampled_from(widths), label="C")
    B = data.draw(st.integers(min_value=1, max_value=6), label="B")
    seed = data.draw(st.integers(min_value=0, max_value=2**16), label="seed")
    code = make_code(scheme, n, k)
    rng = np.random.default_rng(seed)
    stripes = _stripes(code, B, C, rng)
    patterns = []
    for b in range(B):  # single AND double erasures, varying per item
        n_erase = data.draw(st.integers(1, code.m), label=f"n_erase{b}")
        erased = set(rng.choice(code.n, size=n_erase, replace=False).tolist())
        patterns.append(erased)
    avail, wanted = _erasure_batch(code, stripes, patterns)
    want = [code.decode(a, list(w), C) for a, w in zip(avail, wanted)]
    for backend in BACKENDS:
        got = make_engine(backend, code).decode_batch(avail, wanted, C)
        for b in range(B):
            for w in wanted[b]:
                assert np.array_equal(got[b][w], want[b][w]), \
                    (backend, scheme, C, B, b, w)
                # erased positions must also round-trip the true bytes
                assert np.array_equal(got[b][w], stripes[b, w]), \
                    (backend, scheme, C, B, b, w)


def test_mixed_patterns_one_batch_group_per_pattern(rng):
    """One batch holding several distinct erasure patterns plans one
    group (and one cached inversion) per pattern."""
    code = make_code("rs", 10, 8)
    eng = make_engine("jax", code)
    B, C = 6, 64
    stripes = _stripes(code, B, C, rng)
    patterns = [{0}, {0}, {3, 9}, {0}, {3, 9}, {5}]
    avail, wanted = _erasure_batch(code, stripes, patterns)
    plan = eng.plan_decode([a.keys() for a in avail], wanted, C)
    assert isinstance(plan, DecodePlan)
    assert len(plan.groups) == 3          # {0}, {3,9}, {5}
    assert sorted(i for g in plan.groups for i in g.idxs) == list(range(B))
    assert len(eng._inv_cache) == 3
    got = eng.decode_batch(avail, wanted, C)
    for b, erased in enumerate(patterns):
        for w in erased:
            assert np.array_equal(got[b][w], stripes[b, w])


# ---------------------------------------------------------------------------
# dispatch-at-submit probe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("jax", "pallas"))
@pytest.mark.parametrize("scheme,n,k,C", [("rs", 10, 8, 128),
                                          ("rdp", 10, 8, 64)])
def test_submit_decode_dispatches_at_submit(backend, scheme, n, k, C, rng):
    code = make_code(scheme, n, k)
    eng = make_engine(backend, code)
    B = 4
    stripes = _stripes(code, B, C, rng)
    # mixed single/double erasures: two pattern groups, one needing a
    # re-encoded parity row
    patterns = [{1}, {1}, {0, n - 1}, {0, n - 1}]
    avail, wanted = _erasure_batch(code, stripes, patterns)
    before = eng.device_dispatches
    fut = eng.submit_decode(avail, wanted, C)
    assert eng.device_dispatches > before, \
        f"{backend}: submit_decode issued no device work at submit"
    at_submit = eng.device_dispatches
    got = fut.result()
    assert eng.device_dispatches == at_submit, \
        f"{backend}: result() dispatched extra device work"
    for b, erased in enumerate(patterns):
        for w in erased:
            assert np.array_equal(got[b][w], stripes[b, w])


def test_numpy_submit_decode_stays_lazy(rng):
    code = make_code("rs", 10, 8)
    eng = make_engine("numpy", code)
    stripes = _stripes(code, 2, 64, rng)
    avail, wanted = _erasure_batch(code, stripes, [{2}, {2}])
    fut = eng.submit_decode(avail, wanted, 64)
    assert not fut.done and eng.device_dispatches == 0
    got = fut.result()
    assert eng.device_dispatches == 0
    assert np.array_equal(got[0][2], stripes[0, 2])


# ---------------------------------------------------------------------------
# bounded decode-inverse LRU
# ---------------------------------------------------------------------------

def test_inv_cache_is_lru_bounded(rng):
    # jax: decode_batch runs through plan_decode, populating the cache
    # (the numpy oracle loops code.decode and never touches it)
    code = make_code("rs", 10, 8)
    eng = make_engine("jax", code)
    eng.inv_cache_size = 4
    C = 64
    stripes = _stripes(code, 1, C, rng)
    # rolling failures: many distinct erasure patterns, far beyond the cap
    for i in range(10):
        erased = {i % code.n, (i + 3) % code.n}
        avail, wanted = _erasure_batch(code, stripes, [erased])
        got = eng.decode_batch(avail, wanted, C)
        for w in wanted[0]:
            assert np.array_equal(got[0][w], stripes[0, w]), (i, w)
        assert len(eng._inv_cache) <= 4
    # recency: a re-touched pattern survives the next evictions
    keep = {0, 3}
    avail, wanted = _erasure_batch(code, stripes, [keep])
    eng.decode_batch(avail, wanted, C)
    keep_sig = next(reversed(eng._inv_cache))
    for i in range(3):
        avail, wanted = _erasure_batch(code, stripes, [{1 + i}])
        eng.decode_batch(avail, wanted, C)
    assert keep_sig in eng._inv_cache
    assert len(eng._inv_cache) <= 4


def test_inv_cache_env_knob(monkeypatch, rng):
    monkeypatch.setenv("MEMEC_INV_CACHE", "2")
    code = make_code("rs", 6, 4)
    assert NumpyEngine(code).inv_cache_size == 2     # knob resolves
    eng = make_engine("jax", code)
    stripes = _stripes(code, 1, 32, rng)
    for erased in ({0}, {1}, {2}, {3}):
        avail, wanted = _erasure_batch(code, stripes, [erased])
        eng.decode_batch(avail, wanted, 32)
    assert len(eng._inv_cache) == 2
    # ctor arg beats the env var
    assert NumpyEngine(code, inv_cache_size=7).inv_cache_size == 7


# ---------------------------------------------------------------------------
# RDP native Pallas path sanity (analytic 0/1 block matrices)
# ---------------------------------------------------------------------------

def test_rdp_decode_inverse_stays_binary():
    """RDP is a GF(2) system: its block matrix AND every decode inverse
    are 0/1 — the precondition for the bit-plane-free Pallas kernel."""
    code = make_code("rdp", 10, 8)
    assert isinstance(code, RDPCode)
    eng = make_engine("numpy", code)
    assert int(eng.rep.encode.max()) <= 1
    for sig in ((1, 2, 3, 4, 5, 6, 7, 8), (0, 1, 2, 3, 4, 5, 6, 9)):
        _, inv = eng._decode_inverse(sig)
        assert int(inv.max()) <= 1, sig


# ---------------------------------------------------------------------------
# modeled engine queue
# ---------------------------------------------------------------------------

class TestEngineQueue:
    def test_makespan_depth_limited(self):
        inf = CostModel()
        assert inf.engine_makespan([]) == 0.0
        assert inf.engine_makespan([3.0, 2.0, 2.0]) == 3.0
        d2 = CostModel(engine_depth=2)
        # LPT onto 2 lanes: [3], [2, 2] -> 4
        assert d2.engine_makespan([3.0, 2.0, 2.0]) == 4.0
        assert d2.engine_makespan([3.0, 2.0]) == 3.0   # fits the lanes
        d1 = CostModel(engine_depth=1)
        assert d1.engine_makespan([1.0, 2.0, 3.0]) == 6.0

    def _run(self, cost):
        cl = make_cluster(shards=1, num_servers=16, num_proxies=4,
                          scheme="rs", n=10, k=8, c=4, chunk_size=512,
                          max_unsealed=2, cost=cost, async_engine=True)
        cfg = YCSBConfig(num_objects=900, seed=31)
        run_workload(cl, "load", 0, cfg, batch_size=16)
        run_workload(cl, "A", 600, cfg, batch_size=16)
        return cl

    def test_depth_limit_bounds_hiding_and_surfaces_wait(self):
        # strongly coding-bound so the depth-limited fold makespan (not
        # the seal legs' RTT) decides the merged phase duration
        kw = dict(coding_Bps=1e6, coding_fixed_s=2e-4)
        unbounded = self._run(CostModel(**kw))
        bounded = self._run(CostModel(engine_depth=1, **kw))
        # infinite depth records no queue wait — the historical model
        assert unbounded.stats["engine_queue_wait_s"] == 0.0
        # depth=1 serializes the per-parity seal folds: wait shows up
        # and the total modeled time can only grow
        assert bounded.stats["engine_queue_wait_s"] > 0.0
        assert bounded.net.total_recorded_s > unbounded.net.total_recorded_s
        # scheduling only — served bytes are untouched
        w = YCSBWorkload(YCSBConfig(num_objects=900, seed=31))
        keys = [w.key(i) for i in range(900)]
        assert bounded.multi_get(keys) == unbounded.multi_get(keys)

    def test_degraded_decode_overlap_tracked(self):
        """Eager decode on the degraded path: async hides decode behind
        the recon fetches and books the win as decode_overlap_saved_s."""
        cost = CostModel(coding_Bps=5e7, coding_fixed_s=2e-5)
        pair = {}
        for mode in (False, True):
            # one proxy: the YCSB driver would otherwise spread async
            # batches across proxy lanes, changing chunk packing order —
            # the twins must have identical layouts for the per-chunk
            # reconstruction counts to be comparable
            cl = make_cluster(shards=1, num_servers=16, num_proxies=1,
                              scheme="rs", n=10, k=8, c=4, chunk_size=512,
                              max_unsealed=2, cost=cost, async_engine=mode)
            cfg = YCSBConfig(num_objects=1000, seed=32)
            run_workload(cl, "load", 0, cfg, batch_size=16)
            # on-demand mode (no eager batched recovery): every degraded
            # GET to a sealed chunk runs the decode plan
            cl.fail_server(3, recover=False)
            run_workload(cl, "C", 400, YCSBConfig(num_objects=1000, seed=33),
                         batch_size=16)
            pair[mode] = cl
        sync, asy = pair[False], pair[True]
        assert sync.stats["reconstructions"] > 0
        assert sync.stats["reconstructions"] == asy.stats["reconstructions"]
        assert sync.stats["decode_overlap_saved_s"] == 0.0
        assert asy.stats["decode_overlap_saved_s"] > 0.0
        assert asy.net.mean("GET_DEG") < sync.net.mean("GET_DEG")
