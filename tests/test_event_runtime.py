"""Event-driven request runtime (PR 7).

The open-loop ``ArrivalProcess`` + ``EventRuntime`` must be a pure
*scheduling* overlay: execution stays eager and byte-identical, only the
modeled clock changes.  Properties pinned here:

* the default ``closed`` process runs zero event machinery (historical
  numbers bit-identical);
* seeded determinism — same spec, same workload, identical event log;
* closed-loop equivalence — ``poisson:inf:inflight=1`` reproduces the
  serial phase-algebra totals (makespan == closed-loop modeled time);
* offered-load shape — p99 grows monotonically with arrival rate while
  p50 stays near-flat below saturation;
* resource gating — finite ``engine_depth`` lanes and shared endpoint
  clocks delay subsequent submissions;
* one ``LatencyRecorder`` feeds both NetSim and the sharded facade;
* the telemetry snapshot validates against its own schema.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import (ArrivalProcess, CostModel, EventRuntime,
                        LatencyRecorder, MemECCluster, NetSim, make_cluster,
                        resolve_arrival, telemetry)

KW = dict(num_servers=16, scheme="rs", n=10, k=8, c=4,
          chunk_size=512, max_unsealed=2)


def cluster(arrival=None, **kw):
    merged = dict(KW)
    merged.update(kw)
    return MemECCluster(arrival=arrival, **merged)


def drive(cl, n_obj=40, reads=120, seed=0):
    """Deterministic set+get workload; returns the keys written."""
    rng = np.random.default_rng(seed)
    keys = []
    for i in range(n_obj):
        key = b"ev%08d" % i
        cl.set(key, bytes(rng.integers(0, 256, 24, dtype=np.uint8)))
        keys.append(key)
    for i in range(reads):
        assert cl.get(keys[(i * 7) % n_obj]) is not None
    return keys


# ---------------------------------------------------------------------------
# ArrivalProcess parsing + generation
# ---------------------------------------------------------------------------

class TestArrivalProcess:
    def test_default_is_closed(self, monkeypatch):
        monkeypatch.delenv("MEMEC_ARRIVAL", raising=False)
        ap = resolve_arrival()
        assert ap.kind == "closed" and not ap.open_loop

    def test_env_var_resolves(self, monkeypatch):
        monkeypatch.setenv("MEMEC_ARRIVAL", "poisson:500:seed=7:inflight=3")
        ap = resolve_arrival()
        assert (ap.kind, ap.rate, ap.seed, ap.inflight) == ("poisson", 500.0, 7, 3)
        # explicit ctor arg wins over the env var
        assert resolve_arrival("closed").kind == "closed"

    def test_parse_variants(self):
        assert ArrivalProcess.parse("uniform:250").rate == 250.0
        assert ArrivalProcess.parse("poisson:inf").rate == float("inf")
        tr = ArrivalProcess.parse("trace:0.1,0.2,0.4")
        assert tr.trace == [0.1, 0.2, 0.4]

    @pytest.mark.parametrize("bad", ["burst:10", "poisson", "poisson:0",
                                     "trace", "poisson:10:retries=2"])
    def test_parse_rejects(self, bad):
        with pytest.raises(ValueError):
            ArrivalProcess.parse(bad)

    def test_poisson_seeded_and_resettable(self):
        a = ArrivalProcess.parse("poisson:1000:seed=3")
        b = ArrivalProcess.parse("poisson:1000:seed=3")
        xs = [a.next_arrival() for _ in range(50)]
        assert xs == [b.next_arrival() for _ in range(50)]
        assert xs == sorted(xs)          # arrivals are monotonic
        a.reset()
        assert [a.next_arrival() for _ in range(50)] == xs
        c = ArrivalProcess.parse("poisson:1000:seed=4")
        assert [c.next_arrival() for _ in range(50)] != xs

    def test_rate_inf_means_zero_gaps(self):
        ap = ArrivalProcess.parse("poisson:inf")
        assert [ap.next_arrival() for _ in range(5)] == [0.0] * 5

    def test_trace_gap_pattern_cycles(self):
        ap = ArrivalProcess.parse("trace:0.5,1.0")
        assert [ap.next_arrival() for _ in range(4)] == [0.5, 1.0, 1.5, 2.0]


# ---------------------------------------------------------------------------
# closed loop: no event machinery, verbatim records
# ---------------------------------------------------------------------------

class TestClosedLoop:
    def test_no_event_runtime_by_default(self, monkeypatch):
        monkeypatch.delenv("MEMEC_ARRIVAL", raising=False)
        cl = cluster()
        assert cl.net.events is None and not cl.net.arrival.open_loop
        drive(cl, n_obj=10, reads=20)
        st_ = cl.stats
        assert "queue_wait_s" not in st_ and "arrival" not in st_
        assert st_["latency"]["GET"]["p99_s"] >= st_["latency"]["GET"]["p50_s"]

    def test_record_is_verbatim(self):
        net = NetSim(CostModel(), arrival="closed")
        assert net.record("GET", 0.25) == 0.25
        assert net.latencies["GET"] == [0.25]
        assert net.total_recorded_s == 0.25


# ---------------------------------------------------------------------------
# seeded determinism of the event log
# ---------------------------------------------------------------------------

class TestDeterminism:
    SPEC = "poisson:800:seed=5:inflight=2"

    def test_same_seed_identical_events(self):
        a, b = cluster(self.SPEC), cluster(self.SPEC)
        drive(a)
        drive(b)
        assert a.net.events.events == b.net.events.events
        assert a.net.percentile("GET", 99) == b.net.percentile("GET", 99)
        assert a.net.latency_summary() == b.net.latency_summary()

    def test_different_seed_differs(self):
        a = cluster(self.SPEC)
        b = cluster("poisson:800:seed=6:inflight=2")
        drive(a)
        drive(b)
        assert a.net.events.events != b.net.events.events

    def test_reset_rewinds_the_arrival_process(self):
        cl = cluster(self.SPEC)
        drive(cl, n_obj=10, reads=20)
        first = list(cl.net.events.events)
        cl.net.reset()
        assert cl.net.events.offered == 0
        drive(cl, n_obj=10, reads=20, seed=1)  # same op sequence, new values
        replay = cl.net.events.events
        # same arrival draws and same op order -> same arrival column
        assert [e[2] for e in replay] == [e[2] for e in first]


# ---------------------------------------------------------------------------
# closed-loop equivalence: rate -> inf, inflight=1 degenerates to the
# serial phase-algebra totals (the tentpole's backward-compat property)
# ---------------------------------------------------------------------------

class TestClosedLoopEquivalence:
    @settings(max_examples=4, deadline=None)
    @given(st.integers(min_value=0, max_value=3))
    def test_rate_inf_matches_closed_totals(self, seed):
        closed = cluster("closed")
        event = cluster(f"poisson:inf:seed={seed}:inflight=1")
        drive(closed, n_obj=25, reads=60, seed=seed)
        drive(event, n_obj=25, reads=60, seed=seed)
        # execution is identical: per-kind service == closed latencies
        assert dict(event.net.service.latencies) == dict(closed.net.latencies)
        # and the serial schedule reproduces the closed-loop total
        total = closed.net.total_recorded_s
        assert event.net.events.makespan_s == pytest.approx(total, rel=1e-3)
        assert sum(sum(xs) for xs in event.net.service.latencies.values()) \
            == pytest.approx(total, rel=1e-12)

    def test_uniform_overload_inflates_latency(self):
        closed = cluster("closed")
        slow = cluster("uniform:1e9")   # arrivals far faster than service
        drive(closed, n_obj=25, reads=60)
        drive(slow, n_obj=25, reads=60)
        assert slow.net.events.snapshot()["queue_wait_s"] > 0.0
        assert slow.net.percentile("GET", 99) > closed.net.percentile("GET", 99)


# ---------------------------------------------------------------------------
# offered-load shape: p99 monotone, p50 near-flat below saturation
# ---------------------------------------------------------------------------

class TestRateSweep:
    def test_tail_grows_before_the_median(self):
        base = cluster("closed")
        drive(base, n_obj=30, reads=100)
        t0 = base.net.total_recorded_s
        svc_rate = sum(base.net.ops_by_kind.values()) / t0
        rows = {}
        for x in (0.2, 0.8, 4.0):
            cl = cluster(f"poisson:{x * svc_rate:.6g}:seed=11:inflight=2")
            drive(cl, n_obj=30, reads=100)
            rows[x] = {"p50": cl.net.percentile("GET", 50),
                       "p99": cl.net.percentile("GET", 99)}
        p99s = [rows[x]["p99"] for x in (0.2, 0.8, 4.0)]
        assert all(b >= a for a, b in zip(p99s, p99s[1:])), p99s
        assert rows[4.0]["p99"] > 1.5 * rows[0.2]["p99"]
        assert rows[0.8]["p50"] < 2.0 * rows[0.2]["p50"]


# ---------------------------------------------------------------------------
# resource gating: engine lanes, endpoint clocks, admission slots
# ---------------------------------------------------------------------------

class TestResourceGating:
    def test_engine_lanes_serialize_coding(self):
        rt = EventRuntime(CostModel(engine_depth=1),
                          ArrivalProcess.parse("poisson:inf:inflight=4"))
        for _ in range(4):
            rt.submit("GET", 1e-3, engine_s=1e-3)
        assert rt.wait_s_by_resource["engine"] > 0.0
        assert rt.makespan_s == pytest.approx(4e-3)

    def test_infinite_depth_never_gates(self):
        rt = EventRuntime(CostModel(),
                          ArrivalProcess.parse("poisson:inf:inflight=4"))
        for _ in range(4):
            rt.submit("GET", 1e-3, engine_s=1e-3)
        assert rt.wait_s_by_resource["engine"] == 0.0
        assert rt.makespan_s == pytest.approx(1e-3)

    def test_shared_endpoint_serializes(self):
        rt = EventRuntime(CostModel(),
                          ArrivalProcess.parse("poisson:inf:inflight=2"))
        rt.submit("GET", 1e-3, busy={"s0": 8e-4})
        rt.submit("GET", 1e-3, busy={"s0": 8e-4})
        assert rt.wait_s_by_resource["endpoint"] == pytest.approx(8e-4)
        rt.submit("GET", 1e-3, busy={"s1": 8e-4})   # disjoint endpoint
        assert rt.wait_s_by_resource["endpoint"] == pytest.approx(8e-4)

    def test_engine_ready_at_prefers_idle(self):
        rt = EventRuntime(CostModel(engine_depth=2),
                          ArrivalProcess.parse("poisson:inf:inflight=4"))
        assert rt.engine_ready_at() == 0.0
        rt.submit("SET", 1e-3, engine_s=5e-4)
        assert rt.engine_ready_at() == 0.0          # second lane still idle
        rt.submit("SET", 1e-3, engine_s=5e-4)
        assert rt.engine_ready_at() > 0.0

    def test_modeled_engine_busy_accumulates(self):
        cl = cluster()
        # values big enough to fill chunks -> seals -> parity engine calls
        rng = np.random.default_rng(0)
        for i in range(150):
            cl.set(b"mb%07d" % i,
                   bytes(rng.integers(0, 256, 200, dtype=np.uint8)))
        assert cl.engine.stats()["modeled_busy_s"] > 0.0
        assert cl.engine.modeled_busy_s == cl.engine.stats()["modeled_busy_s"]


# ---------------------------------------------------------------------------
# sharded facade: one event runtime at the facade, shards stay closed
# ---------------------------------------------------------------------------

class TestShardedEventMode:
    SPEC = "poisson:2000:seed=9:inflight=2"

    def _sharded(self):
        cl = make_cluster(shards=2, arrival=self.SPEC, **KW)
        rng = np.random.default_rng(0)
        keys = [b"sh%08d" % i for i in range(40)]
        cl.multi_set([(k, bytes(rng.integers(0, 256, 24, dtype=np.uint8)))
                      for k in keys])
        for _ in range(6):
            assert all(v is not None for v in cl.multi_get(keys))
        return cl

    def test_shards_forced_closed_facade_open(self):
        cl = self._sharded()
        assert cl.net.events is not None
        assert all(sh.net.events is None for sh in cl.shards)
        ev = cl.net.events.snapshot()
        assert ev["offered"] > 0
        st_ = cl.stats
        assert st_["arrival"]["kind"] == "poisson"
        assert "MGET" in st_["latency"]
        assert st_["latency"]["MGET"]["p99_s"] >= st_["latency"]["MGET"]["p50_s"]

    def test_facade_percentile_uses_shared_recorder(self):
        cl = self._sharded()
        merged = cl.net.latencies["MGET"]
        assert cl.net.percentile("MGET", 99) \
            == LatencyRecorder.percentile_of(merged, 99.0) \
            == float(np.percentile(merged, 99.0))
        assert cl.net.mean("MGET") == LatencyRecorder.mean_of(merged)


# ---------------------------------------------------------------------------
# shared LatencyRecorder: one formula set for both report paths
# ---------------------------------------------------------------------------

class TestLatencyRecorder:
    def test_summary_shape(self):
        rec = LatencyRecorder()
        for x in (1.0, 2.0, 3.0, 10.0):
            rec.record("GET", x)
        s = rec.summary()["GET"]
        assert s["count"] == 4 and s["mean_s"] == 4.0
        assert s["p50_s"] == float(np.percentile([1, 2, 3, 10], 50))
        assert set(s) == {"count", "mean_s", "p50_s", "p99_s", "p999_s"}

    def test_total_recorded_survives_clear(self):
        rec = LatencyRecorder()
        rec.record("GET", 2.0)
        rec.clear()
        assert rec.total_recorded_s == 2.0 and rec.latencies == {}

    def test_netsim_delegates(self):
        net = NetSim(CostModel())
        for x in (1.0, 5.0, 9.0):
            net.record("GET", x)
        assert net.percentile("GET", 50) == 5.0
        assert net.mean("GET") == 5.0
        assert net.recorder.latencies is net.latencies

    def test_empty_is_nan(self):
        assert np.isnan(LatencyRecorder.percentile_of([], 99.0))
        assert np.isnan(LatencyRecorder.mean_of([]))


# ---------------------------------------------------------------------------
# telemetry snapshot schema
# ---------------------------------------------------------------------------

class TestTelemetry:
    def test_closed_snapshot_validates(self):
        cl = cluster()
        drive(cl, n_obj=10, reads=20)
        snap = telemetry.validate(telemetry.snapshot(cl))
        assert snap["schema"] == telemetry.SCHEMA
        assert snap["version"] == telemetry.VERSION
        assert not snap["open_loop"] and "event" not in snap
        assert snap["latency"]["GET"]["count"] == 20
        assert snap["counters"]  # numeric stats made it through

    def test_open_loop_snapshot_has_event_section(self):
        cl = cluster("poisson:2000:seed=2:inflight=2")
        drive(cl, n_obj=10, reads=20)
        snap = telemetry.validate(telemetry.snapshot(cl))
        assert snap["open_loop"]
        assert snap["event"]["offered"] == snap["latency"]["GET"]["count"] \
            + snap["latency"]["SET"]["count"]
        assert "queue_wait_s" in snap["latency"]["GET"]
        assert set(snap["event"]["queue_wait_s_by_resource"]) \
            == set(EventRuntime.RESOURCES)

    def test_validate_rejects_drift(self):
        cl = cluster()
        drive(cl, n_obj=5, reads=5)
        snap = telemetry.snapshot(cl)
        for corrupt in ({**snap, "schema": "memec/other"},
                        {**snap, "version": telemetry.VERSION + 1},
                        {k: v for k, v in snap.items() if k != "latency"}):
            with pytest.raises(ValueError):
                telemetry.validate(corrupt)

    def test_sharded_snapshot_validates(self):
        cl = make_cluster(shards=2, **KW)
        rng = np.random.default_rng(1)
        cl.multi_set([(b"t%06d" % i,
                       bytes(rng.integers(0, 256, 16, dtype=np.uint8)))
                      for i in range(20)])
        snap = telemetry.validate(telemetry.snapshot(cl))
        assert len(snap["engines"]) == 2
        assert all("modeled_busy_s" in e for e in snap["engines"])
