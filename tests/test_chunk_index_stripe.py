"""All-encoding layout: chunk packing, cuckoo index, stripe lists."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.chunk import (CHUNK_SIZE, ChunkBuilder, ChunkId,
                              fragment_count, pack_object, parse_objects,
                              split_fragments)
from repro.core.index import CuckooIndex, hash_pair
from repro.core.stripe import StripeMapper, generate_stripe_lists, write_loads

keys = st.binary(min_size=1, max_size=32)
values = st.binary(min_size=0, max_size=64)


@given(st.lists(st.tuples(keys, values), min_size=1, max_size=20,
                unique_by=lambda kv: kv[0]))
@settings(max_examples=30, deadline=None)
def test_chunk_pack_parse_roundtrip(kvs):
    b = ChunkBuilder(4096)
    stored = []
    for k, v in kvs:
        if b.fits(k, len(v)):
            b.append(k, v)
            stored.append((k, v))
    parsed = parse_objects(b.buf)
    assert [(k, v) for _, k, v, _ in parsed] == stored


def test_chunk_update_delete_roundtrip():
    b = ChunkBuilder(512)
    off1 = b.append(b"alpha", b"11111111")
    off2 = b.append(b"beta", b"2222")
    b.write_value(off1, 5, b"99999999")
    assert b.read_value(off1, 5, 8) == b"99999999"
    b.mark_deleted(off2, 4, 4)
    parsed = parse_objects(b.buf)
    assert parsed[0][1:3] == (b"alpha", b"99999999")
    assert parsed[1][3] is True            # tombstone
    assert parsed[1][2] == b"\x00" * 4     # zeroed value


@given(st.integers(0, 2**16 - 1), st.integers(0, 2**40 - 1),
       st.integers(0, 255))
@settings(max_examples=50, deadline=None)
def test_chunk_id_roundtrip(sl, sid, pos):
    cid = ChunkId(sl, sid, pos)
    assert ChunkId.unpack(cid.pack()) == cid
    assert len(cid.pack()) == 8


@given(st.binary(min_size=1, max_size=16),
       st.integers(0, 3 * CHUNK_SIZE))
@settings(max_examples=25, deadline=None)
def test_fragmentation_roundtrip(key, vsize):
    value = bytes((i * 31) % 256 for i in range(vsize))
    frags = split_fragments(key, value)
    assert len(frags) == fragment_count(len(value), len(key))
    joined = b"".join(v for _, v in frags)
    assert joined == value
    # every fragment object fits a chunk
    for fk, fv in frags:
        assert 4 + len(fk) + len(fv) <= CHUNK_SIZE


@given(st.lists(st.tuples(keys, st.integers(0, 1000)), min_size=1,
                max_size=200))
@settings(max_examples=20, deadline=None)
def test_cuckoo_vs_dict(ops):
    idx = CuckooIndex(num_buckets=64)
    oracle = {}
    for key, val in ops:
        if val % 5 == 0 and key in oracle:
            assert idx.delete(key)
            del oracle[key]
        else:
            idx.insert(key, val)
            oracle[key] = val
    for k, v in oracle.items():
        assert idx.lookup(k) == v
    assert idx.size == len(oracle)
    assert idx.lookup(b"@@never-inserted@@") is None


def test_cuckoo_occupancy_over_90pct():
    """Paper §3.2: 2-choice 4-way cuckoo reaches >90% utilization."""
    idx = CuckooIndex(num_buckets=256)  # 1024 slots
    target = int(1024 * 0.92)
    for i in range(target):
        assert idx.insert(b"key%06d" % i, i)
    # resize may have been triggered; if not, occupancy exceeded 0.9
    if idx.num_buckets == 256:
        assert idx.occupancy >= 0.9


def test_hash_pair_independent_mod_small():
    """Regression: two-stage hashing must not correlate mod small powers
    of two (the FNV triangularity bug)."""
    r1 = [hash_pair(b"key%08d" % i)[0] % 16 for i in range(500)]
    r2 = [hash_pair(b"key%08d" % i)[1] % 8 for i in range(500)]
    agree = sum(1 for a, b in zip(r1, r2) if a % 8 == b)
    assert agree < 150  # ~1/8 expected, was 100% with the bug


@given(st.sampled_from([(16, 10, 8), (16, 14, 10), (20, 10, 8), (12, 9, 8)]),
       st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_stripe_list_properties(nsk, c):
    num_servers, n, k = nsk
    lists = generate_stripe_lists(num_servers, n, k, c)
    assert len(lists) == c
    for sl in lists:
        assert len(set(sl.servers)) == n       # n distinct servers
        assert len(sl.data_servers) == k
        assert len(sl.parity_servers) == n - k
    # write-load balance (paper §4.3): spread within a small factor
    loads = write_loads(lists, num_servers)
    if c >= num_servers:
        assert loads.max() <= loads.min() + n + k


def test_mapper_deterministic_and_spread():
    lists = generate_stripe_lists(16, 10, 8, 16)
    m = StripeMapper(lists)
    targets = {}
    for i in range(2000):
        key = b"user%010d" % i
        sl, ds = m.data_server_for(key)
        assert ds in sl.data_servers
        sl2, ds2 = m.data_server_for(key)
        assert (sl2.list_id, ds2) == (sl.list_id, ds)
        targets[ds] = targets.get(ds, 0) + 1
    # every server that appears as a data server gets some traffic
    data_servers = {s for sl in lists for s in sl.data_servers}
    assert set(targets) == data_servers
