"""Straggler-tolerant k-of-(k+Δ) reads (PR 9).

Properties pinned here:

* ``race_phase`` — k-th-arrival completion, deterministic tie-break,
  every leg (winner or dropped) accounted in bytes/messages/occupancy;
* ``EventRuntime.submit(optional=...)`` — dropped race traffic does not
  gate or charge endpoint queue wait for its own request, but the link
  clock still advances so *subsequent* requests queue behind it;
* slow-server injection (``inflate``) scales latency and occupancy
  without touching byte counters, and ``factor=1`` restores;
* byte identity — Δ>0 reads return exactly the plain-Δ=0 bytes across
  engines, single and multi-key, under inflation, declared/undeclared
  failures, failed+slow overlap, and sharding;
* load-aware selection — the most-loaded eligible chunk holder is left
  out of the fan-out, the data position always stays in;
* Δ race-erasures plus real erasures never exceed m (dark servers are
  excluded from the candidate pool up front);
* tracing — dropped legs appear as cancelled spans, never on the
  critical path, and tracing does not perturb modeled time.
"""
import numpy as np
import pytest

from repro.core import (ArrivalProcess, CostModel, EventRuntime, Leg,
                        MemECCluster, NetSim, make_cluster)
from repro.core.store import resolve_redundant_reads
from repro.core.trace import components

KW = dict(num_servers=16, scheme="rs", n=10, k=8, c=4,
          chunk_size=512, max_unsealed=2)
N_OBJ = 1400          # enough objects that chunks actually seal


def cluster(**kw):
    merged = dict(KW, engine="numpy")
    merged.update(kw)
    return MemECCluster(**merged)


def load(cl, n_obj=N_OBJ, seed=7):
    rng = np.random.default_rng(seed)
    items = {}
    for i in range(n_obj):
        key = b"strag%06d" % i
        val = bytes(rng.integers(0, 256, 24, dtype=np.uint8))
        assert cl.set(key, val)
        items[key] = val
    if n_obj >= N_OBJ:   # smaller loads exercise the unsealed path only
        assert sealed_data_chunks(cl) > 0, "workload too small to seal"
    return items


def sealed_data_chunks(cl, sid=None):
    sids = range(len(cl.servers)) if sid is None else [sid]
    total = 0
    for s in sids:
        srv = cl.servers[s]
        total += sum(1 for cid, sealed in zip(srv.chunk_ids, srv.sealed)
                     if sealed and cid is not None and cid.position < cl.k)
    return total


def victim_of(cl):
    """Data server holding the most sealed data chunks (worst case for
    a slow-server injection: the most reads depend on it)."""
    return max(range(len(cl.servers)), key=lambda s: sealed_data_chunks(cl, s))


def read_all(cl, items, chunk=16):
    """Interleaved multi_get / single-get sweep; returns key -> value."""
    keys = list(items)
    out = {}
    for i in range(0, len(keys), chunk):
        block = keys[i:i + chunk]
        if (i // chunk) % 3 == 2:           # every third block single-key
            for k in block:
                out[k] = cl.get(k)
        else:
            for k, v in zip(block, cl.multi_get(block)):
                out[k] = v
    return out


# ---------------------------------------------------------------------------
# redundant_reads resolution
# ---------------------------------------------------------------------------

class TestResolve:
    def test_default_zero(self, monkeypatch):
        monkeypatch.delenv("MEMEC_REDUNDANT_READS", raising=False)
        assert resolve_redundant_reads(None) == 0
        assert cluster().redundant_reads == 0

    def test_env_fallback(self, monkeypatch):
        monkeypatch.setenv("MEMEC_REDUNDANT_READS", "2")
        assert resolve_redundant_reads(None) == 2
        assert cluster().redundant_reads == 2

    def test_explicit_wins(self, monkeypatch):
        monkeypatch.setenv("MEMEC_REDUNDANT_READS", "2")
        assert resolve_redundant_reads(1) == 1

    def test_negative_rejected(self):
        with pytest.raises(ValueError):
            resolve_redundant_reads(-1)


# ---------------------------------------------------------------------------
# race_phase unit semantics
# ---------------------------------------------------------------------------

def _group(label, nbytes, src="p0", dst="s0", to_failed=False):
    return (label, [Leg("rget", 8, src, dst, to_failed),
                    Leg("rget_resp", nbytes, dst, src, to_failed)])


class TestRacePhase:
    def test_kth_arrival(self):
        net = NetSim(CostModel())
        groups = [_group(f"g{i}", nb, dst=f"s{i}")
                  for i, nb in enumerate([4000, 100, 2000, 300])]
        t, winners, dropped = net.race_phase(groups, need=2)
        # completes at the 2nd-cheapest group, not the max
        costs = [sum(net.cost.leg(l.nbytes) for l in legs)
                 for _, legs in groups]
        assert t == pytest.approx(sorted(costs)[1])
        assert winners == [1, 3] and dropped == [0, 2]

    def test_need_clamped_to_groups(self):
        net = NetSim(CostModel())
        groups = [_group("a", 100), _group("b", 200, dst="s1")]
        t, winners, dropped = net.race_phase(groups, need=5)
        assert winners == [0, 1] and dropped == []
        assert t == pytest.approx(max(
            sum(net.cost.leg(l.nbytes) for l in legs) for _, legs in groups))

    def test_tie_break_by_index(self):
        net = NetSim(CostModel())
        groups = [_group(f"g{i}", 256, dst=f"s{i}") for i in range(4)]
        _, winners, dropped = net.race_phase(groups, need=2)
        assert winners == [0, 1] and dropped == [2, 3]

    def test_all_legs_accounted(self):
        """Dropped legs still hit bytes / messages / link occupancy."""
        net = NetSim(CostModel())
        groups = [_group(f"g{i}", 512, dst=f"s{i}") for i in range(5)]
        net.race_phase(groups, need=2)
        wire = 512 + net.cost.header_bytes
        req_wire = 8 + net.cost.header_bytes
        assert net.msgs_by_kind["rget"] == 5
        assert net.msgs_by_kind["rget_resp"] == 5
        assert net.bytes_by_kind["rget_resp"] == 5 * wire
        for i in range(5):   # losers' occupancy is on the wire too
            assert net.time_by_endpoint[f"s{i}"] == pytest.approx(
                (wire + req_wire) / net.cost.bw_Bps)

    def test_failed_leg_penalty_loses_race(self):
        net = NetSim(CostModel())
        groups = [_group("failed", 100, dst="s0", to_failed=True),
                  _group("ok", 100, dst="s1")]
        _, winners, dropped = net.race_phase(groups, need=1)
        assert winners == [1] and dropped == [0]


# ---------------------------------------------------------------------------
# EventRuntime: optional (dropped-leg) occupancy gating
# ---------------------------------------------------------------------------

class TestOptionalGating:
    def _rt(self):
        return EventRuntime(CostModel(),
                            ArrivalProcess("poisson", rate=float("inf"),
                                           inflight=4))

    def test_optional_does_not_gate_own_request(self):
        rt = self._rt()
        rt.submit("GET", 0.001, busy={"a": 0.004})          # a busy to 4ms
        detail = {}
        lat = rt.submit("GET", 0.001, busy={"a": 0.003},
                        optional={"a": 0.003}, detail_out=detail)
        # entirely-optional endpoint: no wait, no endpoint attribution
        assert lat == pytest.approx(0.001)
        assert detail["endpoint"] == ""
        assert rt.wait_s_by_resource["endpoint"] == 0.0

    def test_optional_still_advances_link_clock(self):
        rt = self._rt()
        rt.submit("GET", 0.001, busy={"a": 0.004})
        rt.submit("GET", 0.001, busy={"a": 0.003}, optional={"a": 0.003})
        # dropped bytes appended behind the queue, not rewound
        assert rt.link_free["a"] == pytest.approx(0.007)
        # a third, non-optional request queues behind the dropped traffic
        lat = rt.submit("GET", 0.001, busy={"a": 0.001})
        assert lat == pytest.approx(0.007 + 0.001)

    def test_partially_optional_endpoint_still_gates(self):
        rt = self._rt()
        rt.submit("GET", 0.001, busy={"a": 0.004})
        lat = rt.submit("GET", 0.001, busy={"a": 0.003},
                        optional={"a": 0.002})
        assert lat == pytest.approx(0.004 + 0.001)


# ---------------------------------------------------------------------------
# slow-server injection
# ---------------------------------------------------------------------------

class TestInflation:
    def test_leg_cost_and_occupancy_scale(self):
        net = NetSim(CostModel())
        base = net.phase([Leg("get", 512, "p0", "s3")])
        occ0 = net.time_by_endpoint["s3"]
        net.inflate("s3", 10.0)
        slow = net.phase([Leg("get", 512, "p0", "s3")])
        assert slow == pytest.approx(10.0 * base)
        assert net.time_by_endpoint["s3"] - occ0 == pytest.approx(10.0 * occ0)
        net.inflate("s3", 1.0)   # factor 1 removes the entry entirely
        assert "s3" not in net.inflation
        assert net.phase([Leg("get", 512, "p0", "s3")]) == pytest.approx(base)

    def test_bytes_unchanged(self):
        net = NetSim(CostModel())
        net.inflate("s3", 10.0)
        net.phase([Leg("get", 512, "p0", "s3")])
        assert net.bytes_by_kind["get"] == 512 + net.cost.header_bytes

    def test_invalid_factor_rejected(self):
        net = NetSim(CostModel())
        for bad in (0.0, -1.0):
            with pytest.raises(ValueError):
                net.inflate("s3", bad)

    def test_inflation_survives_reset(self):
        net = NetSim(CostModel())
        net.inflate("s3", 10.0)
        base = NetSim(CostModel()).phase([Leg("get", 512, "p0", "s3")])
        net.reset()
        assert net.phase([Leg("get", 512, "p0", "s3")]) == \
            pytest.approx(10.0 * base)

    def test_cluster_inflate_server(self):
        cl = cluster()
        items = load(cl, 200)
        key = next(iter(items))
        cl.get(key)
        p0 = cl.stats["latency"]["GET"]["p50_s"]
        _, ds = cl.mapper.data_server_for(key)
        cl.inflate_server(ds, 10.0)
        for k in items:
            cl.get(k)
        assert cl.stats["latency"]["GET"]["p999_s"] > 5 * p0


# ---------------------------------------------------------------------------
# byte identity: k-of-(k+Δ) == plain k
# ---------------------------------------------------------------------------

def assert_identical(delta, engine="numpy", scenario=lambda cl: None,
                     n_obj=N_OBJ):
    plain = cluster(engine=engine, redundant_reads=0, verify_rebuild=True)
    red = cluster(engine=engine, redundant_reads=delta, verify_rebuild=True)
    items = load(plain)
    assert load(red) == items
    scenario(plain)
    scenario(red)
    got_plain = read_all(plain, items)
    got_red = read_all(red, items)
    assert got_plain == got_red == items
    return plain, red


class TestByteIdentity:
    def test_normal(self):
        _, red = assert_identical(1)
        assert red._stats["redundant_reads"] > 0
        assert red._stats["redundant_decodes"] == 0  # no straggler: primary wins

    def test_under_inflation_decodes(self):
        def inject(cl):
            cl.inflate_server(victim_of(cl), 10.0)
        _, red = assert_identical(1, scenario=inject)
        assert red._stats["redundant_decodes"] > 0
        assert red._stats["redundant_cancelled"] > 0

    def test_delta2(self):
        def inject(cl):
            cl.inflate_server(victim_of(cl), 10.0)
        _, red = assert_identical(2, scenario=inject)
        assert red._stats["redundant_decodes"] > 0

    def test_failed_plus_slow_overlap(self):
        """Δ slow servers overlapping a genuinely failed one: the dark
        server is excluded up front, so Δ + real erasures <= m holds."""
        def inject(cl):
            cl.fail_server(3, recover=False)
            cl.inflate_server(5, 10.0)
        assert_identical(2, scenario=inject)

    def test_undeclared_failure(self):
        """degraded_enabled=False: the failed server stays a candidate
        with its to_failed penalty and simply loses every race."""
        plain = cluster(redundant_reads=0, degraded_enabled=False)
        red = cluster(redundant_reads=1, degraded_enabled=False)
        items = load(plain)
        load(red)
        for cl in (plain, red):
            cl.fail_server(3, recover=False)
        assert read_all(plain, items) == read_all(red, items) == items
        assert red._stats["redundant_decodes"] > 0

    def test_after_restore(self):
        def cycle(cl):
            cl.fail_server(3)
            cl.restore_server(3)
            cl.inflate_server(victim_of(cl), 10.0)
            cl.inflate_server(victim_of(cl), 1.0)   # and un-inflate
        assert_identical(1, scenario=cycle)

    @pytest.mark.slow
    @pytest.mark.parametrize("engine", ["jax", "pallas"])
    def test_engine_grid(self, engine):
        def inject(cl):
            cl.inflate_server(victim_of(cl), 10.0)
        _, red = assert_identical(1, engine=engine, scenario=inject)
        assert red._stats["redundant_decodes"] > 0

    def test_sharded(self):
        kw = dict(KW, engine="numpy", verify_rebuild=True)
        plain = make_cluster(shards=4, redundant_reads=0, **kw)
        red = make_cluster(shards=4, redundant_reads=1, **kw)
        assert red.redundant_reads == 1
        rng = np.random.default_rng(11)
        items = {b"sh%06d" % i: bytes(rng.integers(0, 256, 24, dtype=np.uint8))
                 for i in range(1200)}
        for cl in (plain, red):
            for k, v in items.items():
                assert cl.set(k, v)
        info = red.inflate_server(2, 10.0, shard=1)
        assert info == {"shard": 1, "server": 2, "factor": 10.0}
        plain.inflate_server(2, 10.0, shard=1)
        keys = list(items)
        for cl in (plain, red):
            got = dict(zip(keys, cl.multi_get(keys)))
            got.update({k: cl.get(k) for k in keys[::7]})
            assert got == {k: items[k] for k in got}


# ---------------------------------------------------------------------------
# load-aware selection
# ---------------------------------------------------------------------------

class TestSelection:
    def _sealed_key(self, cl, items):
        for key in items:
            sl, ds = cl.mapper.data_server_for(key)
            srv = cl.servers[ds]
            ref = srv.lookup(key)
            if ref is None:
                continue
            cid = srv.chunk_id_of(ref)
            if srv.get_sealed_chunk(cid) is not None:
                return key, sl, ds, cid
        pytest.fail("no sealed key found")

    def test_busiest_member_excluded(self):
        cl = cluster(redundant_reads=1)
        items = load(cl)
        key, sl, ds, cid = self._sealed_key(cl, items)
        # overload one stripe member that is neither the data position
        # nor dark; with Δ=1 the fan-out takes k-1+Δ of the n-1 others,
        # leaving out exactly the most-loaded one
        others = [i for i in range(len(sl.servers)) if i != cid.position]
        loaded = sl.servers[others[0]]
        cl.net.time_by_endpoint[f"s{loaded}"] += 1e6
        before = cl.net.bytes_by_endpoint.get(f"s{loaded}", 0)
        ds_before = cl.net.bytes_by_endpoint.get(f"s{ds}", 0)
        assert cl.get(key) == items[key]
        assert cl.net.bytes_by_endpoint.get(f"s{loaded}", 0) == before
        assert cl.net.bytes_by_endpoint.get(f"s{ds}", 0) > ds_before

    def test_endpoint_load_reflects_occupancy(self):
        cl = cluster()
        cl.net.time_by_endpoint["s5"] += 1.0
        assert cl._endpoint_load(5) > cl._endpoint_load(6)


# ---------------------------------------------------------------------------
# tail behavior: the actual straggler win
# ---------------------------------------------------------------------------

class TestTailWin:
    def test_redundancy_beats_plain_under_injection(self):
        """One 10x server: Δ=1 p99 stays near baseline, Δ=0 blows up."""
        base = cluster(redundant_reads=0)
        items = load(base)
        read_all(base, items)
        p99_base = base.stats["latency"]["GET"]["p99_s"]

        twins = {}
        for delta in (0, 1):
            cl = cluster(redundant_reads=delta)
            load(cl)
            cl.inflate_server(victim_of(cl), 10.0)
            assert read_all(cl, items) == items
            twins[delta] = cl.stats["latency"]["GET"]["p99_s"]
        assert twins[0] >= 5.0 * p99_base       # plain reads eat the straggler
        assert twins[1] <= 2.0 * p99_base       # redundancy hides it

    def test_event_mode_open_loop(self):
        """Same win under the event runtime, where dropped traffic still
        occupies links but never gates its own request."""
        p99 = {}
        for delta in (0, 1):
            cl = cluster(redundant_reads=delta,
                         arrival="poisson:2000:inflight=2:seed=5")
            items = load(cl, 600)
            cl.inflate_server(victim_of(cl), 10.0)
            assert read_all(cl, items) == items
            st = cl.stats
            p99[delta] = st["latency"]["GET"]["p99_s"]
            waits = st["queue_wait_s_by_resource"]
            assert set(waits) == {"admission", "endpoint", "engine"}
            assert all(w >= 0.0 for w in waits.values())
        assert p99[1] < p99[0]


# ---------------------------------------------------------------------------
# tracing: cancelled spans off the critical path
# ---------------------------------------------------------------------------

class TestTracing:
    def _run(self, trace, delta=1):
        cl = cluster(redundant_reads=delta, trace=trace)
        items = load(cl, 600)
        cl.inflate_server(victim_of(cl), 10.0)
        got = read_all(cl, items)
        assert got == items
        return cl

    def test_cancelled_spans_present_and_consistent(self):
        cl = self._run(trace="1")
        roots = cl.tracer.requests
        assert roots
        cancelled = 0
        for r in roots:
            r.check()
            cancelled += sum(1 for s in r.walk() if s.cat == "cancelled")
        assert cancelled > 0
        assert cancelled == cl._stats["redundant_cancelled"]

    def test_cancelled_never_on_critical_path(self):
        cl = self._run(trace="1")
        for r in cl.tracer.requests:
            assert not any(name.startswith("cancelled:")
                           for name in components(r))

    def test_tracer_does_not_perturb_time(self):
        on = self._run(trace="1")
        off = self._run(trace=None)
        assert on.stats["latency"]["GET"] == off.stats["latency"]["GET"]


# ---------------------------------------------------------------------------
# erasure-budget guard
# ---------------------------------------------------------------------------

class TestErasureBudget:
    def test_candidates_exclude_dark_servers(self):
        """With m=2, one declared failure + Δ=2 must still decode: the
        dark server never enters the candidate pool, so winners are
        always k readable chunk positions."""
        cl = cluster(redundant_reads=2, verify_rebuild=True)
        items = load(cl)
        cl.fail_server(3, recover=False)
        cl.inflate_server(5, 10.0)
        cl.inflate_server(7, 10.0)
        assert read_all(cl, items) == items

    def test_delta_larger_than_pool_clamps(self):
        """Δ bigger than the spare-chunk pool just means 'race them all'
        — need is clamped to the group count, never an error."""
        cl = cluster(redundant_reads=8)
        items = load(cl, 400)
        assert read_all(cl, items) == items
