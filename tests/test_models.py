"""Per-architecture smoke tests (reduced configs): forward shapes, no NaNs,
one train step, and prefill<->decode equivalence."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.configs.shapes import SHAPES, shape_applicable
from repro.models import Model


def make_batch(cfg, B, S, rng):
    if cfg.input_mode == "embeddings":
        batch = {"embeddings": jax.random.normal(
            rng, (B, S, cfg.d_model), jnp.float32) * 0.1}
        toks = None
    else:
        toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": toks}
    if cfg.rope_kind == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None, :], (3, B, S))
    return batch, toks


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_smoke(arch):
    cfg = get_reduced(arch)
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, S = 2, 64
    batch, _ = make_batch(cfg, B, S, rng)
    logits = jax.jit(model.apply)(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch):
    from repro.train.optimizer import make_optimizer
    from repro.train.train_step import make_train_step
    cfg = get_reduced(arch)
    model = Model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    opt = make_optimizer("adamw", lr=1e-3, total_steps=10)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    B, S = 2, 64
    batch, toks = make_batch(cfg, B, S, rng)
    batch["labels"] = (toks if toks is not None
                       else jax.random.randint(rng, (B, S), 0,
                                               cfg.vocab_size))
    p2, o2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["starcoder2-3b", "mamba2-370m",
                                  "recurrentgemma-2b", "minicpm3-4b",
                                  "kimi-k2-1t-a32b"])
def test_decode_matches_forward(arch):
    """Sequential decode == teacher-forced forward (cache correctness);
    one representative per layer family (full matrix in the model-bringup
    scripts; the other archs share these code paths)."""
    cfg = get_reduced(arch)
    if cfg.num_experts:
        cfg = cfg.scaled(moe_capacity_factor=float(cfg.num_experts))
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, S = 2, 96
    batch, toks = make_batch(cfg, B, S, rng)
    full = jax.jit(model.apply)(params, batch).astype(jnp.float32)
    cache = model.init_cache(B, S, dtype=jnp.float32)
    step = jax.jit(model.decode_step)
    for t in range(S):
        tok = (batch["embeddings"][:, t:t + 1, :]
               if cfg.input_mode == "embeddings" else toks[:, t])
        pos = (jnp.full((3, B, 1), t, jnp.int32)
               if cfg.rope_kind == "mrope" else None)
        logits, cache = step(params, cache, tok, jnp.int32(t), pos)
        err = float(jnp.max(jnp.abs(logits.astype(jnp.float32) - full[:, t])))
        assert err < 2e-2, (arch, t, err)


def test_shape_applicability_matrix():
    """40 cells; long_500k runs only for ssm/hybrid (per assignment)."""
    total = runnable = 0
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for s in SHAPES.values():
            total += 1
            ok, why = shape_applicable(cfg, s)
            if ok:
                runnable += 1
            else:
                assert s.name == "long_500k"
                assert cfg.family not in ("ssm", "hybrid")
    assert total == 40
    assert runnable == 32  # 10 archs x 3 shapes + long_500k for ssm/hybrid

def test_param_count_close_to_actual():
    for arch in ARCH_NAMES:
        cfg = get_reduced(arch)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        # formula ignores padding/small norms: within 25% on tiny configs
        assert est == pytest.approx(actual, rel=0.35), arch


def test_moe_capacity_drops_bounded():
    cfg = get_reduced("kimi-k2-1t-a32b")  # top-4 reduced
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, S = 4, 64
    batch, _ = make_batch(cfg, B, S, rng)
    logits = jax.jit(model.apply)(params, batch)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_int8_kv_cache_decode_close():
    """Beyond-paper int8 KV cache: decode within quantization noise."""
    cfg = get_reduced("starcoder2-3b").scaled(kv_cache_dtype="int8")
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, S = 2, 64
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = jax.jit(model.apply)(params, {"tokens": toks}).astype(jnp.float32)
    cache = model.init_cache(B, S)
    assert cache["blocks"][0]["k"].dtype == jnp.int8
    step = jax.jit(model.decode_step)
    errs = []
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(
            logits.astype(jnp.float32) - full[:, t]))))
    assert max(errs) < 0.1, max(errs)
