#!/usr/bin/env bash
# Local verification: tier-1 tests + a short kernel-benchmark smoke so perf
# regressions (e.g. a kernel silently falling back to per-call dispatch)
# are caught before review.
#
#   scripts/verify.sh            # tier-1 (known-green set) + bench smoke
#   FULL=1 scripts/verify.sh     # include known jax-version-broken modules
#   SKIP_BENCH=1 scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# test_distributed / test_hlo_analysis / test_train_serve carry
# pre-existing failures from jax API drift (jax.sharding.AxisType,
# cost_analysis() shape) unrelated to the coding core; exclude them by
# default so the script is a usable regression gate.
DESELECT=(--ignore=tests/test_distributed.py
          --ignore=tests/test_hlo_analysis.py
          --ignore=tests/test_train_serve.py)
if [ -n "${FULL:-}" ]; then
    DESELECT=()
fi

python -m pytest -x -q "${DESELECT[@]}"

if [ -z "${SKIP_BENCH:-}" ]; then
    # MEMEC_BENCH_FAST trims the sweep to the ~10-second smoke variant
    MEMEC_BENCH_FAST=1 timeout 120 python -m benchmarks.run --only kernels_bench
fi
echo "verify: OK"
