#!/usr/bin/env bash
# Local verification: tier-1 tests + a short kernel-benchmark smoke so perf
# regressions (e.g. a kernel silently falling back to per-call dispatch)
# are caught before review.
#
#   scripts/verify.sh            # tier-1 minus `slow`-marked tests + bench smoke
#   scripts/verify.sh --slow     # full suite incl. `slow` + shard-equivalence smoke
#   scripts/verify.sh --ci       # CI mode: also emit BENCH_ci.json (kernel
#                                # smoke numbers + open-loop tail-latency rows
#                                # + critical-path trace rows for the perf
#                                # trajectory), write the TRACE_ci.json
#                                # Chrome-trace artifact, and fail loudly if
#                                # the bench smoke hangs
#   SKIP_BENCH=1 scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

SLOW=""
CI_MODE=""
for arg in "$@"; do
    case "$arg" in
        --slow) SLOW=1 ;;
        --ci) CI_MODE=1 ;;
        *) echo "unknown argument: $arg" >&2; exit 2 ;;
    esac
done

if [ -n "$SLOW" ]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

if [ -n "$SLOW" ]; then
    # shard-equivalence smoke: a ShardedCluster (S=4, mixed engines) must
    # serve byte-identical contents to the unsharded S=1 cluster for the
    # same seeded batched workload, in normal AND degraded mode.
    python - <<'EOF'
from repro.core import make_cluster
from repro.data.ycsb import YCSBConfig, YCSBWorkload, run_workload

kw = dict(num_servers=16, scheme="rs", n=10, k=8, c=16,
          chunk_size=512, max_unsealed=2)
cfg = YCSBConfig(num_objects=1200, seed=3)
s1 = make_cluster(shards=1, **kw)
s4 = make_cluster(shards=4, engine="numpy,jax", **kw)
for cl in (s1, s4):
    run_workload(cl, "load", 0, cfg, batch_size=16)
    run_workload(cl, "A", 1500, cfg, batch_size=16)
s4.fail_server(s4.global_sid(2, 3))
w = YCSBWorkload(cfg)
keys = [w.key(i) for i in range(cfg.num_objects)]
assert s4.multi_get(keys) == s1.multi_get(keys), "shard equivalence broken"
assert s4.shards[2].stats["degraded_requests"] > 0
assert sum(s4.shards[i].stats["degraded_requests"] for i in (0, 1, 3)) == 0
s4.restore_server(s4.global_sid(2, 3))
assert s4.multi_get(keys) == s1.multi_get(keys)
print("shard-equivalence smoke: OK "
      f"(overlap saved {s4.stats['pipeline_overlap_saved_s']*1e3:.1f} modeled ms)")
EOF

    # elastic scale-out smoke: grow a ring-placed cluster S=2 -> 4 while
    # a YCSB window keeps running between migration batches; the scaled
    # cluster must stay byte-identical with an unscaled reference served
    # the exact same op stream, and no get may fail mid-migration.
    python - <<'EOF'
from repro.core import make_cluster
from repro.data.ycsb import YCSBConfig, YCSBWorkload, run_workload

kw = dict(num_servers=16, scheme="rs", n=10, k=8, c=16,
          chunk_size=512, max_unsealed=2, placement="ring")
cfg = YCSBConfig(num_objects=1000, seed=9)
ref = make_cluster(shards=2, **kw)
cl = make_cluster(shards=2, **kw)
for c in (ref, cl):
    run_workload(c, "load", 0, cfg, batch_size=16)
    run_workload(c, "A", 800, cfg, batch_size=16)
w = YCSBWorkload(cfg)
keys = [w.key(i) for i in range(cfg.num_objects)]
state = {"windows": 0, "failed_gets": 0}

def window(p):
    # the live YCSB window: both clusters serve the same ops mid-move
    wcfg = YCSBConfig(num_objects=cfg.num_objects, seed=100 + state["windows"])
    for c in (ref, cl):
        run_workload(c, "C", 120, wcfg, batch_size=16)
    got = cl.multi_get(keys[:: 5])
    state["failed_gets"] += sum(v is None for v in got)
    state["windows"] += 1

r1 = cl.add_shard(batch_size=48, step_cb=window)   # S=2 -> 3
r2 = cl.add_shard(batch_size=48, step_cb=window)   # S=3 -> 4
assert cl.num_shards == 4 and r1["pending_left"] == r2["pending_left"] == 0
assert state["failed_gets"] == 0, "gets failed during live migration"
assert cl.multi_get(keys) == ref.multi_get(keys), "scale-out equivalence broken"
moved = cl.stats["migration_bytes"] / max(cl.stored_payload_bytes(), 1)
print(f"elastic scale-out smoke: OK (S=2->4, {state['windows']} live "
      f"windows, {cl.stats['migrated_keys']} keys moved, "
      f"{moved:.0%} of resident bytes)")
EOF
fi

if [ -z "${SKIP_BENCH:-}" ]; then
    # MEMEC_BENCH_FAST trims the sweep to the ~10-second smoke variant.
    # Run under `timeout` but do NOT rely on its bare exit status: catch
    # 124 explicitly and fail with a loud, attributable message (a silent
    # `set -e` exit used to be indistinguishable from a bench assert).
    BENCH_LOG="$(mktemp)"
    trap 'rm -f "$BENCH_LOG"' EXIT
    BENCH_TIMEOUT="${BENCH_TIMEOUT:-300}"
    set +e
    MEMEC_BENCH_FAST=1 timeout "$BENCH_TIMEOUT" \
        python -m benchmarks.run --only kernels_bench 2>&1 | tee "$BENCH_LOG"
    rc=${PIPESTATUS[0]}
    set -e
    if [ "$rc" -eq 124 ]; then
        echo "verify: FAIL — kernel bench smoke timed out after ${BENCH_TIMEOUT}s" >&2
        exit 1
    elif [ "$rc" -ne 0 ]; then
        echo "verify: FAIL — kernel bench smoke exited with status $rc" >&2
        exit "$rc"
    fi
    if [ -n "$CI_MODE" ]; then
        # CI artifact: parse the smoke's CSV rows into BENCH_ci.json so
        # the workflow can upload a perf-trajectory data point per run
        python - "$BENCH_LOG" <<'EOF'
import json
import os
import sys

from repro.kernels import dispatch

rows = []
for line in open(sys.argv[1]):
    parts = line.strip().split(",")
    if len(parts) == 3 and not line.startswith(("#", "===")):
        name, us, derived = parts
        try:
            rows.append({"name": name, "us_per_call": float(us),
                         "derived": derived})
        except ValueError:
            continue
out = {
    "suite": "kernels_bench",
    "fast": True,
    "engine_env": os.environ.get("MEMEC_ENGINE", "numpy"),
    "async_env": os.environ.get("MEMEC_ASYNC", "0"),
    # kernel dispatch provenance: which path produced the compiled rows
    # (pallas-compiled / xla-compiled / interpret) on this runner
    "dispatch": dispatch.describe(),
    "tune_cache": os.environ.get("MEMEC_TUNE_CACHE", "defaults"),
    "rows": rows,
}
with open("BENCH_ci.json", "w") as f:
    json.dump(out, f, indent=2)
print(f"BENCH_ci.json: {len(rows)} rows captured")
EOF
    fi
fi

if [ -n "$CI_MODE" ]; then
    # open-loop tail-latency smoke: drive the event runtime at an unloaded
    # and a saturated arrival rate, assert p99 >= p50 and queueing-driven
    # p99 inflation, and merge the per-engine p50/p99 rows into
    # BENCH_ci.json so the workflow tracks the tail trajectory too
    python - <<'EOF'
import json
import os

from benchmarks.throughput import tail_smoke

rows = tail_smoke()
out = {}
if os.path.exists("BENCH_ci.json"):
    with open("BENCH_ci.json") as f:
        out = json.load(f)
out["tail"] = rows
with open("BENCH_ci.json", "w") as f:
    json.dump(out, f, indent=2)
print(f"BENCH_ci.json: {len(rows)} tail rows merged "
      f"(engine={rows[0]['engine']})")
EOF

    # trace smoke (PR 8): traced open-loop window -> TRACE_ci.json Chrome
    # trace artifact (Perfetto-loadable) + per-kind critical-path rows
    # merged into BENCH_ci.json under "trace".  trace_smoke itself guards
    # that the artifact is structurally valid trace-event JSON, that
    # capture->replay reproduces p50/p99 exactly, and that tracing-off
    # runs allocate zero tracer state.
    python - <<'EOF'
import json
import os

from benchmarks.throughput import trace_smoke

row = trace_smoke(path="TRACE_ci.json")
out = {}
if os.path.exists("BENCH_ci.json"):
    with open("BENCH_ci.json") as f:
        out = json.load(f)
out["trace"] = row
with open("BENCH_ci.json", "w") as f:
    json.dump(out, f, indent=2)
print(f"BENCH_ci.json: trace rows merged ({len(row['critical_path'])} kinds; "
      f"artifact {row['artifact']})")
EOF

    # straggler smoke (PR 9): one server inflated 10x, Δ=1 vs Δ=0 twins.
    # straggler_smoke itself asserts the acceptance shape (plain reads
    # degrade >= 5x at p99, one redundant read holds p99 within 2x of
    # the no-injection baseline, contents byte-identical) and its rows
    # merge into BENCH_ci.json under "straggler" for the trajectory.
    python - <<'EOF'
import json
import os

from benchmarks.throughput import straggler_smoke

rows = straggler_smoke()
out = {}
if os.path.exists("BENCH_ci.json"):
    with open("BENCH_ci.json") as f:
        out = json.load(f)
out["straggler"] = rows
with open("BENCH_ci.json", "w") as f:
    json.dump(out, f, indent=2)
print(f"BENCH_ci.json: {len(rows)} straggler rows merged "
      f"(engine={rows[0]['engine']})")
EOF

    # update smoke (PR 10): hot-key version-buffer tier on vs off under
    # an update-heavy Zipf window.  update_smoke itself asserts the
    # acceptance shape (hot-on buffers updates, its UPDATE p99 and
    # modeled parity-delta bytes land strictly below the off twin,
    # contents byte-identical, and an RDP r>1 flush dispatches the
    # compiled per-item kernel — no silent jnp fallback); its rows merge
    # into BENCH_ci.json under "update" for the trajectory.
    python - <<'EOF'
import json
import os

from benchmarks.throughput import update_smoke

rows = update_smoke()
out = {}
if os.path.exists("BENCH_ci.json"):
    with open("BENCH_ci.json") as f:
        out = json.load(f)
out["update"] = rows
with open("BENCH_ci.json", "w") as f:
    json.dump(out, f, indent=2)
print(f"BENCH_ci.json: {len(rows)} update rows merged "
      f"(engine={rows[0]['engine']}, rdp_delta_path="
      f"{rows[0]['rdp_delta_path']})")
EOF

    # tail-regression gate: compare the tail + straggler + update rows
    # just merged against the committed per-engine thresholds; a p99
    # regression fails the build here, loudly, not in review
    python -m benchmarks.ci_gates BENCH_ci.json benchmarks/ci_gates.json

    # marker hygiene: `-m "not slow"` must still collect tests in every
    # async-pipeline-touched module — a marker typo that deselects a
    # whole suite would otherwise pass CI silently
    python -m pytest -q tests/test_marker_guard.py
fi
echo "verify: OK"
