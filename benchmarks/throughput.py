"""Paper Experiment 1: MemEC (no coding) vs all-replication vs hybrid.

The paper compares against Redis/Memcached to validate the prototype; our
in-process analogues are the all-replication store (Redis-with-replication
shape) and MemEC with coding disabled.  Reported numbers are the modeled
bottleneck throughput (busiest server NIC) and modeled p95 latencies —
wall-clock of the simulation is also emitted for reference.
"""
from __future__ import annotations

from repro.data.ycsb import YCSBConfig

from .common import (cluster_metrics, emit, make_allrep, make_hybrid,
                     make_memec, timed_workload)

N_OBJECTS = 4000
N_OPS = 6000


def run():
    print("# Experiment 1 — normal-mode comparison (modeled)")
    print("system,phase,modeled_kops,p95_ms,wall_s")
    systems = {
        "memec-nocoding": lambda: make_memec(scheme="none", n=10, k=10),
        "allrep-3way": make_allrep,
        "hybrid-rs": make_hybrid,
        "memec-rs": lambda: make_memec(scheme="rs"),
    }
    cfg = YCSBConfig(num_objects=N_OBJECTS)
    for name, factory in systems.items():
        cl = factory()
        wall, ops = timed_workload(cl, "load", 0, cfg)
        m = cluster_metrics(cl, ops)
        p95 = m.get("p95_SET_ms", float("nan"))
        print(f"{name},load,{m['modeled_kops']:.1f},{p95:.3f},{wall:.2f}")
        for wl in ("A", "B", "C", "D", "F"):
            cl.net.reset()
            wall, ops = timed_workload(cl, wl, N_OPS, cfg)
            m = cluster_metrics(cl, ops)
            p95 = m.get("p95_GET_ms", float("nan"))
            print(f"{name},{wl},{m['modeled_kops']:.1f},{p95:.3f},{wall:.2f}")
    emit("exp1.done", 0.0, "see rows above")


if __name__ == "__main__":
    run()
