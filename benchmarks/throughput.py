"""Paper Experiment 1: MemEC (no coding) vs all-replication vs hybrid.

The paper compares against Redis/Memcached to validate the prototype; our
in-process analogues are the all-replication store (Redis-with-replication
shape) and MemEC with coding disabled.  Reported numbers are the modeled
bottleneck throughput (busiest server NIC) and modeled p95 latencies —
wall-clock of the simulation is also emitted for reference.
"""
from __future__ import annotations

import os

from repro.data.ycsb import YCSBConfig

from .common import (cluster_metrics, emit, make_allrep, make_hybrid,
                     make_memec, modeled_seq_kops, timed_workload)

N_OBJECTS = 4000
N_OPS = 6000
BATCH_SIZES = (1, 8, 32)


def run():
    print("# Experiment 1 — normal-mode comparison (modeled)")
    print("system,phase,modeled_kops,p95_ms,wall_s")
    systems = {  # paper comparison: single-testbed clusters (shards=1)
        "memec-nocoding": lambda: make_memec(scheme="none", n=10, k=10,
                                             shards=1),
        "allrep-3way": make_allrep,
        "hybrid-rs": make_hybrid,
        "memec-rs": lambda: make_memec(scheme="rs", shards=1),
    }
    cfg = YCSBConfig(num_objects=N_OBJECTS)
    for name, factory in systems.items():
        cl = factory()
        wall, ops = timed_workload(cl, "load", 0, cfg)
        m = cluster_metrics(cl, ops)
        p95 = m.get("p95_SET_ms", float("nan"))
        print(f"{name},load,{m['modeled_kops']:.1f},{p95:.3f},{wall:.2f}")
        for wl in ("A", "B", "C", "D", "F"):
            cl.net.reset()
            wall, ops = timed_workload(cl, wl, N_OPS, cfg)
            m = cluster_metrics(cl, ops)
            p95 = m.get("p95_GET_ms", float("nan"))
            print(f"{name},{wl},{m['modeled_kops']:.1f},{p95:.3f},{wall:.2f}")
    emit("exp1.done", 0.0, "see rows above")
    run_batched_sweep()


def run_batched_sweep():
    """Shards x engine-backend x batch-size sweep over the multi-key API.

    `seq_kops` (ops over summed modeled request latency) is the metric
    that exposes batching AND sharding: a batch's fan-out legs share
    phases, and with S>1 the per-shard sub-batches overlap (the facade
    records max-over-shards latency), so ops/sec must come out >= the
    unbatched/unsharded rows.  `modeled_kops` (bandwidth-bound) grows
    with shard count — S independent testbeds add aggregate NIC
    bandwidth — but stays flat in batch size (same bytes on the wire).
    Axes via MEMEC_BENCH_ENGINES=numpy,jax,pallas (device backends are
    interpret-mode-slow on CPU wall-clock; modeled numbers are the
    comparison that matters there) and MEMEC_BENCH_SHARDS=1,4.
    """
    print("\n# Batched multi-key sweep — shards x engine x batch (modeled)")
    print("shards,engine,batch,phase,seq_kops,modeled_kops,wall_s")
    engines = os.environ.get("MEMEC_BENCH_ENGINES", "numpy").split(",")
    shard_counts = [int(s) for s in
                    os.environ.get("MEMEC_BENCH_SHARDS", "1,4").split(",")]
    n_obj, n_ops = 2000, 3000
    cfg = YCSBConfig(num_objects=n_obj)
    for shards in shard_counts:
        for engine in engines:
            for batch in BATCH_SIZES:
                cl = make_memec(scheme="rs", engine=engine, shards=shards)
                wall, ops = timed_workload(cl, "load", 0, cfg,
                                           batch_size=batch)
                print(f"{shards},{engine},{batch},load,"
                      f"{modeled_seq_kops(cl, ops):.1f},"
                      f"{cluster_metrics(cl, ops)['modeled_kops']:.1f},"
                      f"{wall:.2f}")
                cl.net.reset()
                wall, ops = timed_workload(cl, "A", n_ops, cfg,
                                           batch_size=batch)
                print(f"{shards},{engine},{batch},A,"
                      f"{modeled_seq_kops(cl, ops):.1f},"
                      f"{cluster_metrics(cl, ops)['modeled_kops']:.1f},"
                      f"{wall:.2f}")
    emit("batched_sweep.done", 0.0, "see rows above")


if __name__ == "__main__":
    run()
