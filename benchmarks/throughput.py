"""Paper Experiment 1: MemEC (no coding) vs all-replication vs hybrid.

The paper compares against Redis/Memcached to validate the prototype; our
in-process analogues are the all-replication store (Redis-with-replication
shape) and MemEC with coding disabled.  Reported numbers are the modeled
bottleneck throughput (busiest server NIC) and modeled p95 latencies —
wall-clock of the simulation is also emitted for reference.
"""
from __future__ import annotations

import os

from repro.data.ycsb import YCSBConfig

from .common import (cluster_metrics, emit, make_allrep, make_hybrid,
                     make_memec, modeled_seq_kops, timed_workload)

N_OBJECTS = 4000
N_OPS = 6000
BATCH_SIZES = (1, 8, 32)


def run():
    print("# Experiment 1 — normal-mode comparison (modeled)")
    print("system,phase,modeled_kops,p95_ms,wall_s")
    systems = {
        "memec-nocoding": lambda: make_memec(scheme="none", n=10, k=10),
        "allrep-3way": make_allrep,
        "hybrid-rs": make_hybrid,
        "memec-rs": lambda: make_memec(scheme="rs"),
    }
    cfg = YCSBConfig(num_objects=N_OBJECTS)
    for name, factory in systems.items():
        cl = factory()
        wall, ops = timed_workload(cl, "load", 0, cfg)
        m = cluster_metrics(cl, ops)
        p95 = m.get("p95_SET_ms", float("nan"))
        print(f"{name},load,{m['modeled_kops']:.1f},{p95:.3f},{wall:.2f}")
        for wl in ("A", "B", "C", "D", "F"):
            cl.net.reset()
            wall, ops = timed_workload(cl, wl, N_OPS, cfg)
            m = cluster_metrics(cl, ops)
            p95 = m.get("p95_GET_ms", float("nan"))
            print(f"{name},{wl},{m['modeled_kops']:.1f},{p95:.3f},{wall:.2f}")
    emit("exp1.done", 0.0, "see rows above")
    run_batched_sweep()


def run_batched_sweep():
    """Batch-size x engine-backend sweep over the multi-key client API.

    `seq_kops` (ops over summed modeled request latency) is the metric
    that exposes batching: a batch's fan-out legs share phases, so
    batched ops/sec must come out >= the unbatched row.  `modeled_kops`
    (bandwidth-bound) stays flat by construction — same bytes on the
    wire.  Extra engine backends via MEMEC_BENCH_ENGINES=numpy,jax,pallas
    (device backends are interpret-mode-slow on CPU wall-clock; modeled
    numbers are the comparison that matters there).
    """
    print("\n# Batched multi-key sweep — engine x batch_size (modeled)")
    print("engine,batch,phase,seq_kops,modeled_kops,wall_s")
    engines = os.environ.get("MEMEC_BENCH_ENGINES", "numpy").split(",")
    n_obj, n_ops = 2000, 3000
    cfg = YCSBConfig(num_objects=n_obj)
    for engine in engines:
        for batch in BATCH_SIZES:
            cl = make_memec(scheme="rs", engine=engine)
            wall, ops = timed_workload(cl, "load", 0, cfg, batch_size=batch)
            print(f"{engine},{batch},load,{modeled_seq_kops(cl, ops):.1f},"
                  f"{cluster_metrics(cl, ops)['modeled_kops']:.1f},{wall:.2f}")
            cl.net.reset()
            wall, ops = timed_workload(cl, "A", n_ops, cfg, batch_size=batch)
            print(f"{engine},{batch},A,{modeled_seq_kops(cl, ops):.1f},"
                  f"{cluster_metrics(cl, ops)['modeled_kops']:.1f},{wall:.2f}")
    emit("batched_sweep.done", 0.0, "see rows above")


if __name__ == "__main__":
    run()
