"""Paper Experiment 1: MemEC (no coding) vs all-replication vs hybrid.

The paper compares against Redis/Memcached to validate the prototype; our
in-process analogues are the all-replication store (Redis-with-replication
shape) and MemEC with coding disabled.  Reported numbers are the modeled
bottleneck throughput (busiest server NIC) and modeled p95 latencies —
wall-clock of the simulation is also emitted for reference.
"""
from __future__ import annotations

import os

from repro.data.ycsb import YCSBConfig

from .common import (cluster_metrics, emit, make_allrep, make_hybrid,
                     make_memec, modeled_seq_kops, tail_metrics,
                     timed_workload)

N_OBJECTS = 4000
N_OPS = 6000
BATCH_SIZES = (1, 8, 32)


def run():
    print("# Experiment 1 — normal-mode comparison (modeled)")
    print("system,phase,modeled_kops,p95_ms,wall_s")
    systems = {  # paper comparison: single-testbed clusters (shards=1)
        "memec-nocoding": lambda: make_memec(scheme="none", n=10, k=10,
                                             shards=1),
        "allrep-3way": make_allrep,
        "hybrid-rs": make_hybrid,
        "memec-rs": lambda: make_memec(scheme="rs", shards=1),
    }
    cfg = YCSBConfig(num_objects=N_OBJECTS)
    for name, factory in systems.items():
        cl = factory()
        wall, ops = timed_workload(cl, "load", 0, cfg)
        m = cluster_metrics(cl, ops)
        p95 = m.get("p95_SET_ms", float("nan"))
        print(f"{name},load,{m['modeled_kops']:.1f},{p95:.3f},{wall:.2f}")
        for wl in ("A", "B", "C", "D", "F"):
            cl.net.reset()
            wall, ops = timed_workload(cl, wl, N_OPS, cfg)
            m = cluster_metrics(cl, ops)
            p95 = m.get("p95_GET_ms", float("nan"))
            print(f"{name},{wl},{m['modeled_kops']:.1f},{p95:.3f},{wall:.2f}")
    emit("exp1.done", 0.0, "see rows above")
    run_batched_sweep()


def run_batched_sweep():
    """Shards x engine-backend x batch-size sweep over the multi-key API.

    `seq_kops` (ops over summed modeled request latency) is the metric
    that exposes batching AND sharding: a batch's fan-out legs share
    phases, and with S>1 the per-shard sub-batches overlap (the facade
    records max-over-shards latency), so ops/sec must come out >= the
    unbatched/unsharded rows.  `modeled_kops` (bandwidth-bound) grows
    with shard count — S independent testbeds add aggregate NIC
    bandwidth — but stays flat in batch size (same bytes on the wire).
    Axes via MEMEC_BENCH_ENGINES=numpy,jax,pallas (device backends are
    interpret-mode-slow on CPU wall-clock; modeled numbers are the
    comparison that matters there) and MEMEC_BENCH_SHARDS=1,4.
    """
    print("\n# Batched multi-key sweep — shards x engine x batch (modeled)")
    print("shards,engine,batch,phase,seq_kops,modeled_kops,wall_s")
    engines = os.environ.get("MEMEC_BENCH_ENGINES", "numpy").split(",")
    shard_counts = [int(s) for s in
                    os.environ.get("MEMEC_BENCH_SHARDS", "1,4").split(",")]
    n_obj, n_ops = 2000, 3000
    cfg = YCSBConfig(num_objects=n_obj)
    for shards in shard_counts:
        for engine in engines:
            for batch in BATCH_SIZES:
                cl = make_memec(scheme="rs", engine=engine, shards=shards)
                wall, ops = timed_workload(cl, "load", 0, cfg,
                                           batch_size=batch)
                print(f"{shards},{engine},{batch},load,"
                      f"{modeled_seq_kops(cl, ops):.1f},"
                      f"{cluster_metrics(cl, ops)['modeled_kops']:.1f},"
                      f"{wall:.2f}")
                cl.net.reset()
                wall, ops = timed_workload(cl, "A", n_ops, cfg,
                                           batch_size=batch)
                print(f"{shards},{engine},{batch},A,"
                      f"{modeled_seq_kops(cl, ops):.1f},"
                      f"{cluster_metrics(cl, ops)['modeled_kops']:.1f},"
                      f"{wall:.2f}")
    emit("batched_sweep.done", 0.0, "see rows above")
    run_async_sweep()


def _degraded_get_pair(engine, cost, batch, n_obj):
    """Modeled degraded-GET means, sync vs async, on layout-identical
    twins (one proxy — the YCSB driver's async lane spreading would
    otherwise change chunk packing and the per-chunk recon counts).

    `fail_server(recover=False)` keeps the paper's §5.4 on-demand mode:
    every degraded GET to a sealed chunk runs the decode plan, so the
    column isolates eager decode (submitted, overlapped with the recon
    fetches) against the old lazy-thunk/serial baseline (sync pays
    decode + fetches as a sum)."""
    from repro.data.ycsb import run_workload

    out = {}
    cfg = YCSBConfig(num_objects=n_obj)
    rcfg = YCSBConfig(num_objects=n_obj, seed=77)
    for mode in ("sync", "async"):
        kw = dict(scheme="rs", engine=engine, shards=1, c=4,
                  num_proxies=1, chunk_size=512, max_unsealed=2,
                  async_engine=(mode == "async"))
        if cost is not None:
            kw["cost"] = cost
        cl = make_memec(**kw)
        run_workload(cl, "load", 0, cfg, batch_size=batch)

        # fail the server owning the most sealed DATA chunks (layouts are
        # twin-identical, so both modes pick the same victim)
        def sealed_data(srv):
            return sum(1 for idx, cid in enumerate(srv.chunk_ids)
                       if cid is not None and srv.sealed[idx]
                       and cid.position < cl.k)

        victim = max(range(len(cl.servers)),
                     key=lambda s: sealed_data(cl.servers[s]))
        cl.fail_server(victim, recover=False)
        run_workload(cl, "C", max(n_obj // 2, 200), rcfg, batch_size=batch)
        assert cl.stats["reconstructions"] > 0, \
            "degraded sweep exercised no on-demand decodes"
        out[mode] = cl.net.mean("GET_DEG")
    return out


def run_async_sweep():
    """Sync vs async intra-shard pipeline (PR 4) — engines x batch sizes.

    S=1 (the paper's own testbed shape): the async pipeline issues coding
    through engine futures while the shard's netsim legs are in flight
    (`max(coding, network)` per phase vs the serial sum), overlaps seal
    fan-out with SET acks, and spreads multi-key batches across the
    proxies as concurrent lanes.  `seq_kops` must come out >= the sync
    rows and `intra_saved_ms` > 0; contents are byte-identical (asserted
    here on every run via a full key sweep).  A coding-bound variant
    (CostModel with ~50x slower GF throughput) shows the ceiling.

    The `deg_get_ms` column (PR 5) measures degraded-mode GETs with
    on-demand reconstruction (`fail_server(recover=False)`): eager
    plan/execute decode overlapped with the recon fetches must beat the
    serial lazy-thunk baseline — asserted per config.
    """
    import time

    from repro.core.netsim import CostModel
    from repro.data.ycsb import YCSBWorkload, run_workload

    print("\n# Async pipeline sweep — sync vs async, S=1 (modeled)")
    print("engine,batch,mode,cost,seq_kops,modeled_ms_total,intra_saved_ms,"
          "lane_saved_ms,coding_ms,deg_get_ms,wall_s")
    engines = os.environ.get("MEMEC_BENCH_ENGINES", "numpy").split(",")
    fast = bool(os.environ.get("MEMEC_BENCH_FAST"))
    batch_sizes = (1, 32) if fast else BATCH_SIZES
    n_obj, n_ops = (800, 600) if fast else (2000, 2000)
    deg_obj = 600 if fast else 1000
    cfg = YCSBConfig(num_objects=n_obj)
    # 512-byte chunks so the load phase actually seals (coding on the
    # SET path); "coding-bound" slows GF throughput ~50x to show the
    # ceiling of hiding coding behind the network
    costs = {"lan": None,
             "coding-bound": CostModel(coding_Bps=5e7, coding_fixed_s=2e-5)}
    for engine in engines:
        for batch in batch_sizes:
            for cost_name, cost in costs.items():
                contents, modeled = {}, {}
                deg = _degraded_get_pair(engine, cost, batch, deg_obj)
                w = YCSBWorkload(cfg)
                sweep_keys = [w.key(i) for i in range(n_obj)]
                for mode in ("sync", "async"):
                    kw = dict(scheme="rs", engine=engine, shards=1, c=4,
                              chunk_size=512, max_unsealed=2,
                              async_engine=(mode == "async"))
                    if cost is not None:
                        kw["cost"] = cost
                    cl = make_memec(**kw)
                    t0 = time.perf_counter()
                    ops, _ = run_workload(cl, "load", 0, cfg,
                                          batch_size=batch)
                    ops2, _ = run_workload(cl, "A", n_ops, cfg,
                                           batch_size=batch)
                    wall = time.perf_counter() - t0
                    modeled[mode] = cl.net.total_recorded_s
                    contents[mode] = cl.multi_get(sweep_keys)
                    print(f"{engine},{batch},{mode},{cost_name},"
                          f"{modeled_seq_kops(cl, ops + ops2):.1f},"
                          f"{modeled[mode]*1e3:.2f},"
                          f"{cl.stats['intra_overlap_saved_s']*1e3:.2f},"
                          f"{cl.stats['proxy_lane_saved_s']*1e3:.2f},"
                          f"{cl.stats['modeled_coding_s']*1e3:.2f},"
                          f"{deg[mode]*1e3:.3f},"
                          f"{wall:.2f}")
                assert contents["sync"] == contents["async"], \
                    "async contents diverged from sync"
                assert modeled["async"] < modeled["sync"], \
                    "async pipeline did not reduce modeled latency"
                assert deg["async"] < deg["sync"], \
                    "eager decode did not reduce modeled degraded-GET latency"
    emit("async_sweep.done", 0.0,
         "sync==async contents verified; async modeled latency lower; "
         "eager decode cut degraded-GET latency")
    run_tail_sweep()


def _tail_rows(engine, rates, n_obj, n_ops, inflight=2, seed=11):
    """Open-loop GET tail percentiles at several offered-load multiples.

    The service rate is calibrated from a closed-loop twin (ops over
    modeled request time), then each multiple ``x`` drives a fresh
    cluster with a seeded ``poisson:x*rate`` arrival process through the
    same read-only YCSB window.  Returns one row dict per rate with
    p50/p99/p999 and the queue-wait share (via the telemetry snapshot —
    ``tail_metrics`` validates the schema on every call).
    """
    from repro.data.ycsb import run_workload

    cfg = YCSBConfig(num_objects=n_obj)
    kw = dict(scheme="rs", engine=engine, shards=1, c=4,
              chunk_size=512, max_unsealed=2)
    base = make_memec(**kw)
    run_workload(base, "load", 0, cfg, batch_size=1)
    t0 = base.net.total_recorded_s
    ops, _ = run_workload(base, "C", n_ops, cfg, batch_size=1)
    svc_rate = ops / (base.net.total_recorded_s - t0)
    rows = []
    for x in rates:
        rate = x * svc_rate
        cl = make_memec(arrival=f"poisson:{rate:.6g}:seed={seed}"
                                f":inflight={inflight}", **kw)
        run_workload(cl, "load", 0, cfg, batch_size=1)
        cl.net.reset()   # measure the read window, not the load phase
        run_workload(cl, "C", n_ops, cfg, batch_size=1)
        tm = tail_metrics(cl, kinds=("GET",))["GET"]
        rows.append(dict({"engine": engine, "rate_x": x, "rate_ops_s": rate,
                          "kind": "GET"}, **tm))
    return rows


def run_tail_sweep():
    """Open-loop tail-latency sweep (PR 7) — rate multiples per engine.

    The discrete-event runtime replaces "every request sees an idle
    cluster": with a Poisson arrival process, queueing behind busy
    admission slots / links / engine lanes lands in the percentiles.
    Asserted shape per engine: p99 >= p50 everywhere, p99 monotonically
    non-decreasing in offered load, p50 near-flat below saturation
    (queueing is a tail phenomenon until the queue is persistent), and
    saturation (rate >> service rate) inflating p99 well above the
    unloaded run.
    """
    print("\n# Open-loop tail-latency sweep — rate multiples (modeled)")
    print("engine,rate_x,rate_ops_s,kind,p50_ms,p99_ms,p999_ms,qwait_ms")
    engines = os.environ.get("MEMEC_BENCH_ENGINES", "numpy").split(",")
    fast = bool(os.environ.get("MEMEC_BENCH_FAST"))
    n_obj, n_ops = (250, 400) if fast else (600, 1200)
    rates = (0.2, 0.8, 3.0)
    for engine in engines:
        rows = _tail_rows(engine, rates, n_obj, n_ops)
        for r in rows:
            print(f"{r['engine']},{r['rate_x']},{r['rate_ops_s']:.0f},"
                  f"{r['kind']},{r['p50_ms']:.3f},{r['p99_ms']:.3f},"
                  f"{r['p999_ms']:.3f},{r['queue_wait_ms']:.3f}")
        by = {r["rate_x"]: r for r in rows}
        assert all(r["p99_ms"] >= r["p50_ms"] for r in rows), \
            "p99 below p50 — percentile computation broken"
        p99s = [by[x]["p99_ms"] for x in rates]
        assert all(b >= a for a, b in zip(p99s, p99s[1:])), \
            f"p99 not monotone in offered load: {p99s}"
        assert by[0.8]["p50_ms"] < 2.0 * by[0.2]["p50_ms"], \
            "p50 inflated below saturation — queueing should be a tail effect"
        assert by[3.0]["p99_ms"] > 1.5 * by[0.2]["p99_ms"], \
            "saturation did not inflate p99 over the unloaded run"
    emit("tail_sweep.done", 0.0,
         "p99 monotone in offered load; saturation inflates p99; "
         "p50 flat below saturation")
    run_straggler_sweep()


def _straggler_rows(engine, n_obj, n_ops, seed=23):
    """Closed-loop GET tails on three layout-identical twins: baseline
    (no injection, plain reads), one server inflated 10x with plain
    reads (``injected-d0``), and the same injection with one redundant
    read racing the fan-out (``injected-d1``).

    The victim is the server owning the most sealed data chunks — the
    worst case for a single straggler — inflated *after* the load phase
    so all three layouts are twin-identical.  Asserts the contents stay
    byte-identical across the twins and returns one row per case with
    ``p99_vs_baseline`` precomputed for the CI gate.
    """
    from repro.data.ycsb import YCSBWorkload, run_workload

    cfg = YCSBConfig(num_objects=n_obj, seed=seed)
    rcfg = YCSBConfig(num_objects=n_obj, seed=seed + 1)
    kw = dict(scheme="rs", engine=engine, shards=1, c=4,
              chunk_size=512, max_unsealed=2)
    cases = (("baseline", 0, 1.0),
             ("injected-d0", 0, 10.0),
             ("injected-d1", 1, 10.0))
    rows, contents = [], {}
    for case, delta, factor in cases:
        cl = make_memec(redundant_reads=delta, **kw)
        run_workload(cl, "load", 0, cfg, batch_size=1)
        if factor != 1.0:
            def sealed_data(srv):
                return sum(1 for idx, cid in enumerate(srv.chunk_ids)
                           if cid is not None and srv.sealed[idx]
                           and cid.position < cl.k)
            victim = max(range(len(cl.servers)),
                         key=lambda s: sealed_data(cl.servers[s]))
            assert sealed_data(cl.servers[victim]) > 0, \
                "straggler smoke workload sealed no chunks"
            cl.inflate_server(victim, factor)
        cl.net.reset()   # measure the read window, not the load phase
        run_workload(cl, "C", n_ops, rcfg, batch_size=1)
        tm = tail_metrics(cl, kinds=("GET",))["GET"]
        rows.append(dict({"engine": engine, "case": case, "delta": delta,
                          "inflate_x": factor, "kind": "GET",
                          "redundant_decodes":
                              cl.stats["redundant_decodes"]}, **tm))
        wl = YCSBWorkload(cfg)
        contents[case] = {wl.key(i): cl.get(wl.key(i))
                          for i in range(n_obj)}
    assert contents["baseline"] == contents["injected-d0"] \
        == contents["injected-d1"], \
        "redundant reads changed returned bytes"
    base_p99 = rows[0]["p99_ms"]
    for r in rows:
        r["p99_vs_baseline"] = r["p99_ms"] / base_p99
    return rows


def straggler_smoke(engine=None) -> list[dict]:
    """CI straggler smoke: one 10x server, Δ=1 vs Δ=0 twins.

    Returns the ``"straggler"`` rows for BENCH_ci.json after asserting
    the tentpole's acceptance shape: under a single 10x-inflated server,
    plain reads degrade at least 5x at p99 while one redundant read
    (k-of-(k+1) completion) holds p99 within 2x of the no-injection
    baseline — and actually exercised the redundant decode path.
    """
    engine = engine or os.environ.get("MEMEC_ENGINE", "numpy")
    rows = _straggler_rows(engine, n_obj=1600, n_ops=2000)
    by = {r["case"]: r for r in rows}
    assert by["injected-d0"]["p99_vs_baseline"] >= 5.0, \
        "injection too weak: plain reads did not degrade 5x at p99"
    assert by["injected-d1"]["p99_vs_baseline"] <= 2.0, \
        "redundant read failed to hide the straggler at p99"
    assert by["injected-d1"]["redundant_decodes"] > 0, \
        "straggler smoke never took the redundant-decode path"
    return rows


def run_straggler_sweep():
    """Straggler-injection sweep (PR 9) — Δ=0 vs Δ=1 under one slow
    server, per engine; same shape assertions as the CI smoke."""
    print("\n# Straggler sweep — one 10x server, redundant reads (modeled)")
    print("engine,case,delta,inflate_x,p50_ms,p99_ms,p999_ms,"
          "p99_vs_baseline,redundant_decodes")
    engines = os.environ.get("MEMEC_BENCH_ENGINES", "numpy").split(",")
    fast = bool(os.environ.get("MEMEC_BENCH_FAST"))
    n_obj, n_ops = (1600, 2000) if fast else (2400, 3000)
    for engine in engines:
        rows = _straggler_rows(engine, n_obj, n_ops)
        for r in rows:
            print(f"{r['engine']},{r['case']},{r['delta']},{r['inflate_x']},"
                  f"{r['p50_ms']:.3f},{r['p99_ms']:.3f},{r['p999_ms']:.3f},"
                  f"{r['p99_vs_baseline']:.2f},{r['redundant_decodes']}")
        by = {r["case"]: r for r in rows}
        assert by["injected-d0"]["p99_vs_baseline"] >= 5.0
        assert by["injected-d1"]["p99_vs_baseline"] <= 2.0
        assert by["injected-d1"]["redundant_decodes"] > 0
    emit("straggler_sweep.done", 0.0,
         "one 10x server: d0 p99 degrades >=5x, d1 p99 within 2x of "
         "baseline, contents byte-identical")
    run_update_sweep()


def _update_rows(engine, n_obj, n_ops, seed=31):
    """Update-heavy Zipf window on two layout-identical twins: the
    hot-key version-buffer tier on (``hot-on``) vs off (``hot-off``).

    64-byte values on 512-byte chunks so the load phase seals (the tier
    only touches *sealed* updates); the measured window runs unbatched
    (``batch_size=1``) so a buffered UPDATE's latency is just its
    request+ack phases.  The window is *open-loop*: a seeded Poisson
    arrival at ~0.95x the off-twin's calibrated service rate, so the
    skipped parity rounds lower utilization and the win lands in p99 as
    shorter queue waits (closed-loop, every unbuffered sealed UPDATE
    costs the same deterministic modeled latency, so p99 stays pinned at
    that constant no matter how many hot ops get cheaper).
    ``delta_bytes`` is counted *after* the final explicit
    flush, so the hot-on column pays for every deferred fold — the
    reduction is genuinely the V-versions-to-one-round collapse plus the
    per-key leg union, not deferral.  Asserts the twins end byte-equal
    and returns one row per case with ``p99_vs_off`` /
    ``parity_bytes_vs_off`` precomputed for the CI gate.
    """
    from repro.data.ycsb import YCSBWorkload, run_workload

    cfg = YCSBConfig(num_objects=n_obj, value_sizes=(64, 64), seed=seed)
    rcfg = YCSBConfig(num_objects=n_obj, value_sizes=(64, 64), seed=seed + 1)
    kw = dict(scheme="rs", engine=engine, shards=1, c=4,
              chunk_size=512, max_unsealed=2)
    # calibrate the offered load from a closed-loop off twin (ops over
    # modeled request time through the same update window)
    cal = make_memec(hot_key_threshold=0.0, **kw)
    run_workload(cal, "load", 0, cfg, batch_size=1)
    t0 = cal.net.total_recorded_s
    ops, _ = run_workload(cal, "U", n_ops, rcfg, batch_size=1)
    rate = 0.95 * ops / (cal.net.total_recorded_s - t0)
    arrival = f"poisson:{rate:.6g}:seed={seed}:inflight=2"
    # thresholds are explicit on BOTH twins so $MEMEC_HOT_KEYS in the
    # environment cannot silently turn the off-twin on
    cases = (("hot-off", 0.0), ("hot-on", 3.0))
    rows, contents = [], {}
    for case, threshold in cases:
        cl = make_memec(hot_key_threshold=threshold, arrival=arrival, **kw)
        run_workload(cl, "load", 0, cfg, batch_size=1)
        cl.net.reset()   # measure the update window, not the load phase
        run_workload(cl, "U", n_ops, rcfg, batch_size=1)
        cl.flush_hot_buffers()   # pay every deferred fold inside the window
        tm = tail_metrics(cl, kinds=("UPDATE",))["UPDATE"]
        ht = cl.stats.get("hot_tier", {})
        rows.append(dict({"engine": engine, "case": case,
                          "threshold": threshold, "kind": "UPDATE",
                          "delta_bytes": cl.net.bytes_by_kind.get("delta", 0),
                          "buffered_updates": ht.get("buffered_updates", 0),
                          "flushes": ht.get("flushes", 0),
                          "saved_parity_rounds":
                              ht.get("saved_parity_rounds", 0)}, **tm))
        wl = YCSBWorkload(cfg)
        contents[case] = cl.multi_get([wl.key(i) for i in range(n_obj)])
    assert contents["hot-off"] == contents["hot-on"], \
        "hot-key tier changed returned bytes"
    off = rows[0]
    for r in rows:
        r["p99_vs_off"] = r["p99_ms"] / off["p99_ms"]
        r["parity_bytes_vs_off"] = (r["delta_bytes"] / off["delta_bytes"]
                                    if off["delta_bytes"] else float("nan"))
    return rows


def _rdp_delta_provenance(engine="pallas") -> str:
    """The r>1 acceptance check: a hot-tier flush on an RDP cluster
    (r=16 sub-blocks per chunk) must dispatch the compiled per-item
    delta kernel — ``op_paths['delta_per_item']`` on the pallas engine
    must NOT read ``jnp-fallback``.  Returns the recorded path."""
    from repro.data.ycsb import run_workload

    cfg = YCSBConfig(num_objects=600, value_sizes=(64, 64), seed=9)
    cl = make_memec(scheme="rdp", engine=engine, shards=1, c=4,
                    chunk_size=512, max_unsealed=2, hot_key_threshold=2.0)
    run_workload(cl, "load", 0, cfg, batch_size=1)
    run_workload(cl, "U", 800, cfg, batch_size=1)
    cl.flush_hot_buffers()
    ht = cl.stats.get("hot_tier", {})
    assert ht.get("flushed_versions", 0) > 0, \
        "RDP provenance run never flushed a buffered version"
    path = cl.engine.op_paths.get("delta_per_item")
    assert path is not None, \
        "RDP hot-tier flush never dispatched the per-item delta kernel"
    if engine == "pallas":
        assert path != "jnp-fallback", \
            f"r>1 per-item delta took the jnp fallback (path={path!r})"
    return path


def update_smoke(engine=None) -> list[dict]:
    """CI update smoke: hot-key tier on vs off under an update-heavy
    Zipf window.

    Returns the ``"update"`` rows for BENCH_ci.json after asserting the
    tentpole's acceptance shape: the hot-on twin actually buffered
    updates, its UPDATE p99 and modeled parity-delta bytes come out
    strictly below the off twin, contents stay byte-identical (checked
    inside ``_update_rows``), and an RDP (r>1) flush dispatches the
    compiled per-item kernel rather than the jnp fallback.
    """
    engine = engine or os.environ.get("MEMEC_ENGINE", "numpy")
    rows = _update_rows(engine, n_obj=1200, n_ops=3000)
    by = {r["case"]: r for r in rows}
    assert by["hot-on"]["buffered_updates"] > 0, \
        "update smoke never buffered a hot-key update"
    assert by["hot-on"]["p99_ms"] < by["hot-off"]["p99_ms"], \
        "hot-key tier did not reduce update p99"
    assert by["hot-on"]["delta_bytes"] < by["hot-off"]["delta_bytes"], \
        "hot-key tier did not reduce modeled parity-delta bytes"
    path = _rdp_delta_provenance()
    for r in rows:
        r["rdp_delta_path"] = path
    return rows


def run_update_sweep():
    """Update-heavy sweep (PR 10) — hot-key version-buffer tier on vs
    off, per engine; same shape assertions as the CI smoke."""
    print("\n# Update-heavy sweep — hot-key version buffer (modeled)")
    print("engine,case,p50_ms,p99_ms,p99_vs_off,delta_bytes,"
          "parity_bytes_vs_off,buffered_updates,flushes")
    engines = os.environ.get("MEMEC_BENCH_ENGINES", "numpy").split(",")
    fast = bool(os.environ.get("MEMEC_BENCH_FAST"))
    n_obj, n_ops = (1200, 3000) if fast else (1200, 5000)
    for engine in engines:
        rows = _update_rows(engine, n_obj, n_ops)
        for r in rows:
            print(f"{r['engine']},{r['case']},{r['p50_ms']:.3f},"
                  f"{r['p99_ms']:.3f},{r['p99_vs_off']:.2f},"
                  f"{r['delta_bytes']},{r['parity_bytes_vs_off']:.2f},"
                  f"{r['buffered_updates']},{r['flushes']}")
        by = {r["case"]: r for r in rows}
        assert by["hot-on"]["buffered_updates"] > 0
        assert by["hot-on"]["p99_ms"] < by["hot-off"]["p99_ms"]
        assert by["hot-on"]["delta_bytes"] < by["hot-off"]["delta_bytes"]
    emit("update_sweep.done", 0.0,
         "hot-key tier cut update p99 and parity-delta bytes; "
         "contents byte-identical")


def tail_smoke(engine=None) -> list[dict]:
    """CI tail-latency smoke: one engine column, unloaded vs saturated.

    Returns the row dicts for BENCH_ci.json after asserting p99 >= p50
    on every row and that saturation inflates p99 vs the unloaded run.
    """
    engine = engine or os.environ.get("MEMEC_ENGINE", "numpy")
    rows = _tail_rows(engine, rates=(0.2, 3.0), n_obj=250, n_ops=400)
    assert all(r["p99_ms"] >= r["p50_ms"] for r in rows), \
        "p99 below p50 — percentile computation broken"
    by = {r["rate_x"]: r for r in rows}
    assert by[3.0]["p99_ms"] > 1.5 * by[0.2]["p99_ms"], \
        "saturation did not inflate p99 over the unloaded run"
    return rows


def trace_smoke(path="TRACE_ci.json", engine=None) -> dict:
    """CI trace smoke (PR 8): traced open-loop window -> critical-path
    rows + a Perfetto-loadable Chrome trace artifact.

    Guards the tracer end to end: every span tree checks (children nest,
    max-weight path == recorded latency), the exported JSON is
    structurally valid trace-event format, a `TraceCapture` of the run
    replayed via ``arrival="trace:..."`` reproduces the per-kind
    p50/p99 exactly, and a tracing-off twin allocates zero tracer
    state.  Returns the telemetry ``trace`` summary + ``critical_path``
    rows for BENCH_ci.json's ``"trace"`` key.
    """
    from repro.core import telemetry, trace
    from repro.data.ycsb import run_workload

    engine = engine or os.environ.get("MEMEC_ENGINE", "numpy")
    cfg = YCSBConfig(num_objects=200)
    kw = dict(scheme="rs", engine=engine, shards=1, c=4,
              chunk_size=512, max_unsealed=2,
              arrival="poisson:20000:seed=5:inflight=2")
    cl = make_memec(trace=True, **kw)
    run_workload(cl, "load", 0, cfg, batch_size=1)
    run_workload(cl, "A", 300, cfg, batch_size=1)
    for r in cl.tracer.requests:
        r.check()
    snap = telemetry.validate(telemetry.snapshot(cl))
    assert snap["trace"]["enabled"] and snap["critical_path"], \
        "traced run produced no critical-path rows"
    doc = trace.export_chrome(cl, path=path)
    trace.validate_chrome(doc)

    # capture -> replay reproduces the per-kind p50/p99 exactly
    cap = trace.TraceCapture.from_cluster(cl)
    rep = make_memec(**dict(kw, arrival=cap.arrival_spec()))
    run_workload(rep, "load", 0, cfg, batch_size=1)
    run_workload(rep, "A", 300, cfg, batch_size=1)
    orig, got = cl.net.latency_summary(), rep.net.latency_summary()
    for kind in orig:
        for field in ("count", "p50_s", "p99_s"):
            assert orig[kind][field] == got[kind][field], \
                f"trace replay drifted: {kind}.{field}"

    # tracing-off twin: provably zero tracer state
    off = make_memec(**kw)
    run_workload(off, "load", 0, cfg, batch_size=1)
    assert off.tracer is None and off.net.tracer is None, \
        "tracing-off run allocated tracer state"
    return {"engine": engine, "summary": snap["trace"],
            "critical_path": snap["critical_path"], "artifact": path}


if __name__ == "__main__":
    run()
