"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only redundancy,...] [--fast]

Emits ``name,us_per_call,derived`` CSV rows per experiment plus the
per-table detail rows.  ``--fast`` (equivalently ``MEMEC_BENCH_FAST=1``)
trims every sweep that supports it to its CI smoke variant — the shape
``scripts/verify.sh --ci`` captures into ``BENCH_ci.json``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

MODULES = ["redundancy", "throughput", "coding_schemes", "value_sizes",
           "degraded", "transitions", "rebalance", "kernels_bench",
           "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke variant (sets MEMEC_BENCH_FAST=1)")
    args = ap.parse_args()
    if args.fast:
        os.environ["MEMEC_BENCH_FAST"] = "1"
    selected = args.only.split(",") if args.only else MODULES
    for name in selected:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        mod.run()
        print(f"=== {name} done in {time.time() - t0:.1f}s ===", flush=True)


if __name__ == '__main__':
    main()
