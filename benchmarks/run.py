"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only redundancy,...]

Emits ``name,us_per_call,derived`` CSV rows per experiment plus the
per-table detail rows.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = ["redundancy", "throughput", "coding_schemes", "value_sizes",
           "degraded", "transitions", "rebalance", "kernels_bench",
           "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else MODULES
    for name in selected:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        mod.run()
        print(f"=== {name} done in {time.time() - t0:.1f}s ===", flush=True)


if __name__ == '__main__':
    main()
