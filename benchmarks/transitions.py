"""Paper Experiment 5 (Table 2): state-transition overheads.

T_N->D (fail) and T_D->N (restore) with/without ongoing requests, for
single and double failures.  Modeled milliseconds, averaged over runs.
"""
from __future__ import annotations

import numpy as np

from repro.core import PartialFailure
from repro.data.ycsb import YCSBConfig, YCSBWorkload, run_workload

from .common import emit, make_memec

N_OBJECTS = 2500
RUNS = 5
# load + churn go through the batched multi-key API (engine-seam batch
# paths + one-shot batched recovery at fail time); only the deliberately
# hung crash-hook updates below stay single-key — they must stall
# mid-parity-fanout, which a batch would not model
BATCH = 16


def one_run(double: bool, with_requests: bool, seed: int):
    cl = make_memec(scheme="rdp", chunk_size=512, max_unsealed=2,
                    shards=1)  # paper-testbed experiment: single cluster
    cfg = YCSBConfig(num_objects=N_OBJECTS, seed=seed)
    run_workload(cl, "load", 0, cfg, batch_size=BATCH)
    run_workload(cl, "A", 1500, cfg, batch_size=BATCH)
    w = YCSBWorkload(cfg)
    targets = [3, 11] if double else [3]
    if with_requests:
        # leave unacknowledged mutations hanging mid-parity-fanout (§5.3)
        rng = np.random.default_rng(seed)
        hung = 0
        for i in range(40):
            key = w.key(int(rng.integers(0, N_OBJECTS)))
            sl, ds = cl.mapper.data_server_for(key)
            ref = cl.servers[ds].lookup(key)
            if ref is None or not cl.servers[ds].sealed[ref.chunk_local_idx]:
                continue
            if ds not in targets:
                continue
            newval = bytes(rng.integers(0, 256, ref.value_size,
                                        dtype=np.uint8))
            cl.crash_hook = ("update", key, 1)
            try:
                cl.update(key, newval)
            except PartialFailure:
                hung += 1
            cl.crash_hook = None
            if hung >= 4:
                break
    t_nd = sum(cl.fail_server(s)["T_N_to_D"] for s in targets)
    if with_requests:
        # degraded churn before restore (batched; affected keys fall
        # back to coordinated degraded requests per batch)
        run_workload(cl, "A", 600, cfg, batch_size=BATCH)
    t_dn = sum(cl.restore_server(s)["T_D_to_N"] for s in targets)
    return t_nd * 1e3, t_dn * 1e3


def run():
    print("# Experiment 5 — state transition times (modeled ms)")
    print("failure,requests,T_N_to_D_ms,T_D_to_N_ms")
    for double in (False, True):
        for with_req in (True, False):
            nd, dn = [], []
            for seed in range(RUNS):
                a, b = one_run(double, with_req, seed)
                nd.append(a)
                dn.append(b)
            lbl = "double" if double else "single"
            req = "with" if with_req else "no"
            print(f"{lbl},{req},{np.mean(nd):.2f}±{np.std(nd):.2f},"
                  f"{np.mean(dn):.2f}±{np.std(dn):.2f}")
    emit("exp5.done", 0.0, "all transitions sub-second (paper: <1s)")


if __name__ == "__main__":
    run()
