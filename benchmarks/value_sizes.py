"""Paper Experiment 3: throughput across value sizes (8B..16KB).

Values above the 4KB chunk size exercise the fragmentation path (§3.2).
Reports modeled data throughput (MB/s through the busiest server).
"""
from __future__ import annotations

import numpy as np

from repro.data.ycsb import YCSBConfig

from .common import emit, make_memec, server_endpoints


def run():
    print("# Experiment 3 — value sizes (modeled)")
    print("value_size,phase,modeled_kops,modeled_MBps")
    for vsize in (8, 64, 512, 1024, 4096, 16384):
        n_obj = max(200, 200000 // max(vsize, 64))
        n_ops = n_obj
        cl = make_memec(scheme="rdp", n=10, k=8)
        cfg = YCSBConfig(num_objects=n_obj, value_sizes=(vsize,))
        from repro.data.ycsb import run_workload
        run_workload(cl, "load", 0, cfg)
        tput = cl.net.bottleneck_throughput(n_obj, server_endpoints())
        mbps = tput * vsize / 1e6
        print(f"{vsize},load,{tput / 1e3:.2f},{mbps:.1f}")
        for wl in ("A", "C"):
            cl.net.reset()
            run_workload(cl, wl, n_ops, cfg)
            tput = cl.net.bottleneck_throughput(n_ops, server_endpoints())
            print(f"{vsize},{wl},{tput / 1e3:.2f},{tput * vsize / 1e6:.1f}")
    emit("exp3.done", 0.0, "fragmentation exercised for 16KB values")


if __name__ == "__main__":
    run()
