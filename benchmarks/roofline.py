"""Roofline table from the dry-run artifacts (launch/dryrun.py JSONs).

Three terms per (arch x shape x mesh), TPU v5e constants:
    t_compute    = HLO_FLOPs / peak          (197 TFLOP/s bf16)
    t_memory     = HLO_bytes / HBM bw        (819 GB/s)   [upper bound]
    t_collective = wire bytes / ICI bw       (50 GB/s/link)
plus MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and the dominant term.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_cells(mesh="single"):
    cells = []
    for fn in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def fmt_row(c):
    if c.get("status") == "skipped":
        return (f"{c['arch']},{c['shape']},{c['mesh']},SKIP,,,,,"
                f"\"{c.get('reason', '')[:60]}\"")
    if c.get("status") != "ok":
        return f"{c['arch']},{c['shape']},{c['mesh']},ERROR,,,,,"
    dom = c["bottleneck"]
    useful = c.get("useful_flops_ratio", 0.0)
    return (f"{c['arch']},{c['shape']},{c['mesh']},ok,"
            f"{c['t_compute']:.4f},{c['t_memory']:.4f},"
            f"{c['t_collective']:.4f},{dom},{useful:.3f}")


def run():
    print("# roofline terms (seconds per step; v5e: 197TF/s, 819GB/s, "
          "50GB/s link)")
    print("arch,shape,mesh,status,t_compute,t_memory,t_collective,"
          "bottleneck,useful_flops_ratio")
    for mesh in ("single", "multi"):
        for c in load_cells(mesh):
            print(fmt_row(c))


if __name__ == "__main__":
    run()
