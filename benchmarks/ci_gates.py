"""Tail-regression CI gate (PR 9; update-path gates PR 10).

Compares the ``"tail"``, ``"straggler"`` and ``"update"`` rows of a
BENCH_ci.json produced by ``scripts/verify.sh --ci`` against the
committed per-engine thresholds in ``benchmarks/ci_gates.json`` and
exits non-zero — with a loud per-row table — on any regression.
Missing sections or rows the gates expect are themselves failures: a
smoke that silently stopped emitting a row must not read as "no
regression".

Gate semantics (all values in the gates file):

* ``tail.<engine>.<rate_x>.p99_ms_max`` — absolute p99 ceiling per
  offered-load multiple;
* ``straggler.<engine>.<case>.p99_ms_max`` — absolute p99 ceiling
  (used for the no-injection baseline);
* ``straggler.<engine>.<case>.p99_vs_baseline_max`` — the straggler
  win: with one slow server, redundant reads must hold p99 within this
  factor of baseline;
* ``straggler.<engine>.<case>.p99_vs_baseline_min`` — the injection
  sanity floor: plain reads must visibly degrade, else the smoke is no
  longer actually injecting a straggler;
* ``update.<engine>.<case>.p99_vs_off_max`` — the hot-key tier win:
  the hot-on twin's UPDATE p99 must stay under this fraction of the
  tier-off twin's (``< 1`` keeps the reduction a hard invariant);
* ``update.<engine>.<case>.parity_bytes_vs_off_max`` — same, for the
  modeled parity-delta bytes (counted *including* the final flush);
* ``update.<engine>.<case>.buffered_updates_min`` — sanity floor: the
  smoke must actually have buffered hot-key updates.

``<engine>`` falls back to ``"default"`` when there is no entry for the
bench's engine column.  Usage::

    python -m benchmarks.ci_gates BENCH_ci.json benchmarks/ci_gates.json
"""
from __future__ import annotations

import json
import sys


def _engine_gates(gates: dict, section: str, engine: str) -> dict:
    pool = gates.get(section, {})
    got = pool.get(engine, pool.get("default"))
    if got is None:
        raise SystemExit(
            f"ci_gates: no '{section}' thresholds for engine {engine!r} "
            f"and no 'default' entry — refusing to pass ungated")
    return got


def _check_tail(bench: dict, gates: dict, failures: list, checked: list):
    rows = bench.get("tail")
    if not rows:
        failures.append("tail: no rows in BENCH_ci.json "
                        "(tail smoke stopped emitting?)")
        return
    by_rate = {str(r["rate_x"]): r for r in rows}
    eng = rows[0].get("engine", "default")
    for rate_x, th in _engine_gates(gates, "tail", eng).items():
        row = by_rate.get(rate_x)
        if row is None:
            failures.append(f"tail[{rate_x}]: expected row missing "
                            f"(have {sorted(by_rate)})")
            continue
        got, cap = row["p99_ms"], th["p99_ms_max"]
        line = f"tail[rate_x={rate_x}] p99_ms={got:.3f} max={cap:.3f}"
        (failures if got > cap else checked).append(line)


def _check_straggler(bench: dict, gates: dict, failures: list, checked: list):
    rows = bench.get("straggler")
    if not rows:
        failures.append("straggler: no rows in BENCH_ci.json "
                        "(straggler smoke stopped emitting?)")
        return
    by_case = {r["case"]: r for r in rows}
    eng = rows[0].get("engine", "default")
    for case, th in _engine_gates(gates, "straggler", eng).items():
        row = by_case.get(case)
        if row is None:
            failures.append(f"straggler[{case}]: expected row missing "
                            f"(have {sorted(by_case)})")
            continue
        for key, op, word in (("p99_ms_max", float.__gt__, "max"),
                              ("p99_vs_baseline_max", float.__gt__, "max"),
                              ("p99_vs_baseline_min", float.__lt__, "min")):
            if key not in th:
                continue
            field = "p99_ms" if key == "p99_ms_max" else "p99_vs_baseline"
            got, bound = float(row[field]), float(th[key])
            line = (f"straggler[{case}] {field}={got:.3f} "
                    f"{word}={bound:.3f}")
            (failures if op(got, bound) else checked).append(line)


def _check_update(bench: dict, gates: dict, failures: list, checked: list):
    rows = bench.get("update")
    if not rows:
        failures.append("update: no rows in BENCH_ci.json "
                        "(update smoke stopped emitting?)")
        return
    by_case = {r["case"]: r for r in rows}
    eng = rows[0].get("engine", "default")
    for case, th in _engine_gates(gates, "update", eng).items():
        row = by_case.get(case)
        if row is None:
            failures.append(f"update[{case}]: expected row missing "
                            f"(have {sorted(by_case)})")
            continue
        for key, field, op, word in (
                ("p99_ms_max", "p99_ms", float.__gt__, "max"),
                ("p99_vs_off_max", "p99_vs_off", float.__gt__, "max"),
                ("parity_bytes_vs_off_max", "parity_bytes_vs_off",
                 float.__gt__, "max"),
                ("buffered_updates_min", "buffered_updates",
                 float.__lt__, "min")):
            if key not in th:
                continue
            got, bound = float(row[field]), float(th[key])
            line = f"update[{case}] {field}={got:.3f} {word}={bound:.3f}"
            (failures if op(got, bound) else checked).append(line)


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if len(argv) != 2:
        print("usage: python -m benchmarks.ci_gates "
              "BENCH_ci.json benchmarks/ci_gates.json", file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        bench = json.load(f)
    with open(argv[1]) as f:
        gates = json.load(f)
    if gates.get("schema") != "memec/ci-gates":
        print(f"ci_gates: unrecognized gates schema in {argv[1]}",
              file=sys.stderr)
        return 2
    failures: list[str] = []
    checked: list[str] = []
    _check_tail(bench, gates, failures, checked)
    _check_straggler(bench, gates, failures, checked)
    _check_update(bench, gates, failures, checked)
    for line in checked:
        print(f"ci_gates: OK    {line}")
    for line in failures:
        print(f"ci_gates: FAIL  {line}")
    if failures:
        print(f"ci_gates: {len(failures)} tail-regression gate(s) failed "
              f"({len(checked)} passed) — see rows above", file=sys.stderr)
        return 1
    print(f"ci_gates: all {len(checked)} gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
