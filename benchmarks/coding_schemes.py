"""Paper Experiment 2: RDP vs RS vs no-coding (+ 3-way replication ref).

Key paper findings to reproduce in trend form:
* load-phase throughput with coding ~57% of no-coding (parity fan-out);
* Workload A within ~90% of no-coding (delta updates are cheap);
* Workload C unaffected (GETs touch data servers only);
* RS and RDP nearly identical.
"""
from __future__ import annotations

from repro.data.ycsb import YCSBConfig

from .common import (cluster_metrics, emit, make_allrep, make_memec,
                     timed_workload)

N_OBJECTS = 4000
N_OPS = 6000


def run():
    print("# Experiment 2 — coding schemes (modeled)")
    print("scheme,phase,modeled_kops,p95_set_ms,p95_update_ms,p95_get_ms")
    results = {}
    schemes = {
        "nocoding": lambda: make_memec(scheme="none", n=10, k=10),
        "rs(10,8)": lambda: make_memec(scheme="rs", n=10, k=8),
        "rdp(10,8)": lambda: make_memec(scheme="rdp", n=10, k=8),
        "allrep-3way": make_allrep,
    }
    cfg = YCSBConfig(num_objects=N_OBJECTS)
    for name, factory in schemes.items():
        cl = factory()
        for phase, ops_n in (("load", 0), ("A", N_OPS), ("C", N_OPS)):
            cl.net.reset()
            wall, ops = timed_workload(cl, phase, ops_n, cfg)
            m = cluster_metrics(cl, ops)
            results[(name, phase)] = m["modeled_kops"]
            print(f"{name},{phase},{m['modeled_kops']:.1f},"
                  f"{m.get('p95_SET_ms', float('nan')):.3f},"
                  f"{m.get('p95_UPDATE_ms', float('nan')):.3f},"
                  f"{m.get('p95_GET_ms', float('nan')):.3f}")
    for phase in ("load", "A", "C"):
        base = results[("nocoding", phase)]
        for s in ("rs(10,8)", "rdp(10,8)"):
            emit(f"exp2.{s}.{phase}_vs_nocoding", 0.0,
                 f"{results[(s, phase)] / base * 100:.1f}%")


if __name__ == "__main__":
    run()
