"""Paper Experiment 4: impact of transient failures (degraded requests).

Two cases, as in the paper:
* before writes — failure precedes the load phase (degraded SETs, then
  degraded GET/UPDATE in Workload A);
* after writes — load completes, then a failure (degraded GET/UPDATE via
  on-demand chunk reconstruction).
Each compared against normal mode and against degraded handling DISABLED
(requests wait on the congested server — the paper's 469%/326% blowup).
"""
from __future__ import annotations

from repro.data.ycsb import YCSBConfig, run_workload

from .common import cluster_metrics, emit, make_memec

N_OBJECTS = 3000
N_OPS = 4000
FAILED = 3
# batched multi-key driving (engine-seam path); degraded keys fall back
# to coordinated single-key requests and land in the *_DEG series
BATCH = 8


def merged_p95(cl, kind):
    """p95 over every request that served ops of ``kind``: single-key,
    degraded single-key, and batched multi-key (one entry per batch —
    every op in a batch experiences the batch's latency)."""
    import numpy as np
    lat = cl.net.latencies
    xs = (lat.get(kind, []) + lat.get(kind + "_DEG", [])
          + lat.get("M" + kind, []))
    return float(np.percentile(xs, 95)) * 1e3 if xs else float("nan")


def run():
    print("# Experiment 4 — transient failures (modeled p95 latencies, ms)")
    print("case,mode,SET,UPDATE,GET")
    cfg = YCSBConfig(num_objects=N_OBJECTS)

    # --- baseline: normal mode ---
    cl = make_memec(scheme="rdp", chunk_size=512, max_unsealed=2, shards=1)
    run_workload(cl, "load", 0, cfg, batch_size=BATCH)
    set_n = merged_p95(cl, "SET")
    cl.net.reset()
    run_workload(cl, "A", N_OPS, cfg, batch_size=BATCH)
    upd_n, get_n = merged_p95(cl, "UPDATE"), merged_p95(cl, "GET")
    print(f"normal,normal,{set_n:.3f},{upd_n:.3f},{get_n:.3f}")

    # --- before writes ---
    for degraded in (True, False):
        cl = make_memec(scheme="rdp", chunk_size=512, max_unsealed=2,
                        degraded_enabled=degraded, shards=1)
        cl.fail_server(FAILED)
        run_workload(cl, "load", 0, cfg, batch_size=BATCH)
        s = merged_p95(cl, "SET")
        cl.net.reset()
        run_workload(cl, "A", N_OPS, cfg, batch_size=BATCH)
        u, g = merged_p95(cl, "UPDATE"), merged_p95(cl, "GET")
        mode = "degraded" if degraded else "disabled"
        print(f"before-writes,{mode},{s:.3f},{u:.3f},{g:.3f}")
        if degraded:
            emit("exp4.before.set_increase", 0.0,
                 f"{(s / set_n - 1) * 100:.1f}%")
            emit("exp4.before.update_increase", 0.0,
                 f"{(u / upd_n - 1) * 100:.1f}%")
        else:
            emit("exp4.disabled.update_increase", 0.0,
                 f"{(u / upd_n - 1) * 100:.0f}%")
            emit("exp4.disabled.get_increase", 0.0,
                 f"{(g / get_n - 1) * 100:.0f}%")

    # --- after writes ---
    cl = make_memec(scheme="rdp", chunk_size=512, max_unsealed=2, shards=1)
    run_workload(cl, "load", 0, cfg, batch_size=BATCH)
    cl.fail_server(FAILED)
    cl.net.reset()
    run_workload(cl, "A", N_OPS, cfg, batch_size=BATCH)
    uA, gA = merged_p95(cl, "UPDATE"), merged_p95(cl, "GET")
    cl.net.reset()
    run_workload(cl, "C", N_OPS, cfg, batch_size=BATCH)
    gC = merged_p95(cl, "GET")
    print(f"after-writes,degraded-A,nan,{uA:.3f},{gA:.3f}")
    print(f"after-writes,degraded-C,nan,nan,{gC:.3f}")
    emit("exp4.after.getC_increase", 0.0, f"{(gC / get_n - 1) * 100:.1f}%")
    emit("exp4.after.recon_amortized", 0.0,
         f"reconstructions={cl.stats['reconstructions']} "
         f"hits={cl.stats['recon_chunk_hits']}")


if __name__ == "__main__":
    run()
