"""Paper Experiment 4: impact of transient failures (degraded requests).

Two cases, as in the paper:
* before writes — failure precedes the load phase (degraded SETs, then
  degraded GET/UPDATE in Workload A);
* after writes — load completes, then a failure (degraded GET/UPDATE via
  on-demand chunk reconstruction).
Each compared against normal mode and against degraded handling DISABLED
(requests wait on the congested server — the paper's 469%/326% blowup).
"""
from __future__ import annotations

from repro.data.ycsb import YCSBConfig, run_workload

from .common import cluster_metrics, emit, make_memec

N_OBJECTS = 3000
N_OPS = 4000
FAILED = 3


def p95(cl, kind):
    xs = cl.net.latencies.get(kind) or cl.net.latencies.get(kind + "_DEG")
    if not xs and kind.endswith("_DEG"):
        xs = cl.net.latencies.get(kind[:-4])
    import numpy as np
    return float(np.percentile(xs, 95)) * 1e3 if xs else float("nan")


def merged_p95(cl, kind):
    import numpy as np
    xs = (cl.net.latencies.get(kind, [])
          + cl.net.latencies.get(kind + "_DEG", []))
    return float(np.percentile(xs, 95)) * 1e3 if xs else float("nan")


def run():
    print("# Experiment 4 — transient failures (modeled p95 latencies, ms)")
    print("case,mode,SET,UPDATE,GET")
    cfg = YCSBConfig(num_objects=N_OBJECTS)

    # --- baseline: normal mode ---
    cl = make_memec(scheme="rdp", chunk_size=512, max_unsealed=2)
    run_workload(cl, "load", 0, cfg)
    set_n = merged_p95(cl, "SET")
    cl.net.reset()
    run_workload(cl, "A", N_OPS, cfg)
    upd_n, get_n = merged_p95(cl, "UPDATE"), merged_p95(cl, "GET")
    print(f"normal,normal,{set_n:.3f},{upd_n:.3f},{get_n:.3f}")

    # --- before writes ---
    for degraded in (True, False):
        cl = make_memec(scheme="rdp", chunk_size=512, max_unsealed=2, degraded_enabled=degraded)
        cl.fail_server(FAILED)
        run_workload(cl, "load", 0, cfg)
        s = merged_p95(cl, "SET")
        cl.net.reset()
        run_workload(cl, "A", N_OPS, cfg)
        u, g = merged_p95(cl, "UPDATE"), merged_p95(cl, "GET")
        mode = "degraded" if degraded else "disabled"
        print(f"before-writes,{mode},{s:.3f},{u:.3f},{g:.3f}")
        if degraded:
            emit("exp4.before.set_increase", 0.0,
                 f"{(s / set_n - 1) * 100:.1f}%")
            emit("exp4.before.update_increase", 0.0,
                 f"{(u / upd_n - 1) * 100:.1f}%")
        else:
            emit("exp4.disabled.update_increase", 0.0,
                 f"{(u / upd_n - 1) * 100:.0f}%")
            emit("exp4.disabled.get_increase", 0.0,
                 f"{(g / get_n - 1) * 100:.0f}%")

    # --- after writes ---
    cl = make_memec(scheme="rdp", chunk_size=512, max_unsealed=2)
    run_workload(cl, "load", 0, cfg)
    cl.fail_server(FAILED)
    cl.net.reset()
    run_workload(cl, "A", N_OPS, cfg)
    uA, gA = merged_p95(cl, "UPDATE"), merged_p95(cl, "GET")
    cl.net.reset()
    run_workload(cl, "C", N_OPS, cfg)
    gC = merged_p95(cl, "GET")
    print(f"after-writes,degraded-A,nan,{uA:.3f},{gA:.3f}")
    print(f"after-writes,degraded-C,nan,nan,{gC:.3f}")
    emit("exp4.after.getC_increase", 0.0, f"{(gC / get_n - 1) * 100:.1f}%")
    emit("exp4.after.recon_amortized", 0.0,
         f"reconstructions={cl.stats['reconstructions']} "
         f"hits={cl.stats['recon_chunk_hits']}")


if __name__ == "__main__":
    run()
