"""Shared benchmark utilities: timing, CSV emission, cluster factories."""
from __future__ import annotations

import time

from repro.core import (AllReplicationCluster, HybridEncodingCluster,
                        make_cluster, telemetry)
from repro.data.ycsb import YCSBConfig, run_workload


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")


def make_memec(scheme="rs", n=10, k=8, **kw):
    """Paper-testbed cluster; pass ``shards=S`` for a sharded one (each
    shard is a full 16-server testbed)."""
    defaults = dict(num_servers=16, num_proxies=4, c=16, chunk_size=4096,
                    max_unsealed=4)
    defaults.update(kw)
    return make_cluster(scheme=scheme, n=n, k=k, **defaults)


def make_allrep(**kw):
    return AllReplicationCluster(num_servers=16, n=10, k=8, **kw)


def make_hybrid(**kw):
    return HybridEncodingCluster(num_servers=16, scheme="rs", n=10, k=8, **kw)


def timed_workload(cluster, workload: str, num_ops: int, cfg: YCSBConfig,
                   batch_size: int = 1):
    """Run a workload; return (wall_s, ops, modeled stats snapshot)."""
    cluster.net.reset() if hasattr(cluster.net, "reset") else None
    t0 = time.perf_counter()
    ops, _ = run_workload(cluster, workload, num_ops, cfg,
                          batch_size=batch_size)
    wall = time.perf_counter() - t0
    return wall, ops


def modeled_seq_kops(cluster, ops: int) -> float:
    """Sequential-client throughput: ops over total modeled request time.
    Bandwidth-based `modeled_kops` is invariant to batching (same bytes);
    this metric shows the phase-amortization win of multi-key requests."""
    total_s = sum(sum(v) for v in cluster.net.latencies.values())
    return ops / total_s / 1e3 if total_s > 0 else float("nan")


def server_endpoints(num_servers=16):
    return [f"s{i}" for i in range(num_servers)]


def endpoints_for(cluster):
    """Server endpoint labels of a cluster (shard-aware: a ShardedCluster
    namespaces its per-shard endpoints as ``sh{i}:s{j}``)."""
    if hasattr(cluster, "server_endpoint_names"):
        return cluster.server_endpoint_names()
    return server_endpoints()


def cluster_metrics(cluster, ops: int, kinds=("GET", "UPDATE", "SET")):
    """Modeled metrics: aggregate-bandwidth throughput (primary; Zipf hot
    spots smooth out over the paper's 20M-request runs), max-endpoint
    throughput (skew indicator), p95 latencies (ms)."""
    net = cluster.net
    eps = endpoints_for(cluster)
    out = {
        "modeled_kops": net.mean_throughput(ops, eps) / 1e3,
        "hotspot_kops": net.bottleneck_throughput(ops, eps) / 1e3,
    }
    for kind in kinds:
        for suffix in ("", "_DEG"):
            k = kind + suffix
            if net.latencies.get(k):
                out[f"p95_{k}_ms"] = net.percentile(k, 95) * 1e3
    return out


def tail_metrics(cluster, kinds=None) -> dict:
    """Per-kind tail percentiles (ms) off the validated telemetry
    snapshot — the benchmarks' one consumption point for the versioned
    schema (core/telemetry.py, version 2: adds ``trace`` +
    ``critical_path`` sections), so a schema drift — including a stale
    v1 snapshot — fails here, loudly.

    Returns ``{kind: {count, mean_ms, p50_ms, p99_ms, p999_ms
    [, queue_wait_ms]}}``, restricted to ``kinds`` when given.
    """
    snap = telemetry.validate(telemetry.snapshot(cluster))
    out = {}
    for kind, s in snap["latency"].items():
        if kinds is not None and kind not in kinds:
            continue
        row = {"count": s["count"], "mean_ms": s["mean_s"] * 1e3,
               "p50_ms": s["p50_s"] * 1e3, "p99_ms": s["p99_s"] * 1e3,
               "p999_ms": s["p999_s"] * 1e3}
        if "queue_wait_s" in s:
            row["queue_wait_ms"] = s["queue_wait_s"] * 1e3
        out[kind] = row
    return out
