"""Elastic placement benchmarks: migration cost, liveness, skew escape.

Three experiments over the new placement subsystem (core/ring.py +
core/rebalance.py):

1. **Scale-out movement** — ``add_shard`` on a ring placement must move
   ~1/(S+1) of resident bytes; the FNV-mod placement (the naive
   full-reshuffle baseline) moves ~S/(S+1).  Reported as the fraction of
   resident payload bytes migrated, plus the chunk-fetch overhead of
   moving sealed objects chunk-wise.
2. **Throughput during live migration** — a YCSB window interleaves with
   the migration at every batch boundary; every GET must succeed
   (acceptance: zero failed gets mid-migration) and the modeled
   sequential throughput during the window is compared to the
   pre-migration baseline.
3. **Hot-shard escape** — the skewed-workload axis
   (``run_workload(hot_shard=...)``) parks the Zipf-hot ranks on one
   shard; ``rebalance()`` shifts ring weights inversely to load and the
   post-rebalance window's load skew (max/mean shard ops) is compared to
   the pre-rebalance one.
"""
from __future__ import annotations

from repro.data.ycsb import (YCSBConfig, YCSBWorkload, hot_shard_id_map,
                             run_workload)

from .common import cluster_metrics, emit, modeled_seq_kops

import os

FAST = bool(os.environ.get("MEMEC_BENCH_FAST"))
N_OBJECTS = 1500 if FAST else 4000
OPS = 800 if FAST else 2500
SHARDS = 3
KW = dict(num_servers=10, num_proxies=2, scheme="rs", n=4, k=2, c=8,
          chunk_size=512, max_unsealed=2)
BATCH = 16


def _make(placement):
    from repro.core import make_cluster
    return make_cluster(shards=SHARDS, placement=placement, **KW)


def _load(cl, cfg):
    run_workload(cl, "load", 0, cfg, batch_size=BATCH)


def scale_out_movement():
    cfg = YCSBConfig(num_objects=N_OBJECTS, seed=11)
    frac = {}
    for placement in ("ring", "mod"):
        cl = _make(placement)
        _load(cl, cfg)
        resident = cl.stored_payload_bytes()
        rep = cl.add_shard()
        frac[placement] = rep["moved_bytes"] / resident
        emit(f"rebalance/add_shard_{placement}",
             rep["t_modeled_s"] * 1e6,
             f"moved_frac={frac[placement]:.3f} keys={rep['moved_keys']} "
             f"chunk_fetch_B={rep['chunk_fetch_bytes']}")
        # data plane stays intact after the migration
        w = YCSBWorkload(cfg)
        keys = [w.key(i) for i in range(0, cfg.num_objects, 7)]
        assert all(v is not None for v in cl.multi_get(keys))
    # acceptance: ring ≈ 1/(S+1) of resident bytes, far below the naive
    # full reshuffle (mod ≈ S/(S+1))
    bound = 1.0 / (SHARDS + 1) + 0.08
    assert frac["ring"] <= bound, \
        f"ring moved {frac['ring']:.3f} > {bound:.3f} of resident bytes"
    assert frac["ring"] < 0.5 * frac["mod"], "ring should beat full reshuffle"
    emit("rebalance/ring_vs_reshuffle", 0.0,
         f"ring={frac['ring']:.3f} mod={frac['mod']:.3f} "
         f"bound={bound:.3f} OK")


def throughput_during_migration():
    cfg = YCSBConfig(num_objects=N_OBJECTS, seed=12)
    cl = _make("ring")
    _load(cl, cfg)
    w = YCSBWorkload(cfg)
    probe = [w.key(i) for i in range(0, cfg.num_objects, 5)]

    # pre-migration baseline window
    cl.net.reset()
    ops, _ = run_workload(cl, "B", OPS, cfg, batch_size=BATCH)
    base_kops = modeled_seq_kops(cl, ops)

    # migration with a live YCSB window interleaved at batch boundaries
    cl.net.reset()
    failed_gets = 0
    windows = 0

    def cb(progress):
        nonlocal failed_gets, windows
        got = cl.multi_get(probe)
        failed_gets += sum(v is None for v in got)
        windows += 1

    rep = cl.add_shard(batch_size=32, step_cb=cb)
    live_ops = windows * len(probe)
    live_kops = modeled_seq_kops(cl, live_ops)  # includes MIGRATE time
    emit("rebalance/live_migration", rep["t_modeled_s"] * 1e6,
         f"failed_gets={failed_gets} windows={windows} "
         f"kops_before={base_kops:.1f} kops_during={live_kops:.1f} "
         f"moved={rep['moved_keys']}")
    assert failed_gets == 0, "gets failed during live migration"


def hot_shard_escape():
    cfg = YCSBConfig(num_objects=N_OBJECTS, seed=13)
    cl = _make("ring")
    _load(cl, cfg)
    # fixed hot key set: Zipf-hot ranks parked on shard 1's residents
    # (the map stays constant across the rebalance — hot keys belong to
    # the traffic, and the rebalance disperses those keys across shards)
    id_map = hot_shard_id_map(cl, cfg, hot_shard=1)
    cl.reset_load()
    run_workload(cl, "B", OPS, cfg, batch_size=BATCH, id_map=id_map)
    before = cl.load_skew()
    rep = cl.rebalance(skew_threshold=1.1)
    run_workload(cl, "B", OPS, cfg, batch_size=BATCH, id_map=id_map)
    after = cl.load_skew()
    m = cluster_metrics(cl, OPS, kinds=("GET",))
    emit("rebalance/hot_shard_escape", rep.get("t_modeled_s", 0.0) * 1e6,
         f"skew_before={before:.2f} skew_after={after:.2f} "
         f"moved={rep['moved_keys']} kops={m['modeled_kops']:.1f}")
    assert after < before, "rebalance failed to reduce load skew"


def run():
    scale_out_movement()
    throughput_during_migration()
    hot_shard_escape()
