"""Kernel micro-benchmarks: compiled dispatch vs interpret Pallas vs numpy.

The compiled rows go through ``kernels.dispatch`` (XLA bit-plane path on
CPU, compiled Pallas on TPU/GPU); the interpret rows force the serial
Pallas simulator for reference.  Wall-clock on CPU is NOT the TPU
number — the derived column reports bytes-touched per call so the
§Roofline HBM-bound analysis can translate: encode reads k*C + writes
m*C bytes; delta reads 3C + writes C per row.

``--tune`` runs the shape autotuner over the CI bench shapes instead and
persists the cache (see ``repro.kernels.tune``).
"""
from __future__ import annotations

import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.codes import RSCode
from repro.kernels import dispatch, ops

from .common import emit


def timeit(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        # block EVERY rep: jax dispatch is async, so timing only the loop
        # and syncing once at the end measures enqueue cost, not the op
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    dec = dispatch.decide()
    print(f"# kernel micro-benchmarks (backend={dispatch.backend()} "
          f"path={dec.path})")
    # fail loudly if the "compiled" rows would silently time the interpret
    # simulator — only an explicit $MEMEC_INTERPRET=1 may put us there
    if dec.path == dispatch.INTERPRET and not dispatch.interpret_forced():
        raise RuntimeError(
            "kernels_bench: dispatch landed on interpret without "
            "MEMEC_INTERPRET=1 — compiled path silently unavailable")
    fast = bool(os.environ.get("MEMEC_BENCH_FAST"))  # verify.sh smoke mode
    rng = np.random.default_rng(0)
    code = RSCode(n=10, k=8)
    for C in (4096,) if fast else (4096, 65536):
        data = jnp.asarray(rng.integers(0, 256, (8, C), dtype=np.uint8))
        us_k = timeit(lambda d: ops.encode_stripe(code, d), data)
        us_i = timeit(lambda d: ops.encode_stripe(code, d, interpret=True),
                      data)
        us_r = timeit(lambda d: ops.encode_stripe(code, d, use_ref=True), data)
        t0 = time.perf_counter()
        for _ in range(5):
            code.encode(np.asarray(data))
        us_n = (time.perf_counter() - t0) / 5 * 1e6
        touched = (8 + 2) * C
        emit(f"encode.compiled.C{C}", us_k, f"{touched}B/call {dec.path}")
        emit(f"encode.interpret.C{C}", us_i, f"{touched}B/call interpret")
        emit(f"encode.ref.C{C}", us_r, f"{touched}B/call")
        emit(f"encode.numpy.C{C}", us_n, f"{touched}B/call")
        if C == 4096 and dec.compiled:
            # acceptance gate: the compiled path must beat the interpret
            # simulator by >=3x at the paper's chunk size, every run.
            # One re-measure with more reps before failing — single-core
            # CI runners jitter enough to flip a marginal ratio.
            if us_i / us_k < 3.0:
                us_k = timeit(lambda d: ops.encode_stripe(code, d), data,
                              reps=20)
                us_i = timeit(lambda d: ops.encode_stripe(
                    code, d, interpret=True), data, reps=20)
            assert us_i / us_k >= 3.0, (
                f"compiled encode ({us_k:.0f}us) not >=3x faster than "
                f"interpret ({us_i:.0f}us) at C{C}")

        parity = ops.encode_stripe(code, data)
        old = data[3]
        new = jnp.asarray(rng.integers(0, 256, C, dtype=np.uint8))
        us_d = timeit(lambda p, o, n: ops.apply_parity_delta(code, p, 3, o, n),
                      parity, old, new)
        emit(f"delta.compiled.C{C}", us_d, f"{4 * 2 * C}B/call {dec.path}")

    from repro.core.index import CuckooIndex
    idx = CuckooIndex(num_buckets=1 << 12)
    keys = [b"user%019d" % i for i in range(8000)]
    for i, k in enumerate(keys):
        idx.insert(k, i)
    probe = keys[::4]
    us_c = timeit(lambda: ops.batched_index_lookup(idx, probe))
    emit("cuckoo.compiled.q2000", us_c, f"{len(probe)} probes/call")
    us_cr = timeit(lambda: ops.batched_index_lookup(idx, probe, use_ref=True))
    emit("cuckoo.ref.q2000", us_cr, f"{len(probe)} probes/call")

    # CodingEngine backends: per-stripe cost amortization with batching
    from repro.core.codes import make_code
    from repro.core.engine import make_engine
    C = 4096
    engines = ("numpy", "jax") if fast else ("numpy", "jax", "pallas")
    # fast smoke under CI: always include the matrix-selected engine so
    # e.g. the MEMEC_ENGINE=pallas job tracks pallas decode rows too
    sel = os.environ.get("MEMEC_ENGINE", "").split(",")[0].strip()
    if fast and sel and sel not in engines:
        engines += (sel,)
    for name in engines:
        eng = make_engine(name, code)
        for B in (1, 16):
            data = rng.integers(0, 256, (B, 8, C), dtype=np.uint8)
            us = timeit(eng.encode_batch, data, reps=3)
            emit(f"engine.{name}.encode.B{B}", us,
                 f"{B * (8 + 2) * C}B/call {us / B:.1f}us/stripe")

    # decode path (PR 5 plan/execute split): double-erasure recovery
    # shape — one pattern per batch, so the group-by plans one cached
    # inversion and one batched matmul, and jax/pallas dispatch at submit
    for name in engines:
        eng = make_engine(name, code)
        for B in (1, 16):
            data = rng.integers(0, 256, (B, 8, C), dtype=np.uint8)
            parity = eng.encode_batch(data)
            stripes = np.concatenate([data, parity], axis=1)
            avail = [{i: stripes[b, i] for i in range(10)
                      if i not in (0, 9)} for b in range(B)]
            wanted = [[0, 9]] * B
            us = timeit(lambda: eng.decode_batch(avail, wanted, C), reps=3)
            emit(f"engine.{name}.decode.B{B}", us,
                 f"{B * 8 * C}B/call {us / max(B, 1):.1f}us/stripe")

    # native batched RDP on the Pallas grid (PR 5): the (m*r, k*r) 0/1
    # block matrix runs the column-loop kernel, no jnp fallback
    rdp = make_code("rdp", 10, 8)
    B = 4
    for name in engines:
        eng = make_engine(name, rdp)
        data = rng.integers(0, 256, (B, 8, C), dtype=np.uint8)
        us_e = timeit(eng.encode_batch, data, reps=3)
        emit(f"engine.{name}.rdp_encode.B{B}", us_e, f"{B * 10 * C}B/call")
        parity = eng.encode_batch(data)
        stripes = np.concatenate([data, parity], axis=1)
        avail = [{i: stripes[b, i] for i in range(10) if i not in (2, 8)}
                 for b in range(B)]
        wanted = [[2, 8]] * B
        us_d = timeit(lambda: eng.decode_batch(avail, wanted, C), reps=3)
        emit(f"engine.{name}.rdp_decode.B{B}", us_d, f"{B * 8 * C}B/call")

    # hot-tier collapse (PR 10): XOR-fold V buffered versions per key,
    # then ONE r>1 per-item delta round.  The derived column carries the
    # per-op dispatch provenance (``op_paths``) — on the pallas engine
    # the per-item kernel must be a compiled path, and on the plain jax
    # engine it must *say* jnp-fallback rather than claim otherwise.
    B, V = 8, 4
    for name in engines:
        eng = make_engine(name, rdp)
        data = rng.integers(0, 256, (B, 8, C), dtype=np.uint8)
        parity = np.asarray(eng.encode_batch(data))
        idxs = [int(i) for i in rng.integers(0, 8, B)]
        versions = [rng.integers(0, 256, (V, C), dtype=np.uint8)
                    for _ in range(B)]
        us_c = timeit(lambda: eng.submit_delta_collapse(
            parity, idxs, versions).result(), reps=3)
        path = eng.op_paths.get("delta_per_item", "host")
        emit(f"engine.{name}.rdp_collapse.B{B}V{V}", us_c,
             f"{eng.collapse_work_bytes(versions, C)}B/call path={path}")
        if name == "pallas":
            assert path != "jnp-fallback", (
                "pallas engine r>1 per-item delta silently took the jnp "
                "fallback — dispatch/provenance wiring broken")
        if name == "jax":
            assert path == "jnp-fallback", (
                f"jax engine per-item provenance should read jnp-fallback, "
                f"got {path!r}")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tune", action="store_true",
                    help="run the shape autotuner over the CI bench shapes "
                         "and persist the cache instead of benchmarking")
    args = ap.parse_args(argv)
    if args.tune:
        from repro.kernels import tune
        tune.autotune_ci_shapes(verbose=True)
        path = tune.save()
        print(f"tune cache written: {path}")
        return
    run()


if __name__ == "__main__":
    main()
