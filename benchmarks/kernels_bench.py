"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp ref vs numpy.

Wall-clock on CPU is NOT the TPU number — the derived column reports
bytes-touched per call so the §Roofline HBM-bound analysis can translate:
encode reads k*C + writes m*C bytes; delta reads 3C + writes C per row.
"""
from __future__ import annotations

import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.codes import RSCode
from repro.kernels import ops

from .common import emit


def timeit(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        # block EVERY rep: jax dispatch is async, so timing only the loop
        # and syncing once at the end measures enqueue cost, not the op
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    print("# kernel micro-benchmarks (CPU; interpret-mode Pallas)")
    fast = bool(os.environ.get("MEMEC_BENCH_FAST"))  # verify.sh smoke mode
    rng = np.random.default_rng(0)
    code = RSCode(n=10, k=8)
    for C in (4096,) if fast else (4096, 65536):
        data = jnp.asarray(rng.integers(0, 256, (8, C), dtype=np.uint8))
        us_k = timeit(lambda d: ops.encode_stripe(code, d), data)
        us_r = timeit(lambda d: ops.encode_stripe(code, d, use_ref=True), data)
        t0 = time.perf_counter()
        for _ in range(5):
            code.encode(np.asarray(data))
        us_n = (time.perf_counter() - t0) / 5 * 1e6
        touched = (8 + 2) * C
        emit(f"encode.pallas.C{C}", us_k, f"{touched}B/call")
        emit(f"encode.ref.C{C}", us_r, f"{touched}B/call")
        emit(f"encode.numpy.C{C}", us_n, f"{touched}B/call")

        parity = ops.encode_stripe(code, data)
        old = data[3]
        new = jnp.asarray(rng.integers(0, 256, C, dtype=np.uint8))
        us_d = timeit(lambda p, o, n: ops.apply_parity_delta(code, p, 3, o, n),
                      parity, old, new)
        emit(f"delta.pallas.C{C}", us_d, f"{4 * 2 * C}B/call")

    from repro.core.index import CuckooIndex
    idx = CuckooIndex(num_buckets=1 << 12)
    keys = [b"user%019d" % i for i in range(8000)]
    for i, k in enumerate(keys):
        idx.insert(k, i)
    probe = keys[::4]
    us_c = timeit(lambda: ops.batched_index_lookup(idx, probe))
    emit("cuckoo.pallas.q2000", us_c, f"{len(probe)} probes/call")
    us_cr = timeit(lambda: ops.batched_index_lookup(idx, probe, use_ref=True))
    emit("cuckoo.ref.q2000", us_cr, f"{len(probe)} probes/call")

    # CodingEngine backends: per-stripe cost amortization with batching
    from repro.core.engine import make_engine
    C = 4096
    engines = ("numpy", "jax") if fast else ("numpy", "jax", "pallas")
    for name in engines:
        eng = make_engine(name, code)
        for B in (1, 16):
            data = rng.integers(0, 256, (B, 8, C), dtype=np.uint8)
            us = timeit(eng.encode_batch, data, reps=3)
            emit(f"engine.{name}.encode.B{B}", us,
                 f"{B * (8 + 2) * C}B/call {us / B:.1f}us/stripe")


if __name__ == "__main__":
    run()
