"""Paper Figure 2: redundancy of the three data models.

Analytic curves (the paper's formulas) + a *measured* point from a live
MemEC store to validate the analysis empirically.
"""
from __future__ import annotations

import numpy as np

from repro.core.analysis import (MODELS, AnalysisParams, crossover_value,
                                 figure2_table)

from .common import emit, make_memec


def run():
    print("# Figure 2 — redundancy vs value size")
    print("panel,V,all-replication,hybrid-encoding,all-encoding")
    for K, nk in [(8, (10, 8)), (32, (14, 10))]:
        tab = figure2_table(K, nk)
        for i, V in enumerate(tab["V"]):
            print(f"K{K}-n{nk[0]}k{nk[1]},{V},"
                  f"{tab['all-replication'][i]:.3f},"
                  f"{tab['hybrid-encoding'][i]:.3f},"
                  f"{tab['all-encoding'][i]:.3f}")
    # paper claims
    p = AnalysisParams(K=8, V=2, n=10, k=8)
    ar, hy, ae = (MODELS["all-replication"](p), MODELS["hybrid-encoding"](p),
                  MODELS["all-encoding"](p))
    emit("fig2.reduction_vs_allrep", 0.0, f"{(1 - ae / ar) * 100:.1f}%")
    emit("fig2.reduction_vs_hybrid", 0.0, f"{(1 - ae / hy) * 100:.1f}%")
    emit("fig2.crossover_1.3x_allenc", 0.0,
         f"V={crossover_value(8, (10, 8), 1.3, 'all-encoding')}")
    emit("fig2.crossover_1.3x_hybrid", 0.0,
         f"V={crossover_value(8, (10, 8), 1.3, 'hybrid-encoding')}")

    # measured from a live store (16 servers, RS(10,8), 4KB chunks).
    # steady-state accounting: sealed chunks count fully; unsealed chunks
    # count their used bytes (the fill slack amortizes away at the paper's
    # 10M-object scale — at bench scale it would dominate).
    cl = make_memec(max_unsealed=1)
    rng = np.random.default_rng(0)
    K, V, n_obj = 24, 32, 30000
    for i in range(n_obj):
        cl.set(b"%023d!" % i, rng.bytes(V))
    obj_size = K + V + 4
    payload = n_obj * obj_size
    sealed_bytes = unsealed_used = n_chunks = 0
    for s in cl.servers:
        for idx, cid in enumerate(s.chunk_ids):
            if cid is None:
                continue
            n_chunks += 1
            if s.sealed[idx]:
                sealed_bytes += cl.chunk_size
        for ucs in s.unsealed.values():
            for uc in ucs:
                unsealed_used += uc.builder.used
    # steady-state view, matching the §3.3 analysis assumptions: sealed
    # objects only (the unsealed tail is replicated by design and vanishes
    # at the paper's 10M-object scale); indexes amortized at O=0.9
    # occupancy (not the preallocated table size).
    sealed_payload = payload - unsealed_used
    sealed_objs = sealed_payload / obj_size
    idx_bytes = sealed_objs * 8 / 0.9 + n_chunks * (8 + 8 / 0.9)
    total = sealed_bytes + idx_bytes
    formula = MODELS["all-encoding"](AnalysisParams(K=K, V=V, n=10, k=8))
    emit("fig2.measured_redundancy", 0.0,
         f"measured={total / sealed_payload:.3f} formula={formula:.3f} "
         f"(steady-state: sealed objects, amortized indexes)")
    tail = unsealed_used * 3 / payload  # (n-k+1)-way replicated tail
    emit("fig2.transient_tail", 0.0,
         f"unsealed tail {unsealed_used / payload * 100:.1f}% of payload, "
         f"replicated 3x while unsealed (paper §4.2)")


if __name__ == "__main__":
    run()
