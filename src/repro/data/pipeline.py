"""Data pipeline: deterministic synthetic LM batches, sharded host feed.

Synthetic token streams are Zipf-distributed (real vocab usage is heavy-
tailed, which exercises the vocab-sharded embedding path non-uniformly)
and fully deterministic in (seed, step, host) so elastic restarts resume
byte-identically — a restarted host regenerates exactly the shards it
owes, no data-loader checkpoint needed.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    embed_dim: int = 0        # >0 -> embeddings-mode batches (audio/vlm stubs)
    mrope: bool = False


class SyntheticLM:
    """batch(step) -> {tokens|embeddings, labels[, positions]}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # precompute a Zipf remap table: rank -> token id
        rng = np.random.default_rng(cfg.seed)
        self.perm = rng.permutation(cfg.vocab_size)

    def _rng(self, step: int, host: int = 0):
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 977 + host)

    def batch(self, step: int, host: int = 0, host_count: int = 1) -> dict:
        cfg = self.cfg
        per_host = cfg.global_batch // host_count
        rng = self._rng(step, host)
        ranks = rng.zipf(cfg.zipf_a, size=(per_host, cfg.seq_len + 1))
        toks = self.perm[np.clip(ranks - 1, 0, cfg.vocab_size - 1)]
        out = {}
        if cfg.embed_dim:
            emb = rng.standard_normal(
                (per_host, cfg.seq_len, cfg.embed_dim)).astype(np.float32)
            out["embeddings"] = jnp.asarray(emb * 0.02)
        else:
            out["tokens"] = jnp.asarray(toks[:, :-1].astype(np.int32))
        out["labels"] = jnp.asarray(toks[:, 1:].astype(np.int32))
        if cfg.mrope:
            pos = np.broadcast_to(np.arange(cfg.seq_len, dtype=np.int32),
                                  (3, per_host, cfg.seq_len))
            out["positions"] = jnp.asarray(pos)
        return out
