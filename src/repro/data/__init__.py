"""data subpackage."""
