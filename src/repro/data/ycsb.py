"""YCSB-style workload generator (paper §7 evaluation setup).

Workloads A/B/C/D/F with the paper's request mixes; keys are drawn from a
heavy-tailed Zipf(0.99) distribution over a preloaded object population,
matching §7: 24-byte keys; half the objects 8-byte values, half 32-byte.
"""
from __future__ import annotations

import dataclasses

import numpy as np

WORKLOADS = {
    # proportions of (GET, UPDATE, SET, RMW)
    "load": {"set": 1.0},
    "A": {"get": 0.5, "update": 0.5},
    "B": {"get": 0.95, "update": 0.05},
    "C": {"get": 1.0},
    "D": {"get": 0.95, "set": 0.05},
    "F": {"get": 0.5, "rmw": 0.5},
    # update-heavy (the MemEC evaluation's write-side axis; drives the
    # hot-key version-buffer tier in benchmarks/throughput.py)
    "U": {"get": 0.05, "update": 0.95},
}


@dataclasses.dataclass
class YCSBConfig:
    num_objects: int = 10000
    key_size: int = 24
    value_sizes: tuple = (8, 32)
    zipf_theta: float = 0.99
    seed: int = 42


class ZipfGenerator:
    """Classic YCSB zeta-based Zipfian over [0, n)."""

    def __init__(self, n: int, theta: float, rng: np.random.Generator):
        self.n = n
        self.theta = theta
        self.rng = rng
        self.zetan = np.sum(1.0 / np.power(np.arange(1, n + 1), theta))
        self.alpha = 1.0 / (1.0 - theta)
        zeta2 = np.sum(1.0 / np.power(np.arange(1, 3), theta))
        self.eta = (1 - (2.0 / n) ** (1 - theta)) / (1 - zeta2 / self.zetan)

    def sample(self, size: int) -> np.ndarray:
        u = self.rng.random(size)
        uz = u * self.zetan
        out = np.empty(size, dtype=np.int64)
        cut1 = uz < 1.0
        cut2 = (~cut1) & (uz < 1.0 + 0.5 ** self.theta)
        out[cut1] = 0
        out[cut2] = 1
        rest = ~(cut1 | cut2)
        out[rest] = (self.n * np.power(self.eta * u[rest] - self.eta + 1,
                                       self.alpha)).astype(np.int64)
        return np.clip(out, 0, self.n - 1)


class YCSBWorkload:
    def __init__(self, cfg: YCSBConfig, id_map: np.ndarray | None = None):
        """``id_map`` (optional): permutation of object ids applied to the
        Zipf samples — the skewed-workload axis.  Rank r of the Zipf
        distribution hits object ``id_map[r]``, so a map that front-loads
        one shard's objects (see ``hot_shard_id_map``) concentrates the
        hot tail on that shard."""
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.zipf = ZipfGenerator(cfg.num_objects, cfg.zipf_theta, self.rng)
        self.inserted = cfg.num_objects  # next insert id (workload D)
        self.id_map = id_map

    def key(self, i: int) -> bytes:
        return b"user%019d" % i  # 24 bytes, YCSB-style

    def _map_id(self, i: int) -> int:
        if self.id_map is not None and i < len(self.id_map):
            return int(self.id_map[i])
        return i

    def value_size(self, i: int) -> int:
        return self.cfg.value_sizes[i % len(self.cfg.value_sizes)]

    def value(self, i: int, version: int = 0) -> bytes:
        rng = np.random.default_rng(i * 7919 + version)
        return rng.bytes(self.value_size(i))

    def load_ops(self):
        """The load phase: SET every object once."""
        for i in range(self.cfg.num_objects):
            yield ("set", self.key(i), self.value(i))

    def run_ops(self, workload: str, num_ops: int):
        mix = WORKLOADS[workload]
        kinds = list(mix.keys())
        probs = np.array([mix[k] for k in kinds])
        choices = self.rng.choice(len(kinds), size=num_ops, p=probs)
        ids = self.zipf.sample(num_ops)
        for t in range(num_ops):
            kind = kinds[choices[t]]
            i = self._map_id(int(ids[t]))
            if kind == "get":
                yield ("get", self.key(i), None)
            elif kind == "update":
                yield ("update", self.key(i), self.value(i, version=t))
            elif kind == "set":
                i = self.inserted
                self.inserted += 1
                yield ("set", self.key(i), self.value(i))
            elif kind == "rmw":
                yield ("get", self.key(i), None)
                yield ("update", self.key(i), self.value(i, version=t))


def hot_shard_id_map(cluster, cfg: YCSBConfig, hot_shard: int) -> np.ndarray:
    """Skewed-workload axis: a permutation of object ids that parks the
    Zipf-hottest ranks on ``hot_shard``'s keys, turning key-popularity
    skew into *shard* skew (the scenario ``ShardedCluster.rebalance``
    escapes).  Objects resident on ``hot_shard`` take the low (hot) Zipf
    ranks in id order; everything else follows."""
    w = YCSBWorkload(cfg)
    hot, cold = [], []
    for i in range(cfg.num_objects):
        (hot if cluster.shard_of(w.key(i)) == hot_shard else cold).append(i)
    return np.array(hot + cold, dtype=np.int64)


def run_workload(cluster, workload: str, num_ops: int,
                 cfg: YCSBConfig | None = None, num_proxies: int = 4,
                 batch_size: int = 1, hot_shard: int | None = None,
                 id_map: np.ndarray | None = None):
    """Drive a cluster through a workload; returns the op count executed.

    ``batch_size > 1`` collects a *window* of up to ``batch_size`` ops —
    mixed kinds allowed — and flushes it as per-kind multi-key requests
    (``multi_get``/``multi_set``/``multi_update``), amortizing coding and
    network legs (and, on a sharded cluster, pipelining across shards).
    A window is flushed early whenever an incoming op touches a key the
    window already holds under a conflicting kind, so the per-key
    read/write order — and therefore the final store state — matches
    sequential execution exactly.

    ``hot_shard`` (sharded clusters only) engages the skewed-workload
    axis: Zipf-hot ranks are remapped onto that shard's resident objects
    (``hot_shard_id_map``), producing the hot-shard scenario the
    rebalance benchmark and tests measure.  Pass a precomputed ``id_map``
    instead to keep the *same* hot key set across placement changes
    (hot keys are a property of the traffic, not of the placement).
    """
    cfg = cfg or YCSBConfig()
    if id_map is None and hot_shard is not None:
        id_map = hot_shard_id_map(cluster, cfg, hot_shard)
    w = YCSBWorkload(cfg, id_map=id_map)
    stream = (w.load_ops() if workload == "load"
              else w.run_ops(workload, num_ops))
    avail_proxies = getattr(cluster, "num_proxies", None)
    if avail_proxies:   # never address proxies the cluster doesn't have
        num_proxies = min(num_proxies, avail_proxies)
    ops = 0
    batched = batch_size > 1 and hasattr(cluster, "multi_set")
    if not batched:
        for t, (kind, key, val) in enumerate(stream):
            pid = t % num_proxies
            if kind == "get":
                cluster.get(key, proxy_id=pid)
            elif kind == "update":
                cluster.update(key, val, proxy_id=pid)
            elif kind == "set":
                cluster.set(key, val, proxy_id=pid)
            ops += 1
        return ops, w

    window: list[tuple] = []          # (kind, key, val) in arrival order
    in_window: dict[bytes, str] = {}  # key -> kind currently buffered
    flushes = 0
    # async pipeline: hand the whole window to the store and let it
    # spread per-key-hash lanes across its proxies (proxy_id=None) —
    # concurrent lanes instead of one proxy per flush
    spread = bool(getattr(cluster, "async_engine", False)) and num_proxies > 1

    def flush():
        nonlocal window, in_window, flushes
        if not window:
            return
        pid = None if spread else flushes % num_proxies
        flushes += 1
        by_kind: dict[str, list] = {}
        for kind, key, val in window:   # kinds keep first-arrival order
            by_kind.setdefault(kind, []).append((key, val))
        for kind, items in by_kind.items():
            if kind == "get":
                cluster.multi_get([k for k, _ in items], proxy_id=pid)
            elif kind == "set":
                cluster.multi_set(items, proxy_id=pid)
            elif kind == "update":
                cluster.multi_update(items, proxy_id=pid)
        window = []
        in_window = {}

    for kind, key, val in stream:
        # same-kind repeats of a key are safe inside one multi_* call
        # (the batched paths defer duplicates in order); a kind *switch*
        # on a buffered key would reorder a read against a write
        prev = in_window.get(key)
        if (prev is not None and prev != kind) or len(window) >= batch_size:
            flush()
        window.append((kind, key, val))
        in_window[key] = kind
        ops += 1
    flush()
    return ops, w
