"""Training step: CE loss, grad clip, optimizer, optional EC parity fusion.

The EC-fused step is the paper's UPDATE path applied to training state:
the optimizer's parameter delta (old XOR new bytes) feeds the gamma-scaled
delta-parity collectives every step, keeping an erasure-coded in-memory
copy of the model continuously fresh (DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import Model

from .optimizer import Optimizer, apply_updates, clip_by_global_norm


def cross_entropy(logits, labels, z_loss: float = 1e-4):
    """logits (B,S,Vp) (padded vocab), labels (B,S) int32 < logical vocab."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss.mean()


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits = model.apply(params, batch)
        loss = cross_entropy(logits, batch["labels"])
        return loss, {"loss": loss}
    return loss_fn


def make_train_step(model: Model, optimizer: Optimizer, *,
                    grad_clip: float = 1.0, ec_update_fn=None,
                    donate: bool = True):
    """Returns train_step(params, opt_state, batch[, ec_parity]).

    ec_update_fn(old_params, new_params, parity) -> new_parity is the
    shard_map'd delta-parity closure from `distributed.ecstore`; when
    given, the step threads and refreshes the EC parity buffer.
    """
    loss_fn = make_loss_fn(model)

    def base_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, opt_state, metrics

    if ec_update_fn is None:
        return base_step

    def ec_step(params, opt_state, batch, ec_parity):
        new_params, opt_state, metrics = base_step(params, opt_state, batch)
        new_parity = ec_update_fn(params, new_params, ec_parity)
        return new_params, opt_state, new_parity, metrics

    return ec_step


def eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def step(params, batch):
        loss, _ = loss_fn(params, batch)
        return loss

    return step
