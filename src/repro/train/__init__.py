"""train subpackage."""
