"""Optimizers in pure JAX: AdamW, AdamW-8bit (quantized state), Adafactor.

optax-like API:  opt.init(params) -> state;  opt.update(grads, state,
params) -> (updates, state).  AdamW-8bit stores both moments as int8 with
per-block absmax scales — 4x less optimizer HBM, which is what lets the
1T-param MoE fit the 512-chip fleet (DESIGN.md §6); the EC layer protects
whatever representation the optimizer holds.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# AdamW (fp32 moments)
# ---------------------------------------------------------------------------

def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01,
          warmup_steps: int = 100, schedule: str = "cosine",
          total_steps: int = 10000):
    sched = make_schedule(lr, warmup_steps, schedule, total_steps)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        lr_t = sched(c)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return -lr_t * step, m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW-8bit: int8 blockwise-quantized moments
# ---------------------------------------------------------------------------

_QBLOCK = 256


def _quantize(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _QBLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def adamw8bit(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01,
              warmup_steps: int = 100, schedule: str = "cosine",
              total_steps: int = 10000):
    sched = make_schedule(lr, warmup_steps, schedule, total_steps)

    def init(params):
        def qz(p):
            q, s = _quantize(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}
        return {"m": jax.tree.map(qz, params), "v": jax.tree.map(qz, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        lr_t = sched(c)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, mq, vq, p):
            g = g.astype(jnp.float32)
            m = b1 * _dequantize(mq["q"], mq["s"], g.shape) + (1 - b1) * g
            v = b2 * _dequantize(vq["q"], vq["s"], g.shape) + (1 - b2) * g * g
            v = jnp.maximum(v, 0.0)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            nm_q, nm_s = _quantize(m)
            nv_q, nv_s = _quantize(v)
            return -lr_t * step, {"q": nm_q, "s": nm_s}, {"q": nv_q, "s": nv_s}

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_m = treedef.flatten_up_to(state["m"])
        leaves_v = treedef.flatten_up_to(state["v"])
        leaves_p = jax.tree.leaves(params)
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(leaves_g, leaves_m, leaves_v, leaves_p)]
        updates = treedef.unflatten([o[0] for o in out])
        m = treedef.unflatten([o[1] for o in out])
        v = treedef.unflatten([o[2] for o in out])
        return updates, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; no first moment)
# ---------------------------------------------------------------------------

def adafactor(lr=1e-3, decay=0.8, eps=1e-30, weight_decay=0.0,
              warmup_steps: int = 100, schedule: str = "cosine",
              total_steps: int = 10000, clip_threshold: float = 1.0):
    sched = make_schedule(lr, warmup_steps, schedule, total_steps)

    def init(params):
        def z(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(z, params, is_leaf=None),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        lr_t = sched(c)
        beta = 1.0 - (c.astype(jnp.float32)) ** (-decay)

        def upd(g, f, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if g.ndim >= 2:
                vr = beta * f["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * f["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                step = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                            + 1e-12)
                nf = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                step = g / (jnp.sqrt(v) + 1e-12)
                nf = {"v": v}
            rms = jnp.sqrt(jnp.mean(step * step))
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            step = step + weight_decay * p.astype(jnp.float32)
            return -lr_t * step, nf

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_f = treedef.flatten_up_to(state["f"])
        leaves_p = jax.tree.leaves(params)
        out = [upd(g, f, p) for g, f, p in zip(leaves_g, leaves_f, leaves_p)]
        updates = treedef.unflatten([o[0] for o in out])
        f = treedef.unflatten([o[1] for o in out])
        return updates, {"f": f, "count": c}

    return Optimizer(init, update)


def make_schedule(peak_lr, warmup_steps, kind, total_steps):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        if kind == "cosine":
            prog = jnp.clip((s - warmup_steps) /
                            jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
            decay = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        elif kind == "linear":
            decay = jnp.clip(1 - (s - warmup_steps) /
                             jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        else:
            decay = 1.0
        return peak_lr * jnp.minimum(warm, 1.0) * decay
    return sched


OPTIMIZERS = {"adamw": adamw, "adamw8bit": adamw8bit, "adafactor": adafactor}


def make_optimizer(name: str, **kw) -> Optimizer:
    return OPTIMIZERS[name](**kw)
