"""Checkpointing: disk snapshots + EC in-memory protection.

Disk path (cold): one .npy per leaf + a msgpack manifest, written to a tmp
dir and atomically renamed — restart-safe, resumable, GC'd to keep_last.

EC path (hot): `ECCheckpoint` wraps `distributed.ecstore.ECStateStore`;
parity lives in device memory and is either refreshed per-step (fused
delta updates) or on demand.  Recovery reconstructs a lost data-axis
position from k survivors without touching disk — the paper's core
value proposition moved to the fleet (DESIGN.md §2).
"""
from __future__ import annotations

import json
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed.ecstore import ECConfig, ECStateStore


# ---------------------------------------------------------------------------
# disk checkpoints
# ---------------------------------------------------------------------------

_NATIVE_DTYPES = {"float64", "float32", "float16", "int64", "int32",
                  "int16", "int8", "uint64", "uint32", "uint16", "uint8",
                  "bool"}


def _leaf_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, keep_last: int = 3):
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = []
    for i, (name, leaf) in enumerate(_leaf_paths(tree)):
        arr = np.asarray(leaf)
        fn = f"{i:05d}.npy"
        logical = str(arr.dtype)
        if arr.dtype.name not in _NATIVE_DTYPES:
            # bfloat16 & friends (ml_dtypes): persist the raw bytes
            arr = arr.view(np.uint8) if arr.ndim else \
                np.frombuffer(arr.tobytes(), np.uint8)
        np.save(os.path.join(tmp, fn), arr)
        manifest.append({"file": fn, "name": name,
                         "shape": list(np.asarray(leaf).shape),
                         "dtype": logical})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def restore_checkpoint(ckpt_dir: str, step: int, tree_like):
    """Restore into the structure of tree_like (shapes must match)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(manifest["leaves"]) == len(leaves), \
        "checkpoint/tree structure mismatch"
    out = []
    for meta, leaf in zip(manifest["leaves"], leaves):
        a = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] not in _NATIVE_DTYPES:
            import ml_dtypes
            a = np.frombuffer(a.tobytes(), np.dtype(getattr(
                ml_dtypes, meta["dtype"]))).reshape(meta["shape"])
        out.append(jnp.asarray(a, dtype=leaf.dtype))
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# EC in-memory checkpoints
# ---------------------------------------------------------------------------

class ECCheckpoint:
    """Hot, in-memory, erasure-coded copy of training state."""

    def __init__(self, mesh, state_specs, cfg: ECConfig | None = None):
        self.store = ECStateStore(mesh, state_specs, cfg)
        self.parity = None

    def create(self, state):
        self.parity = self.store.encode(state)
        return self.parity

    def update(self, old_state, new_state):
        assert self.parity is not None, "create() first"
        self.parity = self.store.delta_update(old_state, new_state,
                                              self.parity)
        return self.parity

    def reconstruct(self, state, failed_data_index: int):
        """Pages of the failed data-axis position (see ecstore docs)."""
        assert self.parity is not None
        return self.store.reconstruct(state, self.parity, failed_data_index)
