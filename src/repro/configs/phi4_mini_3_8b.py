"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA. [arXiv:2412.08905; hf]
32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=200064, head_dim=128,
    layer_pattern="A", rope_kind="rope", rope_theta=10000.0,
)

REDUCED = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512,
                        attn_block_q=32, attn_block_kv=64)
