"""kimi-k2-1t-a32b [moe] — Kimi K2, trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]
61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=112,
    layer_pattern="M", num_experts=384, experts_per_token=8,
    rope_kind="rope", rope_theta=50000.0,
    # §Perf A1: head-parallel attention (64 heads / 16-way TP)
    attn_parallel="auto",
)

REDUCED = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=512, num_experts=16,
                        experts_per_token=4, attn_block_q=32, attn_block_kv=64)
