"""mistral-large-123b [dense]. [hf:mistralai/Mistral-Large-Instruct-2407]
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=28672, vocab_size=32768, head_dim=128,
    layer_pattern="A", rope_kind="rope", rope_theta=1000000.0,
)

REDUCED = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512,
                        attn_block_q=32, attn_block_kv=64)
