"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]
Backbone only: the vision frontend is a STUB — input_specs() provides
precomputed patch embeddings + (t,h,w) position ids.
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    layer_pattern="A", rope_kind="mrope", mrope_sections=(16, 24, 24),
    input_mode="embeddings",
)

REDUCED = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512,
                        mrope_sections=(2, 3, 3),
                        attn_block_q=32, attn_block_kv=64)
