"""musicgen-medium [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]  Backbone only: the EnCodec frontend is a STUB —
input_specs() provides precomputed frame embeddings.
48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    layer_pattern="A", rope_kind="rope", input_mode="embeddings",
)

REDUCED = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                        head_dim=16, d_ff=128, vocab_size=128,
                        attn_block_q=32, attn_block_kv=64)
