"""Architecture registry: --arch <id> -> ModelConfig (+ reduced variants)."""
from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mamba2-370m": "mamba2_370m",
    "minicpm3-4b": "minicpm3_4b",
    "starcoder2-3b": "starcoder2_3b",
    "mistral-large-123b": "mistral_large_123b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "musicgen-medium": "musicgen_medium",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCH_NAMES = list(_ARCH_MODULES)


def _module(name: str):
    key = name.replace("_", "-")
    if key not in _ARCH_MODULES:
        key = name  # maybe already dashed
    if key not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[key]}")


def get_config(name: str):
    return _module(name).CONFIG


def get_reduced(name: str):
    return _module(name).REDUCED


def memec_config():
    from . import memec
    return memec.CONFIG
