"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2. [arXiv:2402.19427]
26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000, window 2048.
Pattern RRW: two recurrent blocks then one local-attention block."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    layer_pattern="RRW", local_window=2048, rope_kind="rope",
    tie_embeddings=True, logit_softcap=30.0, rglru_conv=4,
)

REDUCED = CONFIG.scaled(num_layers=6, d_model=64, num_heads=4, num_kv_heads=1,
                        head_dim=16, d_ff=128, vocab_size=512, local_window=64,
                        attn_block_q=32, attn_block_kv=64)
