"""The paper's own system configuration (MemEC §7 testbed).

16 servers, 4 proxies, 1 coordinator; (n,k)=(10,8); c=16 stripe lists;
4 KB chunks; RS or RDP coding; YCSB-style workloads with 24-byte keys and
8/32-byte values.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class MemECConfig:
    num_servers: int = 16
    num_proxies: int = 4
    scheme: str = "rs"          # rs | rdp | xor | none
    n: int = 10
    k: int = 8
    c: int = 16                 # stripe lists
    chunk_size: int = 4096
    max_unsealed: int = 4
    key_size: int = 24
    value_sizes: tuple = (8, 32)
    # batched coding-engine backend: numpy | jax | pallas (see
    # core/engine.py).  None defers to $MEMEC_ENGINE, default numpy.
    # A comma-separated list assigns backends per shard (cycling), e.g.
    # "pallas,numpy" = pallas on even shards, numpy on odd.
    engine: str | None = None
    # multi-key request batch size for the batched client API / YCSB
    # driver (1 = classic per-key requests)
    batch_size: int = 1
    # shard count for core/shard.py's ShardedCluster (hash of key ->
    # shard; each shard is an independent paper-testbed cluster).  1 =
    # the paper's single unsharded cluster; None defers to $MEMEC_SHARDS.
    shards: int | None = 1
    # key->shard placement policy (core/ring.py): "mod" (historical
    # FNV-mod), "ring" / "ring:<vnodes>" (elastic consistent-hash ring —
    # required for add_shard/remove_shard/rebalance).  None defers to
    # $MEMEC_PLACEMENT, default "mod".
    placement: str | None = None
    # intra-shard async coding pipeline (core/store.py): submit engine
    # work through futures while the shard's own netsim legs are in
    # flight — request latency charges max(coding, network) per phase
    # instead of the serial sum, and multi-key batches may spread across
    # proxies as concurrent lanes.  Byte-identical to the sync pipeline.
    # None defers to $MEMEC_ASYNC, default off.
    async_engine: bool | None = None


CONFIG = MemECConfig()


def make_configured_cluster(cfg: MemECConfig = CONFIG, **overrides):
    """Build the cluster this config describes (sharded iff shards > 1)."""
    from repro.core.shard import make_cluster
    kw = dict(num_servers=cfg.num_servers, num_proxies=cfg.num_proxies,
              scheme=cfg.scheme, n=cfg.n, k=cfg.k, c=cfg.c,
              chunk_size=cfg.chunk_size, max_unsealed=cfg.max_unsealed,
              engine=cfg.engine, shards=cfg.shards, placement=cfg.placement,
              async_engine=cfg.async_engine)
    kw.update(overrides)
    return make_cluster(**kw)
