"""mamba2-370m [ssm] — SSD (state-space duality). [arXiv:2405.21060]
48L d_model=1024 (attn-free) vocab=50280, ssm_state=128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=0,
    layer_pattern="S", ssm_state=128, ssm_expand=2, ssm_headdim=64,
    ssm_conv=4, ssm_chunk=256, rope_kind="none", tie_embeddings=True,
)

REDUCED = CONFIG.scaled(num_layers=2, d_model=64, vocab_size=512,
                        ssm_state=16, ssm_headdim=16, ssm_chunk=32)
