"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, GQA, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    layer_pattern="M", num_experts=128, experts_per_token=1,
    rope_kind="rope", rope_theta=500000.0,
)

REDUCED = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=96, vocab_size=512, num_experts=8,
                        experts_per_token=1, attn_block_q=32, attn_block_kv=64)
