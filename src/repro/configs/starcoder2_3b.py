"""starcoder2-3b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]
30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152, head_dim=128,
    layer_pattern="A", rope_kind="rope", rope_theta=100000.0,
)

REDUCED = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512,
                        attn_block_q=32, attn_block_kv=64)
