"""minicpm3-4b [dense] — MLA. [hf:openbmb/MiniCPM3-4B; hf]
62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=6400, vocab_size=73448, head_dim=64,
    layer_pattern="L", rope_kind="rope", rope_theta=10000.0,
    q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32,
    v_head_dim=64,
)

REDUCED = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                        head_dim=16, d_ff=128, vocab_size=512, q_lora_rank=32,
                        kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                        v_head_dim=16, attn_block_q=32, attn_block_kv=64)
