"""Assigned input shapes (per-arch shape set) + ShapeDtypeStruct specs.

Four LM shapes:
  train_4k     seq=4096   global_batch=256   (training step)
  prefill_32k  seq=32768  global_batch=32    (inference prefill)
  decode_32k   seq=32768  global_batch=128   (one-token decode, 32k cache)
  long_500k    seq=524288 global_batch=1     (long-context decode;
               sub-quadratic archs only — full-attention archs SKIP)

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
seq_len KV cache), NOT ``train_step``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    subquadratic_only: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1,
                           subquadratic_only=True),
}

# families whose serving state is O(1)/O(window) per token
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.subquadratic_only and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, (f"{shape.name} needs sub-quadratic attention; "
                       f"{cfg.name} is full-attention ({cfg.family}) — "
                       f"skipped per assignment (see DESIGN.md)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {}
        if cfg.input_mode == "embeddings":
            batch["embeddings"] = sds((B, S, cfg.d_model), f32)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        if cfg.rope_kind == "mrope":
            batch["positions"] = sds((3, B, S), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = sds((B, S), jnp.int32)
        return batch
    # decode: one new token against a seq_len cache
    batch = {}
    if cfg.input_mode == "embeddings":
        batch["tokens"] = sds((B, 1, cfg.d_model), f32)
    else:
        batch["tokens"] = sds((B,), jnp.int32)
    if cfg.rope_kind == "mrope":
        batch["positions"] = sds((3, B, 1), jnp.int32)
    batch["cur_len"] = sds((), jnp.int32)
    return batch
