import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init).  For each cell we:

    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...) \
            .lower(*input_specs(...))        # ShapeDtypeStructs only
        compiled = lowered.compile()
        compiled.memory_analysis()           # proves it fits
        compiled.cost_analysis()             # FLOPs/bytes for the roofline

plus a post-SPMD HLO parse that sums per-device collective operand bytes
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-
permute) — cost_analysis does not report them.

Special pseudo-arch ``ecstore``: lowers the MemEC parity delta-update and
decode-from-k reconstruction collectives over the same mesh — the paper's
own technique as a dry-run cell.
"""
import argparse
import json
import re
import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES, input_specs, shape_applicable
from repro.distributed import sharding as shd
from repro.distributed.ecstore import ECConfig, ECStateStore
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step

# TPU v5e hardware constants (roofline targets; DESIGN.md)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8\w*|s64|u64|s32|u32|s16|u16|"
                       r"s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dt = m.group(1)
    base = _DTYPE_BYTES.get(dt[:4] if dt.startswith("f8") else dt, 1)
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * base


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))            # [num_groups, group_size]
    m = _GROUPS_V1_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic from the post-SPMD HLO.

    Optimized HLO names operands without inline shapes, so sizes come from
    the RESULT shape + the replica-group size g:
      operand bytes:  all-reduce/all-to-all/permute = result;
                      all-gather = result/g; reduce-scatter = result*g.
      wire bytes (ring model): all-reduce 2(g-1)/g * result;
                      all-gather (g-1)/g * result;
                      reduce-scatter (g-1) * result;
                      all-to-all (g-1)/g * result; permute = result.
    """
    out = {k: 0 for k in _COLLECTIVES}
    wire = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        opm = re.match(r"(?:\((?:[^()]|\([^)]*\))*\)|\S+)\s+([a-z0-9\-]+)\(",
                       rhs)
        if not opm:
            continue
        op = opm.group(1)
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        kind = next((k for k in _COLLECTIVES if base == k), None)
        if kind is None:
            continue
        shapes = list(_SHAPE_RE.finditer(rhs[: rhs.find("(")]))
        if not shapes:
            continue
        result = sum(_shape_bytes(m) for m in shapes)
        g = max(_group_size(s), 1)
        if kind == "all-gather":
            operand = result // g
            w = result * (g - 1) / g
        elif kind == "all-reduce":
            operand = result
            w = 2.0 * result * (g - 1) / g
        elif kind == "reduce-scatter":
            operand = result * g
            w = result * (g - 1)
        elif kind == "all-to-all":
            operand = result
            w = result * (g - 1) / g
        else:  # collective-permute
            operand = result
            w = float(result)
        out[kind] += operand
        wire[kind] += w
        count[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    out["wire"] = {k: int(v) for k, v in wire.items()}
    out["wire_total"] = int(sum(wire.values()))
    return out


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh, *, optimizer="adamw8bit",
               remat="full", attn=None, kv=None):
    """Returns (step_fn, args_shapes, in_shardings, out_shardings, meta).
    attn/kv None -> respect the arch config's own setting."""
    from repro.models.layers import set_activation_mesh
    set_activation_mesh(mesh)
    over = {"remat": remat}
    if attn is not None:
        over["attn_parallel"] = attn
    if kv is not None:
        over["kv_cache_dtype"] = kv
    cfg = get_config(arch).scaled(**over)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, why
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params_sh = jax.eval_shape(model.init, rng)
    pspecs = shd.param_specs(cfg, params_sh, mesh)
    batch_sh = input_specs(cfg, shape)
    meta = {"params": int(sum(np.prod(x.shape) for x in
                              jax.tree.leaves(params_sh))),
            "model_params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        opt = make_optimizer(optimizer, total_steps=10000)
        opt_sh = jax.eval_shape(opt.init, params_sh)
        ospecs = jax.tree.map(
            lambda _: P(), opt_sh,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        # optimizer state shards like its param (moments are per-param)
        ospecs = _opt_specs(opt_sh, pspecs, mesh)
        bspecs = shd.batch_specs(cfg, batch_sh, mesh)
        step = make_train_step(model, opt)
        args = (params_sh, opt_sh, batch_sh)
        in_sh = (pspecs, ospecs, bspecs)
        out_sh = (pspecs, ospecs, jax.tree.map(lambda _: P(), {"loss": 0, "grad_norm": 0}))
        return (step, args, in_sh, out_sh, meta), None

    if shape.kind == "prefill":
        bspecs = shd.batch_specs(cfg, batch_sh, mesh)

        def prefill_step(params, batch):
            return model.apply(params, batch)

        logits_spec = shd.fit_spec(
            P(("pod", "data") if "pod" in mesh.axis_names else ("data",),
              None, "model"),
            (shape.global_batch, shape.seq_len, cfg.padded_vocab), mesh)
        return ((prefill_step, (params_sh, batch_sh), (pspecs, bspecs),
                 logits_spec, meta), None)

    # decode
    cache_sh = jax.eval_shape(
        partial(model.init_cache, shape.global_batch, shape.seq_len,
                dtype=jnp.bfloat16))
    cspecs = shd.cache_specs(cfg, cache_sh, mesh)
    bspecs = shd.batch_specs(cfg, batch_sh, mesh)

    def serve_step(params, cache, batch):
        logits, new_cache = model.decode_step(
            params, cache, batch["tokens"], batch["cur_len"],
            batch.get("positions"))
        return logits, new_cache

    logits_spec = shd.fit_spec(
        P(("pod", "data") if "pod" in mesh.axis_names else ("data",), "model"),
        (shape.global_batch, cfg.padded_vocab), mesh)
    return ((serve_step, (params_sh, cache_sh, batch_sh),
             (pspecs, cspecs, bspecs), (logits_spec, cspecs), meta), None)


def _opt_specs(opt_sh, pspecs, mesh):
    """Optimizer moments inherit their param's spec (quantized int8 moments
    are flat blocks — replicate the tiny scales, shard q like a flat page)."""
    def build(tree):
        if isinstance(tree, dict) and set(tree) == {"q", "s"}:
            return {"q": P(), "s": P()}
        return None

    def rec(o, p=None):
        if isinstance(o, jax.ShapeDtypeStruct):
            if p is not None and len(p) == len(o.shape):
                return shd.fit_spec(p, o.shape, mesh)
            return P()
        if isinstance(o, dict):
            qd = build(o)
            if qd is not None:
                return qd
            out = {}
            for k2, v in o.items():
                pp = p.get(k2) if isinstance(p, dict) and k2 in p else None
                out[k2] = rec(v, pp)
            return out
        if isinstance(o, (list, tuple)):
            t = [rec(v, p[i] if isinstance(p, (list, tuple)) and
                     i < len(p) else None) for i, v in enumerate(o)]
            return type(o)(t)
        return P()

    # moments mirror the params tree under keys m/v/f
    out = {}
    for key, sub in opt_sh.items():
        if key in ("m", "v", "f"):
            out[key] = rec(sub, pspecs)
        else:
            out[key] = jax.tree.map(
                lambda _: P(), sub,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return out


# ---------------------------------------------------------------------------
# the ecstore pseudo-arch (paper-technique cells)
# ---------------------------------------------------------------------------

def build_ec_cell(mesh, *, bytes_per_device: int = 1 << 28, op="update"):
    """Lower the MemEC parity collectives over the mesh.

    bytes_per_device of protected state per chip (default 256 MiB —
    a 123B-param bf16 model sharded over 512 chips is ~0.5 GiB/chip).
    """
    cfg = ECConfig()
    axes = mesh.axis_names
    sizes = dict(zip(axes, mesh.devices.shape))
    pages_local = bytes_per_device // cfg.page_size
    pages_local -= pages_local % cfg.k
    S = pages_local // cfg.k
    gshape = tuple(sizes[a] for a in axes)
    state_sh = jax.ShapeDtypeStruct(
        gshape + (pages_local, cfg.page_size), jnp.uint8)
    par_sh = jax.ShapeDtypeStruct(
        gshape + (cfg.m, S, cfg.page_size), jnp.uint8)
    sspec = P(*axes, None, None)
    pspec = P(*axes, None, None, None)

    from repro.distributed._compat import shard_map
    from repro.distributed.ecstore import (parity_delta_update,
                                           parity_delta_update_chain,
                                           reconstruct_failed)

    nlead = len(axes)

    if op in ("update", "update_chain"):
        upd = (parity_delta_update_chain if op == "update_chain"
               else parity_delta_update)

        def step(xor_pages, parity):
            def f(xp, par):
                xp = xp.reshape(xp.shape[nlead:])
                par = par.reshape(par.shape[nlead:])
                out = upd(xp, par, cfg)
                return out.reshape((1,) * nlead + out.shape)
            return shard_map(f, mesh=mesh, in_specs=(sspec, pspec),
                             out_specs=pspec, check_rep=False)(
                                 xor_pages, parity)
        args = (state_sh, par_sh)
        in_sh = (sspec, pspec)
        out_sh = pspec
    else:  # reconstruct
        def step(pages, parity):
            def f(pg, par):
                pg = pg.reshape(pg.shape[nlead:])
                par = par.reshape(par.shape[nlead:])
                rec = reconstruct_failed(pg, par, jnp.int32(3), cfg)
                return rec.reshape((1,) * nlead + rec.shape)
            return shard_map(f, mesh=mesh, in_specs=(sspec, pspec),
                             out_specs=sspec, check_rep=False)(pages, parity)
        args = (state_sh, par_sh)
        in_sh = (sspec, pspec)
        out_sh = sspec
    meta = {"bytes_per_device": bytes_per_device, "ec": f"RS({cfg.n},{cfg.k})"}
    return step, args, in_sh, out_sh, meta


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             optimizer="adamw8bit", remat="full", attn=None,
             kv=None, save_hlo: str | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if arch == "ecstore":
        op = shape_name if shape_name in ("update", "update_chain",
                                          "reconstruct") else "update"
        step, args, in_sh, out_sh, meta = build_ec_cell(mesh, op=op)
    else:
        built, why = build_cell(arch, shape_name, mesh,
                                optimizer=optimizer, remat=remat, attn=attn,
                                kv=kv)
        if built is None:
            return {"arch": arch, "shape": shape_name,
                    "mesh": "multi" if multi_pod else "single",
                    "status": "skipped", "reason": why}
        step, args, in_sh, out_sh, meta = built

    def to_named(t):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))

    with mesh:
        jitted = jax.jit(step, in_shardings=to_named(in_sh),
                         out_shardings=to_named(out_sh))
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        from repro.core.analysis import xla_cost_analysis
        cost = xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # scan-aware analysis (XLA cost_analysis counts while bodies once —
    # see hlo_analysis docstring; raw numbers kept for cross-reference)
    from repro.launch.hlo_analysis import analyze as hlo_analyze
    ana = hlo_analyze(hlo)
    coll = {
        "total": ana["collective_bytes_total"],
        "wire_total": ana["collective_wire_total"],
        "counts": ana["collective_counts"],
        "wire": ana["collective_wire_bytes"],
    }
    for k in _COLLECTIVES:
        coll[k] = ana["collective_op_bytes"].get(k, 0)
        coll["wire"].setdefault(k, 0)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    n_dev = mesh.devices.size
    flops = float(ana["flops"])
    bytes_acc = float(ana["bytes"])
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    mem_d = {}
    if mem is not None:
        for attr in ("generated_code_size_in_bytes",
                     "argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_d[attr] = int(v)
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": int(n_dev), "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        # per-device numbers (SPMD module), scan-aware (hlo_analysis)
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "xla_cost_analysis_raw": {"flops": raw_flops, "bytes": raw_bytes},
        "collective_bytes_per_device": coll["total"],
        "collective_wire_bytes_per_device": coll["wire_total"],
        "collectives": {k: coll[k] for k in _COLLECTIVES},
        "collective_wire": coll["wire"],
        "collective_counts": coll["counts"],
        "memory_analysis": mem_d,
        "meta": meta,
    }
    # roofline terms (seconds); collective term uses the ring wire model
    res["t_compute"] = flops / PEAK_FLOPS
    res["t_memory"] = bytes_acc / HBM_BW
    res["t_collective"] = coll["wire_total"] / ICI_BW
    terms = {"compute": res["t_compute"], "memory": res["t_memory"],
             "collective": res["t_collective"]}
    res["bottleneck"] = max(terms, key=terms.get)
    if arch != "ecstore":
        model_flops = _model_flops(arch, shape_name)
        res["model_flops_per_device"] = model_flops / n_dev
        res["useful_flops_ratio"] = (
            (model_flops / n_dev) / flops if flops else 0.0)
    return res


def _model_flops(arch: str, shape_name: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode: D = global_batch."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "decode":
        tokens = shape.global_batch  # one token per sequence
        return 2.0 * n_active * tokens  # forward only
    tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def all_cells():
    cells = []
    for arch in ARCH_NAMES:
        for s in SHAPES:
            cells.append((arch, s))
    cells.append(("ecstore", "update"))
    cells.append(("ecstore", "reconstruct"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--optimizer", default="adamw8bit")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--attn", default=None, choices=["seq", "head", "auto"])
    ap.add_argument("--kv", default=None, choices=["bfloat16", "int8"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = ([(args.arch, args.shape)] if args.arch and args.shape
             else [(a, s) for a, s in all_cells()
                   if (not args.arch or a == args.arch)
                   and (not args.shape or s == args.shape)])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            if args.tag:
                tag += f"__{args.tag}"
            try:
                res = run_cell(arch, shape, mp, optimizer=args.optimizer,
                               remat=args.remat, attn=args.attn, kv=args.kv,
                               save_hlo=args.save_hlo)
            except Exception as e:  # noqa: BLE001 — record the failure
                res = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single",
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1)
            status = res.get("status")
            extra = (res.get("reason") or res.get("error") or
                     f"bottleneck={res.get('bottleneck')} "
                     f"t=({res.get('t_compute', 0):.4f},"
                     f"{res.get('t_memory', 0):.4f},"
                     f"{res.get('t_collective', 0):.4f})s")
            print(f"[{tag}] {status}: {extra}", flush=True)


if __name__ == "__main__":
    main()
