"""Training launcher: end-to-end driver with EC in-memory checkpoints.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --reduced --steps 200 --batch 8 --seq 128 --ec

On CPU this drives reduced configs (the quickstart example); on a real
fleet the same driver runs the full configs over the production mesh.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import sharding as shd
from repro.distributed.ecstore import ECConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import Model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ec", action="store_true",
                    help="maintain an EC in-memory checkpoint")
    ap.add_argument("--ec-k", type=int, default=2)
    ap.add_argument("--ec-m", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_reduced(args.arch) if args.reduced else get_config(args.arch))
    model = Model(cfg)
    mesh = {"host": make_host_mesh,
            "single": make_production_mesh,
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    if args.mesh != "host":
        from repro.models import set_activation_mesh
        set_activation_mesh(mesh)

    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    opt = make_optimizer(args.optimizer, lr=args.lr,
                         warmup_steps=min(20, args.steps // 5 + 1),
                         total_steps=args.steps)
    opt_state = opt.init(params)
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0,
        mrope=cfg.rope_kind == "mrope"))

    ec_update_fn = None
    ec = None
    if args.ec:
        params_sh = jax.eval_shape(lambda: params)
        pspecs = shd.param_specs(cfg, params_sh, mesh)
        ec_cfg = ECConfig(k=args.ec_k, m=args.ec_m, page_size=256,
                          axis="data")
        ec = ckpt.ECCheckpoint(mesh, pspecs, ec_cfg)
        ec.create(params)
        print(f"EC checkpoint created: RS({ec_cfg.n},{ec_cfg.k}), "
              f"overhead {ec_cfg.m}/{ec_cfg.k}")

    step_fn = jax.jit(make_train_step(model, opt))
    start_step = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state = ckpt.restore_checkpoint(args.ckpt_dir, last,
                                            {"p": params, "o": opt_state})
            params, opt_state = state["p"], state["o"]
            start_step = last
            print(f"resumed from step {last}")

    losses = []
    t0 = time.time()
    with mesh:
        for step in range(start_step, args.steps):
            batch = data.batch(step)
            old_params = params
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if ec is not None:
                ec.update(old_params, params)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"({dt:.1f}s)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save_checkpoint(args.ckpt_dir, step + 1,
                                     {"p": params, "o": opt_state})
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
