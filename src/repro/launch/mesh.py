"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    import jax.sharding as jshard
    if hasattr(jshard, "AxisType"):  # explicit axis types need jax >= 0.6
        return jax.make_mesh(
            shape, axes, axis_types=(jshard.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Version-guarded ``jax.make_mesh``: requests ``AxisType.Auto`` axes
    where the installed jax has them and plain axes otherwise.  Every
    mesh construction (tests, examples, launch scripts) must route
    through here — constructing with ``axis_types=`` directly raises
    ``AttributeError`` on jax < 0.6."""
    return _mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke tests."""
    return _mesh((1, 1), ("data", "model"))


def make_test_mesh(data: int = 4, model: int = 2):
    """Small mesh for unit tests (needs XLA_FLAGS device count)."""
    return _mesh((data, model), ("data", "model"))
