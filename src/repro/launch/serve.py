"""Serving launcher: batched generation with EC-protected cache pages.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --reduced --batch 4 --prompt-len 32 --gen 32 --protect
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.distributed import sharding as shd
from repro.distributed.ecstore import ECConfig
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--protect", action="store_true",
                    help="EC-protect the KV cache pages")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_reduced(args.arch) if args.reduced else get_config(args.arch))
    model = Model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    max_len = args.prompt_len + args.gen
    eng = ServeEngine(model, params, max_len=max_len, batch_size=args.batch)

    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    logits = eng.prefill({"tokens": prompts})
    t_prefill = time.time() - t0
    if args.protect:
        mesh = make_host_mesh()
        cache_sh = jax.eval_shape(lambda: eng.cache)
        cspecs = shd.cache_specs(cfg, cache_sh, mesh)
        eng.protect_cache(mesh, cspecs, ECConfig(k=1, m=1, page_size=256))
        print("cache pages EC-protected")
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.time()
    res = eng.decode(args.gen, temperature=args.temperature,
                     first_tokens=first)
    t_decode = time.time() - t0
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen} steps in {t_decode:.2f}s "
          f"({args.batch * args.gen / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample tokens:", res.tokens[0][:16])
    return res


if __name__ == "__main__":
    main()
