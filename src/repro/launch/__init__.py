"""launch subpackage."""
