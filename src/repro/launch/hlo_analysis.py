"""Scan-aware cost analysis of post-SPMD HLO.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
by calibration: a scan of 8 matmuls reports 1 matmul of flops), which
undercounts everything in scan-over-layers models by the trip count —
including the FSDP all-gathers *inside* the layer scan.  This module
re-derives flops / bytes / collective traffic by parsing the HLO module,
walking the call graph, and multiplying by ``known_trip_count``:

* dot flops:      2 * prod(result dims) * prod(lhs contracting dims)
* elementwise:    1 flop per result element (fusions: result elements)
* bytes:          operand + result bytes per instruction; fusion = one op
                  (internals fused); call ops pass by reference (0 bytes)
* collectives:    per-kind operand/wire bytes (ring model), multiplied by
                  enclosing trip counts

Validated against XLA cost_analysis on scan-free graphs (exact match for
dots) and against hand-counted scan graphs.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|"
    r"pred|token|c64|c128)\[([0-9,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "negate",
    "compare", "select", "and", "or", "xor", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "convert", "floor",
    "ceil", "sign", "cosine", "sine", "logistic", "reduce", "clamp",
}


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    """Total (elements, bytes) across all shapes in a type string."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES.get(m.group(1), 1)
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: list
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    shapes: dict  # %name -> result type string


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^=]*?\)|[\w\[\],\{\}\s]*?))\s*"
    r"([\w\-]+)\((.*)$")


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    comment = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment.sub("", raw).strip()
        if not line:
            continue
        if (line.startswith("ENTRY") or
                (line.startswith("%") and "->" in line and
                 line.endswith("{"))):
            m = _COMP_HEAD.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if line.startswith("}"):
            continue
        if cur is None or " = " not in line:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        # split call args (up to matching paren) from attributes
        depth = 0
        idx = 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
        args = rest[:idx]
        attrs = rest[idx + 1:]
        operands = re.findall(r"%([\w\.\-]+)", args)
        cur.instrs.append(Instr(name, rtype, op, operands, attrs))
        cur.shapes[name] = rtype
    return comps


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TOAPPLY_RE = re.compile(r"to_apply=%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


def _permute_ring_distance(attrs: str) -> float:
    """Mean circular hop distance of a collective-permute (torus links):
    a shift-8 permute occupies 8x the per-link bandwidth of a shift-1."""
    m = _PAIRS_RE.search(attrs)
    if not m:
        return 1.0
    pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(1))
    if not pairs:
        return 1.0
    ids = sorted({int(x) for p in pairs for x in p})
    rank = {d: i for i, d in enumerate(ids)}
    n = len(ids)
    dists = []
    for s, t in pairs:
        d = (rank[int(t)] - rank[int(s)]) % n
        dists.append(min(d, n - d))
    return sum(dists) / len(dists) if dists else 1.0


def _group_size(attrs: str) -> int:
    m = _GROUPS_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_wire: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_op.items():
            self.coll_op[k] += v * mult
        for k, v in other.coll_wire.items():
            self.coll_wire[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += v * mult


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    memo: dict[str, Cost] = {}

    def comp_cost(cname: str, stack=()) -> Cost:
        if cname in memo:
            return memo[cname]
        if cname in stack or cname not in comps:
            return Cost()
        comp = comps[cname]
        total = Cost()
        for ins in comp.instrs:
            op = ins.op
            rtype = ins.result_type
            relems, rbytes = _shape_elems_bytes(rtype)
            if op == "while":
                trip = 1
                m = _TRIP_RE.search(ins.attrs)
                if m:
                    trip = int(m.group(1))
                b = _BODY_RE.search(ins.attrs)
                c = _COND_RE.search(ins.attrs)
                if b:
                    total.add(comp_cost(b.group(1), stack + (cname,)), trip)
                if c:
                    total.add(comp_cost(c.group(1), stack + (cname,)),
                              trip + 1)
                continue
            if op in ("call", "async-start"):
                m = _CALLS_RE.search(ins.attrs) or _TOAPPLY_RE.search(ins.attrs)
                if m:
                    total.add(comp_cost(m.group(1), stack + (cname,)))
                continue
            if op == "conditional":
                m = _BRANCH_RE.search(ins.attrs)
                if m:
                    subs = re.findall(r"%([\w\.\-]+)", m.group(1))
                    costs = [comp_cost(s, stack + (cname,)) for s in subs]
                    if costs:  # worst branch
                        total.add(max(costs, key=lambda c: c.flops + c.bytes))
                continue
            # operand bytes (lookup shapes by name within this computation)
            obytes = 0
            for oname in ins.operands:
                t = comp.shapes.get(oname)
                if t:
                    obytes += _shape_elems_bytes(t)[1]
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                g = max(_group_size(ins.attrs), 1)
                if base == "all-gather":
                    operand, wire = rbytes / g, rbytes * (g - 1) / g
                elif base == "all-reduce":
                    operand, wire = rbytes, 2.0 * rbytes * (g - 1) / g
                elif base == "reduce-scatter":
                    operand, wire = rbytes * g, rbytes * (g - 1)
                elif base == "all-to-all":
                    operand, wire = rbytes, rbytes * (g - 1) / g
                else:
                    # per-link cost scales with torus hop distance
                    operand = rbytes
                    wire = float(rbytes) * _permute_ring_distance(ins.attrs)
                total.coll_op[base] += operand
                total.coll_wire[base] += wire
                total.coll_count[base] += 1
                total.bytes += obytes + rbytes
                continue
            if op == "dot":
                lhs_t = comp.shapes.get(ins.operands[0], "") if ins.operands \
                    else ""
                ldims = _dims(lhs_t)
                cm = _CONTRACT_RE.search(ins.attrs)
                contract = 1
                if cm and cm.group(1):
                    for ci in cm.group(1).split(","):
                        i = int(ci)
                        if i < len(ldims):
                            contract *= ldims[i]
                total.flops += 2.0 * relems * contract
                total.bytes += obytes + rbytes
                continue
            if op == "fusion":
                # internals are fused: one result + operands through HBM;
                # count ~1 flop per output element for the fused loop
                total.flops += relems
                total.bytes += obytes + rbytes
                continue
            if op in _ELEMENTWISE:
                total.flops += relems
                total.bytes += obytes + rbytes
                continue
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "copy-start", "copy-done", "after-all",
                      # layout/view ops that fuse into consumers on TPU —
                      # counting them would double-charge HBM traffic
                      "copy", "transpose", "reshape", "broadcast", "iota",
                      "convert", "slice", "pad", "reverse",
                      "bitcast-convert", "partition-id", "replica-id"):
                continue
            # memory-moving ops (dynamic-slice/update, gather, scatter,
            # concatenate, sort, rng, ...)
            total.bytes += obytes + rbytes
        memo[cname] = total
        return total

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0}
    c = comp_cost(entry.name)
    coll_total = sum(c.coll_op.values())
    wire_total = sum(c.coll_wire.values())
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_op_bytes": dict(c.coll_op),
        "collective_wire_bytes": dict(c.coll_wire),
        "collective_counts": {k: int(v) for k, v in c.coll_count.items()},
        "collective_bytes_total": coll_total,
        "collective_wire_total": wire_total,
    }
