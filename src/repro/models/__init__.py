"""Model substrate: unified decoder stack for all assigned architectures."""
from .config import ModelConfig
from .layers import set_activation_mesh, shard_act
from .transformer import Model, apply_layer, init_layer

__all__ = ["ModelConfig", "Model", "apply_layer", "init_layer",
           "set_activation_mesh", "shard_act"]
