"""Core layers: norms, RoPE/M-RoPE, blockwise (flash-style) attention,
GQA / MLA / local attention, SwiGLU, embeddings.

Conventions:
* params are plain dicts of jnp arrays; `init_*` builds them, `*_apply`
  consumes them.  Stacking across layers (for scan) happens in
  transformer.py.
* activations are (batch, seq, ...) in cfg.dtype; reductions in fp32.
* attention is blockwise (online softmax over KV tiles) so 32k-token
  prefill never materializes an (S, S) score matrix.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# activation sharding (GSPMD propagation is lossy through the blockwise-
# attention reshape/transpose/scan chains — without explicit constraints it
# replicates activations over the data axis and inserts full-batch
# all-reduces; verified on the 256-device dry-run).  The launcher installs
# the mesh; model code stays mesh-agnostic.
# ---------------------------------------------------------------------------

_ACT_MESH = None


def set_activation_mesh(mesh):
    """Install (or clear, with None) the mesh used for activation
    sharding constraints.  Called by launchers before tracing."""
    global _ACT_MESH
    _ACT_MESH = mesh


def shard_act(x, *dims):
    """with_sharding_constraint by logical dims.

    dims entries: "batch" -> ("pod","data") filtered to mesh axes;
    "model"; None.  A dim smaller than its axis group is demoted to
    replicated (padding a dim below the axis size wastes >2x); larger
    non-divisible dims are allowed (GSPMD pads, bounded waste).
    """
    if _ACT_MESH is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _ACT_MESH
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    for i, d in enumerate(dims):
        if d is None or i >= x.ndim:
            spec.append(None)
            continue
        axes = (tuple(a for a in ("pod", "data") if a in sizes)
                if d == "batch" else (d,))
        axes = tuple(a for a in axes if a in sizes)
        n = 1
        for a in axes:
            n *= sizes[a]
        if not axes or x.shape[i] < n:
            # try a shrinking prefix for composite batch axes
            while axes and x.shape[i] < n:
                axes = axes[1:]
                n = 1
                for a in axes:
                    n *= sizes[a]
            if not axes:
                spec.append(None)
                continue
        spec.append(axes if len(axes) > 1 else axes[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _init(rng, shape, scale=0.02, dtype=jnp.float32):
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs        # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, ...]):
    """M-RoPE (Qwen2-VL): positions3 (3, B, S) for (t, h, w); head-dim
    frequency pairs are split into `sections` (summing to hd/2), each
    section rotated by its own position stream."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)       # (half,)
    sec_id = np.repeat(np.arange(len(sections)), sections)       # (half,)
    pos = positions3.astype(jnp.float32)[sec_id, :, :]            # (half,B,S)
    ang = jnp.moveaxis(pos, 0, -1) * freqs                        # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def position_embed(cfg: ModelConfig, q, k, positions):
    if cfg.rope_kind == "rope":
        return (apply_rope(q, positions, cfg.rope_theta),
                apply_rope(k, positions, cfg.rope_theta))
    if cfg.rope_kind == "mrope":
        return (apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections),
                apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections))
    return q, k


# ---------------------------------------------------------------------------
# blockwise attention (online softmax over KV tiles; never materializes SxS)
# ---------------------------------------------------------------------------

def _model_axis_size() -> int:
    if _ACT_MESH is None:
        return 1
    sizes = dict(zip(_ACT_MESH.axis_names, _ACT_MESH.devices.shape))
    return sizes.get("model", 1)


def _head_parallel(cfg: ModelConfig, H: int) -> bool:
    M = _model_axis_size()
    want = (cfg.attn_parallel == "head" or
            (cfg.attn_parallel == "auto" and H % max(M, 1) == 0))
    return want and M > 1 and H % M == 0


def _attn_block(q, k, v, mask, softcap):
    """q (M,B,H,bq,hd) k/v (B,H,bkv,hd) (KV already expanded to H);
    mask broadcastable to (M,B,H,bq,bkv) or None.
    Returns online-softmax partials: out (M,B,H,bq,hd), m, l."""
    scores = jnp.einsum("mbhqd,bhtd->mbhqt", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = scores / np.sqrt(q.shape[-1])
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                                  # (M,B,H,bq)
    p = jnp.exp(scores - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("mbhqt,bhtd->mbhqd", p, v.astype(jnp.float32))
    return out, m, l


def blockwise_attention(q, k, v, cfg: ModelConfig, *, causal: bool = True,
                        q_offset: int = 0, window: int = 0, kv_mask=None):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KVH,hd).

    Striped sequence-parallel flash attention: Q tiles are STRIPED over
    the "model" axis (tile t -> stripe t mod M), so every mesh column
    works on a different part of the sequence in the same kv-scan step —
    context parallelism without head sharding (head counts rarely divide
    a 16-wide TP axis; sequence lengths always do).  Striping (not
    contiguous chunking) balances the causal triangle across stripes.
    KV tiles are replicated over "model" and expanded KV->H per tile.
    Online softmax over KV tiles; peak memory O(M * bq * bkv) per head.
    window > 0 adds a sliding-window distance mask (qp - kp < window);
    kv_mask (B, Skv) bool marks per-row valid KV entries.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    Maxis = _model_axis_size()
    head_par = _head_parallel(cfg, H)
    # head-parallel: no striping (M=1), H dim sharded on "model" instead
    M = 1 if head_par else Maxis
    hspec = "model" if head_par else None
    sspec = None if head_par else "model"
    bq = min(cfg.attn_block_q, max(Sq // M, 16))
    bkv = min(cfg.attn_block_kv, Skv)
    nkv = -(-Skv // bkv)
    # pad Sq so the tile count is a multiple of M
    nq = -(-Sq // bq)
    nq = -(-nq // M) * M
    Sq_p, Skv_p = nq * bq, nkv * bkv
    n_local = nq // M
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        if kv_mask is not None:
            kv_mask = jnp.pad(kv_mask, ((0, 0), (0, Skv_p - Skv)))
    # tile t = l*M + m  ->  xs index l, stripe m (sharded over "model")
    qt = q.reshape(B, n_local, M, bq, H, hd).transpose(1, 2, 0, 4, 3, 5)
    qt = shard_act(qt, None, sspec, "batch", hspec, None, None)
    kb = k.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 3, 2, 4)
    kb = shard_act(kb, None, "batch", None, None, None)
    vb = shard_act(vb, None, "batch", None, None, None)
    kv_pos = jnp.arange(Skv_p)
    kv_valid = kv_pos < Skv
    kvm = (kv_mask.reshape(B, nkv, bkv).transpose(1, 0, 2)
           if kv_mask is not None else None)
    stripe = jnp.arange(M)

    def q_step(_, li_qblk):
        li, qblk = li_qblk  # qblk: (M, B, H, bq, hd)

        def kv_step(carry, kj_kv):
            acc, m_run, l_run = carry
            if kvm is None:
                kj, kblk, vblk = kj_kv
                row_mask = None
            else:
                kj, kblk, vblk, row_mask = kj_kv
            # expand KV heads -> H for this tile only (B,KV,bkv,hd)->(B,H,..)
            k_exp = shard_act(jnp.repeat(kblk, G, axis=1),
                              "batch", hspec, None, None)
            v_exp = shard_act(jnp.repeat(vblk, G, axis=1),
                              "batch", hspec, None, None)
            # q positions of stripe m: (li*M + m)*bq + r
            qp = (q_offset + (li * M + stripe)[:, None] * bq
                  + jnp.arange(bq)[None, :])               # (M, bq)
            kp = jax.lax.dynamic_slice(kv_pos, (kj * bkv,), (bkv,))
            mask = jax.lax.dynamic_slice(
                kv_valid, (kj * bkv,), (bkv,))[None, None, :]
            mask = jnp.broadcast_to(mask, (M, 1, bkv))
            if causal:
                mask = mask & (qp[:, :, None] >= kp[None, None, :])
            if window:
                mask = mask & (qp[:, :, None] - kp[None, None, :] < window)
            # (M, bq, bkv) -> (M, 1, 1, bq, bkv); row_mask (B,bkv)
            full_mask = mask[:, None, None, :, :]
            if row_mask is not None:
                full_mask = full_mask & row_mask[None, :, None, None, :]
            out, m, l = _attn_block(qblk, k_exp, v_exp, full_mask,
                                    cfg.attn_logit_softcap)
            m_new = jnp.maximum(m_run, m)
            a = jnp.exp(m_run - m_new)
            b = jnp.exp(m - m_new)
            acc = acc * a[..., None] + out * b[..., None]
            l_new = l_run * a + l * b
            return (acc, m_new, l_new), None

        acc0 = shard_act(jnp.zeros((M, B, H, bq, hd), jnp.float32),
                         sspec, "batch", hspec, None, None)
        m0 = jnp.full((M, B, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((M, B, H, bq), jnp.float32)
        xs = ((jnp.arange(nkv), kb, vb) if kvm is None
              else (jnp.arange(nkv), kb, vb, kvm))
        (acc, m_f, l_f), _ = jax.lax.scan(kv_step, (acc0, m0, l0), xs)
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(n_local), qt))
    # outs: (n_local, M, B, H, bq, hd) -> (B, Sq, H, hd)
    out = outs.transpose(2, 0, 1, 4, 3, 5).reshape(B, Sq_p, H, hd)
    return out[:, :Sq].astype(q.dtype)


def _quantize_kv(x):
    """Per-vector symmetric int8: x (B,S,KV,hd) -> (int8, scale (B,S,KV))."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention_q8(q, k8, ks, v8, vs, cur_len, softcap: float = 0.0):
    """int8-KV decode: scales factored out of the dots so the cache sweep
    reads 1 byte/element; scale corrections apply to the (B,H,S) scores."""
    B, S, KV, hd = k8.shape
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        k8.astype(jnp.float32)) / np.sqrt(hd)
    scores = scores * ks.transpose(0, 2, 1)[:, :, None, :]
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (jnp.arange(S) < cur_len)[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    pv = p * vs.transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bkgs,bskh->bkgh", pv, v8.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, softcap: float = 0.0):
    """Single-token attention over a (B, S, KVH, hd) cache.

    Plain (non-blockwise) form: scores are (B, H, S) — small for Sq=1 —
    and a sequence-sharded cache lets GSPMD turn the softmax/contraction
    into the expected all-reduces.
    """
    B, S, KV, hd = k_cache.shape
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / np.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (jnp.arange(S) < cur_len)[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (A = global, W = local/windowed)
# ---------------------------------------------------------------------------

def init_attention(rng, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    dt = _dtype(cfg)
    return {
        "wq": _init(ks[0], (d, H * hd), dtype=dt),
        "wk": _init(ks[1], (d, KV * hd), dtype=dt),
        "wv": _init(ks[2], (d, KV * hd), dtype=dt),
        "wo": _init(ks[3], (H * hd, d), dtype=dt),
    }


def attention_apply(params, x, cfg: ModelConfig, positions, *,
                    local: bool = False, cache=None, cache_len=None,
                    valid_len=None):
    """x: (B,S,d).  cache (decode): dict(k,v,(B,Smax,KV,hd)); cache_len
    scalar = write slot (ring position for local layers); valid_len =
    number of valid cache entries (defaults to cache_len+1).
    Returns (out, new_cache)."""
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    hp = _head_parallel(cfg, H)
    q = shard_act((x @ params["wq"]).reshape(B, S, H, hd),
                  "batch", None if hp else "model",
                  "model" if hp else None, None)
    k = shard_act((x @ params["wk"]).reshape(B, S, KV, hd),
                  "batch", None, None, None)
    v = shard_act((x @ params["wv"]).reshape(B, S, KV, hd),
                  "batch", None, None, None)
    q, k = position_embed(cfg, q, k, positions)
    if cache is None:
        if local and cfg.local_window and cfg.local_window < S:
            out = _local_attention(q, k, v, cfg)
        else:
            out = blockwise_attention(q, k, v, cfg, causal=True)
        new_cache = {"k": k, "v": v}
    else:
        assert S == 1, "decode step is single-token"
        n_valid = (cache_len + 1) if valid_len is None else valid_len
        if "k_scale" in cache:                      # int8 KV cache
            k8, ks = _quantize_kv(k)
            v8, vs = _quantize_kv(v)
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k8, (0, cache_len, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v8, (0, cache_len, 0, 0))
            ksc = jax.lax.dynamic_update_slice(
                cache["k_scale"], ks.astype(cache["k_scale"].dtype),
                (0, cache_len, 0))
            vsc = jax.lax.dynamic_update_slice(
                cache["v_scale"], vs.astype(cache["v_scale"].dtype),
                (0, cache_len, 0))
            out = decode_attention_q8(q, kc, ksc, vc, vsc, n_valid,
                                      cfg.attn_logit_softcap)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
        else:
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_len, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_len, 0, 0))
            out = decode_attention(q, kc, vc, n_valid, cfg.attn_logit_softcap)
            new_cache = {"k": kc, "v": vc}
    out = shard_act(out, "batch", None if hp else "model",
                    "model" if hp else None, None)
    out = shard_act(out.reshape(B, S, H * hd) @ params["wo"],
                    "batch", None, None)
    return out, new_cache


def _local_attention(q, k, v, cfg: ModelConfig):
    """Sliding-window attention: fold windows into batch; each window
    attends to itself + the previous window (standard SWA tiling)."""
    B, S, H, hd = q.shape
    W = cfg.local_window
    nW = -(-S // W)
    Sp = nW * W
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    KV = k.shape[2]
    qw = q.reshape(B, nW, W, H, hd)
    kw = k.reshape(B, nW, W, KV, hd)
    vw = v.reshape(B, nW, W, KV, hd)
    prev_k = jnp.pad(kw[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    prev_v = jnp.pad(vw[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    k_ctx = jnp.concatenate([prev_k, kw], axis=2)   # (B,nW,2W,KV,hd)
    v_ctx = jnp.concatenate([prev_v, vw], axis=2)
    qf = qw.reshape(B * nW, W, H, hd)
    kf = k_ctx.reshape(B * nW, 2 * W, KV, hd)
    vf = v_ctx.reshape(B * nW, 2 * W, KV, hd)
    # window 0 has no real previous window: mask its zero-padded prev keys
    prev_valid = jnp.broadcast_to((jnp.arange(nW) > 0)[None, :],
                                  (B, nW)).reshape(B * nW)
    kv_mask = jnp.concatenate([
        jnp.broadcast_to(prev_valid[:, None], (B * nW, W)),
        jnp.ones((B * nW, W), bool)], axis=1)
    out = blockwise_attention(qf, kf, vf, cfg, causal=True, q_offset=W,
                              window=W, kv_mask=kv_mask)
    out = out.reshape(B, nW, W, H, hd).reshape(B, Sp, H, hd)
    return out[:, :S]


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek style)
# ---------------------------------------------------------------------------

def init_mla(rng, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.num_heads
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 8)
    dt = _dtype(cfg)
    p = {
        "w_dkv": _init(ks[0], (d, r + rope), dtype=dt),
        "kv_norm": init_rmsnorm(r),
        "w_uk": _init(ks[1], (r, H, nope), dtype=dt),
        "w_uv": _init(ks[2], (r, H, vdim), dtype=dt),
        "wo": _init(ks[3], (H * vdim, d), dtype=dt),
    }
    if qr:
        p["w_dq"] = _init(ks[4], (d, qr), dtype=dt)
        p["q_norm"] = init_rmsnorm(qr)
        p["w_uq"] = _init(ks[5], (qr, H, nope + rope), dtype=dt)
    else:
        p["wq"] = _init(ks[6], (d, H, nope + rope), dtype=dt)
    return p


def mla_apply(params, x, cfg: ModelConfig, positions, *, cache=None,
              cache_len=None):
    B, S, d = x.shape
    H = cfg.num_heads
    r, nope, rope_d, vdim = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                             cfg.qk_rope_dim, cfg.v_head_dim)
    # queries
    if "w_dq" in params:
        ql = rmsnorm(params["q_norm"], x @ params["w_dq"])
        q = jnp.einsum("bsr,rhd->bshd", ql, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    q = shard_act(q, "batch", "model", None, None)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    # latent kv
    ckv = shard_act(x @ params["w_dkv"], "batch", None, None)   # (B,S,r+rope)
    latent = rmsnorm(params["kv_norm"], ckv[..., :r])
    k_rope = ckv[..., r:][:, :, None, :]                         # (B,S,1,rope)
    q_rope, k_rope = position_embed(cfg, q_rope, k_rope, positions)
    if cache is None:
        out = _mla_blockwise(q_nope, q_rope, latent, k_rope, params, cfg)
        new_cache = {"latent": latent, "k_rope": k_rope[:, :, 0, :]}
    else:
        assert S == 1
        lc = jax.lax.dynamic_update_slice(
            cache["latent"], latent.astype(cache["latent"].dtype),
            (0, cache_len, 0))
        rc = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0, :].astype(cache["k_rope"].dtype),
            (0, cache_len, 0))
        out = _mla_decode(q_nope, q_rope, lc, rc, params, cache_len + 1)
        new_cache = {"latent": lc, "k_rope": rc}
    out = out.reshape(B, S, H * vdim) @ params["wo"]
    return out, new_cache


def _mla_blockwise(q_nope, q_rope, latent, k_rope, params, cfg: ModelConfig):
    """Prefill: expand the latent to per-head K/V one KV-tile at a time.
    Q tiles are striped over the "model" axis like blockwise_attention."""
    B, Sq, H, _ = q_nope.shape
    M = _model_axis_size()
    bq = min(cfg.attn_block_q, max(Sq // M, 16))
    bkv = min(cfg.attn_block_kv, Sq)
    nkv = -(-Sq // bkv)
    nq = -(-(-(-Sq // bq)) // M) * M
    Sqp, Skvp = nq * bq, nkv * bkv
    n_local = nq // M
    vdim = cfg.v_head_dim
    if Sqp != Sq:
        pad = ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0))
        q_nope, q_rope = jnp.pad(q_nope, pad), jnp.pad(q_rope, pad)
    latent_p, krope_p = latent, k_rope[:, :, 0, :]
    if Skvp != Sq:
        latent_p = jnp.pad(latent_p, ((0, 0), (0, Skvp - Sq), (0, 0)))
        krope_p = jnp.pad(krope_p, ((0, 0), (0, Skvp - Sq), (0, 0)))

    lat_b = latent_p.reshape(B, nkv, bkv, -1).transpose(1, 0, 2, 3)
    kr_b = krope_p.reshape(B, nkv, bkv, -1).transpose(1, 0, 2, 3)
    # tile t = l*M + m: (n_local, M, B, H, bq, e)
    qn = q_nope.reshape(B, n_local, M, bq, H, -1).transpose(1, 2, 0, 4, 3, 5)
    qr = q_rope.reshape(B, n_local, M, bq, H, -1).transpose(1, 2, 0, 4, 3, 5)
    qn = shard_act(qn, None, "model", "batch", None, None, None)
    qr = shard_act(qr, None, "model", "batch", None, None, None)
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    stripe = jnp.arange(M)

    def q_step(_, args):
        li, qnb, qrb = args  # (M, B, H, bq, e)

        def kv_step(carry, kv):
            acc, m_run, l_run = carry
            kj, lat, kr = kv
            k_nope = jnp.einsum("btr,rhd->bhtd", lat, params["w_uk"])
            v_blk = jnp.einsum("btr,rhd->bhtd", lat, params["w_uv"])
            s = (jnp.einsum("mbhqd,bhtd->mbhqt", qnb.astype(jnp.float32),
                            k_nope.astype(jnp.float32))
                 + jnp.einsum("mbhqd,btd->mbhqt", qrb.astype(jnp.float32),
                              kr.astype(jnp.float32))) * scale
            qp = ((li * M + stripe)[:, None] * bq
                  + jnp.arange(bq)[None, :])              # (M, bq)
            kp = kj * bkv + jnp.arange(bkv)
            mask = qp[:, :, None] >= kp[None, None, :]    # (M, bq, bkv)
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m = jnp.max(s, axis=-1)
            p = jnp.exp(s - m[..., None])
            l = jnp.sum(p, axis=-1)
            o = jnp.einsum("mbhqt,bhtd->mbhqd", p, v_blk.astype(jnp.float32))
            m_new = jnp.maximum(m_run, m)
            a, b2 = jnp.exp(m_run - m_new), jnp.exp(m - m_new)
            return (acc * a[..., None] + o * b2[..., None],
                    m_new, l_run * a + l * b2), None

        acc0 = shard_act(jnp.zeros((M, B, H, bq, vdim), jnp.float32),
                         "model", "batch", None, None, None)
        m0 = jnp.full((M, B, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((M, B, H, bq), jnp.float32)
        (acc, _, l_f), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (jnp.arange(nkv), lat_b, kr_b))
        return None, acc / jnp.maximum(l_f, 1e-30)[..., None]

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(n_local), qn, qr))
    # (n_local, M, B, H, bq, v) -> (B, Sqp, H, v)
    out = outs.transpose(2, 0, 1, 4, 3, 5).reshape(B, Sqp, H, vdim)
    return out[:, :Sq].astype(q_nope.dtype)


def _mla_decode(q_nope, q_rope, latent_c, krope_c, params, cur_len):
    """Absorbed decode: attention in latent space, O(S*r) per head."""
    B, _, H, _ = q_nope.shape
    r = latent_c.shape[-1]
    scale = 1.0 / np.sqrt(q_nope.shape[-1] + q_rope.shape[-1])
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, params["w_uk"])  # (B,1,H,r)
    s = (jnp.einsum("bshr,btr->bhst", q_abs.astype(jnp.float32),
                    latent_c.astype(jnp.float32))
         + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                      krope_c.astype(jnp.float32))) * scale
    S = latent_c.shape[1]
    valid = (jnp.arange(S) < cur_len)[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", p, latent_c.astype(jnp.float32))
    out = jnp.einsum("bshr,rhd->bshd", ctx, params["w_uv"].astype(jnp.float32))
    return out.astype(q_nope.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(rng, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    dt = _dtype(cfg)
    return {"w_gate": _init(ks[0], (d, f), dtype=dt),
            "w_up": _init(ks[1], (d, f), dtype=dt),
            "w_down": _init(ks[2], (f, d), dtype=dt)}


def mlp_apply(params, x):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shard_act(h, "batch", None, "model")
    return shard_act(h @ params["w_down"], "batch", None, None)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embeddings(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    V = cfg.padded_vocab
    p = {"embed": _init(ks[0], (V, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = _init(ks[1], (cfg.d_model, V))
    return p


def embed(params, tokens, cfg: ModelConfig):
    x = params["embed"][tokens].astype(_dtype(cfg))
    return shard_act(x, "batch", None, None)


def unembed(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = x @ params["unembed"].astype(x.dtype)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return shard_act(logits, "batch", None, "model")
