"""Mixture-of-Experts layer (Llama-4 top-1 / Kimi-K2 top-8).

Dispatch is sort-based with a capacity bound: assignments are sorted by
expert id, each token takes a slot in its expert's (E, C, d) buffer
(scatter), experts run as one grouped einsum, and results scatter-add back
weighted by router probabilities.  Compared to the classic one-hot
(T, E, C) dispatch einsum this keeps peak memory at O(E*C*d) instead of
O(T*E*C), which is what lets Kimi-K2's 384 experts fit a per-device tile.

Expert weights are stacked (E, d, f) so expert parallelism is a plain
sharding rule (E -> "model"); GSPMD inserts the token all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _dtype, _init, shard_act


def init_moe(rng, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 4)
    dt = _dtype(cfg)
    return {
        "router": _init(ks[0], (d, E), dtype=jnp.float32),
        "w_gate": _init(ks[1], (E, d, f), dtype=dt),
        "w_up": _init(ks[2], (E, d, f), dtype=dt),
        "w_down": _init(ks[3], (E, f, d), dtype=dt),
    }


def moe_apply(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d).

    Dispatch is grouped *per batch element*: each of the B groups sorts
    its own S*K assignments (vmapped — stays data-parallel and sharded,
    unlike a global argsort over B*S*K, which GSPMD must gather) and
    scatters into its (E, cap, d) buffer.  The expert einsum consumes the
    buffer (batch -> data, experts -> model): the data->expert reshard is
    the standard MoE all-to-all, inserted by GSPMD.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = x.astype(jnp.float32) @ params["router"]             # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                        # (B, S, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(S * K / E * cfg.moe_capacity_factor))
    cap = max(cap, 4)

    flat_e = top_e.reshape(B, S * K)
    flat_w = top_w.reshape(B, S * K)
    tok = jnp.broadcast_to(jnp.arange(S * K, dtype=jnp.int32) // K,
                           (B, S * K))
    order = jnp.argsort(flat_e, axis=-1)                          # per group
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    sw = jnp.take_along_axis(flat_w, order, axis=-1)
    st = jnp.take_along_axis(tok, order, axis=-1)

    def group_counts(e_row):
        return jnp.bincount(e_row, length=E)

    counts = jax.vmap(group_counts)(se)                           # (B, E)
    starts = jnp.cumsum(counts, axis=-1) - counts
    pos_in_e = (jnp.arange(S * K, dtype=jnp.int32)[None, :]
                - jnp.take_along_axis(starts, se, axis=-1))
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, E * cap)          # (B, S*K)

    xf = x                                                         # (B, S, d)

    def group_scatter(slot_row, st_row, keep_row, x_row):
        vals = x_row[st_row] * keep_row[:, None].astype(x_row.dtype)
        return jnp.zeros((E * cap + 1, d), x_row.dtype).at[slot_row].set(vals)

    disp = jax.vmap(group_scatter)(slot, st, keep, xf)            # (B,E*cap+1,d)
    h = disp[:, : E * cap].reshape(B, E, cap, d)
    h = shard_act(h, "batch", "model", None, None)  # EP all-to-all here
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", h, params["w_gate"]))
    u = jnp.einsum("becd,edf->becf", h, params["w_up"])
    g = shard_act(g, "batch", "model", None, None)
    u = shard_act(u, "batch", "model", None, None)
    y = jnp.einsum("becf,efd->becd", g * u, params["w_down"])
    y = shard_act(y, "batch", "model", None, None)
    y = y.reshape(B, E * cap, d)

    def group_gather(y_row, slot_row, st_row, sw_row, keep_row):
        contrib = y_row[jnp.minimum(slot_row, E * cap - 1)] * (
            sw_row * keep_row.astype(jnp.float32))[:, None].astype(y_row.dtype)
        return jnp.zeros((S, d), y_row.dtype).at[st_row].add(contrib)

    out = jax.vmap(group_gather)(y, slot, st, sw, keep)           # (B, S, d)
    return shard_act(out, "batch", None, None)


def moe_aux_stats(params, x, cfg: ModelConfig):
    """Router load statistics (for balance-loss experiments)."""
    T = x.shape[0] * x.shape[1]
    logits = x.reshape(T, -1).astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    load = jnp.bincount(top_e.reshape(-1), length=cfg.num_experts)
    return {"mean_prob": probs.mean(0), "load": load}
