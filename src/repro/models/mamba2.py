"""Mamba-2 block: SSD (state-space duality) with chunked scan.

Forward uses the SSD algorithm of arXiv:2405.21060: split the sequence
into chunks of length Q; compute the intra-chunk (quadratic, attention-
like) term and carry the (H, P, N) chunk states through a linear
recurrence across chunks.  Peak memory is O(B*H*Q^2 + S/Q * B*H*P*N) —
never the O(S * H*P*N) of a naive associative scan over every step,
which is what makes the 524288-token shape feasible.

Decode keeps (conv_state (B, convdim, w-1), ssm_state (B, H, P, N)) and
steps in O(1) per token — the reason the long_500k cell runs for this
family while full-attention archs skip it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _dtype, _init, init_rmsnorm, rmsnorm, shard_act


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_headdim
    N = cfg.ssm_state
    G = cfg.ssm_groups
    return di, H, P, N, G


def init_mamba2(rng, cfg: ModelConfig):
    d = cfg.d_model
    di, H, P, N, G = _dims(cfg)
    conv_dim = di + 2 * G * N
    ks = jax.random.split(rng, 5)
    dt = _dtype(cfg)
    return {
        # order: [z (di), x (di), B (G*N), C (G*N), dt (H)]
        "in_proj": _init(ks[0], (d, 2 * di + 2 * G * N + H), dtype=dt),
        "conv_w": _init(ks[1], (cfg.ssm_conv, conv_dim), scale=0.1,
                        dtype=jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": init_rmsnorm(di),
        "out_proj": _init(ks[2], (di, d), dtype=dt),
    }


def _split_proj(proj, cfg: ModelConfig):
    di, H, P, N, G = _dims(cfg)
    z = proj[..., :di]
    xBC = proj[..., di: di + di + 2 * G * N]
    dt = proj[..., -H:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv along seq: xBC (B,S,D), w (K,D)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(K):
        out = out + pad[:, i: i + xBC.shape[1], :].astype(jnp.float32) * w[i]
    return jax.nn.silu(out + b).astype(xBC.dtype)


def _segsum(a_chunk):
    """log-space cumulative products L[i,j] = prod_{j<s<=i} a_s, (.., Q, Q).
    a_chunk: (..., Q) log decay per step."""
    Q = a_chunk.shape[-1]
    cs = jnp.cumsum(a_chunk, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_{j<s<=i}
    mask = np.tril(np.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -np.inf)


def mamba2_forward(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d); full-sequence SSD."""
    B, S, d = x.shape
    di, H, P, N, G = _dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by ssd chunk {Q}"
    nc = S // Q

    proj = shard_act(x @ params["in_proj"], "batch", None, "model")
    z, xBC, dt = _split_proj(proj, cfg)
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xBC = shard_act(xBC, "batch", None, "model")
    xs = shard_act(xBC[..., :di].reshape(B, S, H, P),
                   "batch", None, "model", None)
    Bm = xBC[..., di: di + G * N].reshape(B, S, G, N)
    Cm = xBC[..., di + G * N:].reshape(B, S, G, N)
    # heads share groups: expand G -> H
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)                    # (B,S,H,N)
    Cm = jnp.repeat(Cm, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])                       # (H,)
    dA = dt * A                                          # log decay (B,S,H)

    # chunked shapes: (B, nc, Q, ...)
    def chunk(t):
        return t.reshape(B, nc, Q, *t.shape[2:])

    xs_c, B_c, C_c, dA_c, dt_c = map(chunk, (xs, Bm, Cm, dA, dt))
    dAh = dA_c.transpose(0, 1, 3, 2)                    # (B,nc,H,Q)

    # intra-chunk (diagonal) term
    L = jnp.exp(_segsum(dAh))                           # (B,nc,H,Q,Q)
    scores = jnp.einsum("bchqn,bchkn->bchqk",
                        C_c.transpose(0, 1, 3, 2, 4), B_c.transpose(0, 1, 3, 2, 4))
    M = scores * L
    xdt = xs_c * dt_c[..., None]                        # weight dt into x
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", M, xdt)

    # chunk states: S_c = sum_k decay_to_end(k) * B_k x_k^T
    decay_end = jnp.exp(jnp.cumsum(dAh[..., ::-1], axis=-1)[..., ::-1]
                        - dAh)                          # (B,nc,H,Q) decay from k (exclusive) to end
    states = jnp.einsum("bchk,bckhn,bckhp->bchpn",
                        decay_end, B_c, xdt)
    # inter-chunk recurrence: carry (B,H,P,N)
    chunk_decay = jnp.exp(jnp.sum(dAh, axis=-1))        # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state *entering* the chunk

    init = jnp.zeros((B, H, P, N), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # inter-chunk contribution: y_off[q] = C_q . (decay_into(q) * S_prev)
    decay_in = jnp.exp(jnp.cumsum(dAh, axis=-1))        # (B,nc,H,Q) decay from chunk start through q
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                       C_c, prev_states, decay_in)
    y = shard_act((y_diag + y_off).reshape(B, S, H, P),
                  "batch", None, "model", None)
    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = shard_act(y.reshape(B, S, di), "batch", None, "model")
    y = rmsnorm(params["out_norm"], (y * jax.nn.silu(z.astype(jnp.float32))
                                     ).astype(x.dtype))
    return shard_act(y @ params["out_proj"], "batch", None, None)


def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, H, P, N, G = _dims(cfg)
    conv_dim = di + 2 * G * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def mamba2_step(params, x, cfg: ModelConfig, cache):
    """Single-token step: x (B, 1, d) -> (B, 1, d), O(1) state update."""
    B = x.shape[0]
    di, H, P, N, G = _dims(cfg)
    proj = x[:, 0] @ params["in_proj"]                  # (B, proj)
    z, xBC, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate(
        [cache["conv"], xBC[:, None, :].astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"]
    acc = jnp.einsum("bkd,kd->bd", conv_in.astype(jnp.float32), w)
    xBC = jax.nn.silu(acc + params["conv_b"]).astype(x.dtype)
    new_conv = conv_in[:, 1:]
    xs = xBC[..., :di].reshape(B, H, P)
    Bm = jnp.repeat(xBC[..., di: di + G * N].reshape(B, G, N), H // G, axis=1)
    Cm = jnp.repeat(xBC[..., di + G * N:].reshape(B, G, N), H // G, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    da = jnp.exp(dt * A)                                # (B,H)
    upd = jnp.einsum("bhn,bhp,bh->bhpn", Bm.astype(jnp.float32),
                     xs.astype(jnp.float32), dt)
    ssm = cache["ssm"] * da[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(jnp.float32), ssm)
    y = y + xs.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(B, di)
    y = rmsnorm(params["out_norm"],
                (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype))
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "ssm": ssm}
