"""Model assembly: scan-over-layers decoder with heterogeneous patterns.

The layer stack is `cfg.layer_pattern` tiled to num_layers.  Layers are
grouped into repeating *units* (e.g. "RRA"): per unit position, parameters
of all repeats are stacked on a leading axis and consumed by `lax.scan` —
one trace regardless of depth (88-layer Mistral compiles as fast as a
2-layer smoke model).  The `num_layers % len(unit)` remainder layers run
unstacked after the scan.

Caches are stacked the same way, so prefill/decode also scan.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention_apply, embed, init_attention, init_embeddings,
                     init_mla, init_mlp, init_rmsnorm, mla_apply, mlp_apply,
                     rmsnorm, unembed)
from .mamba2 import (init_mamba2, mamba2_forward, mamba2_init_cache,
                     mamba2_step)
from .moe import init_moe, moe_apply
from .rglru import init_rglru, rglru_forward, rglru_init_cache, rglru_step

MIXER_KINDS = {"A": "attn", "W": "attn", "M": "attn", "L": "mla",
               "S": "mamba", "R": "rglru"}
FFN_KINDS = {"A": "mlp", "W": "mlp", "L": "mlp", "R": "mlp", "M": "moe",
             "S": None}


# ---------------------------------------------------------------------------
# single-layer init / apply
# ---------------------------------------------------------------------------

def init_layer(rng, kind: str, cfg: ModelConfig):
    k1, k2 = jax.random.split(rng)
    p = {"ln1": init_rmsnorm(cfg.d_model)}
    mixer = MIXER_KINDS[kind]
    if mixer == "attn":
        p["attn"] = init_attention(k1, cfg)
    elif mixer == "mla":
        p["mla"] = init_mla(k1, cfg)
    elif mixer == "mamba":
        p["mamba"] = init_mamba2(k1, cfg)
    elif mixer == "rglru":
        p["rglru"] = init_rglru(k1, cfg)
    ffn = FFN_KINDS[kind]
    if ffn:
        p["ln2"] = init_rmsnorm(cfg.d_model)
        p[ffn] = init_moe(k2, cfg) if ffn == "moe" else init_mlp(k2, cfg)
    return p


def apply_layer(params, x, kind: str, cfg: ModelConfig, positions, *,
                cache=None, cache_len=None, valid_len=None):
    """Returns (x, new_cache)."""
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    mixer = MIXER_KINDS[kind]
    if mixer == "attn":
        out, new_cache = attention_apply(
            params["attn"], h, cfg, positions, local=(kind == "W"),
            cache=cache, cache_len=cache_len, valid_len=valid_len)
    elif mixer == "mla":
        out, new_cache = mla_apply(params["mla"], h, cfg, positions,
                                   cache=cache, cache_len=cache_len)
    elif mixer == "mamba":
        if cache is None:
            out, new_cache = mamba2_forward(params["mamba"], h, cfg), None
        else:
            out, new_cache = mamba2_step(params["mamba"], h, cfg, cache)
    elif mixer == "rglru":
        if cache is None:
            out, new_cache = rglru_forward(params["rglru"], h, cfg), None
        else:
            out, new_cache = rglru_step(params["rglru"], h, cfg, cache)
    x = x + out
    ffn = FFN_KINDS[kind]
    if ffn:
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        out = (moe_apply(params[ffn], h, cfg) if ffn == "moe"
               else mlp_apply(params[ffn], h))
        x = x + out
    return x, new_cache


# ---------------------------------------------------------------------------
# stacked-unit model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def unit(self) -> str:
        return self.cfg.layer_pattern

    @property
    def repeats(self) -> int:
        return self.cfg.num_layers // len(self.unit)

    @property
    def tail(self) -> str:
        return self.unit[: self.cfg.num_layers % len(self.unit)]

    # -- init ---------------------------------------------------------------
    def init(self, rng) -> dict:
        cfg = self.cfg
        k_emb, k_blocks, k_tail = jax.random.split(rng, 3)
        params = {"embeddings": init_embeddings(k_emb, cfg),
                  "final_norm": init_rmsnorm(cfg.d_model)}
        blocks = []
        for u, kind in enumerate(self.unit):
            keys = jax.random.split(jax.random.fold_in(k_blocks, u),
                                    max(self.repeats, 1))
            if self.repeats:
                blocks.append(jax.vmap(
                    lambda k, kind=kind: init_layer(k, kind, cfg))(keys))
            else:
                blocks.append(None)
        params["blocks"] = blocks
        params["tail"] = [init_layer(jax.random.fold_in(k_tail, i), kind, cfg)
                          for i, kind in enumerate(self.tail)]
        return params

    # -- helpers --------------------------------------------------------------
    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.input_mode == "embeddings" and "embeddings" in batch:
            x = batch["embeddings"].astype(jnp.dtype(cfg.dtype))
            B, S = x.shape[:2]
        else:
            x = embed(params["embeddings"], batch["tokens"], cfg)
            B, S = batch["tokens"].shape
        positions = batch.get("positions")
        if positions is None:
            base = jnp.arange(S, dtype=jnp.int32)[None, :]
            positions = jnp.broadcast_to(base, (B, S))
            if cfg.rope_kind == "mrope":
                positions = jnp.broadcast_to(positions[None], (3, B, S))
        return x, positions

    def _remat(self, fn):
        if self.cfg.remat == "full":
            return jax.checkpoint(fn, policy=None)
        if self.cfg.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return fn

    # -- forward (training / scoring) ----------------------------------------
    def apply(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x, positions = self._embed_in(params, batch)

        def unit_body(x, unit_params):
            for u, kind in enumerate(self.unit):
                x, _ = apply_layer(unit_params[u], x, kind, cfg, positions)
            return x

        body = self._remat(unit_body)
        if self.repeats:
            x, _ = jax.lax.scan(lambda c, ps: (body(c, ps), None),
                                x, tuple(params["blocks"]))
        for i, kind in enumerate(self.tail):
            x, _ = apply_layer(params["tail"][i], x, kind, cfg, positions)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return unembed(params["embeddings"], x, cfg)

    # -- cache ---------------------------------------------------------------
    def _layer_cache(self, kind: str, batch: int, max_len: int, dtype):
        cfg = self.cfg
        mixer = MIXER_KINDS[kind]
        if mixer == "attn":
            S = max_len if kind != "W" else min(max_len, cfg.local_window)
            shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
            if cfg.kv_cache_dtype == "int8":
                return {"k": jnp.zeros(shape, jnp.int8),
                        "v": jnp.zeros(shape, jnp.int8),
                        "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                        "v_scale": jnp.zeros(shape[:-1], jnp.float32)}
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if mixer == "mla":
            return {"latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}
        if mixer == "mamba":
            return mamba2_init_cache(cfg, batch, dtype=jnp.float32)
        if mixer == "rglru":
            return rglru_init_cache(cfg, batch, dtype=jnp.float32)
        raise ValueError(kind)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        def stack(tree):
            return jax.tree.map(
                lambda x: jnp.zeros((self.repeats,) + x.shape, x.dtype), tree)

        blocks = [stack(self._layer_cache(kind, batch, max_len, dtype))
                  if self.repeats else None for kind in self.unit]
        tail = [self._layer_cache(kind, batch, max_len, dtype)
                for kind in self.tail]
        return {"blocks": blocks, "tail": tail}

    # -- decode step -----------------------------------------------------------
    def decode_step(self, params, cache, tokens, cur_len, positions=None):
        """tokens: (B,) int32 (or (B,1,d) embeddings); cur_len: scalar count
        of tokens already in the cache.  Returns (logits (B,V), new_cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        if cfg.input_mode == "embeddings" and tokens.ndim == 3:
            x = tokens.astype(jnp.dtype(cfg.dtype))
        else:
            x = embed(params["embeddings"], tokens[:, None], cfg)
        if positions is None:
            pos = jnp.full((B, 1), cur_len, jnp.int32)
            if cfg.rope_kind == "mrope":
                pos = jnp.broadcast_to(pos[None], (3, B, 1))
        else:
            pos = positions
        W = cfg.local_window or 0

        def step_one(x, layer_params, layer_cache, kind):
            if kind == "W" and W:
                # ring buffer: write slot wraps; valid count saturates at W
                return apply_layer(layer_params, x, kind, cfg, pos,
                                   cache=layer_cache, cache_len=cur_len % W,
                                   valid_len=jnp.minimum(cur_len + 1, W))
            return apply_layer(layer_params, x, kind, cfg, pos,
                               cache=layer_cache, cache_len=cur_len)

        # scan over repeats, applying the whole unit per step — the layer
        # ORDER matches apply(): unit[0], unit[1], ..., unit[0], ...
        def unit_step(x, xs):
            ps_list, cache_list = xs
            new_caches = []
            for u, kind in enumerate(self.unit):
                x, nc = step_one(x, ps_list[u], cache_list[u], kind)
                new_caches.append(nc)
            return x, tuple(new_caches)

        new_blocks = list(cache["blocks"])
        if self.repeats:
            x, ncaches = jax.lax.scan(
                unit_step, x,
                (tuple(params["blocks"]), tuple(cache["blocks"])))
            new_blocks = list(ncaches)
        new_tail = []
        for i, kind in enumerate(self.tail):
            x, nc = step_one(x, params["tail"][i], cache["tail"][i], kind)
            new_tail.append(nc)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embeddings"], x, cfg)
        return logits[:, 0], {"blocks": new_blocks, "tail": new_tail}
