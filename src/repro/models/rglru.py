"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The temporal-mixing block is: linear in-projections (x branch + gate
branch), short causal conv on the x branch, then the Real-Gated LRU:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses an associative scan over (a, b) pairs (the recurrence is
linear); decode is an O(1) state update — together with the 2048-token
local-attention window this bounds serving state, which is why the
long_500k cell runs for this arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dtype, _init, shard_act


def init_rglru(rng, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    dt = _dtype(cfg)
    return {
        "w_x": _init(ks[0], (d, d), dtype=dt),
        "w_gate": _init(ks[1], (d, d), dtype=dt),
        "conv_w": _init(ks[2], (cfg.rglru_conv, d), scale=0.1,
                        dtype=jnp.float32),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "wa": _init(ks[3], (d, d), scale=0.01, dtype=jnp.float32),
        "ba": jnp.zeros((d,), jnp.float32),
        "wi": _init(ks[4], (d, d), scale=0.01, dtype=jnp.float32),
        "bi": jnp.zeros((d,), jnp.float32),
        "lam": jnp.linspace(0.9, 5.0, d).astype(jnp.float32),  # softplus arg
        "w_out": _init(ks[5], (d, d), dtype=dt),
    }


def _conv(x, w, b, state=None):
    """Causal depthwise conv; state (B, K-1, d) for decode."""
    K = w.shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = None
    else:
        pad = jnp.concatenate([state, x.astype(state.dtype)], axis=1)
        new_state = pad[:, -(K - 1):]
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(K):
        out = out + pad[:, i: i + x.shape[1], :].astype(jnp.float32) * w[i]
    return (out + b).astype(x.dtype), new_state


def _lru_gates(params, xb, cfg: ModelConfig):
    r = jax.nn.sigmoid(xb.astype(jnp.float32) @ params["wa"] + params["ba"])
    i = jax.nn.sigmoid(xb.astype(jnp.float32) @ params["wi"] + params["bi"])
    log_a = -cfg.rglru_c * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (
        i * xb.astype(jnp.float32))
    return a, gated_in


def rglru_forward(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d)."""
    xb = shard_act(x @ params["w_x"], "batch", None, "model")
    gate = jax.nn.gelu((x @ params["w_gate"]).astype(jnp.float32))
    gate = shard_act(gate, "batch", None, "model")
    xb, _ = _conv(xb, params["conv_w"], params["conv_b"])
    a, gin = _lru_gates(params, xb, cfg)
    a = shard_act(a, "batch", None, "model")
    gin = shard_act(gin, "batch", None, "model")

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, gin), axis=1)
    y = (h * gate).astype(x.dtype)
    return shard_act(y @ params["w_out"], "batch", None, None)


def rglru_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.rglru_conv - 1, cfg.d_model), dtype),
        "h": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


def rglru_step(params, x, cfg: ModelConfig, cache):
    """x: (B, 1, d); O(1) recurrent state update."""
    xb = x @ params["w_x"]
    gate = jax.nn.gelu((x @ params["w_gate"]).astype(jnp.float32))
    xb, new_conv = _conv(xb, params["conv_w"], params["conv_b"],
                         state=cache["conv"])
    a, gin = _lru_gates(params, xb, cfg)
    h = cache["h"] * a[:, 0] + gin[:, 0]
    y = (h[:, None, :] * gate).astype(x.dtype)
    return y @ params["w_out"], {"conv": new_conv, "h": h}
