"""Model configuration covering all assigned architecture families.

One frozen dataclass drives every family: dense GQA transformers, MLA
(MiniCPM3), MoE (Llama-4 / Kimi-K2), SSM (Mamba-2 SSD), hybrid RG-LRU +
local attention (RecurrentGemma), and the stub-frontend audio/VLM decoders
(MusicGen / Qwen2-VL).  `layer_pattern` encodes heterogeneous stacks as a
repeating unit, e.g. "RRA" = two RG-LRU blocks then one local-attention
block (RecurrentGemma's 1:2 ratio).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # layer stack: one char per layer type, tiled to num_layers.
    #   A = global attention + MLP      L = MLA attention + MLP
    #   M = global attention + MoE      S = Mamba-2 (SSD) block
    #   R = RG-LRU recurrent block      W = local (windowed) attention + MLP
    layer_pattern: str = "A"

    # attention
    rope_kind: str = "rope"      # rope | mrope | none
    rope_theta: float = 10000.0
    local_window: int = 0        # for W layers
    attn_logit_softcap: float = 0.0
    attn_block_q: int = 512      # blockwise-attention tile sizes
    attn_block_kv: int = 1024
    # TP strategy for attention: "seq" stripes Q tiles over the model axis
    # (works for any head count); "head" shards heads (classic Megatron —
    # no per-layer seq<->TP reshard, requires H % model_axis == 0);
    # "auto" picks "head" when divisible.
    attn_parallel: str = "seq"
    # KV-cache precision: "int8" stores quantized K/V with per-vector
    # scales factored out of the attention dots (beyond-paper: halves the
    # decode memory term)
    kv_cache_dtype: str = "bfloat16"

    # MLA (minicpm3-style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # RG-LRU (recurrentgemma)
    rglru_conv: int = 4
    rglru_c: float = 8.0

    # frontends: "tokens" or "embeddings" (audio/vlm stubs feed embeddings)
    input_mode: str = "tokens"
    mrope_sections: tuple[int, ...] = ()   # head_dim split for M-RoPE (t,h,w)

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0

    # numerics / execution
    dtype: str = "bfloat16"
    remat: str = "full"          # full | dots | none

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def layers(self) -> str:
        """Full per-layer type string of length num_layers."""
        pat = self.layer_pattern
        return (pat * (self.num_layers // len(pat) + 1))[: self.num_layers]

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab/logits dim
        shards evenly on the model axis (MaxText-style padding)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy (smoke tests) with the same family/pattern."""
        return dataclasses.replace(self, **overrides)

    # --- parameter counting (for 6ND roofline math) -----------------------
    def param_count(self) -> int:
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        return _count_params(self, active_only=True)


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d                      # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d                 # unembedding
    for kind in cfg.layers:
        total += 2 * d                              # pre-norms (approx 2/block)
        if kind in ("A", "M", "W"):
            hd = cfg.head_dim
            total += d * cfg.num_heads * hd         # wq
            total += 2 * d * cfg.num_kv_heads * hd  # wk, wv
            total += cfg.num_heads * hd * d         # wo
        elif kind == "L":
            r = cfg.kv_lora_rank
            qk = cfg.qk_nope_dim + cfg.qk_rope_dim
            if cfg.q_lora_rank:
                total += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * qk
            else:
                total += d * cfg.num_heads * qk
            total += d * (r + cfg.qk_rope_dim)
            total += r * cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
            total += cfg.num_heads * cfg.v_head_dim * d
        elif kind == "S":
            di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            G = cfg.ssm_groups
            total += d * (2 * di + 2 * G * N + H)   # in_proj
            total += cfg.ssm_conv * (di + 2 * G * N)
            total += 2 * H                          # A_log, D
            total += di                             # gated-norm scale
            total += di * d                         # out_proj
        elif kind == "R":
            total += 2 * d * d                      # in gates (x, gate branch)
            total += cfg.rglru_conv * d
            total += 3 * d                          # lru: a_param + 2 gate bias
            total += 2 * d * d                      # gate proj + out proj
        if kind in ("A", "W", "L"):
            total += 3 * d * cfg.d_ff               # SwiGLU
        elif kind == "M":
            e_params = 3 * d * cfg.d_ff
            total += d * cfg.num_experts            # router
            if active_only:
                total += cfg.experts_per_token * e_params
            else:
                total += cfg.num_experts * e_params
        elif kind == "R":
            total += 3 * d * cfg.d_ff               # R blocks carry an MLP too
    total += d                                      # final norm
    return total
