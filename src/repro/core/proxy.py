"""MemEC proxy (paper §4.1, §5.3): client entry point + request backups.

Each proxy:
* maps keys to servers with two-stage hashing (decentralized, normal mode),
* buffers every request until acknowledged (replayable as degraded
  requests after a failure),
* buffers key->chunk-ID mappings piggybacked on SET acks, flushed when the
  data server checkpoints (§5.3),
* attaches a local sequence number + acked watermark so parity servers can
  prune their delta buffers.
"""
from __future__ import annotations

import dataclasses

from .chunk import ChunkId
from .stripe import StripeList, StripeMapper


@dataclasses.dataclass
class PendingRequest:
    seq: int
    kind: str               # SET/UPDATE/DELETE (GETs are read-only, no backup)
    key: bytes
    value: bytes | None
    stripe_list: StripeList
    data_server: int


class Proxy:
    def __init__(self, pid: int, mapper: StripeMapper):
        self.pid = pid
        self.mapper = mapper
        self.seq = 0
        # mutating requests begun through this proxy (GETs excluded: they
        # carry no backup) — load-distribution introspection for the
        # sharded scatter/gather planner tests
        self.requests_begun = 0
        self.pending: dict[int, PendingRequest] = {}
        self.acked: set[int] = set()
        self.ack_watermark = 0  # all seqs <= watermark are acked
        # key -> chunk-ID mapping backups, per data server (§5.3); the
        # SET ack piggybacks the instance seq so recovery merges across
        # proxies keep the newest instance of a re-SET key
        self.mapping_buffer: dict[int, list[tuple[bytes, ChunkId, int | None]]] = {}

    # -- sequencing ------------------------------------------------------
    def next_seq(self) -> int:
        self.seq += 1
        return self.seq

    def begin(self, kind: str, key: bytes, value: bytes | None,
              sl: StripeList, data_server: int) -> PendingRequest:
        req = PendingRequest(self.next_seq(), kind, key, value, sl, data_server)
        self.pending[req.seq] = req
        self.requests_begun += 1
        return req

    def ack(self, seq: int):
        self.pending.pop(seq, None)
        self.acked.add(seq)
        while (self.ack_watermark + 1) in self.acked:
            self.ack_watermark += 1
            self.acked.discard(self.ack_watermark)

    def unacked_seqs(self) -> set[int]:
        return set(self.pending.keys())

    # -- mapping backups ---------------------------------------------------
    def buffer_mapping(self, server_id: int, key: bytes, cid: ChunkId,
                       iseq: int | None = None):
        self.mapping_buffer.setdefault(server_id, []).append((key, cid, iseq))

    def clear_mappings(self, server_id: int):
        self.mapping_buffer.pop(server_id, None)

    def mappings_for(self, server_id: int) -> list[tuple[bytes, ChunkId, int | None]]:
        return list(self.mapping_buffer.get(server_id, []))
