"""Elastic key placement: pluggable Placement interface + consistent-hash ring.

The sharded cluster (core/shard.py) used to freeze the key->shard map at
construction (FNV-1a mod S), so it could neither grow/shrink nor escape a
Zipf hot shard.  This module makes placement a first-class, *pluggable*
policy:

* ``Placement`` — the interface every policy implements: ``shard_for``
  routes a key, ``add_shard``/``remove_shard`` change membership, and
  (optionally) ``set_weight`` biases capacity per shard.
* ``ModPlacement`` — the original FNV-1a-mod policy, generalized to an
  arbitrary active-shard list.  Membership changes remap ``h % S`` for a
  new S, i.e. a near-full reshuffle — it is the *naive baseline* the
  rebalance benchmark compares against.
* ``RingPlacement`` — a deterministic consistent-hash ring with virtual
  nodes and per-shard weights.  Each shard owns ``round(vnodes * weight)``
  points at ``fnv1a("ring:<shard>:<vnode>")``; a key hashes onto the ring
  and belongs to the clockwise-next point's shard.  Adding a shard steals
  only ~1/S of the key space (minimal movement); shrinking a hot shard's
  weight sheds a proportional slice of its arcs — the lever the
  skew-aware ``Rebalancer`` (core/rebalance.py) pulls.

Everything is pure hashing over ``index.fnv1a`` — no RNG, no process
state — so placements are bit-identical across processes and runs
(required: proxies, the coordinator, and offline tools must agree on
routing without coordination).

Selection: ``make_placement(spec, num_shards)``; ``spec=None`` reads
``$MEMEC_PLACEMENT`` (``mod`` | ``ring`` | ``ring:<vnodes>``), default
``mod`` (byte-compatible with the pre-elasticity cluster).
"""
from __future__ import annotations

import bisect
import os

from .index import fnv1a

# key-side hash seed: must match shard.SHARD_SEED so ModPlacement stays
# bit-identical with the historical shard_for_key routing
KEY_SEED = 0x01000193
# ring-point hash seed: independent of key hashing and of the per-shard
# two-stage stripe hashing (stripe.py)
RING_SEED = 0x8FE3C9A1
DEFAULT_VNODES = 64


def key_point(key: bytes) -> int:
    """A key's 64-bit position (shared by every placement policy)."""
    return fnv1a(key, seed=KEY_SEED)


class Placement:
    """Key -> shard-id routing policy with elastic membership.

    Shard ids are stable labels (indices into ``ShardedCluster.shards``);
    removing a shard retires its id — ids are never renumbered.
    """

    kind = "abstract"
    supports_weights = False

    @property
    def shard_ids(self) -> tuple[int, ...]:
        """Active shard ids, ascending."""
        raise NotImplementedError

    @property
    def num_shards(self) -> int:
        return len(self.shard_ids)

    def shard_for(self, key: bytes) -> int:
        raise NotImplementedError

    def add_shard(self, shard: int, weight: float = 1.0) -> int:
        raise NotImplementedError

    def remove_shard(self, shard: int) -> None:
        raise NotImplementedError

    def set_weight(self, shard: int, weight: float) -> None:
        raise NotImplementedError(f"{self.kind} placement has no weights")

    def weight_of(self, shard: int) -> float:
        return 1.0

    def describe(self) -> str:
        return f"{self.kind}({self.num_shards} shards)"


class ModPlacement(Placement):
    """FNV-1a mod over the active-shard list (the historical policy).

    For the construction-time ``[0..S)`` membership this is bit-identical
    to the original ``shard_for_key``.  Membership changes rehash ``h %
    S`` with a new modulus, moving ~(S-1)/S of the keys — the full-
    reshuffle baseline for the migration benchmarks.
    """

    kind = "mod"

    def __init__(self, num_shards: int = 1, shard_ids=None):
        self.active = (sorted(shard_ids) if shard_ids is not None
                       else list(range(num_shards)))
        if not self.active:
            raise ValueError("need at least one shard")

    @property
    def shard_ids(self) -> tuple[int, ...]:
        return tuple(self.active)

    def shard_for(self, key: bytes) -> int:
        if len(self.active) == 1:
            return self.active[0]
        return self.active[key_point(key) % len(self.active)]

    def add_shard(self, shard: int, weight: float = 1.0) -> int:
        if shard in self.active:
            raise ValueError(f"shard {shard} already active")
        bisect.insort(self.active, shard)
        return shard

    def remove_shard(self, shard: int) -> None:
        if shard not in self.active:
            raise ValueError(f"no active shard {shard}")
        if len(self.active) == 1:
            raise ValueError("cannot remove the last shard")
        self.active.remove(shard)


class RingPlacement(Placement):
    """Deterministic consistent-hash ring with virtual nodes and weights.

    Shard ``s`` with weight ``w`` owns ``max(1, round(vnodes * w))``
    points at ``fnv1a(b"ring:<s>:<j>", RING_SEED)``; a key belongs to the
    shard of the first point clockwise from ``key_point(key)``.  Adding a
    shard steals ~1/(S+1) of every incumbent's arc mass; removing one
    spills its arcs onto the clockwise successors; reweighting moves only
    the arc mass the weight delta implies.
    """

    kind = "ring"
    supports_weights = True

    def __init__(self, num_shards: int = 1, vnodes: int = DEFAULT_VNODES,
                 weights: dict[int, float] | None = None, shard_ids=None):
        if vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        self.vnodes = vnodes
        ids = (sorted(shard_ids) if shard_ids is not None
               else list(range(num_shards)))
        if not ids:
            raise ValueError("need at least one shard")
        self.weights: dict[int, float] = {s: 1.0 for s in ids}
        if weights:
            for s, w in weights.items():
                self._check_weight(w)
                self.weights[s] = float(w)
        self._rebuild()

    @staticmethod
    def _check_weight(w: float):
        if not (w > 0):
            raise ValueError(f"weight must be > 0, got {w}")

    def _points_of(self, shard: int) -> int:
        return max(1, round(self.vnodes * self.weights[shard]))

    def _rebuild(self):
        pts = []
        for s in sorted(self.weights):
            for j in range(self._points_of(s)):
                pts.append((fnv1a(b"ring:%d:%d" % (s, j), seed=RING_SEED), s))
        pts.sort()  # (point, shard) — shard id breaks 64-bit point ties
        self._points = [p for p, _ in pts]
        self._owners = [s for _, s in pts]

    @property
    def shard_ids(self) -> tuple[int, ...]:
        return tuple(sorted(self.weights))

    def shard_for(self, key: bytes) -> int:
        i = bisect.bisect_right(self._points, key_point(key))
        return self._owners[i % len(self._owners)]

    def add_shard(self, shard: int, weight: float = 1.0) -> int:
        if shard in self.weights:
            raise ValueError(f"shard {shard} already active")
        self._check_weight(weight)
        self.weights[shard] = float(weight)
        self._rebuild()
        return shard

    def remove_shard(self, shard: int) -> None:
        if shard not in self.weights:
            raise ValueError(f"no active shard {shard}")
        if len(self.weights) == 1:
            raise ValueError("cannot remove the last shard")
        del self.weights[shard]
        self._rebuild()

    def set_weight(self, shard: int, weight: float) -> None:
        if shard not in self.weights:
            raise ValueError(f"no active shard {shard}")
        self._check_weight(weight)
        self.weights[shard] = float(weight)
        self._rebuild()

    def weight_of(self, shard: int) -> float:
        return self.weights[shard]

    def arc_fractions(self) -> dict[int, float]:
        """Fraction of the 64-bit ring each shard owns (diagnostics)."""
        span = 1 << 64
        out = {s: 0 for s in self.weights}
        prev = self._points[-1] - span  # wrap-around arc
        for p, s in zip(self._points, self._owners):
            out[s] += p - prev
            prev = p
        return {s: v / span for s, v in out.items()}

    def describe(self) -> str:
        return (f"ring({self.num_shards} shards, {self.vnodes} vnodes, "
                f"{len(self._points)} points)")


def make_placement(spec=None, num_shards: int = 1) -> Placement:
    """Placement factory.  ``spec``: a ``Placement`` instance (adopted as
    is; its membership must already cover ``[0, num_shards)``), ``"mod"``,
    ``"ring"``, ``"ring:<vnodes>"``, or None (``$MEMEC_PLACEMENT``,
    default ``mod`` — the historical routing)."""
    if isinstance(spec, Placement):
        if set(spec.shard_ids) != set(range(num_shards)):
            raise ValueError(
                f"placement covers shards {spec.shard_ids}, cluster has "
                f"[0, {num_shards})")
        return spec
    if spec is None:
        spec = os.environ.get("MEMEC_PLACEMENT") or "mod"
    name, _, arg = str(spec).partition(":")
    if name == "mod":
        return ModPlacement(num_shards)
    if name == "ring":
        vnodes = int(arg) if arg else DEFAULT_VNODES
        return RingPlacement(num_shards, vnodes=vnodes)
    raise ValueError(f"unknown placement {spec!r} (mod | ring | ring:<vnodes>)")
