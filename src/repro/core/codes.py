"""Erasure codes for MemEC: Reed-Solomon (Cauchy), RDP, and single-XOR.

All codes are systematic: a stripe is ``n`` chunks = ``k`` data chunks
followed by ``m = n - k`` parity chunks.  MDS codes recover the stripe from
any ``k`` of the ``n`` chunks.

This module is the *host* (numpy) data plane used by the in-process cluster
simulation — the paper's C++ servers run coding on CPU too.  The TPU data
plane lives in ``repro.kernels`` (Pallas) and ``repro.distributed``
(shard_map collectives); both are validated against this module.

Delta parity updates exploit linearity (paper §2):

    P_j' = P_j  ⊕  gamma_{j,i} · (D_i' ⊕ D_i)
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from . import gf256


class Code:
    """Interface shared by RS / RDP / XOR / NoCode."""

    n: int
    k: int

    @property
    def m(self) -> int:
        return self.n - self.k

    def encode(self, data: np.ndarray) -> np.ndarray:
        """data: (k, C) uint8 -> parity (m, C) uint8."""
        raise NotImplementedError

    def decode(self, available: dict[int, np.ndarray], wanted: list[int],
               chunk_size: int) -> dict[int, np.ndarray]:
        """Reconstruct stripe positions ``wanted`` from ``available``."""
        raise NotImplementedError

    def xor_delta(self, data_index: int, xor: np.ndarray) -> np.ndarray:
        """Parity deltas (m, C) for data chunk ``data_index`` changing by
        ``xor`` = D ⊕ D' (full chunk width; sparse updates are zero-padded).
        Apply with ``parity ^= delta[j]``.
        """
        raise NotImplementedError

    def parity_delta(self, data_index: int, old: np.ndarray, new: np.ndarray) -> np.ndarray:
        old = np.asarray(old, dtype=np.uint8)
        new = np.asarray(new, dtype=np.uint8)
        return self.xor_delta(data_index, old ^ new)


# ---------------------------------------------------------------------------
# Reed-Solomon (systematic Cauchy construction — always MDS)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _cauchy_parity(n: int, k: int) -> np.ndarray:
    if n > 256:
        raise ValueError("RS over GF(2^8) requires n <= 256")
    m = n - k
    A = np.zeros((m, k), dtype=np.uint8)
    for j in range(m):
        for i in range(k):
            A[j, i] = gf256.gf_inv_np((k + j) ^ i)
    A.setflags(write=False)
    return A


@dataclasses.dataclass(frozen=True)
class RSCode(Code):
    """Systematic Reed-Solomon (Cauchy) code over GF(2^8)."""

    n: int
    k: int

    def __post_init__(self):
        if not (0 < self.k < self.n <= 256):
            raise ValueError(f"invalid RS parameters n={self.n} k={self.k}")

    @property
    def parity_matrix(self) -> np.ndarray:
        return _cauchy_parity(self.n, self.k)

    @property
    def generator(self) -> np.ndarray:
        """(n, k) systematic generator [I_k ; A]."""
        return np.concatenate([np.eye(self.k, dtype=np.uint8), self.parity_matrix])

    def encode(self, data):
        data = np.asarray(data, dtype=np.uint8)
        assert data.shape[0] == self.k, (data.shape, self.k)
        return gf256.gf_matmul_np(self.parity_matrix, data)

    def decode_matrix(self, available_idx) -> tuple[np.ndarray, list[int]]:
        """(k, k) inverse mapping k available chunks -> k data chunks."""
        avail = sorted(available_idx)
        if len(avail) < self.k:
            raise ValueError(
                f"need {self.k} chunks, got {len(avail)} — beyond erasure "
                f"tolerance of RS({self.n},{self.k})")
        idx = avail[: self.k]
        return gf256.gf_mat_inv(self.generator[idx]), idx

    def decode(self, available, wanted, chunk_size):
        inv, idx = self.decode_matrix(list(available.keys()))
        stacked = np.stack([np.asarray(available[i], dtype=np.uint8) for i in idx])
        data = gf256.gf_matmul_np(inv, stacked)  # (k, C)
        out = {}
        need_par = [w for w in wanted if w >= self.k]
        for w in wanted:
            if w < self.k:
                out[w] = data[w]
        if need_par:
            rows = self.generator[need_par]
            par = gf256.gf_matmul_np(rows, data)
            for r, w in enumerate(need_par):
                out[w] = par[r]
        return out

    def xor_delta(self, data_index, xor):
        xor = np.asarray(xor, dtype=np.uint8)
        gammas = self.parity_matrix[:, data_index]  # (m,)
        return gf256.MUL_TABLE[gammas[:, None], xor[None, :]]

    def parity_coeffs(self, data_index: int) -> np.ndarray:
        return self.parity_matrix[:, data_index]


# ---------------------------------------------------------------------------
# RDP — Row-Diagonal Parity (double-failure XOR code, paper Exp. 2)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _prime_at_least(x: int) -> int:
    def is_prime(v):
        if v < 2:
            return False
        f = 2
        while f * f <= v:
            if v % f == 0:
                return False
            f += 1
        return True

    p = x
    while not is_prime(p):
        p += 1
    return p


@dataclasses.dataclass(frozen=True)
class RDPCode(Code):
    """RDP(p): k data + 2 parity (row + diagonal), pure-XOR, tolerates any
    double erasure.  k real disks embed into the p-1 virtual disks of an RDP
    array with prime p >= k+1 (the rest are imaginary zero disks).  Chunks
    are viewed as (p-1, C/(p-1)) sub-block arrays; C=4096 uses p=17.

    Row parity  P[s]  = XOR_i D[i][s]
    Diag parity Q[d]  = XOR over {disk i at sub-row s : (i+s) mod p == d}
                        of D[i][s], including the row-parity disk at virtual
                        position k; diagonal p-1 is not stored.
    """

    n: int
    k: int
    p: int = 17

    def __post_init__(self):
        if self.n - self.k != 2:
            raise ValueError("RDP provides exactly 2 parity chunks")
        if self.k + 1 > self.p - 1:
            raise ValueError(f"RDP(p={self.p}) supports at most k={self.p-2}")

    @property
    def row_disk(self) -> int:
        """Virtual position of the row-parity disk in the diagonal layout."""
        return self.k

    def _blocks(self, chunk: np.ndarray) -> np.ndarray:
        C = chunk.shape[-1]
        r = self.p - 1
        if C % r:
            raise ValueError(f"chunk size {C} not divisible by p-1={r}")
        return chunk.reshape(chunk.shape[:-1] + (r, C // r))

    def encode(self, data):
        data = np.asarray(data, dtype=np.uint8)
        k, C = data.shape
        assert k == self.k
        r = self.p - 1
        blocks = self._blocks(data)  # (k, r, C/r)
        row_p = blocks[0].copy()
        for i in range(1, k):
            row_p ^= blocks[i]
        diag = np.zeros_like(row_p)
        cols = list(blocks) + [row_p]
        for i, col in enumerate(cols):  # virtual positions 0..k
            for s in range(r):
                d = (i + s) % self.p
                if d != self.p - 1:
                    diag[d] ^= col[s]
        return np.stack([row_p.reshape(C), diag.reshape(C)])

    def decode(self, available, wanted, chunk_size):
        missing = [i for i in range(self.n) if i not in available]
        if len(missing) > 2:
            raise ValueError("RDP tolerates at most 2 erasures")
        C = chunk_size
        r = self.p - 1
        kr = self.k * r

        def var(i, s):
            return i * r + s

        # Express every known disk as GF(2) equations over data sub-blocks.
        masks, rhss = [], []
        for i in range(self.k):
            if i in available:
                col = np.asarray(available[i], dtype=np.uint8).reshape(r, C // r)
                for s in range(r):
                    m = np.zeros(kr, dtype=np.uint8)
                    m[var(i, s)] = 1
                    masks.append(m)
                    rhss.append(col[s].copy())
        if self.k in available:  # row parity
            col = np.asarray(available[self.k], dtype=np.uint8).reshape(r, C // r)
            for s in range(r):
                m = np.zeros(kr, dtype=np.uint8)
                for i in range(self.k):
                    m[var(i, s)] = 1
                masks.append(m)
                rhss.append(col[s].copy())
        if self.k + 1 in available:  # diagonal parity
            col = np.asarray(available[self.k + 1], dtype=np.uint8).reshape(r, C // r)
            for d in range(r):
                m = np.zeros(kr, dtype=np.uint8)
                rhs = col[d].copy()
                for i in range(self.k):
                    s = (d - i) % self.p
                    if s < r:
                        m[var(i, s)] ^= 1
                # the row-parity disk's diagonal contribution
                s = (d - self.row_disk) % self.p
                if s < r:
                    if self.k in available:
                        rhs ^= np.asarray(available[self.k],
                                          dtype=np.uint8).reshape(r, C // r)[s]
                    else:
                        for i in range(self.k):  # expand rowP[s] = XOR_i D[i][s]
                            m[var(i, s)] ^= 1
                masks.append(m)
                rhss.append(rhs)
        # GF(2) Gaussian elimination with byte-vector right-hand sides.
        A = np.stack(masks)
        B = np.stack(rhss)
        piv_of = {}
        row = 0
        for col_i in range(kr):
            sel = next((rr for rr in range(row, A.shape[0]) if A[rr, col_i]), None)
            if sel is None:
                continue
            if sel != row:
                A[[row, sel]] = A[[sel, row]]
                B[[row, sel]] = B[[sel, row]]
            hit = (A[:, col_i] == 1)
            hit[row] = False
            A[hit] ^= A[row]
            B[hit] ^= B[row]
            piv_of[col_i] = row
            row += 1
        if len(piv_of) < kr:
            raise ValueError("RDP decode: system underdetermined")
        data = np.zeros((self.k, r, C // r), dtype=np.uint8)
        for i in range(self.k):
            for s in range(r):
                data[i, s] = B[piv_of[var(i, s)]]
        data = data.reshape(self.k, C)
        out = {}
        par = None
        for w in wanted:
            if w < self.k:
                out[w] = data[w]
            else:
                if par is None:
                    par = self.encode(data)
                out[w] = par[w - self.k]
        return out

    def xor_delta(self, data_index, xor):
        xor = np.asarray(xor, dtype=np.uint8)
        C = xor.shape[-1]
        r = self.p - 1
        xb = xor.reshape(r, C // r)
        diag_d = np.zeros((r, C // r), dtype=np.uint8)
        for src in (data_index, self.row_disk):  # direct + via row parity
            for s in range(r):
                d = (src + s) % self.p
                if d != self.p - 1:
                    diag_d[d] ^= xb[s]
        return np.stack([xor, diag_d.reshape(C)])

    def block_matrix(self) -> np.ndarray:
        """The code as one (2r, k*r) 0/1 matrix over sub-block rows.

        Chunk i reshapes to r = p-1 sub-block rows; column ``i*r + s``
        is disk i's sub-row s.  Output rows 0..r-1 are the row parity,
        rows r..2r-1 the diagonals (the row-parity disk's diagonal
        contribution expands to XOR over all data disks at its sub-row).
        This is the analytic form of what ``engine.block_rep`` used to
        probe out of ``encode`` with k*r basis vectors — pure-XOR, so
        every entry is 0/1 and the Pallas column-loop kernels apply.
        """
        r = self.p - 1
        E = np.zeros((2 * r, self.k * r), dtype=np.uint8)
        for i in range(self.k):
            for s in range(r):
                E[s, i * r + s] ^= 1                    # row parity
                d = (i + s) % self.p
                if d != self.p - 1:
                    E[r + d, i * r + s] ^= 1            # direct diagonal
        for s in range(r):  # row-parity disk's diagonal contribution
            d = (self.row_disk + s) % self.p
            if d != self.p - 1:
                for i in range(self.k):
                    E[r + d, i * r + s] ^= 1
        return E


# ---------------------------------------------------------------------------
# Single-parity XOR code (n = k + 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class XORCode(Code):
    n: int
    k: int

    def __post_init__(self):
        if self.n - self.k != 1:
            raise ValueError("XORCode has exactly 1 parity chunk")

    def encode(self, data):
        data = np.asarray(data, dtype=np.uint8)
        out = data[0].copy()
        for i in range(1, self.k):
            out ^= data[i]
        return out[None]

    def decode(self, available, wanted, chunk_size):
        missing = [i for i in range(self.n) if i not in available]
        if len(missing) > 1:
            raise ValueError("XOR code tolerates a single erasure")
        rec = None
        if missing:
            for c in available.values():
                c = np.asarray(c, dtype=np.uint8)
                rec = c.copy() if rec is None else rec ^ c
        out = {}
        for w in wanted:
            out[w] = (np.asarray(available[w], dtype=np.uint8)
                      if w in available else rec)
        return out

    def xor_delta(self, data_index, xor):
        return np.asarray(xor, dtype=np.uint8)[None]


# ---------------------------------------------------------------------------
# "No coding" — zero parity (paper Exp. 1 configuration)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NoCode(Code):
    n: int

    @property
    def k(self) -> int:  # type: ignore[override]
        return self.n

    def encode(self, data):
        data = np.asarray(data, dtype=np.uint8)
        return np.zeros((0, data.shape[-1]), dtype=np.uint8)

    def decode(self, available, wanted, chunk_size):
        out = {}
        for w in wanted:
            if w not in available:
                raise ValueError("NoCode cannot reconstruct lost chunks")
            out[w] = np.asarray(available[w], dtype=np.uint8)
        return out

    def xor_delta(self, data_index, xor):
        return np.zeros((0, np.asarray(xor).shape[-1]), dtype=np.uint8)


def make_code(scheme: str, n: int, k: int) -> Code:
    scheme = scheme.lower()
    if scheme in ("rs", "reed-solomon", "reed_solomon"):
        return RSCode(n=n, k=k)
    if scheme == "rdp":
        return RDPCode(n=n, k=k, p=_prime_at_least(max(k + 2, 17)))
    if scheme == "xor":
        return XORCode(n=n, k=k)
    if scheme in ("none", "nocode", "no-coding"):
        return NoCode(n=n)
    raise ValueError(f"unknown coding scheme {scheme!r}")
