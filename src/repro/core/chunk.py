"""All-encoding chunk layout (paper §3.2, Figure 1).

Storage is divided into fixed-size chunks (default 4 KB) prefixed by an
8-byte chunk ID.  A data chunk packs objects back-to-back:

    object := [ metadata | key | value ]
    metadata := key_size (1 byte) | value_size (3 bytes, little-endian)

so M = 4 bytes, matching the paper's analysis (§3.3).  Objects are appended
until the chunk is full, then the chunk is *sealed* and erasure-coded.

Chunk ID := stripe_list_id (2B) | stripe_id (5B) | chunk_position (1B)
(8 bytes total, I = 8 in the analysis).

Large objects (value larger than a chunk) are split into fragments, each
stored as its own object with a fragment-offset tag embedded in the key
suffix (paper §3.2 "Handling large objects").
"""
from __future__ import annotations

import dataclasses
import struct

import numpy as np

CHUNK_SIZE = 4096
CHUNK_ID_SIZE = 8
METADATA_SIZE = 4  # 1B key size + 3B value size
MAX_KEY = 255
MAX_VALUE = (1 << 24) - 1

# tombstone: value_size field's top bit (we cap real values below 2^23)
_DELETED_BIT = 1 << 23


def object_size(key_size: int, value_size: int) -> int:
    return METADATA_SIZE + key_size + value_size


@dataclasses.dataclass(frozen=True)
class ChunkId:
    stripe_list_id: int
    stripe_id: int
    position: int  # 0..n-1 within stripe

    def pack(self) -> bytes:
        if not (0 <= self.stripe_list_id < 1 << 16):
            raise ValueError("stripe_list_id out of range")
        if not (0 <= self.stripe_id < 1 << 40):
            raise ValueError("stripe_id out of range")
        if not (0 <= self.position < 256):
            raise ValueError("position out of range")
        return struct.pack("<HIH", self.stripe_list_id,
                           self.stripe_id & 0xFFFFFFFF,
                           ((self.stripe_id >> 32) & 0xFF) | (self.position << 8))

    @staticmethod
    def unpack(raw: bytes) -> "ChunkId":
        sl, lo, hi = struct.unpack("<HIH", raw[:CHUNK_ID_SIZE])
        stripe_id = lo | ((hi & 0xFF) << 32)
        position = (hi >> 8) & 0xFF
        return ChunkId(sl, stripe_id, position)

    def key(self) -> tuple:
        return (self.stripe_list_id, self.stripe_id, self.position)

    def stripe_key(self) -> tuple:
        return (self.stripe_list_id, self.stripe_id)


@dataclasses.dataclass
class ObjectRef:
    """Reference stored in the object index: where an object lives."""
    chunk_local_idx: int   # index of the chunk in the server's memory region
    offset: int            # byte offset of the object inside the chunk
    key_size: int
    value_size: int

    @property
    def value_offset(self) -> int:
        return self.offset + METADATA_SIZE + self.key_size


def pack_object(key: bytes, value: bytes, deleted: bool = False) -> bytes:
    if len(key) > MAX_KEY:
        raise ValueError(f"key too long ({len(key)} > {MAX_KEY})")
    if len(value) >= _DELETED_BIT:
        raise ValueError("value too long for a single object")
    vfield = len(value) | (_DELETED_BIT if deleted else 0)
    md = struct.pack("<B", len(key)) + struct.pack("<I", vfield)[:3]
    return md + key + value


def parse_objects(content: np.ndarray | bytes):
    """Parse a data chunk's content into [(offset, key, value, deleted)].

    Stops at the first zero key_size byte (chunks are zero-initialized).
    """
    if isinstance(content, np.ndarray):
        content = content.tobytes()
    out = []
    off = 0
    n = len(content)
    while off + METADATA_SIZE <= n:
        ksz = content[off]
        if ksz == 0:
            break
        vfield = int.from_bytes(content[off + 1: off + 4], "little")
        deleted = bool(vfield & _DELETED_BIT)
        vsz = vfield & (_DELETED_BIT - 1)
        start_k = off + METADATA_SIZE
        key = content[start_k: start_k + ksz]
        value = content[start_k + ksz: start_k + ksz + vsz]
        if len(key) < ksz or len(value) < vsz:
            break  # truncated tail
        out.append((off, key, value, deleted))
        off = start_k + ksz + vsz
    return out


class ChunkBuilder:
    """Mutable data chunk being filled by SET requests (an *unsealed* chunk).

    Backed by a zero-initialized numpy byte array of CHUNK_SIZE.
    """

    __slots__ = ("chunk_size", "buf", "used", "objects", "sealed")

    def __init__(self, chunk_size: int = CHUNK_SIZE):
        self.chunk_size = chunk_size
        self.buf = np.zeros(chunk_size, dtype=np.uint8)
        self.used = 0
        self.objects: list[tuple[bytes, int]] = []  # (key, offset)
        self.sealed = False

    @property
    def free(self) -> int:
        return self.chunk_size - self.used

    def fits(self, key: bytes, value_size: int) -> bool:
        return object_size(len(key), value_size) <= self.free

    def append(self, key: bytes, value: bytes) -> int:
        """Append an object; returns its byte offset inside the chunk."""
        if self.sealed:
            raise RuntimeError("chunk already sealed")
        blob = pack_object(key, value)
        if len(blob) > self.free:
            raise ValueError("object does not fit in chunk")
        off = self.used
        self.buf[off: off + len(blob)] = np.frombuffer(blob, dtype=np.uint8)
        self.used += len(blob)
        self.objects.append((key, off))
        return off

    def write_value(self, offset: int, key_size: int, value: bytes):
        """In-place value overwrite (UPDATE; size must be unchanged)."""
        vo = offset + METADATA_SIZE + key_size
        self.buf[vo: vo + len(value)] = np.frombuffer(value, dtype=np.uint8)

    def read_value(self, offset: int, key_size: int, value_size: int) -> bytes:
        vo = offset + METADATA_SIZE + key_size
        return self.buf[vo: vo + value_size].tobytes()

    def mark_deleted(self, offset: int, key_size: int, value_size: int):
        """Tombstone + zero the value (paper: delta treats new value as 0)."""
        vfield = value_size | _DELETED_BIT
        self.buf[offset + 1: offset + 4] = np.frombuffer(
            struct.pack("<I", vfield)[:3], dtype=np.uint8)
        vo = offset + METADATA_SIZE + key_size
        self.buf[vo: vo + value_size] = 0

    def seal(self) -> np.ndarray:
        self.sealed = True
        return self.buf


def split_fragments(key: bytes, value: bytes, chunk_size: int = CHUNK_SIZE):
    """Split a large object into (fragment_key, fragment_value) pairs.

    Every fragment replicates the key plus a 4-byte fragment-offset suffix
    (paper §3.2: "all fragments keep both key and metadata").  Fragment
    payloads are sized so each fragment object fits in one chunk.
    """
    frag_key_size = len(key) + 4
    payload = chunk_size - METADATA_SIZE - frag_key_size
    if payload <= 0:
        raise ValueError("key too large for fragmentation")
    frags = []
    off = 0
    idx = 0
    while off < len(value) or (off == 0 and len(value) == 0):
        part = value[off: off + payload]
        frags.append((key + struct.pack("<I", idx), part))
        off += payload
        idx += 1
        if len(value) == 0:
            break
    return frags


def fragment_count(value_size: int, key_size: int, chunk_size: int = CHUNK_SIZE) -> int:
    payload = chunk_size - METADATA_SIZE - (key_size + 4)
    return max(1, -(-value_size // payload))
