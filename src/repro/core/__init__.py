"""MemEC core: the paper's primary contribution in library form.

Layers:
* gf256 / codes — GF(2^8) arithmetic + RS/RDP/XOR erasure codes with
  delta-based parity updates (paper §2);
* engine — the unified batched coding data plane: one `CodingEngine`
  interface (encode_batch / decode_batch / apply_delta_batch) with
  pluggable numpy / jax / pallas backends, shared by servers, the
  cluster's batched request paths, and batched recovery.  Backend
  selection: the `engine=` constructor knob (configs/memec.py) or the
  `MEMEC_ENGINE` env var;
* chunk / index / stripe — the all-encoding data model: 4KB chunk packing,
  cuckoo-hash object & chunk indexes, write-balanced stripe lists (§3, §4.3);
* server / proxy / coordinator / store — the cluster: decentralized
  normal-mode requests (single-key and batched multi_get/multi_set/
  multi_update), coordinated degraded mode, server states, backups,
  one-shot batched recovery, migration (§4, §5);
* shard — the scale-out layer: `ShardedCluster` hash-partitions the key
  space across S independent shard stores (own stripe lists, coordinator,
  `CodingEngine` — mixed backends allowed), plans multi-key requests
  across shards with pipelined scatter/gather, and scopes failure
  recovery per shard.  `make_cluster(shards=... )` / `$MEMEC_SHARDS`;
  S=1 returns the plain `MemECCluster`;
* ring / rebalance — the elastic placement subsystem: pluggable
  `Placement` routing (FNV-mod or a deterministic consistent-hash ring
  with vnodes + weights, `placement=` / `$MEMEC_PLACEMENT`), live stripe
  migration (`Rebalancer`: chunk-wise moves through the engine/netsim
  paths, redirect-style forwarding keeps every key readable
  mid-migration), and skew-aware rebalancing via
  `ShardedCluster.add_shard/remove_shard/rebalance`;
* trace / telemetry — the observability layer: opt-in per-request span
  tracing (`trace=` / `$MEMEC_TRACE`) with critical-path attribution,
  Chrome trace-event export (Perfetto-loadable), deterministic trace
  capture/replay (`TraceCapture` → `arrival="trace:..."`), and the
  versioned telemetry snapshot every consumer reads;
* baselines — all-replication + hybrid-encoding comparison stores (§3.1);
* analysis — the redundancy formulas of §3.3 (Figure 2).
"""
from .analysis import (AnalysisParams, redundancy_all_encoding,
                       redundancy_all_replication, redundancy_hybrid_encoding)
from .baselines import AllReplicationCluster, HybridEncodingCluster
from .chunk import CHUNK_SIZE, ChunkBuilder, ChunkId, ObjectRef
from .codes import Code, NoCode, RDPCode, RSCode, XORCode, make_code
from .coordinator import Coordinator, ServerState
from .engine import (CodingEngine, DecodePlan, EngineFuture, JaxEngine,
                     NumpyEngine, PallasEngine, make_engine, resolve_async)
from .engine import engine_specs
from .index import CuckooIndex
from .netsim import (ArrivalProcess, CostModel, EventRuntime, LatencyRecorder,
                     Leg, NetSim, resolve_arrival)
from .proxy import Proxy
from .rebalance import MigrationPlan, Rebalancer
from .ring import (ModPlacement, Placement, RingPlacement, make_placement)
from .server import Server
from .shard import (ShardedCluster, ShardedNet, make_cluster, resolve_shards,
                    shard_for_key)
from .store import MemECCluster, PartialFailure
from .stripe import StripeList, StripeMapper, generate_stripe_lists
from .trace import (Span, TraceCapture, Tracer, critical_paths,
                    describe_critical_path, export_chrome, resolve_trace,
                    validate_chrome)
from . import telemetry
from . import trace

__all__ = [
    "AnalysisParams", "redundancy_all_encoding", "redundancy_all_replication",
    "redundancy_hybrid_encoding", "AllReplicationCluster",
    "HybridEncodingCluster", "CHUNK_SIZE", "ChunkBuilder", "ChunkId",
    "ObjectRef", "Code", "NoCode", "RDPCode", "RSCode", "XORCode",
    "make_code", "CodingEngine", "EngineFuture", "JaxEngine", "NumpyEngine",
    "PallasEngine", "make_engine", "resolve_async", "engine_specs",
    "Coordinator", "ServerState", "CostModel", "ArrivalProcess",
    "EventRuntime", "LatencyRecorder", "resolve_arrival",
    "Leg", "NetSim", "Proxy", "Server", "MemECCluster", "PartialFailure",
    "ShardedCluster", "ShardedNet", "make_cluster", "resolve_shards",
    "shard_for_key", "StripeList", "StripeMapper", "generate_stripe_lists",
    "Placement", "ModPlacement", "RingPlacement", "make_placement",
    "Rebalancer", "MigrationPlan", "telemetry", "trace", "Span", "Tracer",
    "TraceCapture", "critical_paths", "describe_critical_path",
    "export_chrome", "resolve_trace", "validate_chrome",
]
