"""Cuckoo-hash indexes (paper §3.2).

Two hash functions map a key to two candidate buckets; each bucket is 4-way
set-associative (4 slots).  Inserts relocate (kick) existing entries on
collision via a bounded random walk; occupancy reaches >90 % (paper cites
[28, 29]).  Both the *object index* (key -> ObjectRef) and the *chunk index*
(chunk ID -> chunk reference) use this structure.

The insert/kick path is host-side (as in the C++ original); the data-plane
batched lookup (`bucket_arrays` + `repro.kernels.cuckoo_lookup`) exposes the
table as flat arrays so GET probes can run on device.
"""
from __future__ import annotations

import numpy as np

SLOTS_PER_BUCKET = 4
MAX_KICKS = 512

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def fnv1a(data: bytes, seed: int = 0) -> int:
    h = (_FNV_OFFSET ^ seed) & _MASK64
    for b in data:
        h ^= b
        h = (h * _FNV_PRIME) & _MASK64
    # murmur3 fmix64 avalanche: FNV's xor/multiply chain is bit-triangular
    # (low bits never see high bits), which correlates h mod 2^b across
    # seeds — fatal for two-stage hashing.  The finalizer fixes it.
    h ^= h >> 33
    h = (h * 0xFF51AFD7ED558CCD) & _MASK64
    h ^= h >> 33
    h = (h * 0xC4CEB9FE1A85EC53) & _MASK64
    h ^= h >> 33
    return h


def hash_pair(key: bytes) -> tuple[int, int]:
    """Two independent 64-bit hashes of a key."""
    h1 = fnv1a(key, seed=0)
    h2 = fnv1a(key, seed=0x9E3779B97F4A7C15)
    return h1, h2


class CuckooIndex:
    """4-way set-associative cuckoo hash mapping key-bytes -> python object.

    Stores the full 64-bit fingerprint per slot plus a sidecar dict from
    (bucket, slot) -> (key, value) to resolve fingerprint collisions exactly
    (the C++ original stores object pointers; we keep exactness for tests).
    """

    def __init__(self, num_buckets: int = 1024, rng: np.random.Generator | None = None):
        if num_buckets & (num_buckets - 1):
            raise ValueError("num_buckets must be a power of two")
        self.num_buckets = num_buckets
        self.fingerprints = np.zeros((num_buckets, SLOTS_PER_BUCKET), dtype=np.uint64)
        self.occupied = np.zeros((num_buckets, SLOTS_PER_BUCKET), dtype=bool)
        self.slot_data: dict[tuple[int, int], tuple[bytes, object]] = {}
        self.size = 0
        self._rng = rng or np.random.default_rng(0)
        self.total_kicks = 0

    # -- internals --------------------------------------------------------
    def _buckets_for(self, key: bytes) -> tuple[int, int, int]:
        h1, h2 = hash_pair(key)
        fp = h1 if h1 != 0 else 1  # 0 is the empty sentinel
        return h1 % self.num_buckets, h2 % self.num_buckets, fp

    def _find(self, key: bytes):
        b1, b2, fp = self._buckets_for(key)
        for b in (b1, b2):
            row = self.fingerprints[b]
            for s in range(SLOTS_PER_BUCKET):
                if self.occupied[b, s] and row[s] == fp:
                    k, v = self.slot_data[(b, s)]
                    if k == key:
                        return b, s
        return None

    # -- public API -------------------------------------------------------
    def lookup(self, key: bytes):
        loc = self._find(key)
        if loc is None:
            return None
        return self.slot_data[loc][1]

    def __contains__(self, key: bytes) -> bool:
        return self._find(key) is not None

    def insert(self, key: bytes, value: object) -> bool:
        """Insert or overwrite.  Returns False if the table is too full."""
        loc = self._find(key)
        if loc is not None:
            k, _ = self.slot_data[loc]
            self.slot_data[loc] = (k, value)
            return True
        b1, b2, fp = self._buckets_for(key)
        for b in (b1, b2):
            for s in range(SLOTS_PER_BUCKET):
                if not self.occupied[b, s]:
                    self._place(b, s, fp, key, value)
                    return True
        # Kick path: bounded random walk.
        cur_key, cur_val, cur_fp = key, value, fp
        b = b1 if self._rng.integers(2) else b2
        for _ in range(MAX_KICKS):
            s = int(self._rng.integers(SLOTS_PER_BUCKET))
            vk, vv = self.slot_data[(b, s)]
            vfp = int(self.fingerprints[b, s])
            self._place(b, s, cur_fp, cur_key, cur_val, replacing=True)
            cur_key, cur_val, cur_fp = vk, vv, vfp
            self.total_kicks += 1
            vb1, vb2, _ = self._buckets_for(cur_key)
            b = vb2 if b == vb1 else vb1
            for s2 in range(SLOTS_PER_BUCKET):
                if not self.occupied[b, s2]:
                    self._place(b, s2, cur_fp, cur_key, cur_val)
                    return True
        # Give the displaced key a home back via resize.
        self._resize()
        return self.insert(cur_key, cur_val)

    def _place(self, b, s, fp, key, value, replacing=False):
        if not replacing and self.occupied[b, s]:
            raise RuntimeError("slot occupied")
        if not self.occupied[b, s]:
            self.size += 1
        self.fingerprints[b, s] = np.uint64(fp)
        self.occupied[b, s] = True
        self.slot_data[(b, s)] = (key, value)

    def delete(self, key: bytes) -> bool:
        loc = self._find(key)
        if loc is None:
            return False
        b, s = loc
        self.occupied[b, s] = False
        self.fingerprints[b, s] = 0
        del self.slot_data[(b, s)]
        self.size -= 1
        return True

    def _resize(self):
        old = list(self.slot_data.values())
        self.num_buckets *= 2
        self.fingerprints = np.zeros((self.num_buckets, SLOTS_PER_BUCKET), dtype=np.uint64)
        self.occupied = np.zeros((self.num_buckets, SLOTS_PER_BUCKET), dtype=bool)
        self.slot_data = {}
        self.size = 0
        for k, v in old:
            self.insert(k, v)

    def keys(self) -> list[bytes]:
        """Every stored key (arbitrary order; callers sort for
        determinism).  Used by migration planning to enumerate a server's
        resident objects."""
        return [k for k, _ in self.slot_data.values()]

    @property
    def occupancy(self) -> float:
        return self.size / (self.num_buckets * SLOTS_PER_BUCKET)

    def items(self):
        return list(self.slot_data.values())

    def clear(self):
        self.fingerprints[:] = 0
        self.occupied[:] = False
        self.slot_data.clear()
        self.size = 0

    # -- data-plane export -------------------------------------------------
    def bucket_arrays(self):
        """(fingerprints u64 [B,4], occupied bool [B,4]) for device lookup."""
        return self.fingerprints.copy(), self.occupied.copy()
