"""Live stripe migration + skew-aware rebalancing for the sharded cluster.

The ``Rebalancer`` turns a placement change (shard added/removed, ring
weights shifted) into a *minimal-movement* migration plan — only keys
whose placement no longer matches their resident shard move — and then
executes it **live**:

* sealed objects move chunk-wise: each distinct source chunk is fetched
  once (one ``mig_chunk`` leg, accounted on the facade netsim), its
  moving objects are extracted from the authoritative chunk bytes, and
  the destination shard ingests them through the batched
  ``multi_set`` -> ``CodingEngine`` seal path;
* unsealed objects drain individually via redirect-style forwarding: the
  facade's pending-key table keeps routing every GET/SET/UPDATE/DELETE to
  whichever shard currently holds the bytes, so requests keep succeeding
  mid-migration;
* migration overlapping a ``fail_server`` falls back to the shard's
  batched-decode recovery: a failed source server's chunks are read from
  the redirected server's reconstruction cache (warmed by the one-shot
  batched decode in ``fail_server``), or decoded on demand through the
  same engine path;
* the source copy is physically drained (deleted, with parity deltas)
  after the destination acknowledges, so a later membership change can
  never resurrect a stale copy.

Between batches the executor invokes ``step_cb`` so callers (tests, the
rebalance benchmark, the verify.sh smoke) can interleave client traffic
and fault injection with the migration — the "no key is ever unreadable
mid-rebalance" property is exercised there.

Skew-aware rebalancing (``ShardedCluster.rebalance``) watches the
per-shard load counters the facade keeps (max/mean shard ops — the same
metric ``stats()``/``snapshot()`` expose) and, when the skew crosses a
threshold, shifts ring weights inversely to load before planning the
migration, so a Zipf hot shard sheds arcs to its underloaded peers.
"""
from __future__ import annotations

import dataclasses
import struct

from .chunk import fragment_count, object_size
from .netsim import Leg
from .store import LARGE_MAGIC, large_total

# migration leg kinds (facade netsim): one chunk fetch per distinct source
# chunk, one object transfer per moved object
MIG_CHUNK = "mig_chunk"
MIG_OBJ = "mig_obj"


@dataclasses.dataclass
class MigrationPlan:
    """Minimal-movement plan: every (key, src, dst) whose placement
    changed, capped at ``max_moves`` (excess stays forwarded via the
    pending table until a later rebalance)."""
    moves: list[tuple[bytes, int, int]]
    mismatched: int          # residents off-placement (incl. beyond the cap)
    residents: int           # logical residents scanned (fragments excluded)
    est_bytes: int           # object bytes the capped plan will move

    @property
    def move_fraction(self) -> float:
        return self.mismatched / self.residents if self.residents else 0.0


class Rebalancer:
    def __init__(self, cluster, batch_size: int = 64):
        self.cluster = cluster
        self.batch_size = max(1, batch_size)

    # ------------------------------------------------------------------
    # reading resident objects without client-request accounting
    # ------------------------------------------------------------------
    def _read(self, si: int, key: bytes):
        """Authoritative read of a mover for the actual transfer.

        Returns ``(value|None, chunk_token|None, extra_modeled_s)`` where
        ``chunk_token`` identifies the sealed source chunk the value came
        out of (each distinct token is fetched — and charged — once per
        migration).  Plain (non-decoding) resolution is the shard's
        ``peek_value``; the extra work here is chunk attribution plus the
        on-demand batched-decode fallback when a failed source server's
        chunk is not in the reconstruction cache yet.
        """
        sh = self.cluster.shards[si]
        sl, ds = sh.mapper.data_server_for(key)
        if sh._is_failed(ds) and sh._degraded_active(ds):
            value = sh.peek_value(key)
            if value is not None:
                cid = sh.coordinator.chunk_id_for(ds, key)
                rc = (sh._rs(sh.coordinator.redirected_server(sl, ds))
                      .recon.get(cid.key()) if cid is not None else None)
                if rc is not None and rc.value_of(key) == value:
                    r = sh.coordinator.redirected_server(sl, ds)
                    return value, ("recon", si, r, cid.key()), 0.0
                return value, None, 0.0   # shadowed object / replica
            # not peekable: a sealed chunk of the lost server that is not
            # reconstructed yet — batched-decode fallback through the
            # engine (normally fail_server pre-decoded the inventory)
            cid = sh.coordinator.chunk_id_for(ds, key)
            if cid is None:
                return None, None, 0.0
            r = sh.coordinator.redirected_server(sl, ds)
            rc, t_rec = sh._ensure_recon(sl, ds, cid.position,
                                         cid.stripe_id, r)
            return rc.value_of(key), ("recon", si, r, cid.key()), t_rec
        srv = sh.servers[ds]
        ref = srv.lookup(key)
        if ref is None:
            return None, None, 0.0
        value = srv.get_value(key)
        if srv.sealed[ref.chunk_local_idx]:
            return value, ("chunk", si, ds, ref.chunk_local_idx), 0.0
        return value, None, 0.0

    # ------------------------------------------------------------------
    # planning
    # ------------------------------------------------------------------
    def plan(self, max_moves: int | None = None) -> MigrationPlan:
        """Scan every shard store's resident keys, collect the ones whose
        placement no longer matches, and install the forwarding table
        (``cluster._pending``) that keeps *all* mismatched keys routed to
        their current bytes — including any beyond the ``max_moves`` cap.
        """
        cl = self.cluster
        movers: list[tuple[bytes, int, int]] = []
        pending: dict[bytes, int] = {}
        sizes: dict[bytes, int] = {}
        residents = 0
        for si, sh in enumerate(cl.shards):
            keys = sh.resident_keys()
            keyset = set(keys)
            # large objects move logically: their per-fragment keys are
            # internal to the owning shard and must never migrate alone.
            # Fragments are found structurally — a key whose 4-byte-suffix
            # -stripped parent is also resident AND reads as a manifest —
            # so plan time only ever peeks candidate parents, never the
            # whole data set.
            frag_skip: set[bytes] = set()
            for key in keys:
                if len(key) <= 4:
                    continue
                parent = key[:-4]
                if parent not in keyset or parent in sizes:
                    continue
                total = large_total(sh.peek_value(parent))
                if total is None:
                    continue
                sizes[parent] = total
                nfrag = fragment_count(total, len(parent), cl.chunk_size)
                for fi in range(nfrag):
                    frag_skip.add(parent + struct.pack("<I", fi))
            for key in keys:
                if key in frag_skip:
                    continue
                residents += 1
                dst = cl.placement.shard_for(key)
                if dst != si:
                    pending[key] = si
                    movers.append((key, si, dst))
        mismatched = len(movers)
        if max_moves is not None and mismatched > max_moves:
            # cap pressure goes to the hottest source shards first
            load = cl.shard_ops
            movers.sort(key=lambda m: -load[m[1]] if m[1] < len(load) else 0)
            movers = movers[:max_moves]
        cl._pending.clear()
        cl._pending.update(pending)
        est = 0
        for key, si, _ in movers:   # size only what the capped plan moves
            if key not in sizes:
                head = cl.shards[si].peek_value(key)
                sizes[key] = len(head) if head is not None else 0
            est += object_size(len(key), sizes[key])
        return MigrationPlan(moves=movers, mismatched=mismatched,
                             residents=residents, est_bytes=est)

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def execute(self, plan: MigrationPlan, step_cb=None) -> dict:
        """Move the planned keys in batches; live traffic may interleave
        at every ``step_cb`` boundary (called after each batch)."""
        cl = self.cluster
        moved_keys = moved_bytes = chunk_bytes = skipped = 0
        chunks_seen: set[tuple] = set()
        t_total = 0.0
        nbatches = 0
        for start in range(0, len(plan.moves), self.batch_size):
            batch = plan.moves[start: start + self.batch_size]
            legs: list[Leg] = []
            per_dst: dict[int, list[tuple[bytes, bytes]]] = {}
            large: list[tuple[bytes, int, int, bytes]] = []
            drains: list[tuple[int, bytes]] = []
            t_extra = 0.0

            def charge_chunk(token):
                nonlocal chunk_bytes
                if token is None or token in chunks_seen:
                    return
                chunks_seen.add(token)
                src_ep = f"sh{token[1]}:s{token[2]}"
                legs.append(Leg(MIG_CHUNK, cl.chunk_size, src_ep, "mig"))
                chunk_bytes += cl.chunk_size

            for key, si, di in batch:
                value, token, t_rec = self._read(si, key)
                t_extra += t_rec
                if value is None:
                    # deleted (or lost) since planning — nothing to move;
                    # routing falls through to the new placement
                    cl._pending.pop(key, None)
                    skipped += 1
                    continue
                total = large_total(value)
                if total is not None:
                    parts = []
                    nfrag = fragment_count(total, len(key), cl.chunk_size)
                    for fi in range(nfrag):
                        fval, ftok, t_rec2 = self._read(
                            si, key + struct.pack("<I", fi))
                        t_extra += t_rec2
                        if fval is None:
                            break
                        charge_chunk(ftok)
                        parts.append(fval)
                    if len(parts) < nfrag:
                        # a fragment is unreadable right now: moving a
                        # truncated object and draining the source would
                        # be silent corruption — leave the key forwarded
                        # (still pending) for a later pass instead
                        skipped += 1
                        continue
                    charge_chunk(token)
                    full = b"".join(parts)[:total]
                    large.append((key, si, di, full))
                    nbytes = object_size(len(key), len(full))
                else:
                    charge_chunk(token)
                    per_dst.setdefault(di, []).append((key, value))
                    nbytes = object_size(len(key), len(value))
                legs.append(Leg(MIG_OBJ, nbytes, "mig", f"sh{di}:p0"))
                moved_bytes += nbytes
                drains.append((si, key))
            # migration transfer time: bulk, link-serialized per endpoint
            t = cl.net.local.serialized_phase(legs) + t_extra
            # destination ingest through the batched engine/seal path
            for di, items in sorted(per_dst.items()):
                cl.shards[di].multi_set(items)
            for key, si, di, full in large:
                cl.shards[di].set(key, full)
            # flip routing to the destination, then drain the source copy
            for si, key in drains:
                cl._pending.pop(key, None)
            for si, key in drains:
                cl.shards[si].delete(key)
            moved_keys += len(drains)
            if legs or t_extra:
                cl.net.record("MIGRATE", t)
                t_total += t
            nbatches += 1
            if step_cb is not None:
                step_cb({"batch": nbatches, "moved_keys": moved_keys,
                         "planned": len(plan.moves)})
        cl._stats["migrations"] += 1
        cl._stats["migrated_keys"] += moved_keys
        cl._stats["migration_bytes"] += moved_bytes
        cl._stats["migration_chunk_bytes"] += chunk_bytes
        return {
            "moved_keys": moved_keys,
            "moved_bytes": moved_bytes,
            "chunk_fetch_bytes": chunk_bytes,
            "chunks_fetched": len(chunks_seen),
            "skipped_missing": skipped,
            "batches": nbatches,
            "mismatched": plan.mismatched,
            "residents": plan.residents,
            "move_fraction": plan.move_fraction,
            "pending_left": len(cl._pending),
            "t_modeled_s": t_total,
        }

    def run(self, max_moves: int | None = None, step_cb=None) -> dict:
        return self.execute(self.plan(max_moves=max_moves), step_cb=step_cb)


def skewed_weights(placement, loads: dict[int, float], damp: float = 2.0,
                   floor: float = 0.25, ceil: float = 4.0) -> dict[int, float]:
    """New ring weights inversely proportional to observed load.

    ``loads``: ops per active shard.  A shard at 2x the mean load sheds
    arc mass; an underloaded one grows.  The per-pass factor is damped to
    [1/damp, damp] — a single window (e.g. a shard with no history yet)
    must not swing the ring hard enough to *relocate* the hot spot
    instead of dispersing it; repeated passes converge.  Absolute weights
    clamp to [floor, ceil].
    """
    ids = list(placement.shard_ids)
    total = sum(loads.get(s, 0.0) for s in ids)
    if total <= 0:
        return {s: placement.weight_of(s) for s in ids}
    mean = total / len(ids)
    out = {}
    for s in ids:
        factor = mean / max(loads.get(s, 0.0), mean / damp)
        factor = min(damp, max(1.0 / damp, factor))
        out[s] = min(ceil, max(floor, placement.weight_of(s) * factor))
    return out
