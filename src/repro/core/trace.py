"""Per-request span tracing, critical-path attribution, capture/replay.

PR 7 made the tail measurable (``stats["latency"]`` p50/p99/p999); this
module makes it *attributable*.  With tracing enabled (``trace=`` ctor
arg or ``$MEMEC_TRACE``; off by default and zero-cost when off — no
tracer object is even allocated), every recorded request produces a
span tree:

    GET (request) ............................ dur == recorded latency
      queued (par) ........................... start - arrival
        wait:admission
        wait:endpoint:s3 ..................... occupying endpoint named
        wait:engine
      service (seq) .......................... phase-algebra latency
        get:p0->s3 (link) .................... one span per (kind, dst)
        engine:decode (engine) ............... lanes from engine_makespan
        ack:s3->p0 (link)

Span semantics are series-parallel: a ``seq`` span's children tile it
(a residual ``other`` leaf absorbs un-attributed time), a ``par``
span's duration is the max over children.  Two invariants hold for
every tree (``Span.check``): children nest inside parents, and the
max-weight root-to-leaf path — ``components(root)`` summed — equals
the recorded request latency.

On top of the spans:

* ``critical_paths(cluster)`` — per request kind, decompose the
  p50/p99/p999 *witness* request into additive wait components
  ("p99 of GET = 61% link p0->s5, 24% engine, ...").  Exported as
  telemetry v2's ``critical_path`` section.
* ``export_chrome(cluster)`` — Chrome trace-event JSON
  (Perfetto/about:tracing loadable): one pid per shard, one tid per
  server endpoint / engine lane.
* ``TraceCapture`` — record a live open-loop run's arrival timestamps
  and per-request kinds, serialize them, and replay any workload
  deterministically via ``arrival="trace:..."`` (closing the ROADMAP's
  trace-capture loop: a CI tail incident becomes a replayable file).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os

# seq-residual floor: anything smaller is float noise, not a span
_EPS = 1e-15


@dataclasses.dataclass
class Span:
    """One node of a series-parallel span tree.

    ``mode``: ``leaf`` (no children), ``seq`` (children tile the span
    back to back), ``par`` (children share the span's start; duration
    is the max child).  ``t0`` is assigned by ``_layout`` once the tree
    is rooted under a request.
    """
    name: str
    cat: str = "span"
    dur: float = 0.0
    mode: str = "leaf"
    t0: float = 0.0
    children: list = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.t0 + self.dur

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()

    def check(self, eps: float = 1e-9):
        """Assert the nesting + series-parallel invariants recursively."""
        for c in self.children:
            assert c.t0 >= self.t0 - eps, (self.name, c.name)
            assert c.end <= self.end + eps, (self.name, c.name)
            c.check(eps)
        if self.children:
            durs = [c.dur for c in self.children]
            if self.mode == "seq":
                assert abs(sum(durs) - self.dur) <= eps, self.name
            elif self.mode == "par":
                assert max(durs) <= self.dur + eps, self.name

    def to_dict(self) -> dict:
        d = {"name": self.name, "cat": self.cat, "dur": self.dur,
             "mode": self.mode, "t0": self.t0}
        if self.meta:
            d["meta"] = dict(self.meta)
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d


def _fill_seq(span: Span):
    """Append a residual ``other`` leaf so seq children tile the span."""
    resid = span.dur - sum(c.dur for c in span.children)
    if resid > _EPS:
        span.children.append(Span("other", "slack", resid))


def _layout(span: Span, t0: float):
    """Assign absolute start times: seq children run back to back from
    the parent's start; par children share it."""
    span.t0 = t0
    cursor = t0
    for c in span.children:
        _layout(c, cursor if span.mode == "seq" else t0)
        if span.mode == "seq":
            cursor += c.dur


def lpt_schedule(durations, depth):
    """Reconstruct ``CostModel.engine_makespan``'s LPT schedule.

    Returns ``[(lane, start_offset, dur), ...]`` with
    ``max(start + dur) == engine_makespan(durations)`` bit-exactly —
    same sort, same greedy, same float accumulation order.
    """
    ds = sorted((d for d in durations if d > 0), reverse=True)
    if not ds:
        return []
    if depth == float("inf") or len(ds) <= depth:
        return [(i, 0.0, d) for i, d in enumerate(ds)]
    lanes = [0.0] * max(1, int(depth))
    out = []
    for d in ds:
        i = min(range(len(lanes)), key=lanes.__getitem__)
        out.append((i, lanes[i], d))
        lanes[i] += d
    return out


def components(span: Span, out: dict | None = None) -> dict:
    """Additive decomposition of the max-weight root-to-leaf path.

    seq nodes contribute every child; par nodes contribute their
    longest child plus a named slack term for the serialization floor
    (when the merged duration exceeds the longest branch).  The values
    sum to ``span.dur`` (property-tested to 1e-9).
    """
    if out is None:
        out = {}
    if not span.children:
        out[span.name] = out.get(span.name, 0.0) + span.dur
    elif span.mode == "seq":
        for c in span.children:
            components(c, out)
    else:  # par
        top = max(span.children, key=lambda c: c.dur)
        components(top, out)
        slack = span.dur - top.dur
        if slack > _EPS:
            key = f"{span.name}:slack"
            out[key] = out.get(key, 0.0) + slack
    return out


def path_weight(span: Span) -> float:
    return sum(components(span).values())


class Tracer:
    """Frame-stack request tracer.

    The store pushes a *frame* at every request entry point (including
    requests nested inside other requests — degraded fallbacks, upsert
    delegation, per-proxy lanes); phase/engine hooks append spans to
    the top frame; ``finish`` pops exactly its own frame into a rooted
    request tree.  All hooks no-op when no frame is open, so
    control-plane traffic (fail/restore/checkpoint phases outside any
    request) is dropped rather than misattributed.
    """

    def __init__(self):
        self.requests: list[Span] = []
        self._frames: list[list[Span]] = []
        self._clock = 0.0   # closed-loop virtual timeline

    # -- frames --------------------------------------------------------
    def push(self):
        self._frames.append([])

    def pop(self) -> list[Span]:
        return self._frames.pop() if self._frames else []

    def cancel(self):
        if self._frames:
            self._frames.pop()

    def add(self, span: Span):
        if self._frames:
            self._frames[-1].append(span)

    # -- netsim hooks --------------------------------------------------
    def phase(self, dur: float, leg_costs):
        """Fan-out phase: one leaf per (kind, dst) keeping the max-cost
        representative (the occupying endpoint is in the name)."""
        if not self._frames or dur <= 0.0:
            return
        agg: dict = {}
        for leg, cost in leg_costs:
            key = (leg.kind, leg.dst)
            e = agg.get(key)
            if e is None:
                agg[key] = [cost, leg.src, 1]
            else:
                e[2] += 1
                if cost > e[0]:
                    e[0], e[1] = cost, leg.src
        kids = []
        for (kind, dst), (cost, src, n) in agg.items():
            name = f"{kind}:{src}->{dst}" if dst else f"{kind}:{src}"
            meta = {"src": src, "dst": dst}
            if n > 1:
                meta["n"] = n
            kids.append(Span(name, "link", cost, meta=meta))
        if len(kids) == 1:
            self._frames[-1].append(kids[0])
        else:
            top = max(kids, key=lambda s: s.dur)
            self._frames[-1].append(
                Span(f"fanout:{top.name}", "phase", dur, "par",
                     children=kids))

    def drain(self, dur: float, per_dst: dict, leg_costs):
        """Serialized phase: per destination, inbound legs drain
        sequentially (grouped per kind); destinations run in parallel."""
        if not self._frames or dur <= 0.0:
            return
        groups = []
        for dst, total in per_dst.items():
            kinds: dict = {}
            for leg, cost in leg_costs:
                if leg.dst != dst:
                    continue
                e = kinds.setdefault(leg.kind, [0.0, 0])
                e[0] += cost
                e[1] += 1
            kids = [Span(f"{kind}->{dst}", "link", c,
                         meta={"dst": dst, "n": n})
                    for kind, (c, n) in kinds.items()]
            g = Span(f"drain:{dst}", "phase", total, "seq",
                     children=kids, meta={"dst": dst})
            _fill_seq(g)
            groups.append(g)
        if len(groups) == 1:
            self._frames[-1].append(groups[0])
        else:
            self._frames[-1].append(
                Span("drain", "phase", dur, "par", children=groups))

    def race(self, dur: float, entries):
        """k-of-(k+Δ) race phase: ``entries`` is ``[(name, cost, won)]``
        per candidate round trip.  Winners become link leaves; losers
        become ``cancelled:*`` spans (cat ``cancelled``) clipped to the
        race duration — they show the redundant fetch in the timeline
        but can never be the critical path: winners are listed first,
        and the k-th winner's cost equals the race duration, so the
        par-mode max-child tie-break always lands on a winner.
        """
        if not self._frames or dur <= 0.0:
            return
        winners, losers = [], []
        for name, cost, won in entries:
            if won:
                winners.append(Span(name, "link", cost))
            else:
                losers.append(Span(f"cancelled:{name}", "cancelled",
                                   min(cost, dur),
                                   meta={"cancelled": True,
                                         "full_cost": cost}))
        kids = winners + losers
        if len(kids) == 1:
            self._frames[-1].append(kids[0])
            return
        top = max(winners, key=lambda s: s.dur) if winners else kids[0]
        self._frames[-1].append(
            Span(f"race:{top.name}", "phase", dur, "par", children=kids,
                 meta={"need": len(winners), "dropped": len(losers)}))

    def par(self, name: str, dur: float, segs: list):
        """Wrap spans built in a sub-frame as one parallel composite
        (e.g. the per-key races of one batched coded read)."""
        if not self._frames or not segs:
            return
        if len(segs) == 1 and segs[0].dur == dur:
            self._frames[-1].append(segs[0])
            return
        self._frames[-1].append(Span(name, "phase", dur, "par",
                                     children=segs))

    # -- store hooks ---------------------------------------------------
    def merge_coding(self, coding_s: float, net_s: float, merged: float,
                     kind, lane_durs, depth, async_mode: bool):
        """Replace the just-appended network phase span (if any) with
        the merged coding+network span: par in async mode (dur = max),
        seq otherwise (children tile)."""
        if not self._frames:
            return
        frame = self._frames[-1]
        net_seg = None
        if net_s > 0.0:
            if frame and frame[-1].dur == net_s:
                net_seg = frame.pop()
            else:
                net_seg = Span("net", "phase", net_s)
        eng = None
        if coding_s > 0.0:
            label = f"engine:{kind or 'code'}"
            nz = [d for d in (lane_durs or []) if d > 0]
            if len(nz) > 1:
                kids = []
                for lane, start, d in lpt_schedule(lane_durs, depth):
                    body = Span(label, "engine", d, meta={"lane": lane})
                    if start > 0.0:
                        kids.append(Span(label, "engine", start + d, "seq",
                                         meta={"lane": lane},
                                         children=[
                                             Span("engine:queue", "engine",
                                                  start,
                                                  meta={"lane": lane}),
                                             body]))
                    else:
                        kids.append(body)
                eng = (kids[0] if len(kids) == 1 else
                       Span(f"{label}[{len(kids)}]", "engine", coding_s,
                            "par", children=kids))
            else:
                eng = Span(label, "engine", coding_s)
        kids = [s for s in (eng, net_seg) if s is not None]
        if not kids:
            return
        if len(kids) == 1:
            frame.append(kids[0])
            return
        mode = "par" if async_mode else "seq"
        frame.append(Span(f"merge:{kind or 'code'}", "merge", merged,
                          mode, children=kids))

    def overlap(self, merged: float, branches, async_mode: bool):
        """Two traced branches merged by ``_overlap`` (seal+ack):
        ``branches`` is ``[(name, dur, segs), ...]``."""
        if not self._frames:
            return
        kids = []
        for name, dur, segs in branches:
            if segs and len(segs) == 1 and segs[0].dur == dur:
                kids.append(segs[0])
            else:
                g = Span(name, "group", dur, "seq",
                         children=list(segs or []))
                _fill_seq(g)
                kids.append(g)
        mode = "par" if async_mode else "seq"
        self._frames[-1].append(
            Span("overlap", "merge", merged, mode, children=kids))

    def lanes(self, merged: float, lane_entries, par: bool):
        """Per-proxy lane composite: ``lane_entries`` is
        ``[(proxy_id, dur, segs), ...]``."""
        if not self._frames:
            return
        kids = []
        for pid, dur, segs in lane_entries:
            g = Span(f"lane:p{pid}", "group", dur, "seq",
                     children=list(segs or []), meta={"proxy": pid})
            _fill_seq(g)
            kids.append(g)
        if len(kids) == 1 and kids[0].dur == merged:
            self._frames[-1].append(kids[0])
            return
        self._frames[-1].append(
            Span("lanes", "merge", merged, "par" if par else "seq",
                 children=kids))

    # -- completion ----------------------------------------------------
    def finish(self, kind: str, latency_s: float,
               detail: dict | None = None) -> Span | None:
        """Pop the current frame into a rooted request span.

        Closed loop: the root spans ``[clock, clock + latency)`` on a
        virtual serial timeline.  Event mode (``detail`` from
        ``EventRuntime.submit``): the root spans
        ``[arrival, completion)`` and leads with a ``queued`` par span
        holding the clipped per-resource waits.
        """
        if not self._frames:
            return None
        segs = self._frames.pop()
        meta = {"degraded": kind.endswith("_DEG")}
        if detail is None:
            root = Span(kind, "request", latency_s, "seq",
                        children=segs, meta=meta)
            _fill_seq(root)
            t0 = self._clock
            self._clock += latency_s
        else:
            arrival = detail["arrival"]
            wait = detail["start"] - arrival
            kids = []
            if wait > 0.0:
                wkids = []
                for label, ready in (("admission", detail["admit_ready"]),
                                     ("endpoint", detail["link_ready"]),
                                     ("engine", detail["engine_ready"])):
                    w = min(wait, ready - arrival)
                    if w <= 0.0:
                        continue
                    name = f"wait:{label}"
                    wmeta = {}
                    if label == "endpoint" and detail.get("endpoint"):
                        name = f"wait:endpoint:{detail['endpoint']}"
                        wmeta["endpoint"] = detail["endpoint"]
                    if label == "engine" and detail.get("lane", -1) >= 0:
                        wmeta["lane"] = detail["lane"]
                    wkids.append(Span(name, "wait", w, meta=wmeta))
                kids.append(Span("queued", "wait", wait, "par",
                                 children=wkids))
            svc = Span("service", "group", detail["service"], "seq",
                       children=segs)
            _fill_seq(svc)
            kids.append(svc)
            root = Span(kind, "request", latency_s, "seq",
                        children=kids, meta=meta)
            _fill_seq(root)
            t0 = arrival
        _layout(root, t0)
        self.requests.append(root)
        return root

    # -- reporting -----------------------------------------------------
    def span_count(self) -> int:
        return sum(1 for r in self.requests for _ in r.walk())

    def summary(self) -> dict:
        return {"enabled": True, "requests": len(self.requests),
                "spans": self.span_count(),
                "open_frames": len(self._frames)}

    def reset(self):
        self.requests.clear()
        self._frames.clear()
        self._clock = 0.0


def resolve_trace(trace=None, env: str = "MEMEC_TRACE"):
    """Ctor arg wins; else ``$MEMEC_TRACE``; else off (returns None —
    with tracing off no tracer state is allocated at all)."""
    if isinstance(trace, Tracer):
        return trace
    if trace is None:
        trace = os.environ.get(env, "")
    if isinstance(trace, str):
        trace = trace.strip().lower() not in ("", "0", "false", "off", "no")
    return Tracer() if trace else None


def _cluster_tracers(cluster):
    """``[(pid, name, tracer), ...]`` — facade/unsharded first (pid 0),
    then one pid per shard."""
    tr = getattr(cluster, "tracer", None)
    shards = getattr(cluster, "shards", None)
    if shards is None:
        return [(0, "cluster", tr)] if tr is not None else []
    out = [(0, "facade", tr)] if tr is not None else []
    for si, sh in enumerate(shards):
        if sh.tracer is not None:
            out.append((si + 1, f"shard{si}", sh.tracer))
    return out


# -- critical-path analysis ------------------------------------------------

_PCTS = ((50.0, "p50"), (99.0, "p99"), (99.9, "p999"))


def critical_paths(cluster) -> dict:
    """Per request kind, the additive critical-path decomposition of the
    p50/p99/p999 witness request::

        {"GET": {"count": 812,
                 "p99": {"latency_s": 0.0021,
                         "components": {"get:p0->s5": 0.0013, ...}},
                 ...}, ...}

    Witnesses are nearest-rank order statistics over the traced
    requests, so ``components`` sums to that witness's exact recorded
    latency (the property the tests pin to 1e-9).
    """
    tracers = _cluster_tracers(cluster)
    by_kind: dict[str, list[Span]] = {}
    for _, _, tr in tracers:
        for r in tr.requests:
            by_kind.setdefault(r.name, []).append(r)
    out = {}
    for kind, roots in sorted(by_kind.items()):
        ranked = sorted(roots, key=lambda r: r.dur)
        row: dict = {"count": len(roots)}
        for q, label in _PCTS:
            i = min(len(ranked) - 1,
                    max(0, math.ceil(q / 100.0 * len(ranked)) - 1))
            w = ranked[i]
            comp = components(w)
            row[label] = {
                "latency_s": w.dur,
                "components": dict(sorted(comp.items(),
                                          key=lambda kv: -kv[1])),
            }
        out[kind] = row
    return out


def describe_critical_path(entry: dict, top: int = 3) -> str:
    """Human one-liner: ``"61% get:p0->s5, 24% engine:decode, ..."``."""
    lat = entry["latency_s"]
    if not lat:
        return "0s"
    parts = [f"{100.0 * v / lat:.0f}% {k}"
             for k, v in list(entry["components"].items())[:top]]
    return ", ".join(parts)


# -- Chrome trace-event export ---------------------------------------------

def _tid_label(span: Span) -> str:
    if span.cat == "link":
        return span.meta.get("dst") or span.meta.get("src") or "net"
    if span.cat == "engine":
        lane = span.meta.get("lane")
        return f"engine/lane{lane}" if lane is not None else "engine"
    return "requests"


def export_chrome(cluster, path: str | None = None) -> dict:
    """Chrome trace-event JSON (``ph: "X"`` complete events, µs units):
    one pid per shard (pid 0 = facade/unsharded), one tid per server
    endpoint / engine lane, plus a ``requests`` tid carrying the span
    hierarchy.  Load in Perfetto (ui.perfetto.dev) or about:tracing."""
    events: list[dict] = []
    pid_names: dict[int, str] = {}
    tid_ids: dict[tuple, int] = {}

    def tid_of(pid: int, label: str) -> int:
        key = (pid, label)
        if key not in tid_ids:
            tid_ids[key] = len([k for k in tid_ids if k[0] == pid])
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid_ids[key],
                           "args": {"name": label}})
        return tid_ids[key]

    def emit(span: Span, pid: int):
        if span.cat == "shard":
            pid = int(span.meta.get("shard", 0)) + 1
        ev = {"name": span.name, "cat": span.cat, "ph": "X",
              "pid": pid, "tid": tid_of(pid, _tid_label(span)),
              "ts": span.t0 * 1e6, "dur": max(span.dur, 0.0) * 1e6}
        if span.meta:
            ev["args"] = {k: v for k, v in span.meta.items()}
        events.append(ev)
        for c in span.children:
            emit(c, pid)

    for pid, name, tracer in _cluster_tracers(cluster):
        if pid not in pid_names:
            pid_names[pid] = name
            events.append({"name": "process_name", "ph": "M", "pid": pid,
                           "tid": 0, "args": {"name": name}})
        for root in tracer.requests:
            emit(root, pid)
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f)
    return doc


def validate_chrome(doc: dict) -> dict:
    """Structural guard for the trace-event format; raises ValueError."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("chrome trace: missing traceEvents")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("chrome trace: traceEvents must be a list")
    for ev in evs:
        if not isinstance(ev, dict):
            raise ValueError("chrome trace: event must be a dict")
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"chrome trace: event missing {field!r}")
        if not isinstance(ev["pid"], int) or not isinstance(ev["tid"], int):
            raise ValueError("chrome trace: pid/tid must be ints")
        if ev["ph"] == "X":
            if "ts" not in ev or "dur" not in ev:
                raise ValueError("chrome trace: X event needs ts+dur")
            if ev["dur"] < 0 or ev["ts"] < 0:
                raise ValueError("chrome trace: negative ts/dur")
        elif ev["ph"] != "M":
            raise ValueError(f"chrome trace: unexpected ph {ev['ph']!r}")
    return doc


# -- capture / replay ------------------------------------------------------

class TraceCapture:
    """Arrival timestamps + request kinds of a live open-loop run.

    ``from_cluster`` reads the EventRuntime's event log;
    ``arrival_spec()`` serializes the timestamps back into an
    ``arrival="trace:..."`` spec, so replaying the same workload
    reproduces every arrival — and therefore every queue wait and
    per-kind percentile — deterministically.  ``save``/``load``
    round-trip through JSON (``arrival="trace:@file.json"`` loads one
    directly).
    """

    SCHEMA = "memec/trace-capture"
    VERSION = 1

    def __init__(self, arrivals, kinds=None, inflight: int = 1):
        self.arrivals = [float(t) for t in arrivals]
        self.kinds = list(kinds or [])
        self.inflight = max(1, int(inflight))
        if not self.arrivals:
            raise ValueError("capture needs at least one arrival")

    @classmethod
    def from_cluster(cls, cluster) -> "TraceCapture":
        net = getattr(cluster.net, "local", cluster.net)
        if net.events is None:
            raise ValueError("trace capture needs an open-loop run "
                             "(arrival=poisson/uniform/trace)")
        evs = sorted(net.events.events)   # (seq, kind, arrival, ...)
        return cls([e[2] for e in evs], [e[1] for e in evs],
                   net.arrival.inflight)

    def arrival_spec(self) -> str:
        """An ``arrival=`` spec replaying these arrivals verbatim."""
        ts = ",".join(repr(t) for t in self.arrivals)
        return f"trace:{ts}:inflight={self.inflight}"

    def to_json(self) -> dict:
        return {"schema": self.SCHEMA, "version": self.VERSION,
                "inflight": self.inflight, "arrivals": self.arrivals,
                "kinds": self.kinds}

    @classmethod
    def from_json(cls, doc: dict) -> "TraceCapture":
        if doc.get("schema") != cls.SCHEMA:
            raise ValueError(f"not a trace capture: {doc.get('schema')!r}")
        if doc.get("version") != cls.VERSION:
            raise ValueError(f"trace-capture version {doc.get('version')!r}"
                             f" != {cls.VERSION}")
        return cls(doc["arrivals"], doc.get("kinds"),
                   doc.get("inflight", 1))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path

    @classmethod
    def load(cls, path: str) -> "TraceCapture":
        with open(path) as f:
            return cls.from_json(json.load(f))
