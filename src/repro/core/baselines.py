"""Baseline data models the paper compares against (§3.1, Exp. 1/2).

* All-replication — (n-k+1) full object copies (key, value, metadata,
  reference), as in Memcache/Redis-with-replication/RAMCloud.
* Hybrid-encoding — erasure-code values across objects; replicate key +
  metadata + reference (n-k+1)x, as in LH*RS / Cocytus.

Both expose the MemECCluster request API (set/get/update/delete +
fail/restore) and the same netsim accounting so the Exp. 1/2 benchmarks
compare like for like.  They are deliberately simpler than MemEC: their
point is redundancy + traffic shape, not degraded-mode machinery.
"""
from __future__ import annotations

import numpy as np

from .chunk import CHUNK_SIZE, object_size
from .codes import make_code
from .index import CuckooIndex, fnv1a
from .netsim import CostModel, Leg, NetSim
from .stripe import StripeMapper, generate_stripe_lists


class AllReplicationCluster:
    """(n-k+1)-way replication KV store."""

    def __init__(self, num_servers: int = 16, num_proxies: int = 4,
                 n: int = 10, k: int = 8, c: int = 16,
                 cost: CostModel | None = None):
        self.num_servers = num_servers
        self.replicas = n - k + 1
        self.net = NetSim(cost)
        self.stores = [dict() for _ in range(num_servers)]
        self.indexes = [CuckooIndex(1 << 12) for _ in range(num_servers)]
        self.failed: set[int] = set()
        # reuse stripe lists purely as replica placement groups
        self.lists = generate_stripe_lists(num_servers, n, k, c)
        self.mapper = StripeMapper(self.lists)

    def _replica_set(self, key: bytes) -> list[int]:
        sl, primary = self.mapper.data_server_for(key)
        others = [s for s in sl.servers if s != primary]
        h = fnv1a(key, seed=7)
        picks = [primary]
        i = h % len(others)
        while len(picks) < self.replicas:
            picks.append(others[i % len(others)])
            i += 1
        return picks

    def set(self, key: bytes, value: bytes, proxy_id: int = 0):
        targets = self._replica_set(key)
        nbytes = object_size(len(key), len(value))
        t = self.net.phase([Leg("set", nbytes, f"p{proxy_id}", f"s{s}",
                                s in self.failed) for s in targets])
        for s in targets:
            self.stores[s][key] = value
            self.indexes[s].insert(key, len(value))
        t += self.net.phase([Leg("set_ack", 8, f"s{s}", f"p{proxy_id}",
                                 s in self.failed) for s in targets])
        self.net.record("SET", t)
        return True

    def get(self, key: bytes, proxy_id: int = 0):
        targets = self._replica_set(key)
        # read from the first available replica
        for s in targets:
            if s not in self.failed:
                t = self.net.phase([Leg("get", len(key), f"p{proxy_id}", f"s{s}")])
                v = self.stores[s].get(key)
                t += self.net.phase([Leg("get_resp", len(v) if v else 0,
                                         f"s{s}", f"p{proxy_id}")])
                self.net.record("GET", t)
                return v
        s = targets[0]
        t = self.net.phase([Leg("get", len(key), f"p{proxy_id}", f"s{s}", True)])
        v = self.stores[s].get(key)
        self.net.record("GET", t)
        return v

    def update(self, key: bytes, value: bytes, proxy_id: int = 0):
        targets = self._replica_set(key)
        t = self.net.phase([Leg("update", len(key) + len(value), f"p{proxy_id}",
                                f"s{s}", s in self.failed) for s in targets])
        ok = False
        for s in targets:
            if key in self.stores[s]:
                self.stores[s][key] = value
                ok = True
        t += self.net.phase([Leg("update_ack", 8, f"s{targets[0]}",
                                 f"p{proxy_id}")])
        self.net.record("UPDATE", t)
        return ok

    def delete(self, key: bytes, proxy_id: int = 0):
        targets = self._replica_set(key)
        t = self.net.phase([Leg("delete", len(key), f"p{proxy_id}", f"s{s}",
                                s in self.failed) for s in targets])
        ok = False
        for s in targets:
            ok |= self.stores[s].pop(key, None) is not None
            self.indexes[s].delete(key)
        self.net.record("DELETE", t)
        return ok

    def fail_server(self, sid: int):
        self.failed.add(sid)
        return {"T_N_to_D": 0.0}

    def restore_server(self, sid: int):
        self.failed.discard(sid)
        return {"T_D_to_N": 0.0}

    def total_memory(self) -> dict:
        payload = sum(len(k) + len(v) + 4 for st in self.stores
                      for k, v in st.items())
        refs = sum(ix.num_buckets * 4 * 8 for ix in self.indexes)
        return {"objects": payload, "index": refs}


class HybridEncodingCluster:
    """Cocytus-style: values erasure-coded across objects; keys + metadata +
    references replicated to the data server and all parity servers.

    Value chunks stripe across the k data servers of a stripe list: local
    value-chunk i of each data server position forms stripe (list, i); the
    m parity chunks of that stripe live on the list's parity servers.
    """

    def __init__(self, num_servers: int = 16, num_proxies: int = 4,
                 scheme: str = "rs", n: int = 10, k: int = 8, c: int = 16,
                 chunk_size: int = CHUNK_SIZE, cost: CostModel | None = None):
        self.code = make_code(scheme, n, k)
        self.n, self.k = self.code.n, self.code.k
        self.chunk_size = chunk_size
        self.net = NetSim(cost)
        self.lists = generate_stripe_lists(num_servers, n, k, c)
        self.mapper = StripeMapper(self.lists)
        self.num_servers = num_servers
        # value_chunks[sid][list_id] -> list of 4KB arrays (stripe position
        # of array i is this server's data position in the list; stripe i)
        self.value_chunks: list[dict[int, list[np.ndarray]]] = [
            {} for _ in range(num_servers)]
        self.fill: list[dict[int, int]] = [{} for _ in range(num_servers)]
        self.key_index: list[CuckooIndex] = [CuckooIndex(1 << 12)
                                             for _ in range(num_servers)]
        # (list_id, stripe_idx, parity_row) -> parity chunk
        self.parity_chunks: dict[tuple, np.ndarray] = {}
        self.failed: set[int] = set()
        self.key_meta_bytes = 0  # replicated key+metadata+ref accounting

    def _value_loc(self, sid: int, list_id: int, vsize: int):
        chunks = self.value_chunks[sid].setdefault(list_id, [])
        fill = self.fill[sid].get(list_id, self.chunk_size)
        if fill + vsize > self.chunk_size:
            chunks.append(np.zeros(self.chunk_size, np.uint8))
            fill = 0
        idx = len(chunks) - 1
        self.fill[sid][list_id] = fill + vsize
        return idx, fill

    def _apply_parity_delta(self, sl, dpos: int, idx: int, off: int,
                            xor: np.ndarray):
        full = np.zeros(self.chunk_size, np.uint8)
        full[off: off + len(xor)] = xor
        deltas = self.code.xor_delta(dpos, full)
        for j in range(self.n - self.k):
            pk = (sl.list_id, idx, j)
            if pk not in self.parity_chunks:
                self.parity_chunks[pk] = np.zeros(self.chunk_size, np.uint8)
            self.parity_chunks[pk] ^= deltas[j]

    def set(self, key: bytes, value: bytes, proxy_id: int = 0):
        sl, ds = self.mapper.data_server_for(key)
        vsize = max(len(value), 1)
        idx, off = self._value_loc(ds, sl.list_id, vsize)
        buf = self.value_chunks[ds][sl.list_id][idx]
        buf[off: off + len(value)] = np.frombuffer(value, np.uint8)
        meta = (idx, off, len(value))
        legs = [Leg("set", object_size(len(key), len(value)), f"p{proxy_id}",
                    f"s{ds}", ds in self.failed)]
        self.key_index[ds].insert(key, meta)
        kmr = len(key) + 4 + 8
        self.key_meta_bytes += kmr * (self.n - self.k + 1)
        dpos = sl.data_servers.index(ds)
        self._apply_parity_delta(sl, dpos, idx,
                                 off, np.frombuffer(value, np.uint8))
        for p in sl.parity_servers:
            self.key_index[p].insert(key, meta)
            legs.append(Leg("set_parity", kmr + len(value), f"p{proxy_id}",
                            f"s{p}", p in self.failed))
        t = self.net.phase(legs)
        t += self.net.phase([Leg("set_ack", 8, f"s{ds}", f"p{proxy_id}",
                                 ds in self.failed)])
        self.net.record("SET", t)
        return True

    def get(self, key: bytes, proxy_id: int = 0):
        sl, ds = self.mapper.data_server_for(key)
        if ds not in self.failed:
            t = self.net.phase([Leg("get", len(key), f"p{proxy_id}", f"s{ds}")])
            meta = self.key_index[ds].lookup(key)
            if meta is None:
                self.net.record("GET", t)
                return None
            idx, off, vlen = meta
            v = self.value_chunks[ds][sl.list_id][idx][off: off + vlen].tobytes()
            t += self.net.phase([Leg("get_resp", vlen, f"s{ds}", f"p{proxy_id}")])
            self.net.record("GET", t)
            return v
        # degraded read: decode the failed server's value chunk
        meta = None
        probe = None
        for p in sl.parity_servers:
            if p not in self.failed:
                probe = p
                meta = self.key_index[p].lookup(key)
                break
        if meta is None:
            self.net.record("GET_DEG", 0.0)
            return None
        idx, off, vlen = meta
        dpos = sl.data_servers.index(ds)
        available = {}
        legs = []
        for i, s in enumerate(sl.data_servers):
            if s in self.failed or i == dpos:
                continue
            chunks = self.value_chunks[s].get(sl.list_id, [])
            available[i] = (chunks[idx] if idx < len(chunks)
                            else np.zeros(self.chunk_size, np.uint8))
            legs.append(Leg("recon_fetch", self.chunk_size, f"s{s}", f"s{probe}"))
        for j in range(self.n - self.k):
            s = sl.parity_servers[j]
            if s in self.failed:
                continue
            pk = (sl.list_id, idx, j)
            available[self.k + j] = self.parity_chunks.get(
                pk, np.zeros(self.chunk_size, np.uint8))
            legs.append(Leg("recon_fetch", self.chunk_size, f"s{s}", f"s{probe}"))
        t = self.net.phase(legs[: self.k])
        rec = self.code.decode(available, [dpos], self.chunk_size)[dpos]
        v = rec[off: off + vlen].tobytes()
        t += self.net.phase([Leg("get_resp", vlen, f"s{probe}", f"p{proxy_id}")])
        self.net.record("GET_DEG", t)
        return v

    def update(self, key: bytes, value: bytes, proxy_id: int = 0):
        sl, ds = self.mapper.data_server_for(key)
        meta = self.key_index[ds].lookup(key) if ds not in self.failed else None
        if meta is None:
            self.net.record("UPDATE", 0.0)
            return False
        idx, off, vlen = meta
        if len(value) != vlen:
            raise ValueError("value size fixed across updates")
        buf = self.value_chunks[ds][sl.list_id][idx]
        old = buf[off: off + vlen].copy()
        buf[off: off + vlen] = np.frombuffer(value, np.uint8)
        xor = old ^ buf[off: off + vlen]
        dpos = sl.data_servers.index(ds)
        self._apply_parity_delta(sl, dpos, idx, off, xor)
        legs = [Leg("update", len(key) + vlen, f"p{proxy_id}", f"s{ds}")]
        legs += [Leg("delta", vlen, f"s{ds}", f"s{p}", p in self.failed)
                 for p in sl.parity_servers]
        t = self.net.phase(legs)
        t += self.net.phase([Leg("update_ack", 8, f"s{ds}", f"p{proxy_id}")])
        self.net.record("UPDATE", t)
        return True

    def delete(self, key: bytes, proxy_id: int = 0):
        sl, ds = self.mapper.data_server_for(key)
        meta = self.key_index[ds].lookup(key)
        if meta is None:
            return False
        idx, off, vlen = meta
        self.update(key, b"\x00" * vlen, proxy_id)
        for s in [ds] + list(sl.parity_servers):
            self.key_index[s].delete(key)
        return True

    def fail_server(self, sid: int):
        self.failed.add(sid)
        return {"T_N_to_D": 0.0}

    def restore_server(self, sid: int):
        self.failed.discard(sid)
        return {"T_D_to_N": 0.0}

    def total_memory(self) -> dict:
        chunks = sum(len(cs) for d in self.value_chunks
                     for cs in d.values()) * self.chunk_size
        parity = len(self.parity_chunks) * self.chunk_size
        refs = sum(ix.num_buckets * 4 * 8 for ix in self.key_index)
        return {"value_chunks": chunks, "parity_chunks": parity,
                "replicated_keys_meta": self.key_meta_bytes, "index": refs}
