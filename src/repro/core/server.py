"""MemEC storage server (paper §4): memory region, indexes, sealing, parity.

A server plays a *data* role for some stripe lists and a *parity* role for
others (roles are per-list, §2).  The server owns:

* a memory region of fixed-size chunks (list of 4 KB numpy buffers),
* the local-only object index (key -> ObjectRef) and chunk index
  (chunk-ID -> local chunk slot) — cuckoo hash tables (§3.2),
* per-list unsealed data chunks (fixed count; min-free-fit policy §4.2),
* per-list stripe-ID counters,
* the parity-role temporary replica buffer (objects of unsealed remote
  chunks) and parity chunks proper,
* a delta buffer for revert-on-failure (§5.3), and
* the key->chunk-ID mapping log with periodic checkpoints (§5.3).

Implementation note: the paper assigns the stripe ID at *seal* time; we
assign it at chunk-*open* time (same uniqueness/monotonicity) so that the
key->chunk-ID mapping can be piggybacked on the SET acknowledgement, which
§5.3 requires.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from .chunk import (CHUNK_SIZE, ChunkBuilder, ChunkId, ObjectRef,
                    object_size, pack_object, parse_objects)
from .codes import Code
from .engine import CodingEngine, NumpyEngine
from .index import CuckooIndex
from .stripe import StripeList


@dataclasses.dataclass
class UnsealedChunk:
    builder: ChunkBuilder
    local_idx: int
    chunk_id: ChunkId


@dataclasses.dataclass
class SealEvent:
    """Emitted when a data chunk seals; the network carries keys only.

    ``iseqs`` (aligned with ``ordered_keys``) are the per-instance
    sequence numbers the data server assigned at SET time: a key that was
    deleted and re-SET has several instances in flight (the tombstoned
    slot in the old unsealed chunk plus the live one), and the parity
    rebuild must consume each chunk's *own* instance replica regardless
    of the order the chunks seal in."""
    stripe_list: StripeList
    chunk_id: ChunkId
    ordered_keys: list[bytes]
    payload_bytes: int  # what actually crosses the network
    iseqs: list[int] | None = None


@dataclasses.dataclass
class DeltaRecord:
    """Parity-side backup of an applied delta, for revert (§5.3)."""
    proxy_id: int
    seq: int
    local_idx: int          # parity chunk slot (-1 => replica update)
    offset: int
    applied: np.ndarray     # exact bytes XORed into the parity chunk
    key: bytes | None = None
    old_value: bytes | None = None  # for unsealed-replica updates
    old_deleted: bool = False


class Server:
    def __init__(self, sid: int, code: Code, chunk_size: int = CHUNK_SIZE,
                 max_unsealed_per_list: int = 4, mapping_ckpt_every: int = 256,
                 engine: CodingEngine | None = None):
        self.sid = sid
        self.code = code
        # all parity math goes through the batched coding engine (the
        # cluster passes its shared backend; standalone servers get the
        # numpy oracle)
        self.engine = engine if engine is not None else NumpyEngine(code)
        self.chunk_size = chunk_size
        self.max_unsealed = max_unsealed_per_list
        self.mapping_ckpt_every = mapping_ckpt_every

        self.region: list[np.ndarray] = []           # local chunk slots
        self.chunk_ids: list[ChunkId | None] = []    # slot -> id
        self.sealed: list[bool] = []                 # slot -> sealed?
        self.chunk_index = CuckooIndex(num_buckets=1 << 10)
        self.object_index = CuckooIndex(num_buckets=1 << 12)

        self.unsealed: dict[int, list[UnsealedChunk]] = defaultdict(list)
        self.stripe_counters: dict[int, int] = defaultdict(int)

        # parity role: `temp_replicas` holds the LIVE instance per key
        # (what degraded reads and replica deltas see); a superseded
        # instance whose unsealed chunk has not sealed yet parks in
        # `zombie_replicas` under (key, instance seq) until its seal
        # consumes it — chunks seal in arbitrary (min-free-victim) order,
        # so instance identity, not recency, picks the rebuild bytes.
        self.temp_replicas: dict[bytes, tuple[bytes, bool]] = {}  # key -> (value, deleted)
        self.replica_iseq: dict[bytes, int] = {}     # key -> live instance seq
        self.zombie_replicas: dict[tuple[bytes, int | None],
                                   tuple[bytes, bool]] = {}
        self.delta_buffer: dict[int, list[DeltaRecord]] = defaultdict(list)

        # key -> chunk-ID mapping log (checkpointed to coordinator §5.3);
        # entries carry the instance seq so the coordinator's recovery
        # merge keeps the *newest* instance when a key was re-SET into a
        # different chunk (delete/re-add churn, shard migration)
        self.mapping_log: list[tuple[bytes, ChunkId, int]] = []
        self.mappings_since_ckpt = 0

        # data role: per-SET instance sequence numbers, (chunk slot,
        # offset) -> iseq, piggybacked on seal events so parity replica
        # consumption matches instances (see SealEvent.iseqs)
        self.obj_seq = 0
        self._iseq: dict[tuple[int, int], int] = {}

        # stats
        self.seals = 0
        self.bytes_stored = 0

    # ------------------------------------------------------------------
    # region management
    # ------------------------------------------------------------------
    def _alloc_slot(self, chunk_id: ChunkId | None, buf: np.ndarray | None = None) -> int:
        idx = len(self.region)
        self.region.append(buf if buf is not None else np.zeros(self.chunk_size, np.uint8))
        self.chunk_ids.append(chunk_id)
        self.sealed.append(False)
        if chunk_id is not None:
            self.chunk_index.insert(chunk_id.pack(), idx)
        return idx

    def slot_of_chunk(self, chunk_id: ChunkId) -> int | None:
        return self.chunk_index.lookup(chunk_id.pack())

    def get_sealed_chunk(self, chunk_id: ChunkId) -> np.ndarray | None:
        """Sealed chunk content, or None (unsealed/unknown chunks encode as
        zero in parity, so callers substitute zeros)."""
        idx = self.slot_of_chunk(chunk_id)
        if idx is None or not self.sealed[idx]:
            return None
        return self.region[idx]

    # ------------------------------------------------------------------
    # data role: SET / GET / UPDATE / DELETE
    # ------------------------------------------------------------------
    def _open_chunk(self, sl: StripeList) -> UnsealedChunk:
        position = sl.data_servers.index(self.sid)
        sid_ctr = self.stripe_counters[sl.list_id]
        self.stripe_counters[sl.list_id] = sid_ctr + 1
        cid = ChunkId(sl.list_id, sid_ctr, position)
        builder = ChunkBuilder(self.chunk_size)
        idx = self._alloc_slot(cid, builder.buf)
        uc = UnsealedChunk(builder, idx, cid)
        self.unsealed[sl.list_id].append(uc)
        return uc

    def _seal(self, sl: StripeList, uc: UnsealedChunk) -> SealEvent:
        self.unsealed[sl.list_id].remove(uc)
        uc.builder.seal()
        self.sealed[uc.local_idx] = True
        self.seals += 1
        keys = [k for k, _ in uc.builder.objects]
        iseqs = [self._iseq.pop((uc.local_idx, off), None)
                 for _, off in uc.builder.objects]
        payload = sum(len(k) + 1 for k in keys)  # keys (+1B length) only
        return SealEvent(sl, uc.chunk_id, keys, payload, iseqs=iseqs)

    def set_object(self, sl: StripeList, key: bytes, value: bytes
                   ) -> tuple[ChunkId, int, list[SealEvent]]:
        """Append a new object; returns (chunk_id, offset, seal events)."""
        need = object_size(len(key), len(value))
        if need > self.chunk_size:
            raise ValueError("object exceeds chunk size; fragment first")
        events: list[SealEvent] = []
        chunks = self.unsealed[sl.list_id]
        # min-free-fit: the unsealed chunk with the least free space that fits
        fitting = [c for c in chunks if c.builder.free >= need]
        if fitting:
            target = min(fitting, key=lambda c: c.builder.free)
        else:
            if len(chunks) >= self.max_unsealed and chunks:
                # seal the chunk with the least free space to make room
                victim = min(chunks, key=lambda c: c.builder.free)
                events.append(self._seal(sl, victim))
            target = self._open_chunk(sl)
        off = target.builder.append(key, value)
        ref = ObjectRef(target.local_idx, off, len(key), len(value))
        self.object_index.insert(key, ref)
        self._iseq[(target.local_idx, off)] = self.obj_seq
        self.obj_seq += 1
        self.mapping_log.append((key, target.chunk_id, self._iseq[(target.local_idx, off)]))
        self.mappings_since_ckpt += 1
        self.bytes_stored += need
        return target.chunk_id, off, events

    def live_iseq(self, key: bytes) -> int | None:
        """Instance sequence of the key's live (unsealed) slot, if any —
        what callers pass to the parity servers' ``store_replica``."""
        ref = self.lookup(key)
        if ref is None:
            return None
        return self._iseq.get((ref.chunk_local_idx, ref.offset))

    def lookup(self, key: bytes) -> ObjectRef | None:
        return self.object_index.lookup(key)

    def get_value(self, key: bytes) -> bytes | None:
        ref = self.lookup(key)
        if ref is None:
            return None
        buf = self.region[ref.chunk_local_idx]
        vo = ref.value_offset
        return buf[vo: vo + ref.value_size].tobytes()

    def chunk_id_of(self, ref: ObjectRef) -> ChunkId:
        cid = self.chunk_ids[ref.chunk_local_idx]
        assert cid is not None
        return cid

    def update_value(self, key: bytes, new_value: bytes
                     ) -> tuple[ChunkId, bool, int, np.ndarray] | None:
        """In-place value update.  Returns (chunk_id, chunk_sealed,
        object_offset, xor_over_object_extent) or None if key unknown.
        Value sizes are fixed across updates (paper §4.2).
        """
        ref = self.lookup(key)
        if ref is None:
            return None
        if len(new_value) != ref.value_size:
            raise ValueError("value size must not change across updates")
        buf = self.region[ref.chunk_local_idx]
        ext = object_size(ref.key_size, ref.value_size)
        old = buf[ref.offset: ref.offset + ext].copy()
        vo = ref.value_offset
        buf[vo: vo + ref.value_size] = np.frombuffer(new_value, np.uint8)
        xor = old ^ buf[ref.offset: ref.offset + ext]
        return self.chunk_id_of(ref), self.sealed[ref.chunk_local_idx], ref.offset, xor

    def delete_object(self, key: bytes
                      ) -> tuple[ChunkId, bool, int, np.ndarray] | None:
        """Tombstone + zero the value.  Returns like update_value."""
        ref = self.lookup(key)
        if ref is None:
            return None
        buf = self.region[ref.chunk_local_idx]
        ext = object_size(ref.key_size, ref.value_size)
        old = buf[ref.offset: ref.offset + ext].copy()
        self._builder_view(ref).mark_deleted(ref.offset, ref.key_size, ref.value_size)
        xor = old ^ buf[ref.offset: ref.offset + ext]
        self.object_index.delete(key)
        return self.chunk_id_of(ref), self.sealed[ref.chunk_local_idx], ref.offset, xor

    def _builder_view(self, ref: ObjectRef):
        """A ChunkBuilder-shaped view over a slot for in-place ops."""
        v = ChunkBuilder.__new__(ChunkBuilder)
        v.chunk_size = self.chunk_size
        v.buf = self.region[ref.chunk_local_idx]
        v.used = self.chunk_size
        v.objects = []
        v.sealed = False
        return v

    # ------------------------------------------------------------------
    # parity role
    # ------------------------------------------------------------------
    def store_replica(self, key: bytes, value: bytes,
                      iseq: int | None = None):
        """Store the live replica of an unsealed object.  When a prior
        instance of the key is still awaiting its chunk's seal (delete →
        re-SET while the old chunk never sealed), it parks as a zombie
        under its own instance seq so the old chunk's rebuild consumes
        the frozen tombstone, not the new value."""
        old = self.temp_replicas.get(key)
        old_iseq = self.replica_iseq.get(key)
        if old is not None and old_iseq != iseq:
            # a superseded instance is always a tombstone (set_object
            # only re-adds a key after delete), so park its final state
            # even if this copy missed the delete delta (failed parity)
            self.zombie_replicas[(key, old_iseq)] = \
                (b"\x00" * len(old[0]), True)
        self.temp_replicas[key] = (value, False)
        if iseq is None:
            self.replica_iseq.pop(key, None)
        else:
            self.replica_iseq[key] = iseq

    def get_replica(self, key: bytes):
        return self.temp_replicas.get(key)

    def _consume_replica(self, key: bytes, iseq: int | None
                         ) -> tuple[tuple[bytes, bool], bool]:
        """Replica bytes for instance ``iseq`` of ``key`` at seal time:
        a parked zombie instance wins; otherwise the live entry must
        match (or carry no instance id — legacy/shadow-migrated state).
        Returns (replica, consumed_live)."""
        if iseq is not None:
            z = self.zombie_replicas.pop((key, iseq), None)
            if z is not None:
                return z, False
        rep = self.temp_replicas.get(key)
        live = self.replica_iseq.get(key)
        if rep is not None and (iseq is None or live is None or live == iseq):
            return rep, True
        z = self.zombie_replicas.pop((key, None), None)
        if z is not None:
            return z, False
        raise KeyError(f"parity {self.sid}: missing replica for {key!r} "
                       f"(instance {iseq}, live {live})")

    def _parity_slot_for(self, sl: StripeList, stripe_id: int) -> int:
        ppos = sl.parity_servers.index(self.sid)
        cid = ChunkId(sl.list_id, stripe_id, sl.k + ppos)
        idx = self.slot_of_chunk(cid)
        if idx is None:
            idx = self._alloc_slot(cid)
            self.sealed[idx] = True  # parity chunks are never appended to
        return idx

    def parity_row(self, sl: StripeList, stripe_id: int) -> np.ndarray:
        """Parity role: this server's parity chunk for a stripe
        (allocated zero on first touch — identical bytes to the
        unallocated case).  The cluster's fused delta+apply path gathers
        these as the kernel's parity input."""
        return self.region[self._parity_slot_for(sl, stripe_id)]

    def rebuild_seal_chunk(self, ev: SealEvent) -> tuple[int, int, np.ndarray]:
        """Parity role, step 1 of a seal: rebuild the sealed data chunk from
        replicas, allocate the parity slot, and drop the consumed replicas.
        Returns (parity slot, data position, rebuilt chunk); the parity fold
        itself is batched across seal events by the caller (paper §4.2).

        Replicas are matched by instance (see ``SealEvent.iseqs``): the
        seal of an old chunk holding a superseded tombstone consumes that
        instance's parked zombie replica and leaves the live replica of
        the key's re-SET instance — still unsealed elsewhere — intact."""
        iseqs = ev.iseqs or [None] * len(ev.ordered_keys)
        rebuilt = np.zeros(self.chunk_size, np.uint8)
        off = 0
        consumed_live: list[bytes] = []
        for key, iseq in zip(ev.ordered_keys, iseqs):
            (value, deleted), was_live = self._consume_replica(key, iseq)
            blob = pack_object(key, value if not deleted else b"\x00" * len(value),
                               deleted=deleted)
            rebuilt[off: off + len(blob)] = np.frombuffer(blob, np.uint8)
            off += len(blob)
            if was_live:
                consumed_live.append(key)
        idx = self._parity_slot_for(ev.stripe_list, ev.chunk_id.stripe_id)
        for key in consumed_live:
            self.temp_replicas.pop(key, None)
            self.replica_iseq.pop(key, None)
        return idx, ev.chunk_id.position, rebuilt

    def apply_seal(self, ev: SealEvent) -> np.ndarray:
        """Parity role: rebuild + fold one sealed chunk (B=1 case of
        `fold_seal_batch`)."""
        return self.fold_seal_batch([ev])[0]

    def fold_seal_batch(self, events: list[SealEvent]) -> list[np.ndarray]:
        """Parity role: rebuild all sealed chunks, then fold their parity
        contributions in one batched engine call."""
        fut, finish = self.submit_fold_seals(events)
        if fut is not None:
            fut.result()
        return finish()

    def submit_fold_seals(self, events: list[SealEvent]):
        """Async seal fold: rebuild the sealed chunks from replicas (host
        work), *submit* the batched parity-delta computation, and return
        ``(future, finish)`` — the caller models its netsim legs while the
        engine call is in flight, then calls ``finish()`` to fold the
        deltas into the parity region and get the rebuilt chunks back.
        Byte-identical to ``fold_seal_batch`` (same engine call, same fold
        order), only the synchronization point moves."""
        if not events:
            return None, lambda: []
        rebuilds = [self.rebuild_seal_chunk(ev) for ev in events]
        positions = np.array([pos for _, pos, _ in rebuilds])
        xors = np.stack([reb for _, _, reb in rebuilds])
        # fused encode + seal-fold: this server only ever folds its OWN
        # parity row per event, so submit the row-fold (r*C work/item)
        # instead of the full m-row delta the old path discarded m-1 of
        rows = np.array([ev.stripe_list.parity_servers.index(self.sid)
                         for ev in events])
        slots = [idx for idx, _, _ in rebuilds]
        old_rows = np.stack([self.region[idx] for idx in slots])
        fut = self.engine.submit_fold_rows(positions, xors, rows, old_rows)

        def finish() -> list[np.ndarray]:
            new_rows = fut.result()                       # (B, C)
            counts: dict[int, int] = {}
            for idx in slots:
                counts[idx] = counts.get(idx, 0) + 1
            for i, idx in enumerate(slots):
                if counts[idx] == 1:
                    self.region[idx][:] = new_rows[i]
                else:
                    # two chunks of one stripe sealing in the same batch
                    # share a parity slot; both folds gathered the same
                    # pre-batch row, so apply each event's exact delta
                    # (new ^ old) instead of letting the writes clobber
                    self.region[idx] ^= new_rows[i] ^ old_rows[i]
            return [reb for _, _, reb in rebuilds]

        return fut, finish

    def apply_data_delta(self, sl: StripeList, chunk_id: ChunkId, offset: int,
                         xor_seg: np.ndarray, proxy_id: int, seq: int):
        """Parity role: apply a (sealed-chunk) update delta; buffer for
        revert (§5.3).  Runs the fused single-row fold (this server's
        parity row only) rather than materializing all m delta rows."""
        full = np.zeros(self.chunk_size, np.uint8)
        full[offset: offset + len(xor_seg)] = xor_seg
        ppos = sl.parity_servers.index(self.sid)
        idx = self._parity_slot_for(sl, chunk_id.stripe_id)
        folded = self.engine.submit_fold_rows(
            np.array([chunk_id.position]), full[None], np.array([ppos]),
            self.region[idx][None]).result()[0]
        self.apply_data_delta_row(sl, chunk_id, folded ^ self.region[idx],
                                  proxy_id, seq)

    def apply_data_delta_row(self, sl: StripeList, chunk_id: ChunkId,
                             delta_row: np.ndarray, proxy_id: int, seq: int):
        """Parity role: fold a precomputed delta row for this server's
        parity position (the multi-key path computes rows for all parity
        servers in one batched engine call)."""
        idx = self._parity_slot_for(sl, chunk_id.stripe_id)
        self.region[idx] ^= delta_row
        self.delta_buffer[proxy_id].append(DeltaRecord(
            proxy_id=proxy_id, seq=seq, local_idx=idx, offset=0,
            applied=np.array(delta_row, np.uint8)))

    def apply_replica_delta(self, key: bytes, new_value: bytes, deleted: bool,
                            proxy_id: int, seq: int):
        """Parity role: update an unsealed object's replica; buffer old."""
        rep = self.temp_replicas.get(key)
        if rep is None:
            raise KeyError(f"parity {self.sid}: no replica for {key!r}")
        old_value, old_deleted = rep
        if deleted and not new_value:
            new_value = b"\x00" * len(old_value)  # keep size for rebuild
        self.temp_replicas[key] = (new_value, deleted)
        self.delta_buffer[proxy_id].append(DeltaRecord(
            proxy_id=proxy_id, seq=seq, local_idx=-1, offset=0,
            applied=np.zeros(0, np.uint8), key=key,
            old_value=old_value, old_deleted=old_deleted))

    def revert_deltas(self, proxy_id: int, unacked_seqs: set[int]) -> int:
        """Revert buffered deltas of a proxy's unacknowledged requests."""
        reverted = 0
        keep = []
        for rec in self.delta_buffer.get(proxy_id, []):
            if rec.seq in unacked_seqs:
                if rec.local_idx >= 0:
                    self.region[rec.local_idx] ^= rec.applied
                else:
                    self.temp_replicas[rec.key] = (rec.old_value, rec.old_deleted)
                reverted += 1
            else:
                keep.append(rec)
        self.delta_buffer[proxy_id] = keep
        return reverted

    def prune_deltas(self, proxy_id: int, acked_watermark: int):
        buf = self.delta_buffer.get(proxy_id)
        if buf:
            self.delta_buffer[proxy_id] = [r for r in buf if r.seq > acked_watermark]

    # ------------------------------------------------------------------
    # mapping checkpoints (§5.3)
    # ------------------------------------------------------------------
    def should_checkpoint(self) -> bool:
        return self.mappings_since_ckpt >= self.mapping_ckpt_every

    def take_checkpoint(self) -> list[tuple[bytes, ChunkId, int]]:
        """Return (and clear) the mappings accumulated since the last
        checkpoint; the coordinator merges them into its persistent view."""
        out = self.mapping_log
        self.mapping_log = []
        self.mappings_since_ckpt = 0
        return out

    # ------------------------------------------------------------------
    # recovery helpers
    # ------------------------------------------------------------------
    def rebuild_indexes(self):
        """Rebuild both indexes from region contents (paper §3.2: indexes
        are local-only because they are reconstructible)."""
        self.object_index.clear()
        self.chunk_index.clear()
        for idx, (buf, cid) in enumerate(zip(self.region, self.chunk_ids)):
            if cid is None:
                continue
            self.chunk_index.insert(cid.pack(), idx)
            if cid.position < self.code.k:  # data chunk -> parse objects
                for off, key, value, deleted in parse_objects(buf):
                    if not deleted:
                        self.object_index.insert(
                            key, ObjectRef(idx, off, len(key), len(value)))

    def memory_bytes(self) -> dict:
        """Storage accounting for the redundancy benchmarks."""
        chunk_bytes = len(self.region) * self.chunk_size
        id_bytes = len(self.region) * 8
        obj_slots = self.object_index.num_buckets * 4
        chk_slots = self.chunk_index.num_buckets * 4
        replica_bytes = sum(len(k) + len(v) + 4 for k, (v, _) in self.temp_replicas.items())
        replica_bytes += sum(len(k) + len(v) + 4
                             for (k, _), (v, _) in self.zombie_replicas.items())
        return {
            "chunks": chunk_bytes,
            "chunk_ids": id_bytes,
            "object_index": obj_slots * 8,
            "chunk_index": chk_slots * 8,
            "replicas": replica_bytes,
        }
