"""MemEC cluster: normal-mode + degraded-mode request orchestration.

This module wires servers, proxies, and the coordinator into an in-process
cluster simulation with modeled network costs (``netsim``).  It implements
the full request workflows of paper §4.2 (SET/GET/UPDATE/DELETE), stripe
management §4.3, fault tolerance §5 (server states, backups, degraded
requests, migration after restore), and large-object fragmentation §3.2.

Implementation deviations from the paper (each noted inline):
* stripe IDs are assigned at chunk-open (not seal) time so SET acks can
  piggyback key->chunk-ID mappings (§5.3 requires the piggyback);
* DELETE of an unsealed object keeps a tombstoned (zero-valued) replica at
  parity servers instead of removing it, so seal-time chunk rebuild stays
  byte-identical;
* SET of an existing key routes through the UPDATE path (upsert) so a key
  never occupies two chunk slots — required for parity-side chunk rebuild;
* degraded UPDATE of an *unsealed* object shadows the new value at the
  redirected server (migrated back as a normal UPDATE on restore);
* overlapping-failure hardening beyond the paper's single-failure
  narrative (driven by tests/test_transitions_prop.py): redirect targets
  are sticky per (failed server, stripe list) and hand their degraded
  state off when they themselves fail; SET of an existing key in
  degraded mode routes through the mutate path (upsert); shadow replicas
  migrate to *every* restored parity server of a list.

Intra-shard async pipeline (PR 4): coding now carries a modeled cost
(``CostModel.coding_s`` over ``CodingEngine`` work bytes).  With
``async_engine=False`` (default, ``$MEMEC_ASYNC``) coding time adds
serially to a request's network phases; with ``async_engine=True`` the
store *submits* engine work (``engine.submit_*`` futures) while the same
shard's netsim legs are modeled in flight and charges
``max(coding, network)`` per phase — plus two further overlaps: the seal
fan-out runs concurrently with the SET acks, and ``multi_*`` requests
with ``proxy_id=None`` spread across the shard's proxies as concurrent
lanes (``NetSim.merge_lanes``; per-server serialization preserved).
Stored bytes are identical in both modes — only the synchronization
points and the latency accounting move.  ``stats["intra_overlap_saved_s"]``
tracks the genuine sync-vs-async win (phases the sync pipeline pays as a
sum); ``stats["proxy_lane_saved_s"]`` tracks lane overlap relative to
serially executed per-proxy calls (a different baseline — sync callers
issuing one batch per proxy call never pay that serialization).

Plan/execute decode + engine queue (PR 5): ``submit_decode`` now
dispatches on-device at submit on the jax/pallas backends (the engine
builds a ``DecodePlan`` from host metadata), so degraded reconstruction
(``_ensure_recon``) and ``fail_server`` batched recovery genuinely
overlap decode with their fetch legs; their share of the async win is
``stats["decode_overlap_saved_s"]``.  The degraded-mutate redirect
deltas are likewise computed through ONE submitted ``submit_delta`` call
merged with the redirect legs (they used to mutate recon chunks serially
with unmodeled cost).  Concurrent engine calls in one phase contend for
``CostModel.engine_depth`` lanes (default inf = the historical
no-contention merge); the extra wait a finite depth induces is
``stats["engine_queue_wait_s"]``.
"""
from __future__ import annotations

import dataclasses
import os
import struct

import numpy as np

from .chunk import (CHUNK_SIZE, METADATA_SIZE, ChunkId, fragment_count,
                    object_size, parse_objects, split_fragments)
from .codes import Code, make_code
from .coordinator import Coordinator, ServerState
from .engine import CodingEngine, make_engine, resolve_async
from .hotkey import HotTier, resolve_hot_keys
from .index import fnv1a
from .netsim import CostModel, Leg, NetSim
from .proxy import Proxy
from .server import Server
from .stripe import StripeList, StripeMapper, generate_stripe_lists

LARGE_MAGIC = b"\x00MEMEC_LRG"

# dedicated hash seed for proxy-lane assignment: every occurrence of a
# key must land in the same lane (duplicate upserts keep request order),
# and the spread must stay independent of shard and stripe hashing
PROXY_LANE_SEED = 0x9e3779b9


def large_total(head: bytes | None) -> int | None:
    """Total payload size if ``head`` is a large-object manifest, else
    None — the one place that knows the manifest wire format."""
    if head is None or not head.startswith(LARGE_MAGIC):
        return None
    return struct.unpack("<I", head[len(LARGE_MAGIC):len(LARGE_MAGIC) + 4])[0]


class PartialFailure(Exception):
    """Raised by fault injection mid-request (testing §5.3 revert)."""


def resolve_redundant_reads(redundant_reads=None,
                            env: str = "MEMEC_REDUNDANT_READS") -> int:
    """Ctor arg wins; else ``$MEMEC_REDUNDANT_READS``; else 0 (the plain
    wait-for-every-chunk read path, bit-identical to history)."""
    if redundant_reads is None:
        redundant_reads = os.environ.get(env, "0") or "0"
    redundant_reads = int(redundant_reads)
    if redundant_reads < 0:
        raise ValueError(
            f"redundant_reads must be >= 0, got {redundant_reads}")
    return redundant_reads


@dataclasses.dataclass
class ReconChunk:
    """A chunk reconstructed on a redirected server (degraded mode)."""
    chunk_id: ChunkId
    buf: np.ndarray
    dirty: bool = False
    # for data chunks: key -> (offset, key_size, value_size, deleted)
    objects: dict | None = None

    def parse(self):
        self.objects = {}
        for off, key, value, deleted in parse_objects(self.buf):
            self.objects[key] = (off, len(key), len(value), deleted)

    def value_of(self, key: bytes) -> bytes | None:
        """A live object's bytes out of the reconstructed chunk."""
        entry = (self.objects or {}).get(key)
        if entry is None or entry[3]:
            return None
        off, ksz, vsz, _ = entry
        vo = off + METADATA_SIZE + ksz
        return self.buf[vo: vo + vsz].tobytes()


class RedirectStore:
    """Degraded-mode state held by a redirected server (§5.4)."""

    def __init__(self):
        self.temp_objects: dict[bytes, bytes] = {}   # degraded SET / shadows
        self.temp_deletes: set[bytes] = set()
        # shadow replicas for a failed parity: key -> (value, deleted,
        # instance seq) — the iseq disambiguates a same-instance mutation
        # from a delete/re-SET new instance when the state migrates back
        self.temp_replicas: dict[bytes, tuple[bytes, bool, int | None]] = {}
        self.recon: dict[tuple, ReconChunk] = {}     # chunk-id key -> chunk

    def clear(self):
        self.temp_objects.clear()
        self.temp_deletes.clear()
        self.temp_replicas.clear()
        self.recon.clear()


class MemECCluster:
    def __init__(self, num_servers: int = 16, num_proxies: int = 4,
                 scheme: str = "rs", n: int = 10, k: int = 8, c: int = 16,
                 chunk_size: int = CHUNK_SIZE, max_unsealed: int = 4,
                 cost: CostModel | None = None, degraded_enabled: bool = True,
                 verify_rebuild: bool = False, mapping_ckpt_every: int = 256,
                 engine: str | CodingEngine | None = None,
                 shard_id: int | None = None,
                 async_engine: bool | None = None,
                 arrival=None, trace=None,
                 redundant_reads: int | None = None,
                 hot_key_threshold: float | None = None,
                 hot_max_versions: int = 8, hot_max_keys: int = 64):
        self.shard_id = shard_id   # None when not part of a ShardedCluster
        # intra-shard async pipeline (None defers to $MEMEC_ASYNC): issue
        # coding through engine futures while netsim legs are in flight
        # and merge latencies as max(coding, network) instead of the sum
        self.async_engine = resolve_async(async_engine)
        self.code: Code = make_code(scheme, n, k)
        # one batched coding engine shared by every server and every
        # cluster-level batch operation (numpy | jax | pallas; see
        # core/engine.py and $MEMEC_ENGINE)
        self.engine: CodingEngine = make_engine(engine, self.code)
        self.n, self.k = self.code.n, self.code.k
        self.chunk_size = chunk_size
        self.stripe_lists = generate_stripe_lists(num_servers, self.n, self.k, c)
        self.mapper = StripeMapper(self.stripe_lists)
        self.servers = [Server(s, self.code, chunk_size, max_unsealed,
                               mapping_ckpt_every, engine=self.engine)
                        for s in range(num_servers)]
        self.proxies = [Proxy(p, self.mapper) for p in range(num_proxies)]
        self.num_proxies = num_proxies
        self.coordinator = Coordinator(num_servers, self.stripe_lists,
                                       shard_id=shard_id)
        # arrival: open-loop event mode ("poisson:RATE" / "uniform:RATE" /
        # "trace:..." / ArrivalProcess; None defers to $MEMEC_ARRIVAL,
        # default closed loop — see core/netsim.py EventRuntime)
        # trace: per-request span tracing ("1" / Tracer instance; None
        # defers to $MEMEC_TRACE, default off — see core/trace.py)
        self.net = NetSim(cost, arrival=arrival, trace=trace)
        # straggler-tolerant reads (Hydra-style late binding): GETs fan
        # out to k+Δ chunk candidates and complete at the k-th arrival,
        # treating the slowest Δ as a per-request erasure pattern for
        # DecodePlan.  Δ=0 (default) keeps the historical plain-k path
        # bit-identical (redundant_reads= / $MEMEC_REDUNDANT_READS).
        self.redundant_reads = resolve_redundant_reads(redundant_reads)
        # hot-key update tier (version-buffered delta coding): sealed
        # updates to keys whose EWMA update score reaches the threshold
        # buffer their version deltas instead of paying a parity round
        # per SET; the buffer collapses into ONE parity round at flush
        # (capacity, eviction, read barrier, failure, or
        # flush_hot_buffers()).  0/None = off — zero tier state and a
        # byte-identical baseline (hot_key_threshold= / $MEMEC_HOT_KEYS).
        self.hot_key_threshold = resolve_hot_keys(hot_key_threshold)
        self.hot = (HotTier(self.hot_key_threshold,
                            max_keys=hot_max_keys,
                            max_versions=hot_max_versions)
                    if self.hot_key_threshold > 0 else None)
        self.degraded_enabled = degraded_enabled
        self.verify_rebuild = verify_rebuild
        self.failed: set[int] = set()          # injected transient failures
        self.redirect: dict[int, RedirectStore] = {}
        # fault-injection hook: ("update"|"delete"|"set", key, parity_legs)
        self.crash_hook: tuple | None = None
        self._stats = {"reconstructions": 0, "recon_chunk_hits": 0,
                      "reverted_deltas": 0, "degraded_requests": 0,
                      "migrated_objects": 0, "migrated_chunks": 0,
                      "batch_recovered_chunks": 0, "redirect_handoffs": 0,
                      "modeled_coding_s": 0.0, "intra_overlap_saved_s": 0.0,
                      "proxy_lane_batches": 0, "proxy_lane_saved_s": 0.0,
                      "engine_queue_wait_s": 0.0,
                      "decode_overlap_saved_s": 0.0,
                      "redundant_reads": 0, "redundant_decodes": 0,
                      "redundant_cancelled": 0,
                      "redundant_replica_fallbacks": 0}

    @property
    def stats(self) -> dict:
        """Counter dict plus derived observability: per-kind latency
        percentiles (``latency[kind] = {count, mean_s, p50_s, p99_s,
        p999_s}``) and, in open-loop event mode, per-kind/per-resource
        queue-wait breakdowns plus the arrival descriptor."""
        out = dict(self._stats)
        if self.hot is not None:
            out["hot_tier"] = self.hot.snapshot()
        out["latency"] = self.net.latency_summary()
        if self.net.events is not None:
            ev = self.net.events.snapshot()
            out["arrival"] = ev["arrival"]
            out["queue_wait_s"] = ev["queue_wait_s"]
            out["queue_wait_s_by_kind"] = ev["queue_wait_s_by_kind"]
            out["queue_wait_s_by_resource"] = ev["queue_wait_s_by_resource"]
            out["event_makespan_s"] = ev["makespan_s"]
        return out

    @property
    def tracer(self):
        """The span tracer (None when tracing is off)."""
        return self.net.tracer

    def server_endpoint_names(self) -> list[str]:
        """Netsim endpoint labels of this cluster's storage servers."""
        return [f"s{i}" for i in range(len(self.servers))]

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _sv(self, sid: int) -> Server:
        return self.servers[sid]

    def _rs(self, sid: int) -> RedirectStore:
        return self.redirect.setdefault(sid, RedirectStore())

    def _is_failed(self, sid: int) -> bool:
        return sid in self.failed

    def _degraded_active(self, sid: int) -> bool:
        """True if requests touching sid must go through the coordinator."""
        return self.degraded_enabled and self.coordinator.state_of(sid) in (
            ServerState.INTERMEDIATE, ServerState.DEGRADED,
            ServerState.COORDINATED_NORMAL)

    def _positions(self, sl: StripeList) -> list[int]:
        return list(sl.servers)

    def _chunk_owner(self, sl: StripeList, position: int) -> int:
        return sl.servers[position]

    def _stripe_chunk_id(self, sl: StripeList, stripe_id: int, position: int) -> ChunkId:
        return ChunkId(sl.list_id, stripe_id, position)

    # ------------------------------------------------------------------
    # async-pipeline latency merging
    # ------------------------------------------------------------------
    def _overlap(self, *phase_times: float) -> float:
        """Merged duration of phases that the async pipeline overlaps
        (coding vs network legs, seal fan-out vs SET acks).  Sync mode
        runs them back to back — the historical sum."""
        if not self.async_engine:
            return sum(phase_times)
        t = max(phase_times, default=0.0)
        self._stats["intra_overlap_saved_s"] += sum(phase_times) - t
        return t

    def _trace_frame(self):
        """Open a span frame for the request about to execute (returns
        the tracer, or None when tracing is off — the zero-cost path)."""
        tr = self.net.tracer
        if tr is not None:
            tr.push()
        return tr

    def _overlap_branches(self, *branches) -> float:
        """``_overlap`` over named thunks (``(name, fn)``), grouping each
        branch's spans when tracing (e.g. seal fan-out vs SET acks)."""
        tr = self.net.tracer
        if tr is None:
            return self._overlap(*(fn() for _, fn in branches))
        entries = []
        for name, fn in branches:
            tr.push()
            dur = fn()
            entries.append((name, dur, tr.pop()))
        t = self._overlap(*(dur for _, dur, _ in entries))
        tr.overlap(t, entries, self.async_engine)
        return t

    def _merge_coding(self, coding_s: float, net_s: float,
                      kind: str | None = None,
                      lane_durs: list[float] | None = None,
                      queue_wait_s: float = 0.0) -> float:
        """Coding vs in-flight netsim legs: serial in sync mode,
        max(coding, network) in async mode.  ``kind="decode"`` phases
        additionally track their share of the async win in
        ``stats["decode_overlap_saved_s"]`` (a subset of
        ``intra_overlap_saved_s`` — the read-repair overlap)."""
        self._stats["modeled_coding_s"] += coding_s
        # event-mode demand capture: the in-flight request's engine-busy
        # seconds (gates later submits on the engine lanes) + the shard
        # engine's cumulative modeled-busy clock (idle-engine planning).
        # Demand excludes the intra-phase makespan wait (queue_wait_s):
        # that wait is already inside the service latency via
        # engine_queue_wait_s, so forwarding the full makespan would
        # price the same depth contention twice (once per phase, again
        # as event-mode lane occupancy in queue_wait_s_by_resource).
        self.net.note_coding(coding_s - queue_wait_s)
        self.engine.note_modeled_busy(coding_s)
        t = self._overlap(coding_s, net_s)
        if self.async_engine and kind == "decode":
            self._stats["decode_overlap_saved_s"] += coding_s + net_s - t
        tr = self.net.tracer
        if tr is not None and (coding_s > 0.0 or net_s > 0.0):
            tr.merge_coding(coding_s, net_s, t, kind, lane_durs,
                            self.net.cost.engine_depth, self.async_engine)
        return t

    def _merge_coding_calls(self, durs: list[float], net_s: float,
                            kind: str | None = None) -> float:
        """Several engine calls submitted in one overlapped phase
        contend for the shard engine's ``CostModel.engine_depth`` lanes:
        the phase's coding duration is the depth-limited makespan (== the
        historical max at the default infinite depth), with the extra
        wait surfaced in ``stats["engine_queue_wait_s"]``."""
        durs = [d for d in durs if d > 0]
        span = self.net.cost.engine_makespan(durs)
        wait = span - max(durs) if durs else 0.0
        self._stats["engine_queue_wait_s"] += wait
        return self._merge_coding(span, net_s, kind, lane_durs=durs,
                                  queue_wait_s=wait)

    def _coding_s(self, fut) -> float:
        """Modeled duration of a submitted engine call."""
        if fut is None:
            return 0.0
        return self.net.cost.coding_s(fut.work_bytes)

    # ------------------------------------------------------------------
    # normal-mode seal fan-out (data server -> parity servers)
    # ------------------------------------------------------------------
    def _handle_seals(self, sl: StripeList, ds: int, events) -> float:
        return self._handle_seals_batched([(sl, ds, ev) for ev in events])

    def _handle_seals_batched(self, items: list[tuple]) -> float:
        """Fan seal events out to parity servers, folding each parity
        server's whole batch of rebuilt chunks through one engine call.
        ``items``: (stripe_list, data_server, SealEvent) triples — possibly
        from different stripe lists (multi-key SETs).

        Coding is *submitted* before the seal legs are modeled: distinct
        parity servers fold concurrently up to the engine queue's depth
        (their coding phase is the depth-limited makespan — the plain
        max at the default infinite ``CostModel.engine_depth``), and the
        async pipeline overlaps that fold with the in-flight seal legs
        (``max(coding, network)``; serial in sync mode)."""
        t = 0.0
        legs = []
        per_parity: dict[int, list[tuple]] = {}
        for sl, ds, ev in items:
            for p in sl.parity_servers:
                if self._is_failed(p) and self._degraded_active(p):
                    t += self._seal_to_failed_parity(sl, ds, ev, p)
                    continue
                legs.append(Leg("seal", ev.payload_bytes, f"s{ds}", f"s{p}",
                                self._is_failed(p)))
                per_parity.setdefault(p, []).append((sl, ds, ev))
        folds = [(p, pitems, *self._sv(p).submit_fold_seals(
                    [ev for _, _, ev in pitems]))
                 for p, pitems in per_parity.items()]
        net_t = self.net.phase(legs) if legs else 0.0
        durs = [self._coding_s(fut) for _, _, fut, _ in folds]
        for p, pitems, fut, finish in folds:
            rebuilts = finish()
            if self.verify_rebuild:
                for (sl, ds, ev), rebuilt in zip(pitems, rebuilts):
                    src = self._sv(ds).get_sealed_chunk(ev.chunk_id)
                    assert src is not None and np.array_equal(rebuilt, src), \
                        "parity rebuild mismatch"
        if folds or legs:
            t += self._merge_coding_calls(durs, net_t, kind="seal")
        return t

    def _seal_to_failed_parity(self, sl: StripeList, ds: int, ev, failed_p: int) -> float:
        """Seal while a parity server is down: recompute that parity row on
        the redirected server from the k data chunks (costly but correct —
        the failed parity's replicas are unreachable)."""
        r = self.coordinator.redirected_server(sl, failed_p)
        rs = self._rs(r)
        t = 0.0
        data = np.zeros((self.k, self.chunk_size), np.uint8)
        legs = []
        for i in range(self.k):
            c, src = self._best_data_chunk(sl, ev.chunk_id.stripe_id, i)
            if c is not None:
                data[i] = c
            legs.append(Leg("recon_fetch", self.chunk_size, f"s{src}", f"s{r}"))
        fut = self.engine.submit_encode(data[None])
        t += self._merge_coding(self._coding_s(fut), self.net.phase(legs),
                                kind="seal")
        parity = fut.result()[0]
        ppos = sl.parity_servers.index(failed_p)
        cid = self._stripe_chunk_id(sl, ev.chunk_id.stripe_id, self.k + ppos)
        rc = ReconChunk(cid, parity[ppos].copy(), dirty=True)
        rs.recon[cid.key()] = rc
        self._stats["reconstructions"] += 1
        return t

    def _maybe_checkpoint(self, ds: int) -> float:
        srv = self._sv(ds)
        if not srv.should_checkpoint():
            return 0.0
        mappings = srv.take_checkpoint()
        payload = sum(len(k) + 12 for k, _, _ in mappings)
        t = self.net.phase([Leg("mapping_ckpt", payload, f"s{ds}", "coord")])
        self.coordinator.store_checkpoint(ds, mappings)
        legs = [Leg("ckpt_ack", 8, f"s{ds}", f"p{p.pid}") for p in self.proxies]
        t += self.net.phase(legs)
        for p in self.proxies:
            p.clear_mappings(ds)
        return t

    # ------------------------------------------------------------------
    # public request API (routed through a proxy)
    # ------------------------------------------------------------------
    def peek_value(self, key: bytes) -> bytes | None:
        """Degraded-aware local read of a key's stored bytes with NO
        netsim accounting — for control-plane probes (upsert head checks,
        migration planning/transfer), not client requests.  Resolves a
        failed data server through the redirect state: shadowed objects,
        the batched-decode reconstruction cache, then a parity replica."""
        sl, ds = self.mapper.data_server_for(key)
        if not (self._is_failed(ds) and self._degraded_active(ds)):
            return self._sv(ds).get_value(key)
        r = self.coordinator.redirected_server(sl, ds)
        rs = self._rs(r)
        if key in rs.temp_deletes:
            return None
        if key in rs.temp_objects:
            return rs.temp_objects[key]
        cid = self.coordinator.chunk_id_for(ds, key)
        if cid is None:
            return None
        rc = rs.recon.get(cid.key())
        if rc is not None:
            return rc.value_of(key)
        for p in sl.parity_servers:
            if not self._is_failed(p):
                rep = self._sv(p).get_replica(key)
                if rep is None:
                    break
                value, deleted = rep
                return None if deleted else value
        return None

    def set(self, key: bytes, value: bytes, proxy_id: int = 0):
        # upsert over a large object tears the old fragments down first —
        # overwriting only the manifest head would orphan them.  The probe
        # is data-server-local (no modeled legs, like _set_small's upsert
        # lookup) and copies only manifest-sized head bytes on the normal
        # path; a failed data server resolves through the degraded view.
        sl, ds = self.mapper.data_server_for(key)
        head = None
        if self._is_failed(ds) and self._degraded_active(ds):
            head = self.peek_value(key)
        else:
            srv = self._sv(ds)
            ref = srv.lookup(key)
            if ref is not None:
                vo = ref.value_offset
                n = min(ref.value_size, len(LARGE_MAGIC) + 4)
                head = srv.region[ref.chunk_local_idx][vo: vo + n].tobytes()
        if large_total(head) is not None:
            self.delete(key, proxy_id)
        if object_size(len(key), len(value)) > self.chunk_size:
            return self._set_large(key, value, proxy_id)
        return self._set_small(key, value, proxy_id)

    def get(self, key: bytes, proxy_id: int = 0):
        v = self._get_small(key, proxy_id)
        total = large_total(v)
        if total is not None:
            return self._get_large(key, total, proxy_id)
        return v

    def update(self, key: bytes, value: bytes, proxy_id: int = 0) -> bool:
        head = self._get_small(key, proxy_id)
        if head is not None and head.startswith(LARGE_MAGIC):
            return self._update_large(key, value, proxy_id)
        return self._update_small(key, value, proxy_id)

    def delete(self, key: bytes, proxy_id: int = 0) -> bool:
        head = self._get_small(key, proxy_id)
        if head is not None and head.startswith(LARGE_MAGIC):
            return self._delete_large(key, head, proxy_id)
        return self._delete_small(key, proxy_id)

    # ------------------------------------------------------------------
    # batched multi-key API — amortizes coding (one engine call per
    # batch) and netsim legs (one fan-out phase per batch).  Keys that
    # need special handling (degraded stripes, large objects, upserts,
    # in-batch duplicates) fall back to the single-key workflows, so the
    # batched paths stay byte-identical with sequential execution.
    #
    # ``proxy_id=None`` spreads the batch across this cluster's proxies
    # as per-key-hash lanes (every occurrence of a key stays in one lane,
    # preserving per-key request order); with the async pipeline the
    # lanes' modeled latencies overlap (``NetSim.merge_lanes``, busiest
    # shared server as the serialization floor), in sync mode they run
    # back to back.
    # ------------------------------------------------------------------
    def _proxy_lanes(self, keys) -> list[tuple[int, list[int]]]:
        lanes: dict[int, list[int]] = {}
        for i, key in enumerate(keys):
            pid = fnv1a(key, seed=PROXY_LANE_SEED) % self.num_proxies
            lanes.setdefault(pid, []).append(i)
        return sorted(lanes.items())

    def _run_proxy_lanes(self, kind: str, keys, impl) -> list:
        """``impl(idxs, pid) -> (results, t|None)``; results merge back in
        request order, lane latencies merge into one facade record."""
        results: list = [None] * len(keys)
        dts: list[float] = []
        busys: list[dict] = []
        tr = self._trace_frame()
        lane_tr: list[tuple] = []
        for pid, idxs in self._proxy_lanes(keys):
            b0 = self.net.busy_snapshot()
            if tr is not None:
                tr.push()
            res, t = impl(idxs, pid)
            segs = tr.pop() if tr is not None else None
            for i, v in zip(idxs, res):
                results[i] = v
            if t is not None:
                dts.append(t)
                busys.append(NetSim.busy_delta(b0, self.net.busy_snapshot()))
                lane_tr.append((pid, t, segs))
        if dts:
            if self.async_engine and len(dts) > 1:
                merged = NetSim.merge_lanes(dts, busys)
                # savings vs *serially executed lanes* (what sequential
                # per-proxy multi_* calls would have cost) — tracked
                # apart from intra_overlap_saved_s, which only counts
                # overlaps the sync pipeline genuinely pays as a sum
                # (coding vs legs, seal fan-out vs acks)
                self._stats["proxy_lane_saved_s"] += sum(dts) - merged
            else:
                merged = sum(dts)
            if len(dts) > 1:
                self._stats["proxy_lane_batches"] += 1
            if tr is not None:
                tr.lanes(merged, lane_tr,
                         par=self.async_engine and len(dts) > 1)
            self.net.record(kind, merged)
        elif tr is not None:
            tr.cancel()
        return results

    def multi_get(self, keys, proxy_id: int | None = 0) -> list:
        keys = list(keys)
        if proxy_id is None and self.num_proxies > 1 and len(keys) > 1:
            return self._run_proxy_lanes(
                "MGET", keys,
                lambda idxs, pid: self._multi_get_impl(
                    [keys[i] for i in idxs], pid))
        tr = self._trace_frame()
        out, t = self._multi_get_impl(keys, proxy_id or 0)
        if t is not None:
            self.net.record("MGET", t)
        elif tr is not None:
            tr.cancel()
        return out

    def _multi_get_impl(self, keys, proxy_id: int):
        proxy = self.proxies[proxy_id]
        out: list = [None] * len(keys)
        plan = []
        for i, key in enumerate(keys):
            sl, ds = self.mapper.data_server_for(key)
            if self._is_failed(ds) and self._degraded_active(ds):
                out[i] = self.get(key, proxy_id)       # degraded fallback
            else:
                plan.append((i, key, sl, ds))
        t = None
        if plan:
            if self.redundant_reads > 0 and self.code.m > 0:
                vals, t = self._coded_read_batch(
                    proxy, [(key, sl, ds) for _, key, sl, ds in plan])
                for (i, _, _, _), v in zip(plan, vals):
                    out[i] = v
            else:
                t = self.net.phase([Leg("get", len(key), f"p{proxy.pid}",
                                        f"s{ds}", self._is_failed(ds))
                                    for _, key, _, ds in plan])
                resp_legs = []
                for i, key, _, ds in plan:
                    v = self._sv(ds).get_value(key)
                    resp_legs.append(Leg("get_resp", len(v) if v else 0,
                                         f"s{ds}", f"p{proxy.pid}",
                                         self._is_failed(ds)))
                    out[i] = v
                t += self.net.phase(resp_legs)
            for i, key, _, ds in plan:  # large objects: fetch fragments
                total = large_total(out[i])
                if total is not None:
                    out[i] = self._get_large(key, total, proxy_id)
        return out, t

    def multi_set(self, items, proxy_id: int | None = 0) -> list[bool]:
        items = list(items)
        if proxy_id is None and self.num_proxies > 1 and len(items) > 1:
            return self._run_proxy_lanes(
                "MSET", [k for k, _ in items],
                lambda idxs, pid: self._multi_set_impl(
                    [items[i] for i in idxs], pid))
        tr = self._trace_frame()
        ok, t = self._multi_set_impl(items, proxy_id or 0)
        if t is not None:
            self.net.record("MSET", t)
        elif tr is not None:
            tr.cancel()
        return ok

    def _multi_set_impl(self, items, proxy_id: int):
        proxy = self.proxies[proxy_id]
        ok = [False] * len(items)
        batch, deferred, seen = [], [], set()
        for i, (key, value) in enumerate(items):
            sl, ds = self.mapper.data_server_for(key)
            involved = [ds] + list(sl.parity_servers)
            if key in seen:
                deferred.append((i, key, value))       # keep batch order
            elif (object_size(len(key), len(value)) > self.chunk_size
                  or any(self._degraded_active(s) and self._is_failed(s)
                         for s in involved)
                  or self._sv(ds).lookup(key) is not None):
                ok[i] = self.set(key, value, proxy_id)  # fallback
            else:
                seen.add(key)
                batch.append((i, key, value, sl, ds))
        t = None
        if batch:
            t = 0.0
            reqs, legs = [], []
            for i, key, value, sl, ds in batch:
                reqs.append(proxy.begin("SET", key, value, sl, ds))
                obj = object_size(len(key), len(value))
                legs.append(Leg("set", obj, f"p{proxy.pid}", f"s{ds}",
                                self._is_failed(ds)))
                legs += [Leg("set_replica", obj, f"p{proxy.pid}", f"s{p}",
                             self._is_failed(p)) for p in sl.parity_servers]
            t += self.net.phase(legs)
            seal_items, ack_legs, touched = [], [], []
            for (i, key, value, sl, ds), req in zip(batch, reqs):
                cid, off, events = self._sv(ds).set_object(sl, key, value)
                iseq = self._sv(ds).live_iseq(key)
                for p in sl.parity_servers:
                    self._sv(p).store_replica(key, value, iseq=iseq)
                seal_items += [(sl, ds, ev) for ev in events]
                ack_legs.append(Leg("set_ack", len(key) + 8, f"s{ds}",
                                    f"p{proxy.pid}", self._is_failed(ds)))
                ack_legs += [Leg("set_ack", 8, f"s{p}", f"p{proxy.pid}",
                                 self._is_failed(p))
                             for p in sl.parity_servers]
                proxy.buffer_mapping(ds, key, cid, iseq)
                touched.append(ds)
                ok[i] = True
            # async: the seal fan-out (parity rebuild + fold) overlaps
            # the SET acknowledgements already in flight
            t += self._overlap_branches(
                ("seal", lambda: self._handle_seals_batched(seal_items)),
                ("ack", lambda: self.net.phase(ack_legs)))
            for req in reqs:
                proxy.ack(req.seq)
            for ds in dict.fromkeys(touched):
                t += self._maybe_checkpoint(ds)
        for i, key, value in deferred:   # duplicate keys: now upserts
            ok[i] = self.set(key, value, proxy_id)
        return ok, t

    def multi_update(self, items, proxy_id: int | None = 0) -> list[bool]:
        items = list(items)
        if self.crash_hook is not None and self.crash_hook[0] == "update":
            # fault injection must fire exactly as in sequential mode:
            # everything before the crashing key completes first, the
            # crash raises, and nothing after it executes
            hook_i = next((i for i, (k, _) in enumerate(items)
                           if k == self.crash_hook[1]), None)
            if hook_i is not None:
                hook_pid = proxy_id if proxy_id is not None else 0
                ok = [False] * len(items)
                ok[:hook_i] = self.multi_update(items[:hook_i], proxy_id)
                ok[hook_i] = self.update(*items[hook_i], hook_pid)
                ok[hook_i + 1:] = self.multi_update(items[hook_i + 1:],
                                                    proxy_id)
                return ok
        if proxy_id is None and self.num_proxies > 1 and len(items) > 1:
            return self._run_proxy_lanes(
                "MUPDATE", [k for k, _ in items],
                lambda idxs, pid: self._multi_update_impl(
                    [items[i] for i in idxs], pid))
        tr = self._trace_frame()
        ok, t = self._multi_update_impl(items, proxy_id or 0)
        if t is not None:
            self.net.record("MUPDATE", t)
        elif tr is not None:
            tr.cancel()
        return ok

    def _multi_update_impl(self, items, proxy_id: int):
        proxy = self.proxies[proxy_id]
        ok = [False] * len(items)
        batch, deferred, seen = [], [], set()
        for i, (key, value) in enumerate(items):
            sl, ds = self.mapper.data_server_for(key)
            involved = [ds] + list(sl.parity_servers)
            if key in seen:
                deferred.append((i, key, value))
                continue
            if any(self._degraded_active(s) and self._is_failed(s)
                   for s in involved):
                ok[i] = self.update(key, value, proxy_id)  # degraded
                continue
            head = self._sv(ds).get_value(key)
            if head is not None and head.startswith(LARGE_MAGIC):
                ok[i] = self._update_large(key, value, proxy_id)
                continue
            seen.add(key)
            batch.append((i, key, value, sl, ds, head))
        t = None
        if batch:
            # head-probe round trip (sequential update() pays a modeled
            # GET per key before choosing the update path — charge the
            # batched equivalent so MUPDATE stays comparable)
            t = self.net.phase([Leg("get", len(key), f"p{proxy.pid}",
                                    f"s{ds}", self._is_failed(ds))
                                for _, key, _, _, ds, _ in batch])
            t += self.net.phase([Leg("get_resp",
                                     len(head) if head else 0, f"s{ds}",
                                     f"p{proxy.pid}", self._is_failed(ds))
                                 for _, _, _, _, ds, head in batch])
            t += self.net.phase([Leg("update", len(key) + len(value),
                                     f"p{proxy.pid}", f"s{ds}",
                                     self._is_failed(ds))
                                 for _, key, value, _, ds, _ in batch])
            sealed_jobs, replica_jobs, done_reqs = [], [], []
            for i, key, value, sl, ds, _head in batch:
                req = proxy.begin("UPDATE", key, value, sl, ds)
                res = self._sv(ds).update_value(key, value)
                if res is None:
                    proxy.ack(req.seq)
                    continue
                cid, sealed, off, xor = res
                nz = np.nonzero(xor)[0]
                if len(nz):
                    seg_off = off + int(nz[0])
                    seg = xor[int(nz[0]): int(nz[-1]) + 1]
                else:
                    seg_off, seg = off, xor[:0]
                if sealed:
                    if (self._hot_eligible() and self._hot_buffer_update(
                            key, sl, ds, cid, seg_off, seg)):
                        pass   # hot key: parity round deferred to flush
                    else:
                        sealed_jobs.append((sl, ds, cid, seg_off, seg, req))
                else:
                    replica_jobs.append((sl, ds, key, value, req))
                done_reqs.append(req)
                ok[i] = True
            legs = []
            fut = None
            old_par = None
            if sealed_jobs:
                # one *submitted* engine call computes AND folds every
                # parity row of every updated chunk (fused delta+apply —
                # no separate (B, m, C) delta materialization); the delta
                # legs are modeled while it is in flight
                fulls = np.zeros((len(sealed_jobs), self.chunk_size),
                                 np.uint8)
                for b, (sl, ds, cid, seg_off, seg, req) in enumerate(sealed_jobs):
                    fulls[b, seg_off: seg_off + len(seg)] = seg
                positions = np.array(
                    [cid.position for _, _, cid, _, _, _ in sealed_jobs])
                old_par = np.stack(
                    [np.stack([self._sv(p).parity_row(sl, cid.stripe_id)
                               for p in sl.parity_servers])
                     for sl, ds, cid, _, _, _ in sealed_jobs])
                fut = self.engine.submit_apply_delta(old_par, positions,
                                                     fulls)
                for sl, ds, cid, seg_off, seg, req in sealed_jobs:
                    legs += [Leg("delta", len(seg), f"s{ds}", f"s{p}",
                                 self._is_failed(p))
                             for p in sl.parity_servers]
            for sl, ds, key, value, req in replica_jobs:
                for p in sl.parity_servers:
                    self._sv(p).apply_replica_delta(key, value, False,
                                                    proxy.pid, req.seq)
                    legs.append(Leg("replica_delta", len(key) + len(value),
                                    f"s{ds}", f"s{p}", self._is_failed(p)))
            net_t = self.net.phase(legs) if legs else 0.0
            if fut is not None:
                # per-row deltas (new ^ old) feed the §5.3 revert buffer;
                # extraction is stale-proof even when two jobs share a
                # stripe's parity slot — the delta never depends on the
                # gathered parity content
                deltas = fut.result() ^ old_par
                for (sl, ds, cid, seg_off, seg, req), delta in zip(
                        sealed_jobs, deltas):
                    for j, p in enumerate(sl.parity_servers):
                        self._sv(p).apply_data_delta_row(
                            sl, cid, delta[j], proxy.pid, req.seq)
            if legs or fut is not None:
                t += self._merge_coding(self._coding_s(fut), net_t,
                                        kind="delta")
            t += self.net.phase([Leg("update_ack", 8, f"s{ds}",
                                     f"p{proxy.pid}", self._is_failed(ds))
                                 for _, _, _, _, ds, _ in batch])
            parity_set = {p for _, _, _, sl, _, _ in batch
                          for p in sl.parity_servers}
            for req in done_reqs:
                proxy.ack(req.seq)
            for p in parity_set:
                self._sv(p).prune_deltas(proxy.pid, proxy.ack_watermark)
        for i, key, value in deferred:
            ok[i] = self.update(key, value, proxy_id)
        return ok, t

    # ------------------------------------------------------------------
    # SET
    # ------------------------------------------------------------------
    def _set_small(self, key: bytes, value: bytes, proxy_id: int):
        proxy = self.proxies[proxy_id]
        sl, ds = self.mapper.data_server_for(key)
        involved = [ds] + list(sl.parity_servers)
        if any(self._degraded_active(s) and self._is_failed(s) for s in involved):
            return self._degraded_set(proxy, sl, ds, key, value)
        req = proxy.begin("SET", key, value, sl, ds)
        t = 0.0
        # upsert: a key must never occupy two chunk slots (see module doc)
        if self._sv(ds).lookup(key) is not None:
            ref = self._sv(ds).lookup(key)
            if ref.value_size == len(value):
                proxy.ack(req.seq)
                return self._update_small(key, value, proxy_id)
            self._delete_small(key, proxy_id)
        self._trace_frame()
        obj_bytes = object_size(len(key), len(value))
        legs = [Leg("set", obj_bytes, f"p{proxy.pid}", f"s{ds}", self._is_failed(ds))]
        for p in sl.parity_servers:
            legs.append(Leg("set_replica", obj_bytes, f"p{proxy.pid}", f"s{p}",
                            self._is_failed(p)))
        t += self.net.phase(legs)
        cid, off, seal_events = self._sv(ds).set_object(sl, key, value)
        iseq = self._sv(ds).live_iseq(key)
        for p in sl.parity_servers:
            self._sv(p).store_replica(key, value, iseq=iseq)
        # acks (data server piggybacks the key->chunk-ID mapping, §5.3);
        # async overlaps the seal fan-out with the acks in flight
        ack_legs = [Leg("set_ack", len(key) + 8, f"s{ds}", f"p{proxy.pid}",
                        self._is_failed(ds))]
        ack_legs += [Leg("set_ack", 8, f"s{p}", f"p{proxy.pid}", self._is_failed(p))
                     for p in sl.parity_servers]
        t += self._overlap_branches(
            ("seal", lambda: self._handle_seals(sl, ds, seal_events)),
            ("ack", lambda: self.net.phase(ack_legs)))
        proxy.buffer_mapping(ds, key, cid, iseq)
        t += self._maybe_checkpoint(ds)
        proxy.ack(req.seq)
        self.net.record("SET", t)
        return True

    def _set_large(self, key: bytes, value: bytes, proxy_id: int):
        frags = split_fragments(key, value, self.chunk_size)
        for fkey, fval in frags:
            self._set_small(fkey, fval, proxy_id)
        manifest = LARGE_MAGIC + struct.pack("<I", len(value))
        return self._set_small(key, manifest, proxy_id)

    # ------------------------------------------------------------------
    # GET
    # ------------------------------------------------------------------
    def _endpoint_load(self, sid: int) -> float:
        """Load-aware chunk selection score for one server: cumulative
        link occupancy (``time_by_endpoint``) plus, in open-loop event
        mode, the link's current free-at clock — so redundant fetches
        avoid the busiest endpoints.  An inflated straggler's occupancy
        grows ``factor``x faster, so selection learns to deprioritize it
        without being told (the races hide it meanwhile).  Within one
        shard every candidate shares the engine, so the
        ``CodingEngine.modeled_busy_s`` half of load-awareness lives at
        the cross-shard ``_scatter`` seam (idle-engine preference)."""
        ep = f"s{sid}"
        load = self.net.time_by_endpoint.get(ep, 0.0)
        if self.net.events is not None:
            load += self.net.events.link_free.get(ep, 0.0)
        return load

    def _coded_read_batch(self, proxy, entries):
        """Straggler-tolerant k-of-(k+Δ) GET fan-out (Hydra-style late
        binding; Δ = ``redundant_reads``).

        Per ``(key, sl, ds)`` entry, pick the read mode:

        * sealed object — race the data server's value response against
          the k-1+Δ least-loaded other stripe members returning their
          full chunks; the request completes at the k-th arrival.  If
          the data server is among the dropped Δ, the winners' chunk set
          flows into ``DecodePlan`` as a per-request erasure pattern
          (one batched ``submit_decode`` across the whole batch).
        * unsealed object — race the data server against Δ of its alive
          parity replicas (unsealed objects are replicated there).
        * miss — nothing to race; a single round trip, cost-identical
          to the plain path.

        Dark servers (failed + degraded-active) are excluded from the
        candidate set, so Δ race-erasures plus real erasures can never
        exceed m; merely-slow or failed-but-undeclared servers stay in
        and lose the race naturally.  Dropped legs are fully accounted
        (bytes, messages, link occupancy — future requests queue behind
        them) but never gate this request's completion and appear as
        cancelled spans in the tracer, not latency contributors.

        Returns ``(values, modeled_t)``; races of one batch run
        concurrently (t = max over entries, like the plain batched
        fan-out phases).
        """
        if self.hot is not None and len(self.hot.buffer):
            # read barrier: the sealed races below may read parity
            # chunks of these stripes — collapse any buffered hot-key
            # deltas owed to them first, so decode sees consistent parity
            stripes = []
            for key, sl, ds in entries:
                srv = self._sv(ds)
                ref = srv.lookup(key)
                if ref is not None and srv.sealed[ref.chunk_local_idx]:
                    stripes.append((sl, srv.chunk_id_of(ref)))
            self._hot_barrier_stripes(stripes)
        delta = self.redundant_reads
        pp = f"p{proxy.pid}"
        vals: list = [None] * len(entries)
        race_ts: list[float] = []
        decode_jobs = []   # (slot, key, cid, pos, available, expected)
        tr = self.net.tracer
        if tr is not None:
            tr.push()
        for slot, (key, sl, ds) in enumerate(entries):
            srv = self._sv(ds)
            ref = srv.lookup(key)
            failed_ds = self._is_failed(ds)
            v = srv.get_value(key)
            vsz = len(v) if v else 0
            primary = (f"get:{pp}->s{ds}",
                       [Leg("get", len(key), pp, f"s{ds}", failed_ds),
                        Leg("get_resp", vsz, f"s{ds}", pp, failed_ds)])
            if ref is None:
                # miss/deleted: one round trip, cost-identical to plain
                t, _, _ = self.net.race_phase([primary], need=1)
                race_ts.append(t)
                vals[slot] = v
                continue
            if not srv.sealed[ref.chunk_local_idx]:
                # unsealed: replicated at every alive parity server
                cands = sorted(
                    (self._endpoint_load(p), p) for p in sl.parity_servers
                    if not (self._is_failed(p) and self._degraded_active(p)))
                cands = cands[:delta]
                groups = [primary]
                for _, p in cands:
                    fp = self._is_failed(p)
                    groups.append(
                        (f"rget:{pp}->s{p}",
                         [Leg("rget", len(key), pp, f"s{p}", fp),
                          Leg("rget_resp", vsz, f"s{p}", pp, fp)]))
                if len(groups) > 1:
                    self._stats["redundant_reads"] += 1
                t, winners, dropped = self.net.race_phase(groups, need=1)
                race_ts.append(t)
                self._stats["redundant_cancelled"] += len(dropped)
                if winners == [0]:
                    vals[slot] = v
                else:
                    rep = self._sv(cands[winners[0] - 1][1]).get_replica(key)
                    if rep is None:
                        self._stats["redundant_replica_fallbacks"] += 1
                        vals[slot] = v
                    else:
                        rv, deleted = rep
                        vals[slot] = None if deleted else rv
                continue
            # sealed: race the stripe (data-position chunks preferred —
            # deterministic (load, is_parity, position) ranking)
            cid = srv.chunk_id_of(ref)
            pos = cid.position
            cand_pos = sorted(
                (self._endpoint_load(owner), i >= self.k, i)
                for i, owner in enumerate(sl.servers)
                if i != pos and not (self._is_failed(owner)
                                     and self._degraded_active(owner)))
            take = cand_pos[: self.k - 1 + delta]
            groups, members = [primary], [pos]
            for _, _, i in take:
                owner = self._chunk_owner(sl, i)
                fo = self._is_failed(owner)
                groups.append(
                    (f"rget:{pp}->s{owner}",
                     [Leg("rget", len(key), pp, f"s{owner}", fo),
                      Leg("rget_resp", self.chunk_size, f"s{owner}", pp,
                          fo)]))
                members.append(i)
            if len(groups) > 1:
                self._stats["redundant_reads"] += 1
            t, winners, dropped = self.net.race_phase(
                groups, need=min(self.k, len(groups)))
            race_ts.append(t)
            self._stats["redundant_cancelled"] += len(dropped)
            if 0 in winners:
                vals[slot] = v
            else:
                # the data server lost the race: its position is this
                # request's erasure; decode from the k chunk winners
                # (sealed-or-zero, mirroring _gather_available)
                available = {}
                for gi in winners:
                    i = members[gi]
                    c = self._sv(self._chunk_owner(sl, i)).get_sealed_chunk(
                        self._stripe_chunk_id(sl, cid.stripe_id, i))
                    available[i] = (c if c is not None else
                                    np.zeros(self.chunk_size, np.uint8))
                decode_jobs.append((slot, key, cid, pos, available, v))
        max_t = max(race_ts, default=0.0)
        if tr is not None:
            tr.par("races", max_t, tr.pop())
        if not decode_jobs:
            return vals, max_t
        self._stats["redundant_decodes"] += len(decode_jobs)
        fut = self.engine.submit_decode(
            [av for _, _, _, _, av, _ in decode_jobs],
            [[pos] for _, _, _, pos, _, _ in decode_jobs],
            self.chunk_size)
        t_total = self._merge_coding(self._coding_s(fut), max_t,
                                     kind="decode")
        for (slot, key, cid, pos, _, expected), rec in zip(
                decode_jobs, fut.result()):
            rc = ReconChunk(cid, np.array(rec[pos], np.uint8))
            rc.parse()
            vals[slot] = rc.value_of(key)
            if self.verify_rebuild:
                assert vals[slot] == expected, \
                    f"race decode diverged for {key!r}"
        return vals, t_total

    def _get_small(self, key: bytes, proxy_id: int):
        proxy = self.proxies[proxy_id]
        sl, ds = self.mapper.data_server_for(key)
        if self._is_failed(ds) and self._degraded_active(ds):
            return self._degraded_get(proxy, sl, ds, key)
        if self.redundant_reads > 0 and self.code.m > 0:
            # straggler-tolerant k-of-(k+Δ) read (contents byte-identical
            # to the plain path; only the who-answers race differs)
            self._trace_frame()
            vals, t = self._coded_read_batch(proxy, [(key, sl, ds)])
            self.net.record("GET", t)
            return vals[0]
        self._trace_frame()
        t = self.net.phase([Leg("get", len(key), f"p{proxy.pid}", f"s{ds}",
                                self._is_failed(ds))])
        v = self._sv(ds).get_value(key)
        t += self.net.phase([Leg("get_resp", len(v) if v else 0, f"s{ds}",
                                 f"p{proxy.pid}", self._is_failed(ds))])
        self.net.record("GET", t)
        return v

    def _get_large(self, key: bytes, total: int, proxy_id: int):
        nfrag = fragment_count(total, len(key), self.chunk_size)
        parts = []
        for i in range(nfrag):
            fkey = key + struct.pack("<I", i)
            part = self._get_small(fkey, proxy_id)
            if part is None:
                return None
            parts.append(part)
        return b"".join(parts)[:total]

    # ------------------------------------------------------------------
    # UPDATE / DELETE (shared delta fan-out)
    # ------------------------------------------------------------------
    # ------------------------------------------------------------------
    # hot-key update tier (version-buffered delta coding)
    # ------------------------------------------------------------------
    def _hot_eligible(self) -> bool:
        """May sealed updates buffer right now?  Only in a fully healthy
        cluster with no fault injection armed — every degraded, replay,
        and recovery path may read parity, so buffering pauses the
        moment a failure exists (the ``fail_server`` barrier already
        drained what was buffered before it)."""
        return (self.hot is not None and self.code.m > 0
                and not self.failed and self.crash_hook is None)

    def _hot_buffer_update(self, key: bytes, sl: StripeList, ds: int,
                           cid: ChunkId, seg_off: int,
                           seg: np.ndarray) -> bool:
        """Absorb one sealed update into the version buffer.

        Returns True when buffered — the caller then skips its parity
        round entirely (the data server already mutated in place; only
        the parity delta is deferred).  False means the key is not hot:
        take the normal per-SET parity round."""
        hot = self.hot
        entry = hot.buffer.get(key)
        if entry is not None and entry.cid != cid:
            # the key was deleted/re-SET into a different chunk since
            # buffering began — the old region's obligation flushes
            # first, then this update starts a fresh entry
            self._flush_hot_entries([hot.buffer.pop(key)], barrier=True)
            entry = None
        is_hot = hot.tracker.touch(key)
        if entry is None and not is_hot:
            return False
        entry, evicted = hot.buffer.append(key, sl, cid, seg_off, seg)
        hot.stats["buffered_updates"] += 1
        flush_now = []
        if evicted is not None:
            hot.stats["evictions"] += 1
            flush_now.append(evicted)
        if hot.buffer.full(entry):
            flush_now.append(hot.buffer.pop(key))
        if flush_now:
            self._flush_hot_entries(flush_now)
        return True

    def _hot_barrier_stripes(self, stripe_entries) -> None:
        """Read barrier: before any sealed-chunk race/decode touches a
        stripe's parity, collapse that stripe's buffered deltas back in
        (``stripe_entries``: iterable of (sl, cid))."""
        if self.hot is None or not len(self.hot.buffer):
            return
        drained = []
        for sl, cid in stripe_entries:
            drained += self.hot.buffer.pop_stripe(sl, cid)
        if drained:
            self._flush_hot_entries(drained, barrier=True)

    def _flush_hot_entries(self, entries, *, barrier: bool = False) -> float:
        """Fold buffered version deltas back into their sealed stripes.

        ONE batched ``submit_delta_collapse`` serves every entry: the
        engine XOR-collapses each key's V versions into the base→latest
        delta and folds it into the gathered parity rows — N buffered
        updates cost one parity round.  The m delta legs per key carry
        the union extent of the versions (what actually crosses the
        wire), and the whole drain is recorded as its own nested
        ``HOT_FLUSH`` request.  Applied rows use the proxy's ack
        watermark as their seq and prune immediately: a flush is acked
        by construction, so §5.3 reverts can never roll it back.
        """
        entries = [e for e in entries if e is not None and e.versions]
        if not entries:
            return 0.0
        hot = self.hot
        proxy = self.proxies[0]
        self._trace_frame()
        C = self.chunk_size
        parity = np.stack(
            [np.stack([self._sv(p).parity_row(e.sl, e.cid.stripe_id)
                       for p in e.sl.parity_servers]) for e in entries])
        positions = np.array([e.cid.position for e in entries])
        version_xors, legs = [], []
        for e in entries:
            vx = np.zeros((len(e.versions), C), np.uint8)
            for vi, (off, seg) in enumerate(e.versions):
                vx[vi, off: off + len(seg)] ^= seg
            version_xors.append(vx)
            ds = self._chunk_owner(e.sl, e.cid.position)
            lo, hi = e.extent()
            legs += [Leg("delta", hi - lo, f"s{ds}", f"s{p}",
                         self._is_failed(p))
                     for p in e.sl.parity_servers]
        fut = self.engine.submit_delta_collapse(parity, positions,
                                                version_xors)
        rows = fut.result() ^ parity
        wm = proxy.ack_watermark
        for e, erows in zip(entries, rows):
            for j, p in enumerate(e.sl.parity_servers):
                self._sv(p).apply_data_delta_row(e.sl, e.cid, erows[j],
                                                 proxy.pid, wm)
                self._sv(p).prune_deltas(proxy.pid, wm)
            m = len(e.sl.parity_servers)
            lo, hi = e.extent()
            seg_bytes = sum(len(seg) for _, seg in e.versions)
            hot.stats["flushed_keys"] += 1
            hot.stats["flushed_versions"] += len(e.versions)
            hot.stats["saved_parity_rounds"] += len(e.versions) - 1
            hot.stats["saved_parity_bytes"] += \
                max(0, seg_bytes - (hi - lo)) * m
        hot.stats["flushes"] += 1
        if barrier:
            hot.stats["barrier_flushes"] += 1
        t = self._merge_coding(self._coding_s(fut), self.net.phase(legs),
                               kind="delta")
        self.net.record("HOT_FLUSH", t)
        return t

    def flush_hot_buffers(self) -> int:
        """Drain the hot-key version buffer entirely (cooling/eviction
        happen organically; this is the explicit barrier for tests,
        benches, and shutdown).  Returns the number of entries folded."""
        if self.hot is None:
            return 0
        entries = self.hot.buffer.pop_all()
        self._flush_hot_entries(entries)
        return len(entries)

    def _mutate_small(self, kind: str, key: bytes, value: bytes | None,
                      proxy_id: int) -> bool:
        proxy = self.proxies[proxy_id]
        sl, ds = self.mapper.data_server_for(key)
        involved = [ds] + list(sl.parity_servers)
        if any(self._degraded_active(s) and self._is_failed(s) for s in involved):
            return self._degraded_mutate(kind, proxy, sl, ds, key, value)
        self._trace_frame()
        req = proxy.begin(kind.upper(), key, value, sl, ds)
        t = self.net.phase([Leg(kind, len(key) + (len(value) if value else 0),
                                f"p{proxy.pid}", f"s{ds}", self._is_failed(ds))])
        srv = self._sv(ds)
        if kind == "update":
            res = srv.update_value(key, value)
        else:
            res = srv.delete_object(key)
        if res is None:
            proxy.ack(req.seq)
            self.net.record(kind.upper(), t)
            return False
        cid, sealed, off, xor = res
        # trim the xor to its nonzero extent (what crosses the wire)
        nz = np.nonzero(xor)[0]
        if len(nz):
            seg_off, seg = off + int(nz[0]), xor[int(nz[0]): int(nz[-1]) + 1]
        else:
            seg_off, seg = off, xor[:0]
        crash = (self.crash_hook is not None and self.crash_hook[0] == kind
                 and self.crash_hook[1] == key)
        if (kind == "update" and sealed and self._hot_eligible()
                and self._hot_buffer_update(key, sl, ds, cid, seg_off,
                                            seg)):
            # hot key: the version delta is buffered and the parity
            # round deferred to the flush — ack and return with only
            # the request/ack legs on this UPDATE's clock
            t += self.net.phase([Leg("update_ack", 8, f"s{ds}",
                                     f"p{proxy.pid}",
                                     self._is_failed(ds))])
            proxy.ack(req.seq)
            self.net.record(kind.upper(), t)
            return True
        # one submitted engine call serves every parity server (fused
        # delta+apply over the gathered parity rows); resolution is safe
        # before the crash check — engine calls carry no cluster state,
        # and the per-row deltas extracted here feed the per-leg applies
        fut = None
        rows = None
        if sealed and self.code.m > 0:
            full = np.zeros(self.chunk_size, np.uint8)
            full[seg_off: seg_off + len(seg)] = seg
            old_par = np.stack([self._sv(p).parity_row(sl, cid.stripe_id)
                                for p in sl.parity_servers])
            fut = self.engine.submit_apply_delta(
                old_par[None], np.array([cid.position]), full[None])
            rows = fut.result()[0] ^ old_par
        applied = 0
        legs = []
        for j, p in enumerate(sl.parity_servers):
            if crash and applied >= self.crash_hook[2]:
                self.crash_hook = None
                raise PartialFailure(f"data server {ds} crashed after "
                                     f"{applied} parity legs")
            psrv = self._sv(p)
            if sealed:
                legs.append(Leg("delta", len(seg), f"s{ds}", f"s{p}",
                                self._is_failed(p)))
                psrv.apply_data_delta_row(sl, cid, rows[j], proxy.pid,
                                          req.seq)
            else:
                nv = value if kind == "update" else b""
                legs.append(Leg("replica_delta", len(key) + len(nv),
                                f"s{ds}", f"s{p}", self._is_failed(p)))
                psrv.apply_replica_delta(key, nv, kind == "delete",
                                         proxy.pid, req.seq)
            applied += 1
        t += self._merge_coding(self._coding_s(fut), self.net.phase(legs),
                                kind="delta")
        t += self.net.phase([Leg(f"{kind}_ack", 8, f"s{ds}", f"p{proxy.pid}",
                                 self._is_failed(ds))])
        proxy.ack(req.seq)
        # parity servers prune delta buffers using the ack watermark (§5.3)
        for p in sl.parity_servers:
            self._sv(p).prune_deltas(proxy.pid, proxy.ack_watermark)
        self.net.record(kind.upper(), t)
        return True

    def _update_small(self, key: bytes, value: bytes, proxy_id: int) -> bool:
        return self._mutate_small("update", key, value, proxy_id)

    def _delete_small(self, key: bytes, proxy_id: int) -> bool:
        return self._mutate_small("delete", key, None, proxy_id)

    def _update_large(self, key: bytes, value: bytes, proxy_id: int) -> bool:
        frags = split_fragments(key, value, self.chunk_size)
        ok = True
        for fkey, fval in frags:
            ok &= self._update_small(fkey, fval, proxy_id)
        return ok

    def _delete_large(self, key: bytes, head: bytes, proxy_id: int) -> bool:
        total = large_total(head)
        nfrag = fragment_count(total, len(key), self.chunk_size)
        for i in range(nfrag):
            self._delete_small(key + struct.pack("<I", i), proxy_id)
        return self._delete_small(key, proxy_id)

    # ------------------------------------------------------------------
    # degraded requests (§5.4) — all coordinated
    # ------------------------------------------------------------------
    def _coord_hop(self, proxy: Proxy, nbytes: int) -> float:
        return self.net.phase([Leg("coord", nbytes, f"p{proxy.pid}", "coord")])

    def _degraded_set(self, proxy: Proxy, sl: StripeList, ds: int,
                      key: bytes, value: bytes) -> bool:
        if not self._is_failed(ds):
            ref = self._sv(ds).lookup(key)
            if ref is not None:
                # upsert while a parity server is down: a key must never
                # occupy two chunk slots (module doc), so route through the
                # degraded mutate path exactly as _set_small does normally
                if ref.value_size == len(value):
                    return self._degraded_mutate("update", proxy, sl, ds,
                                                 key, value)
                self._degraded_mutate("delete", proxy, sl, ds, key, None)
        self._trace_frame()
        self._stats["degraded_requests"] += 1
        t = self._coord_hop(proxy, len(key))
        obj_bytes = object_size(len(key), len(value))
        if self._is_failed(ds):
            r = self.coordinator.redirected_server(sl, ds)
            rs = self._rs(r)
            t += self.net.phase([Leg("set_redirect", obj_bytes,
                                     f"p{proxy.pid}", f"s{r}")])
            rs.temp_objects[key] = value
            rs.temp_deletes.discard(key)
        else:
            # data server alive; some parity failed — write normally to the
            # working set, shadow-replicate to the redirected server
            legs = [Leg("set", obj_bytes, f"p{proxy.pid}", f"s{ds}")]
            cid, off, seal_events = self._sv(ds).set_object(sl, key, value)
            iseq = self._sv(ds).live_iseq(key)
            for p in sl.parity_servers:
                if self._is_failed(p):
                    r = self.coordinator.redirected_server(sl, p)
                    self._rs(r).temp_replicas[key] = (value, False, iseq)
                    legs.append(Leg("set_replica", obj_bytes,
                                    f"p{proxy.pid}", f"s{r}"))
                else:
                    self._sv(p).store_replica(key, value, iseq=iseq)
                    legs.append(Leg("set_replica", obj_bytes,
                                    f"p{proxy.pid}", f"s{p}"))
            t += self.net.phase(legs)
            t += self._handle_seals(sl, ds, seal_events)
            proxy.buffer_mapping(ds, key, cid, iseq)
        self.net.record("SET_DEG", t)
        return True

    def _best_data_chunk(self, sl: StripeList, stripe_id: int, i: int
                         ) -> tuple[np.ndarray | None, int]:
        """Best-known bytes of data chunk ``i`` of a stripe (or None if it
        never sealed), plus the server that actually serves them.  A
        failed owner's reconstructed copy at its redirected server wins
        over the owner's frozen memory — the recon chunk carries
        degraded-mode updates the memory never saw."""
        owner = sl.data_servers[i]
        cid = self._stripe_chunk_id(sl, stripe_id, i)
        if self._is_failed(owner) and self._degraded_active(owner):
            r = self.coordinator.redirected_server(sl, owner)
            rc = self._rs(r).recon.get(cid.key())
            if rc is not None:
                return rc.buf, r
        return self._sv(owner).get_sealed_chunk(cid), owner

    def _gather_available(self, sl: StripeList, stripe_id: int, position: int,
                          r: int) -> tuple[dict[int, np.ndarray], list[Leg]]:
        """Collect the surviving stripe chunks needed to reconstruct
        ``position`` at redirected server ``r`` (sealed-or-zero semantics;
        shared by on-demand and batched recovery)."""
        available: dict[int, np.ndarray] = {}
        legs = []
        # data positions: sealed-or-zero on working servers
        for i in range(self.k):
            owner = sl.data_servers[i]
            if self._is_failed(owner) or i == position:
                continue
            c = self._sv(owner).get_sealed_chunk(
                self._stripe_chunk_id(sl, stripe_id, i))
            available[i] = c if c is not None else np.zeros(self.chunk_size, np.uint8)
            legs.append(Leg("recon_fetch", self.chunk_size, f"s{owner}", f"s{r}"))
        # parity positions
        for j in range(self.n - self.k):
            owner = sl.parity_servers[j]
            pos = self.k + j
            if self._is_failed(owner) or pos == position:
                continue
            c = self._sv(owner).get_sealed_chunk(
                self._stripe_chunk_id(sl, stripe_id, pos))
            if c is not None:
                available[pos] = c
                legs.append(Leg("recon_fetch", self.chunk_size, f"s{owner}", f"s{r}"))
            elif len(available) < self.k:
                # parity never materialized => no seal happened => zero
                available[pos] = np.zeros(self.chunk_size, np.uint8)
                legs.append(Leg("recon_fetch", self.chunk_size, f"s{owner}", f"s{r}"))
        return available, legs

    def _ensure_recon(self, sl: StripeList, failed_sid: int, position: int,
                      stripe_id: int, r: int) -> tuple[ReconChunk, float]:
        """On-demand chunk reconstruction at the redirected server (§5.4).
        After `fail_server`'s batched recovery this is normally a cache hit
        (only chunks sealed *after* the failure still decode here)."""
        rs = self._rs(r)
        cid = self._stripe_chunk_id(sl, stripe_id, position)
        rc = rs.recon.get(cid.key())
        if rc is not None:
            self._stats["recon_chunk_hits"] += 1
            return rc, 0.0
        available, legs = self._gather_available(sl, stripe_id, position, r)
        # plan/execute decode: jax/pallas dispatch the pattern-group
        # matmul on-device HERE, then the fetch legs are modeled while
        # the device works (async merges the two as max)
        fut = self.engine.submit_decode([available], [[position]],
                                        self.chunk_size)
        net_t = self.net.phase(legs[: self.k]) if legs else 0.0
        t = self._merge_coding(self._coding_s(fut), net_t, kind="decode")
        rec = fut.result()[0]
        rc = ReconChunk(cid, np.array(rec[position], np.uint8))
        if position < self.k:
            rc.parse()
        rs.recon[cid.key()] = rc
        self._stats["reconstructions"] += 1
        return rc, t

    def _batch_recover_server(self, sid: int) -> tuple[float, int]:
        """Reconstruct every sealed chunk the failed server owned in ONE
        batched decode at its redirected servers (the paper's fast-recovery
        claim, §5.4/§5.5).  The coordinator knows the chunk inventory from
        the checkpointed key->chunk-ID mappings; the simulation reads it
        off the failed server's metadata directly."""
        if self.code.m == 0:
            return 0.0, 0   # no parity — nothing can be reconstructed
        srv = self._sv(sid)
        tasks = []
        for idx, cid in enumerate(srv.chunk_ids):
            if cid is None or not srv.sealed[idx]:
                continue
            sl = self.stripe_lists[cid.stripe_list_id]
            r = self.coordinator.redirected_server(sl, sid)
            if cid.key() in self._rs(r).recon:
                continue
            tasks.append((sl, cid, r))
        if not tasks:
            return 0.0, 0
        avail_list, wanted, all_legs = [], [], []
        for sl, cid, r in tasks:
            av, legs = self._gather_available(sl, cid.stripe_id,
                                              cid.position, r)
            avail_list.append(av)
            wanted.append([cid.position])
            all_legs.extend(legs[: self.k])
        # recovery time scales with volume: each redirected server drains
        # its chunk fetches link-serialized, redirected servers in parallel;
        # the one-shot batched decode is submitted first — on jax/pallas
        # the per-pattern matmuls dispatch on-device at submit (plan/
        # execute split) — and its modeled time overlaps the bulk fetches
        fut = self.engine.submit_decode(avail_list, wanted, self.chunk_size)
        t = self._merge_coding(self._coding_s(fut),
                               self.net.serialized_phase(all_legs),
                               kind="decode")
        recs = fut.result()
        for (sl, cid, r), rec in zip(tasks, recs):
            rc = ReconChunk(cid, np.array(rec[cid.position], np.uint8))
            if cid.position < self.k:
                rc.parse()
            self._rs(r).recon[cid.key()] = rc
        self._stats["reconstructions"] += len(tasks)
        self._stats["batch_recovered_chunks"] += len(tasks)
        return t, len(tasks)

    def _degraded_get(self, proxy: Proxy, sl: StripeList, ds: int, key: bytes):
        self._trace_frame()
        self._stats["degraded_requests"] += 1
        t = self._coord_hop(proxy, len(key))
        r = self.coordinator.redirected_server(sl, ds)
        rs = self._rs(r)
        t += self.net.phase([Leg("get_redirect", len(key), f"p{proxy.pid}", f"s{r}")])
        # 1. degraded-SET / shadowed objects
        if key in rs.temp_deletes:
            self.net.record("GET_DEG", t)
            return None
        if key in rs.temp_objects:
            v = rs.temp_objects[key]
            t += self.net.phase([Leg("get_resp", len(v), f"s{r}", f"p{proxy.pid}")])
            self.net.record("GET_DEG", t)
            return v
        # 2. locate the chunk via the recovered key->chunk-ID mappings
        cid = self.coordinator.chunk_id_for(ds, key)
        if cid is None:
            self.net.record("GET_DEG", t)
            return None
        rc = rs.recon.get(cid.key())
        if rc is None:
            # 3. unsealed chunk? fetch the replica from a working parity
            for p in sl.parity_servers:
                if self._is_failed(p):
                    continue
                rep = self._sv(p).get_replica(key)
                t += self.net.phase([Leg("replica_fetch", len(key),
                                         f"s{r}", f"s{p}")])
                if rep is not None:
                    value, deleted = rep
                    v = None if deleted else value
                    if v is not None:
                        t += self.net.phase([Leg("get_resp", len(v), f"s{r}",
                                                 f"p{proxy.pid}")])
                    self.net.record("GET_DEG", t)
                    return v
                break  # one probe is enough: replicas are on all parities
            # 4. sealed chunk: reconstruct on demand (chunk granularity)
            rc, t_rec = self._ensure_recon(sl, ds, cid.position,
                                           cid.stripe_id, r)
            t += t_rec
        else:
            self._stats["recon_chunk_hits"] += 1
        entry = (rc.objects or {}).get(key)
        if entry is None:
            self.net.record("GET_DEG", t)
            return None
        off, ksz, vsz, deleted = entry
        if deleted:
            self.net.record("GET_DEG", t)
            return None
        vo = off + 4 + ksz
        v = rc.buf[vo: vo + vsz].tobytes()
        t += self.net.phase([Leg("get_resp", len(v), f"s{r}", f"p{proxy.pid}")])
        self.net.record("GET_DEG", t)
        return v

    def _fan_redirect_deltas(self, cid: ChunkId, seg_off: int, seg,
                             redirected: list, legs: list[Leg]) -> float:
        """Delta fan-out completion for a degraded mutate of a sealed
        chunk.  ONE submitted engine call computes every parity row
        (each failed parity's redirect target consumes its row from it —
        previously one serial ``delta_batch`` per target with unmodeled
        cost); the legs are modeled while it is in flight and the
        redirected recon chunks are patched at resolution."""
        fut = None
        if redirected:
            full = np.zeros(self.chunk_size, np.uint8)
            full[seg_off: seg_off + len(seg)] = seg
            fut = self.engine.submit_delta(np.array([cid.position]),
                                           full[None])
        t = self._merge_coding(self._coding_s(fut), self.net.phase(legs),
                               kind="delta")
        if fut is not None:
            rows = fut.result()[0]
            for j, rc in redirected:
                rc.buf ^= rows[j]
                rc.dirty = True
        return t

    def _degraded_mutate(self, kind: str, proxy: Proxy, sl: StripeList,
                         ds: int, key: bytes, value: bytes | None) -> bool:
        self._trace_frame()
        self._stats["degraded_requests"] += 1
        t = self._coord_hop(proxy, len(key))
        if self._is_failed(ds):
            ok, t2 = self._degraded_mutate_failed_ds(kind, proxy, sl, ds, key, value)
            self.net.record(f"{kind.upper()}_DEG", t + t2)
            return ok
        # data server alive; failed parity server(s).
        # Reconstruct-first (§5.4): materialize every failed parity chunk
        # from the *pre-update* stripe before mutating anything, else the
        # decoded snapshot would already contain the update and the delta
        # would be double-applied.
        srv = self._sv(ds)
        ref = srv.lookup(key)
        if ref is None:
            self.net.record(f"{kind.upper()}_DEG", t)
            return False
        pre_cid = srv.chunk_id_of(ref)
        pre_iseq = srv.live_iseq(key)   # instance the shadow belongs to
        if srv.sealed[ref.chunk_local_idx]:
            for j, p in enumerate(sl.parity_servers):
                if self._is_failed(p):
                    r = self.coordinator.redirected_server(sl, p)
                    _, t_rec = self._ensure_recon(sl, p, self.k + j,
                                                  pre_cid.stripe_id, r)
                    t += t_rec
        res = srv.update_value(key, value) if kind == "update" else srv.delete_object(key)
        if res is None:
            self.net.record(f"{kind.upper()}_DEG", t)
            return False
        cid, sealed, off, xor = res
        nz = np.nonzero(xor)[0]
        seg_off = off + (int(nz[0]) if len(nz) else 0)
        seg = xor[int(nz[0]): int(nz[-1]) + 1] if len(nz) else xor[:0]
        legs = []
        redirected: list[tuple[int, ReconChunk]] = []
        for j, p in enumerate(sl.parity_servers):
            pos = self.k + j
            if not self._is_failed(p):
                if sealed:
                    self._sv(p).apply_data_delta(sl, cid, seg_off, seg,
                                                 proxy.pid, proxy.seq)
                else:
                    nv = value if kind == "update" else b""
                    self._sv(p).apply_replica_delta(key, nv, kind == "delete",
                                                    proxy.pid, proxy.seq)
                legs.append(Leg("delta", len(seg), f"s{ds}", f"s{p}"))
                continue
            # failed parity: delta goes to its redirected server (§5.4),
            # which reconstructs the parity chunk first
            r = self.coordinator.redirected_server(sl, p)
            if sealed:
                rc, t_rec = self._ensure_recon(sl, p, pos, cid.stripe_id, r)
                t += t_rec
                redirected.append((j, rc))
            else:
                # shadow must keep the value size (zero-filled) exactly
                # like apply_replica_delta does — the eventual seal
                # rebuild packs tombstones at their original extent
                nv = (value if kind == "update"
                      else b"\x00" * ref.value_size)
                self._rs(r).temp_replicas[key] = (nv, kind == "delete",
                                                  pre_iseq)
            legs.append(Leg("delta_redirect", len(seg), f"s{ds}", f"s{r}"))
        t += self._fan_redirect_deltas(cid, seg_off, seg, redirected, legs)
        self.net.record(f"{kind.upper()}_DEG", t)
        return True

    def _degraded_mutate_failed_ds(self, kind, proxy, sl, ds, key, value):
        """UPDATE/DELETE when the object's data server is down."""
        t = 0.0
        r = self.coordinator.redirected_server(sl, ds)
        rs = self._rs(r)
        # degraded-SET'd or shadowed object
        if key in rs.temp_objects:
            if kind == "update":
                rs.temp_objects[key] = value
            else:
                rs.temp_objects.pop(key, None)
                rs.temp_deletes.add(key)
            return True, t
        cid = self.coordinator.chunk_id_for(ds, key)
        if cid is None:
            return False, t
        # is the chunk sealed? probe a working parity for a replica
        probe_parity = next((p for p in sl.parity_servers
                             if not self._is_failed(p)), None)
        rep = self._sv(probe_parity).get_replica(key) if probe_parity is not None else None
        t += self.net.phase([Leg("replica_fetch", len(key), f"s{r}",
                                 f"s{probe_parity}")])
        if rep is not None:
            # unsealed object: shadow the mutation at the redirected server
            # (migrated back as a normal UPDATE/DELETE on restore)
            if kind == "update":
                rs.temp_objects[key] = value
            else:
                rs.temp_deletes.add(key)
            return True, t
        # sealed chunk: reconstruct-first (§5.4) — the data chunk AND any
        # failed parity chunks, all from the pre-update stripe — then
        # mutate and fan out deltas.
        rc, t_rec = self._ensure_recon(sl, ds, cid.position, cid.stripe_id, r)
        t += t_rec
        for j2, p2 in enumerate(sl.parity_servers):
            if self._is_failed(p2):
                r2 = self.coordinator.redirected_server(sl, p2)
                _, t_rec2 = self._ensure_recon(sl, p2, self.k + j2,
                                               cid.stripe_id, r2)
                t += t_rec2
        entry = (rc.objects or {}).get(key)
        if entry is None or entry[3]:
            return False, t
        off, ksz, vsz, _ = entry
        ext = object_size(ksz, vsz)
        old = rc.buf[off: off + ext].copy()
        if kind == "update":
            if len(value) != vsz:
                raise ValueError("value size must not change across updates")
            rc.buf[off + 4 + ksz: off + 4 + ksz + vsz] = np.frombuffer(value, np.uint8)
        else:
            vfield = vsz | (1 << 23)
            rc.buf[off + 1: off + 4] = np.frombuffer(
                struct.pack("<I", vfield)[:3], np.uint8)
            rc.buf[off + 4 + ksz: off + 4 + ksz + vsz] = 0
            rc.objects[key] = (off, ksz, vsz, True)
        rc.dirty = True
        xor = old ^ rc.buf[off: off + ext]
        nz = np.nonzero(xor)[0]
        seg_off = off + (int(nz[0]) if len(nz) else 0)
        seg = xor[int(nz[0]): int(nz[-1]) + 1] if len(nz) else xor[:0]
        legs = []
        redirected = []
        for j, p in enumerate(sl.parity_servers):
            if self._is_failed(p):
                r2 = self.coordinator.redirected_server(sl, p)
                rc2, t_rec2 = self._ensure_recon(sl, p, self.k + j,
                                                 cid.stripe_id, r2)
                t += t_rec2
                redirected.append((j, rc2))
                legs.append(Leg("delta_redirect", len(seg), f"s{r}", f"s{r2}"))
            else:
                self._sv(p).apply_data_delta(sl, cid, seg_off, seg,
                                             proxy.pid, proxy.seq)
                legs.append(Leg("delta", len(seg), f"s{r}", f"s{p}"))
        t += self._fan_redirect_deltas(cid, seg_off, seg, redirected, legs)
        return True, t

    # ------------------------------------------------------------------
    # failure / restore transitions (§5.2, §5.5)
    # ------------------------------------------------------------------
    def inflate_server(self, sid: int, factor: float):
        """Slow-server injection (the straggler axis, alongside
        fail/recover): every leg touching server ``sid`` is
        latency-inflated by ``factor``; ``factor=1.0`` restores.  The
        server keeps serving — it is slow, not failed — which is
        exactly the case degraded mode can't see and k-of-(k+Δ) reads
        mitigate."""
        self.net.inflate(f"s{sid}", factor)

    def fail_server(self, sid: int, recover: bool = True) -> dict:
        """Inject a transient failure; returns transition timings.

        ``recover=False`` skips the eager one-shot batched recovery so
        every degraded request reconstructs on demand through
        ``_ensure_recon`` — the paper's §5.4 on-demand mode, used by the
        benchmarks to expose the decode path on degraded GET latency."""
        if self.hot is not None and len(self.hot.buffer):
            # failure barrier: collapse every buffered hot-key delta
            # while the cluster is still healthy — recovery, degraded
            # decode, and replay all read parity, and buffering stays
            # paused until the failure set empties (_hot_eligible)
            self._flush_hot_entries(self.hot.buffer.pop_all(),
                                    barrier=True)
        self.failed.add(sid)
        if not self.degraded_enabled:
            return {"T_N_to_D": 0.0}
        t = 0.0
        # NORMAL -> INTERMEDIATE: atomic broadcast includes the failed
        # (congested) server — hence the higher latency the paper observes.
        self.coordinator.set_state(sid, ServerState.INTERMEDIATE)
        legs = [Leg("state_bcast", 16, "coord", f"s{s}", s in self.failed)
                for s in range(len(self.servers))]
        legs += [Leg("state_bcast", 16, "coord", f"p{p.pid}") for p in self.proxies]
        t += self.net.phase(legs)
        # resolve inconsistency: revert parity deltas of unacked requests
        replay: list[tuple[int, object]] = []
        for proxy in self.proxies:
            unacked = proxy.unacked_seqs()
            if not unacked:
                continue
            legs = []
            for srv in self.servers:
                if srv.sid in self.failed:
                    continue
                nrev = srv.revert_deltas(proxy.pid, unacked)
                if nrev:
                    self._stats["reverted_deltas"] += nrev
                    legs.append(Leg("revert", 16 * nrev, f"p{proxy.pid}",
                                    f"s{srv.sid}"))
            if legs:
                t += self.net.phase(legs)
            for seq, req in sorted(proxy.pending.items()):
                if req.data_server == sid or sid in req.stripe_list.servers:
                    replay.append((proxy.pid, req))
        # collect key->chunk-ID mapping backups from proxies (§5.3)
        proxy_maps = []
        legs = []
        for proxy in self.proxies:
            pm = proxy.mappings_for(sid)
            proxy_maps.append(pm)
            legs.append(Leg("mapping_push", sum(len(k) + 12 for k, _, _ in pm),
                            f"p{proxy.pid}", "coord"))
        t += self.net.phase(legs)
        self.coordinator.merge_proxy_mappings(sid, proxy_maps)
        # also merge the server's own mapping log that was checkpointed;
        # plus anything in its log the proxies still buffer — done above.
        # INTERMEDIATE -> DEGRADED
        self.coordinator.set_state(sid, ServerState.DEGRADED)
        legs = [Leg("state_bcast", 16, "coord", f"s{s}")
                for s in range(len(self.servers)) if s not in self.failed]
        legs += [Leg("state_bcast", 16, "coord", f"p{p.pid}") for p in self.proxies]
        t += self.net.phase(legs)
        # if sid itself hosted degraded state as a redirect target for an
        # earlier failure, hand it off to freshly assigned targets
        t += self._handoff_redirect_state(sid)
        timings = {"T_N_to_D": t}
        # fast batched recovery (§5.4): reconstruct every chunk the failed
        # server owned in one batched decode at the redirected servers,
        # so degraded requests (and the replay below) hit a warm cache.
        # Timed separately — the paper reports transition and recovery
        # durations independently.
        t_rec, n_rec = (self._batch_recover_server(sid) if recover
                        else (0.0, 0))
        timings["T_recovery"] = t_rec
        timings["recovered_chunks"] = n_rec
        # replay incomplete requests as degraded requests
        for pid, req in replay:
            self.proxies[pid].pending.pop(req.seq, None)
            self.proxies[pid].ack(req.seq)
            if req.kind == "SET":
                self._degraded_set(self.proxies[pid], req.stripe_list,
                                   req.data_server, req.key, req.value)
            elif req.kind == "UPDATE":
                self._degraded_mutate("update", self.proxies[pid],
                                      req.stripe_list, req.data_server,
                                      req.key, req.value)
            elif req.kind == "DELETE":
                self._degraded_mutate("delete", self.proxies[pid],
                                      req.stripe_list, req.data_server,
                                      req.key, None)
        return timings

    def _handoff_redirect_state(self, failing: int) -> float:
        """Graceful transition under overlapping failures (§5.2 spirit):
        when a server that is itself a redirect target fails, the degraded
        state it hosts (reconstructed chunks, degraded-SET objects, shadow
        replicas) is handed off to freshly chosen redirect targets during
        the INTERMEDIATE window, before the server goes fully dark.
        Without this, a fail(A) -> fail(redirect-of-A) interleaving would
        strand acknowledged degraded writes."""
        rs = self.redirect.get(failing)
        if rs is None:
            return 0.0
        legs = []
        moved = 0
        # 1. reconstructed chunks — owners are still-failed servers
        #    (restore_server already drained entries of restored owners)
        for key_t, rc in list(rs.recon.items()):
            del rs.recon[key_t]
            sl = self.stripe_lists[rc.chunk_id.stripe_list_id]
            owner = self._chunk_owner(sl, rc.chunk_id.position)
            if not self._is_failed(owner):
                continue  # stale entry; owner's memory is authoritative
            r2 = self.coordinator.redirected_server(sl, owner)
            self._rs(r2).recon[key_t] = rc
            legs.append(Leg("handoff_chunk", self.chunk_size,
                            f"s{failing}", f"s{r2}"))
            moved += 1
        # 2. degraded-SET objects and shadowed deletes
        for okey in list(rs.temp_objects):
            val = rs.temp_objects.pop(okey)
            sl2, ds2 = self.mapper.data_server_for(okey)
            if self._is_failed(ds2):
                r2 = self.coordinator.redirected_server(sl2, ds2)
                self._rs(r2).temp_objects[okey] = val
                self._rs(r2).temp_deletes.discard(okey)
                legs.append(Leg("handoff_obj", len(okey) + len(val),
                                f"s{failing}", f"s{r2}"))
                moved += 1
            else:  # owner back already: land it as a normal request
                self.set(okey, val, 0)
        for okey in list(rs.temp_deletes):
            rs.temp_deletes.discard(okey)
            sl2, ds2 = self.mapper.data_server_for(okey)
            if self._is_failed(ds2):
                r2 = self.coordinator.redirected_server(sl2, ds2)
                self._rs(r2).temp_deletes.add(okey)
                self._rs(r2).temp_objects.pop(okey, None)
                moved += 1
            else:
                self.delete(okey, 0)
        # 3. shadow replicas for failed parity servers (one copy per
        # distinct redirect target still covering a failed parity)
        for okey, rep in list(rs.temp_replicas.items()):
            del rs.temp_replicas[okey]
            sl2, _ = self.mapper.data_server_for(okey)
            targets = {self.coordinator.redirected_server(sl2, p)
                       for p in sl2.parity_servers if self._is_failed(p)}
            for r2 in sorted(targets):
                self._rs(r2).temp_replicas[okey] = rep
                legs.append(Leg("handoff_replica", len(okey) + len(rep[0]),
                                f"s{failing}", f"s{r2}"))
                moved += 1
        self._stats["redirect_handoffs"] += moved
        return self.net.phase(legs) if legs else 0.0

    def restore_server(self, sid: int) -> dict:
        """Restore a transiently-failed server (§5.5): migrate, then NORMAL."""
        if sid not in self.failed:
            return {"T_D_to_N": 0.0}
        t = 0.0
        if not self.degraded_enabled:
            self.failed.discard(sid)
            return {"T_D_to_N": 0.0}
        self.coordinator.set_state(sid, ServerState.COORDINATED_NORMAL)
        legs = [Leg("state_bcast", 16, "coord", f"s{s}")
                for s in range(len(self.servers))]
        legs += [Leg("state_bcast", 16, "coord", f"p{p.pid}") for p in self.proxies]
        t += self.net.phase(legs)
        self.failed.discard(sid)
        restored = self._sv(sid)
        # --- migration from every redirected server ---
        for r, rs in list(self.redirect.items()):
            legs = []
            # 1. dirty reconstructed chunks owned by sid
            for key_t, rc in list(rs.recon.items()):
                sl = self.stripe_lists[rc.chunk_id.stripe_list_id]
                owner = self._chunk_owner(sl, rc.chunk_id.position)
                if owner != sid:
                    continue
                if rc.dirty:
                    slot = restored.slot_of_chunk(rc.chunk_id)
                    if slot is None:
                        slot = restored._alloc_slot(rc.chunk_id)
                        restored.sealed[slot] = True
                    restored.region[slot][:] = rc.buf
                    legs.append(Leg("migrate_chunk", self.chunk_size,
                                    f"s{r}", f"s{sid}"))
                    self._stats["migrated_chunks"] += 1
                    if rc.chunk_id.position < self.k:
                        # fix the object index for objects deleted in
                        # degraded mode — only when the index still points
                        # at THIS slot: a tombstone that predates the
                        # failure may coexist with a live re-SET instance
                        # of the same key in another chunk (delete-then-
                        # re-add churn, e.g. migrate-out/migrate-back)
                        for okey, (off, ksz, vsz, deleted) in (rc.objects or {}).items():
                            if not deleted:
                                continue
                            ref = restored.lookup(okey)
                            if (ref is not None
                                    and ref.chunk_local_idx == slot
                                    and ref.offset == off):
                                restored.object_index.delete(okey)
                del rs.recon[key_t]
            # 2. degraded-SET objects + shadowed mutations routed to sid
            for okey in list(rs.temp_objects.keys()):
                sl2, ds2 = self.mapper.data_server_for(okey)
                if ds2 != sid:
                    continue
                val = rs.temp_objects.pop(okey)
                legs.append(Leg("migrate_obj", len(okey) + len(val),
                                f"s{r}", f"s{sid}"))
                self._stats["migrated_objects"] += 1
                ref = restored.lookup(okey)
                if ref is not None and ref.value_size == len(val):
                    self._update_small(okey, val, 0)
                else:
                    if ref is not None:
                        self._delete_small(okey, 0)
                    self._set_small(okey, val, 0)
            for okey in list(rs.temp_deletes):
                sl2, ds2 = self.mapper.data_server_for(okey)
                if ds2 != sid:
                    continue
                rs.temp_deletes.discard(okey)
                if restored.lookup(okey) is not None:
                    self._delete_small(okey, 0)
            # 3. shadow replicas destined to sid (it was a parity server).
            # One shadow entry serves every failed parity of the list that
            # redirected here, so migrate a COPY and only drop the entry
            # once no parity of the list remains failed.
            for okey, (val, deleted, siseq) in list(rs.temp_replicas.items()):
                sl2, _ = self.mapper.data_server_for(okey)
                if sid not in sl2.parity_servers:
                    continue
                old = restored.temp_replicas.get(okey)
                old_iseq = restored.replica_iseq.get(okey)
                if (old is not None and old_iseq is not None
                        and old_iseq != siseq):
                    # the shadow belongs to a NEWER instance: the one this
                    # parity still holds was deleted during the outage (a
                    # key is only re-added after delete), so park its
                    # final tombstone state for that chunk's future seal
                    restored.zombie_replicas[(okey, old_iseq)] = \
                        (b"\x00" * len(old[0]), True)
                restored.temp_replicas[okey] = (val, deleted)
                if siseq is None:
                    restored.replica_iseq.pop(okey, None)
                else:
                    restored.replica_iseq[okey] = siseq
                legs.append(Leg("migrate_replica", len(okey) + len(val),
                                f"s{r}", f"s{sid}"))
                if not any(self._is_failed(p) for p in sl2.parity_servers):
                    del rs.temp_replicas[okey]
            if legs:
                t += self.net.phase(legs)
        # 4. heal replica invariants: re-replicate sid's unsealed objects
        legs = []
        for lid, ucs in restored.unsealed.items():
            sl = self.stripe_lists[lid]
            for uc in ucs:
                for okey, off in uc.builder.objects:
                    ref = restored.lookup(okey)
                    if ref is None or ref.chunk_local_idx != uc.local_idx \
                            or ref.offset != off:
                        continue  # superseded copy
                    val = restored.get_value(okey)
                    iseq = restored.live_iseq(okey)
                    for p in sl.parity_servers:
                        self._sv(p).store_replica(okey, val, iseq=iseq)
                        legs.append(Leg("rereplicate", len(okey) + len(val),
                                        f"s{sid}", f"s{p}"))
        if legs:
            t += self.net.phase(legs)
        # 5. GC stale replicas: chunks that sealed while sid was down never
        # popped sid's replicas; a stale replica would shadow post-seal
        # updates on a future degraded read.
        self._gc_stale_replicas(sid)
        # drop sticky degraded-routing assignments for the restored server
        self.coordinator.clear_redirects(sid)
        # COORDINATED_NORMAL -> NORMAL
        self.coordinator.set_state(sid, ServerState.NORMAL)
        legs = [Leg("state_bcast", 16, "coord", f"s{s}")
                for s in range(len(self.servers))]
        legs += [Leg("state_bcast", 16, "coord", f"p{p.pid}") for p in self.proxies]
        t += self.net.phase(legs)
        return {"T_D_to_N": t}

    def _gc_stale_replicas(self, sid: int):
        srv = self._sv(sid)
        for key in list(srv.temp_replicas.keys()):
            sl, ds = self.mapper.data_server_for(key)
            if sid not in sl.parity_servers:
                del srv.temp_replicas[key]
                srv.replica_iseq.pop(key, None)
                continue
            dsrv = self._sv(ds)
            ref = dsrv.lookup(key)
            if ref is not None and dsrv.sealed[ref.chunk_local_idx]:
                del srv.temp_replicas[key]
                srv.replica_iseq.pop(key, None)
            # ref is None (deleted object): keep the tombstoned replica —
            # it reads as None either way and may still be needed for a
            # pending seal rebuild.

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def resident_keys(self) -> list[bytes]:
        """Every key this shard currently answers for, sorted (stable
        across runs).  Covers the data servers' object indexes plus
        degraded-mode state parked at redirected servers (degraded-SET
        objects that no server index has seen yet).  Used by the
        migration planner; includes large-object fragment/manifest keys —
        the planner filters fragments itself."""
        out: set[bytes] = set()
        for srv in self.servers:
            out.update(srv.object_index.keys())
        for rs in self.redirect.values():
            out.update(rs.temp_objects.keys())
        return sorted(out)

    def total_memory(self) -> dict:
        agg: dict[str, int] = {}
        for s in self.servers:
            for k, v in s.memory_bytes().items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def stored_payload_bytes(self) -> int:
        return sum(s.bytes_stored for s in self.servers)
