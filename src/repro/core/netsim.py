"""Network cost model + accounting for the in-process cluster simulation.

The paper evaluates MemEC on a Gigabit LAN (125 MB/s, sub-ms RTT) and
simulates transient failures with tc-netem (normal(2ms, 1ms) delay per
packet).  The simulation executes requests in-process and *models* time:

    leg(bytes)           = rtt + bytes / bw + proc          (one message)
    phase(parallel legs) = max(leg costs)                    (fan-out)
    request latency      = sum of its phases

Two outputs feed the benchmarks:
* latency — per-request modeled time (sum of phases);
* throughput — bottleneck-based: the busiest endpoint's byte traffic
  divided by link bandwidth bounds aggregate ops/s (this is what actually
  limits the paper's Gigabit testbed, e.g. the (n-k+1)-way SET fan-out).

Coding cost (PR 4): ``CostModel.coding_s`` converts a ``CodingEngine``
work-bytes figure into modeled seconds (GF(2^8) table-lookup throughput
plus a fixed per-call dispatch).  The synchronous store adds it serially
to the request phases; the async pipeline (``async_engine=True``) merges
it as ``max(coding, network)`` per phase — the overlap the paper hides
coding behind.

Engine queue (PR 5): concurrent engine calls submitted in one overlapped
phase (e.g. per-parity seal folds) contend for ``CostModel.engine_depth``
execution lanes.  The phase's coding duration is ``engine_makespan`` —
a depth-limited LPT schedule that degenerates to ``max`` at the default
infinite depth — so ``max(coding, network)`` is a queue-aware merge and
``stats["engine_queue_wait_s"]`` exposes the bound on hiding.

Concurrent lanes: ``merge_lanes`` models independent request pipelines
(e.g. per-proxy sub-batches of one multi-key request) running at the
same time.  Lanes overlap freely, but a server appearing in several
lanes serializes its own legs — the merged duration is
``max(slowest lane, busiest shared endpoint)``, clamped by the fully
serial sum.  Per-endpoint busy time is tracked in ``time_by_endpoint``
(snapshot/diff via ``busy_snapshot``).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass
class Leg:
    kind: str
    nbytes: int
    src: str = ""
    dst: str = ""
    to_failed: bool = False


@dataclasses.dataclass
class CostModel:
    rtt_s: float = 0.0002          # LAN round-trip
    bw_Bps: float = 125e6          # Gigabit
    proc_s: float = 2e-6           # per-message processing
    failed_delay_s: float = 0.002  # injected delay to a congested server
    header_bytes: int = 24         # protocol header per message
    # GF(2^8) coding throughput of one server core (table-lookup mults;
    # the paper's servers run coding on CPU) + fixed per-engine-call
    # dispatch.  Consumed via `coding_s` with a CodingEngine work-bytes
    # figure; shrink `coding_Bps` to model a coding-bound deployment.
    coding_Bps: float = 2.5e9
    coding_fixed_s: float = 2e-6
    # concurrent-call capacity of one shard's coding engine: engine
    # calls submitted within one overlapped phase contend for this many
    # execution lanes.  inf (default) is the historical no-contention
    # assumption — every modeled latency is unchanged at depth=inf;
    # finite depths bound how much coding the pipeline can hide and
    # surface the extra wait as stats["engine_queue_wait_s"].
    engine_depth: float = float("inf")

    def leg(self, payload_bytes: int, to_failed: bool = False) -> float:
        t = self.rtt_s + (payload_bytes + self.header_bytes) / self.bw_Bps + self.proc_s
        if to_failed:
            t += self.failed_delay_s
        return t

    def coding_s(self, work_bytes: float, calls: int = 1) -> float:
        """Modeled duration of a batched coding-engine call."""
        if work_bytes <= 0 and calls <= 0:
            return 0.0
        return calls * self.coding_fixed_s + work_bytes / self.coding_Bps

    def engine_makespan(self, durations) -> float:
        """Completion time of engine calls submitted concurrently.

        Longest-processing-time greedy onto ``engine_depth`` lanes —
        deterministic and within 4/3 of optimal.  At the default
        ``inf`` depth (or when the calls fit the lanes) this is just
        ``max(durations)``, the historical infinite-concurrency merge.
        """
        ds = sorted((d for d in durations if d > 0), reverse=True)
        if not ds:
            return 0.0
        depth = self.engine_depth
        if depth == float("inf") or len(ds) <= depth:
            return ds[0]
        lanes = [0.0] * max(1, int(depth))
        for d in ds:
            i = min(range(len(lanes)), key=lanes.__getitem__)
            lanes[i] += d
        return max(lanes)


class NetSim:
    """Accumulates modeled time and byte counters."""

    def __init__(self, cost: CostModel | None = None):
        self.cost = cost or CostModel()
        self.bytes_by_kind: dict[str, int] = defaultdict(int)
        self.msgs_by_kind: dict[str, int] = defaultdict(int)
        self.bytes_by_endpoint: dict[str, int] = defaultdict(int)
        # modeled link-occupancy seconds (wire bytes over bandwidth) per
        # endpoint — the per-server serialization floor for concurrent
        # lanes.  Occupancy only: RTT/processing pipeline across legs, so
        # they don't serialize; draining bytes through one NIC does.
        self.time_by_endpoint: dict[str, float] = defaultdict(float)
        self.latencies: dict[str, list[float]] = defaultdict(list)
        self.ops_by_kind: dict[str, int] = defaultdict(int)
        # monotonic sum of every recorded request latency; lets callers
        # (e.g. the sharded facade) take O(1) before/after snapshots of
        # modeled time spent inside a call
        self.total_recorded_s = 0.0

    # -- request construction ------------------------------------------
    def _account_leg(self, leg: Leg) -> float:
        """Byte/message/occupancy accounting shared by every phase
        flavor; returns the leg's modeled cost."""
        wire = leg.nbytes + self.cost.header_bytes
        self.bytes_by_kind[leg.kind] += wire
        self.msgs_by_kind[leg.kind] += 1
        occupancy = wire / self.cost.bw_Bps
        if leg.src:
            self.bytes_by_endpoint[leg.src] += wire
            self.time_by_endpoint[leg.src] += occupancy
        if leg.dst:
            self.bytes_by_endpoint[leg.dst] += wire
            self.time_by_endpoint[leg.dst] += occupancy
        return self.cost.leg(leg.nbytes, leg.to_failed)

    def phase(self, legs: list[Leg]) -> float:
        worst = 0.0
        for leg in legs:
            worst = max(worst, self._account_leg(leg))
        return worst

    def serialized_phase(self, legs: list[Leg]) -> float:
        """Bulk-transfer phase: each destination drains its inbound legs
        sequentially (link-limited), destinations proceed in parallel —
        max over dst of sum(leg costs).  Use where volume, not a single
        RTT, dominates (e.g. batched recovery); `phase` would report the
        max single leg regardless of how much data moves."""
        per_dst: dict[str, float] = defaultdict(float)
        for leg in legs:
            per_dst[leg.dst] += self._account_leg(leg)
        return max(per_dst.values()) if per_dst else 0.0

    # -- concurrent lanes (cross-proxy pipelining) ----------------------
    def busy_snapshot(self) -> dict[str, float]:
        """Copy of per-endpoint busy seconds; diff two snapshots around a
        lane's execution to get that lane's endpoint occupancy."""
        return dict(self.time_by_endpoint)

    @staticmethod
    def busy_delta(before: dict[str, float],
                   after: dict[str, float]) -> dict[str, float]:
        return {ep: t - before.get(ep, 0.0) for ep, t in after.items()
                if t - before.get(ep, 0.0) > 0.0}

    @staticmethod
    def merge_lanes(lane_durations: list[float],
                    lane_busys: list[dict[str, float]]) -> float:
        """Merged duration of concurrently executing lanes.

        Lanes overlap freely (independent proxies driving disjoint
        sub-batches), but any endpoint shared by several lanes serializes
        its own legs: the merged time is the slowest lane or the busiest
        endpoint's total occupancy, whichever is larger — and never worse
        than running the lanes back to back."""
        if not lane_durations:
            return 0.0
        serial = sum(lane_durations)
        busy: dict[str, float] = defaultdict(float)
        for b in lane_busys:
            for ep, t in b.items():
                busy[ep] += t
        floor = max(busy.values(), default=0.0)
        return min(serial, max(max(lane_durations), floor))

    def record(self, req_kind: str, latency_s: float):
        self.latencies[req_kind].append(latency_s)
        self.ops_by_kind[req_kind] += 1
        self.total_recorded_s += latency_s

    # -- reporting -------------------------------------------------------
    def percentile(self, req_kind: str, q: float) -> float:
        import numpy as np
        xs = self.latencies.get(req_kind, [])
        if not xs:
            return float("nan")
        return float(np.percentile(xs, q))

    def mean(self, req_kind: str) -> float:
        xs = self.latencies.get(req_kind, [])
        return sum(xs) / len(xs) if xs else float("nan")

    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def bottleneck_throughput(self, total_ops: int, endpoints: list[str] | None = None) -> float:
        """ops/s bound by the busiest endpoint's traffic over link bw
        (pessimistic under Zipf hot keys — see mean_throughput)."""
        pool = (self.bytes_by_endpoint if endpoints is None
                else {e: self.bytes_by_endpoint.get(e, 0) for e in endpoints})
        if not pool or total_ops == 0:
            return float("nan")
        worst = max(pool.values())
        if worst == 0:
            return float("inf")
        return total_ops / (worst / self.cost.bw_Bps)

    def mean_throughput(self, total_ops: int, endpoints: list[str] | None = None) -> float:
        """ops/s bound by aggregate endpoint traffic over aggregate bw —
        models a cluster that load-balances over time (the paper's long
        YCSB runs smooth Zipf hot spots across 20M requests)."""
        pool = (self.bytes_by_endpoint if endpoints is None
                else {e: self.bytes_by_endpoint.get(e, 0) for e in endpoints})
        if not pool or total_ops == 0:
            return float("nan")
        total = sum(pool.values())
        if total == 0:
            return float("inf")
        return total_ops / (total / (len(pool) * self.cost.bw_Bps))

    def reset(self):
        self.bytes_by_kind.clear()
        self.msgs_by_kind.clear()
        self.bytes_by_endpoint.clear()
        self.time_by_endpoint.clear()
        self.latencies.clear()
        self.ops_by_kind.clear()

    def snapshot(self) -> dict:
        return {
            "bytes_by_kind": dict(self.bytes_by_kind),
            "msgs_by_kind": dict(self.msgs_by_kind),
            "bytes_by_endpoint": dict(self.bytes_by_endpoint),
        }
