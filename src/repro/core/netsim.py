"""Network cost model + accounting for the in-process cluster simulation.

The paper evaluates MemEC on a Gigabit LAN (125 MB/s, sub-ms RTT) and
simulates transient failures with tc-netem (normal(2ms, 1ms) delay per
packet).  The simulation executes requests in-process and *models* time:

    leg(bytes)           = rtt + bytes / bw + proc          (one message)
    phase(parallel legs) = max(leg costs)                    (fan-out)
    request latency      = sum of its phases

Two outputs feed the benchmarks:
* latency — per-request modeled time (sum of phases);
* throughput — bottleneck-based: the busiest endpoint's byte traffic
  divided by link bandwidth bounds aggregate ops/s (this is what actually
  limits the paper's Gigabit testbed, e.g. the (n-k+1)-way SET fan-out).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass
class Leg:
    kind: str
    nbytes: int
    src: str = ""
    dst: str = ""
    to_failed: bool = False


@dataclasses.dataclass
class CostModel:
    rtt_s: float = 0.0002          # LAN round-trip
    bw_Bps: float = 125e6          # Gigabit
    proc_s: float = 2e-6           # per-message processing
    failed_delay_s: float = 0.002  # injected delay to a congested server
    header_bytes: int = 24         # protocol header per message

    def leg(self, payload_bytes: int, to_failed: bool = False) -> float:
        t = self.rtt_s + (payload_bytes + self.header_bytes) / self.bw_Bps + self.proc_s
        if to_failed:
            t += self.failed_delay_s
        return t


class NetSim:
    """Accumulates modeled time and byte counters."""

    def __init__(self, cost: CostModel | None = None):
        self.cost = cost or CostModel()
        self.bytes_by_kind: dict[str, int] = defaultdict(int)
        self.msgs_by_kind: dict[str, int] = defaultdict(int)
        self.bytes_by_endpoint: dict[str, int] = defaultdict(int)
        self.latencies: dict[str, list[float]] = defaultdict(list)
        self.ops_by_kind: dict[str, int] = defaultdict(int)
        # monotonic sum of every recorded request latency; lets callers
        # (e.g. the sharded facade) take O(1) before/after snapshots of
        # modeled time spent inside a call
        self.total_recorded_s = 0.0

    # -- request construction ------------------------------------------
    def phase(self, legs: list[Leg]) -> float:
        worst = 0.0
        for leg in legs:
            wire = leg.nbytes + self.cost.header_bytes
            self.bytes_by_kind[leg.kind] += wire
            self.msgs_by_kind[leg.kind] += 1
            if leg.src:
                self.bytes_by_endpoint[leg.src] += wire
            if leg.dst:
                self.bytes_by_endpoint[leg.dst] += wire
            worst = max(worst, self.cost.leg(leg.nbytes, leg.to_failed))
        return worst

    def serialized_phase(self, legs: list[Leg]) -> float:
        """Bulk-transfer phase: each destination drains its inbound legs
        sequentially (link-limited), destinations proceed in parallel —
        max over dst of sum(leg costs).  Use where volume, not a single
        RTT, dominates (e.g. batched recovery); `phase` would report the
        max single leg regardless of how much data moves."""
        per_dst: dict[str, float] = defaultdict(float)
        for leg in legs:
            wire = leg.nbytes + self.cost.header_bytes
            self.bytes_by_kind[leg.kind] += wire
            self.msgs_by_kind[leg.kind] += 1
            if leg.src:
                self.bytes_by_endpoint[leg.src] += wire
            if leg.dst:
                self.bytes_by_endpoint[leg.dst] += wire
            per_dst[leg.dst] += self.cost.leg(leg.nbytes, leg.to_failed)
        return max(per_dst.values()) if per_dst else 0.0

    def record(self, req_kind: str, latency_s: float):
        self.latencies[req_kind].append(latency_s)
        self.ops_by_kind[req_kind] += 1
        self.total_recorded_s += latency_s

    # -- reporting -------------------------------------------------------
    def percentile(self, req_kind: str, q: float) -> float:
        import numpy as np
        xs = self.latencies.get(req_kind, [])
        if not xs:
            return float("nan")
        return float(np.percentile(xs, q))

    def mean(self, req_kind: str) -> float:
        xs = self.latencies.get(req_kind, [])
        return sum(xs) / len(xs) if xs else float("nan")

    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def bottleneck_throughput(self, total_ops: int, endpoints: list[str] | None = None) -> float:
        """ops/s bound by the busiest endpoint's traffic over link bw
        (pessimistic under Zipf hot keys — see mean_throughput)."""
        pool = (self.bytes_by_endpoint if endpoints is None
                else {e: self.bytes_by_endpoint.get(e, 0) for e in endpoints})
        if not pool or total_ops == 0:
            return float("nan")
        worst = max(pool.values())
        if worst == 0:
            return float("inf")
        return total_ops / (worst / self.cost.bw_Bps)

    def mean_throughput(self, total_ops: int, endpoints: list[str] | None = None) -> float:
        """ops/s bound by aggregate endpoint traffic over aggregate bw —
        models a cluster that load-balances over time (the paper's long
        YCSB runs smooth Zipf hot spots across 20M requests)."""
        pool = (self.bytes_by_endpoint if endpoints is None
                else {e: self.bytes_by_endpoint.get(e, 0) for e in endpoints})
        if not pool or total_ops == 0:
            return float("nan")
        total = sum(pool.values())
        if total == 0:
            return float("inf")
        return total_ops / (total / (len(pool) * self.cost.bw_Bps))

    def reset(self):
        self.bytes_by_kind.clear()
        self.msgs_by_kind.clear()
        self.bytes_by_endpoint.clear()
        self.latencies.clear()
        self.ops_by_kind.clear()

    def snapshot(self) -> dict:
        return {
            "bytes_by_kind": dict(self.bytes_by_kind),
            "msgs_by_kind": dict(self.msgs_by_kind),
            "bytes_by_endpoint": dict(self.bytes_by_endpoint),
        }
