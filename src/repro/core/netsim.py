"""Network cost model + accounting for the in-process cluster simulation.

The paper evaluates MemEC on a Gigabit LAN (125 MB/s, sub-ms RTT) and
simulates transient failures with tc-netem (normal(2ms, 1ms) delay per
packet).  The simulation executes requests in-process and *models* time:

    leg(bytes)           = rtt + bytes / bw + proc          (one message)
    phase(parallel legs) = max(leg costs)                    (fan-out)
    request latency      = sum of its phases

Two outputs feed the benchmarks:
* latency — per-request modeled time (sum of phases);
* throughput — bottleneck-based: the busiest endpoint's byte traffic
  divided by link bandwidth bounds aggregate ops/s (this is what actually
  limits the paper's Gigabit testbed, e.g. the (n-k+1)-way SET fan-out).

Coding cost (PR 4): ``CostModel.coding_s`` converts a ``CodingEngine``
work-bytes figure into modeled seconds (GF(2^8) table-lookup throughput
plus a fixed per-call dispatch).  The synchronous store adds it serially
to the request phases; the async pipeline (``async_engine=True``) merges
it as ``max(coding, network)`` per phase — the overlap the paper hides
coding behind.

Engine queue (PR 5): concurrent engine calls submitted in one overlapped
phase (e.g. per-parity seal folds) contend for ``CostModel.engine_depth``
execution lanes.  The phase's coding duration is ``engine_makespan`` —
a depth-limited LPT schedule that degenerates to ``max`` at the default
infinite depth — so ``max(coding, network)`` is a queue-aware merge and
``stats["engine_queue_wait_s"]`` exposes the bound on hiding.

Concurrent lanes: ``merge_lanes`` models independent request pipelines
(e.g. per-proxy sub-batches of one multi-key request) running at the
same time.  Lanes overlap freely, but a server appearing in several
lanes serializes its own legs — the merged duration is
``max(slowest lane, busiest shared endpoint)``, clamped by the fully
serial sum.  Per-endpoint busy time is tracked in ``time_by_endpoint``
(snapshot/diff via ``busy_snapshot``).

Event runtime (PR 7): the phase algebra above prices one request in
isolation — a busy engine never delays the *next* request.  With an
open-loop ``ArrivalProcess`` (``arrival=`` / ``$MEMEC_ARRIVAL``:
``poisson:RATE`` / ``uniform:RATE`` / ``trace:T0,T1,...``), every
recorded request additionally becomes a discrete event in an
``EventRuntime``: arrival drawn from the process, start gated FCFS on
admission slots (``inflight`` client contexts), per-endpoint link
occupancy clocks (``time_by_endpoint`` deltas) and
``CostModel.engine_depth`` coding lanes, completion = start + service.
Recorded latency then includes queue wait, so ``p50/p99/p999`` per
request kind reflect contention; the pure phase-algebra service times
stay available in ``NetSim.service``.  The default ``closed`` process
keeps the historical numbers bit-identical (no event machinery at all),
and ``inflight=1`` with rate→inf degenerates back to the serial
closed-loop totals (property-tested in tests/test_event_runtime.py).
"""
from __future__ import annotations

import dataclasses
import os
from collections import defaultdict


@dataclasses.dataclass
class Leg:
    kind: str
    nbytes: int
    src: str = ""
    dst: str = ""
    to_failed: bool = False


@dataclasses.dataclass
class CostModel:
    rtt_s: float = 0.0002          # LAN round-trip
    bw_Bps: float = 125e6          # Gigabit
    proc_s: float = 2e-6           # per-message processing
    failed_delay_s: float = 0.002  # injected delay to a congested server
    header_bytes: int = 24         # protocol header per message
    # GF(2^8) coding throughput of one server core (table-lookup mults;
    # the paper's servers run coding on CPU) + fixed per-engine-call
    # dispatch.  Consumed via `coding_s` with a CodingEngine work-bytes
    # figure; shrink `coding_Bps` to model a coding-bound deployment.
    coding_Bps: float = 2.5e9
    coding_fixed_s: float = 2e-6
    # concurrent-call capacity of one shard's coding engine: engine
    # calls submitted within one overlapped phase contend for this many
    # execution lanes.  inf (default) is the historical no-contention
    # assumption — every modeled latency is unchanged at depth=inf;
    # finite depths bound how much coding the pipeline can hide and
    # surface the extra wait as stats["engine_queue_wait_s"].
    engine_depth: float = float("inf")

    def leg(self, payload_bytes: int, to_failed: bool = False) -> float:
        t = self.rtt_s + (payload_bytes + self.header_bytes) / self.bw_Bps + self.proc_s
        if to_failed:
            t += self.failed_delay_s
        return t

    def coding_s(self, work_bytes: float, calls: int = 1) -> float:
        """Modeled duration of a batched coding-engine call."""
        if work_bytes <= 0 and calls <= 0:
            return 0.0
        return calls * self.coding_fixed_s + work_bytes / self.coding_Bps

    def engine_makespan(self, durations) -> float:
        """Completion time of engine calls submitted concurrently.

        Longest-processing-time greedy onto ``engine_depth`` lanes —
        deterministic and within 4/3 of optimal.  At the default
        ``inf`` depth (or when the calls fit the lanes) this is just
        ``max(durations)``, the historical infinite-concurrency merge.
        """
        ds = sorted((d for d in durations if d > 0), reverse=True)
        if not ds:
            return 0.0
        depth = self.engine_depth
        if depth == float("inf") or len(ds) <= depth:
            return ds[0]
        lanes = [0.0] * max(1, int(depth))
        for d in ds:
            i = min(range(len(lanes)), key=lanes.__getitem__)
            lanes[i] += d
        return max(lanes)


class LatencyRecorder:
    """Single source of truth for latency aggregation.

    Both the unsharded ``NetSim`` and the sharded facade report from one
    of these, so percentile/mean formulas cannot diverge between paths
    (they used to be copy-pasted into ``core/shard.py``).
    ``total_recorded_s`` is monotonic — it survives ``clear()`` so
    callers can take O(1) before/after snapshots of modeled time.
    """

    PERCENTILES = ((50.0, "p50_s"), (99.0, "p99_s"), (99.9, "p999_s"))

    def __init__(self):
        self.latencies: dict[str, list[float]] = defaultdict(list)
        self.ops_by_kind: dict[str, int] = defaultdict(int)
        self.total_recorded_s = 0.0

    def record(self, kind: str, latency_s: float):
        self.latencies[kind].append(latency_s)
        self.ops_by_kind[kind] += 1
        self.total_recorded_s += latency_s

    @staticmethod
    def percentile_of(xs, q: float) -> float:
        import numpy as np
        if not xs:
            return float("nan")
        return float(np.percentile(xs, q))

    @staticmethod
    def mean_of(xs) -> float:
        return sum(xs) / len(xs) if xs else float("nan")

    def percentile(self, kind: str, q: float) -> float:
        return self.percentile_of(self.latencies.get(kind, []), q)

    def mean(self, kind: str) -> float:
        return self.mean_of(self.latencies.get(kind, []))

    @classmethod
    def summary_of(cls, xs) -> dict:
        out = {"count": len(xs), "mean_s": cls.mean_of(xs)}
        for q, name in cls.PERCENTILES:
            out[name] = cls.percentile_of(xs, q)
        return out

    def summary(self) -> dict:
        """``{kind: {count, mean_s, p50_s, p99_s, p999_s}}``."""
        return {k: self.summary_of(xs)
                for k, xs in sorted(self.latencies.items())}

    def clear(self):
        self.latencies.clear()
        self.ops_by_kind.clear()


class ArrivalProcess:
    """Open-loop arrival-time generator for the event runtime.

    Specs (``arrival=`` ctor arg, else ``$MEMEC_ARRIVAL``, else closed):

    * ``closed`` — the historical closed loop: the next request is
      issued when the previous completes.  No event machinery runs.
    * ``poisson:RATE`` — seeded exponential inter-arrival gaps at RATE
      req/s (``inf`` → zero gaps, i.e. everything arrives at t=0).
    * ``uniform:RATE`` — deterministic 1/RATE gaps.
    * ``trace:T0,T1,...`` — explicit arrival times in seconds; the gap
      pattern cycles if the workload outruns the trace.

    Extra ``:key=val`` fields: ``seed=N`` (poisson rng),
    ``inflight=K`` (concurrent client contexts admitted by the
    EventRuntime; default 1 matches the sequential closed-loop driver).
    """

    def __init__(self, kind: str = "closed", rate: float | None = None,
                 seed: int = 0, inflight: int = 1,
                 trace: list[float] | None = None):
        if kind not in ("closed", "poisson", "uniform", "trace"):
            raise ValueError(f"unknown arrival kind: {kind!r}")
        self.kind = kind
        self.rate = rate
        self.seed = int(seed)
        self.inflight = max(1, int(inflight))
        self.trace = list(trace or [])
        if kind in ("poisson", "uniform") and not (rate and rate > 0):
            raise ValueError(f"{kind} arrival needs a positive rate")
        if kind == "trace" and not self.trace:
            raise ValueError("trace arrival needs at least one time")
        self.reset()

    @classmethod
    def parse(cls, spec: str) -> "ArrivalProcess":
        parts = [p for p in str(spec).strip().split(":") if p != ""]
        if not parts:
            return cls("closed")
        kind, args = parts[0].lower(), parts[1:]
        kw: dict = {}
        for a in args:
            if "=" in a:
                key, val = a.split("=", 1)
                if key == "seed":
                    kw["seed"] = int(val)
                elif key == "inflight":
                    kw["inflight"] = int(val)
                else:
                    raise ValueError(f"unknown arrival option: {a!r}")
            elif kind == "trace":
                if a.startswith("@"):
                    # trace:@capture.json — a TraceCapture file
                    import json
                    with open(a[1:]) as f:
                        doc = json.load(f)
                    kw["trace"] = [float(t) for t in doc["arrivals"]]
                    kw.setdefault("inflight", int(doc.get("inflight", 1)))
                else:
                    kw["trace"] = [float(t) for t in a.split(",")]
            else:
                kw["rate"] = float(a)
        return cls(kind, **kw)

    @property
    def open_loop(self) -> bool:
        return self.kind != "closed"

    def reset(self):
        import numpy as np
        self._t = 0.0
        self._rng = np.random.default_rng(self.seed)
        self._trace_i = 0
        if self.kind == "trace":
            ts = self.trace
            self._gaps = [ts[0]] + [b - a for a, b in zip(ts, ts[1:])]

    def next_arrival(self) -> float:
        """Absolute arrival time of the next request (monotonic)."""
        if self.kind == "poisson":
            gap = 0.0 if self.rate == float("inf") else \
                float(self._rng.exponential(1.0 / self.rate))
        elif self.kind == "uniform":
            gap = 0.0 if self.rate == float("inf") else 1.0 / self.rate
        elif self.kind == "trace":
            gap = self._gaps[self._trace_i % len(self._gaps)]
            self._trace_i += 1
        else:  # closed — never driven through the event runtime
            gap = 0.0
        self._t = max(0.0, self._t + gap)
        return self._t

    def describe(self) -> dict:
        d = {"kind": self.kind, "inflight": self.inflight}
        if self.rate is not None:
            d["rate"] = self.rate
        if self.kind == "poisson":
            d["seed"] = self.seed
        if self.kind == "trace":
            d["trace_len"] = len(self.trace)
        return d


def resolve_arrival(arrival=None, env: str = "MEMEC_ARRIVAL") -> ArrivalProcess:
    """Ctor arg wins; else ``$MEMEC_ARRIVAL``; else the closed loop."""
    if isinstance(arrival, ArrivalProcess):
        return arrival
    if arrival is None:
        arrival = os.environ.get(env) or "closed"
    return ArrivalProcess.parse(arrival)


class EventRuntime:
    """Discrete-event scheduling overlay over eager request execution.

    Requests still *execute* eagerly in program order — what the runtime
    replays is time.  Each recorded request becomes one event chain:

        arrival    — drawn from the open-loop ArrivalProcess
        start      — max(arrival, FCFS resource clocks)
        completion — start + service   (service = phase-algebra latency)

    Resources, each a ``free_at`` clock:

    * admission slots: ``arrival.inflight`` concurrent client contexts.
      ``inflight=1`` is the sequential closed-loop driver — at rate→inf
      it reproduces the serial phase-algebra totals (makespan ==
      sum(service) up to link-occupancy overhang).
    * per-endpoint links: held for the request's ``time_by_endpoint``
      occupancy delta — two admitted requests hammering the same server
      NIC serialize there.
    * coding-engine lanes: ``CostModel.engine_depth`` lanes held for the
      request's modeled coding seconds (``NetSim.note_coding``) — a busy
      engine delays the next request's submit.  Infinite depth keeps the
      historical no-contention assumption.

    Queue wait = start − arrival, with a per-resource breakdown
    (clipped maxima, not additive — waits overlap).
    """

    RESOURCES = ("admission", "endpoint", "engine")

    def __init__(self, cost: CostModel, arrival: ArrivalProcess):
        self.cost = cost
        self.arrival = arrival
        self.slots = [0.0] * arrival.inflight
        self.link_free: dict[str, float] = defaultdict(float)
        depth = cost.engine_depth
        self.engine_lanes = ([] if depth == float("inf")
                             else [0.0] * max(1, int(depth)))
        self.waits = LatencyRecorder()
        self.wait_s_by_resource: dict[str, float] = dict.fromkeys(
            self.RESOURCES, 0.0)
        # (seq, kind, arrival, start, completion) — determinism probe
        self.events: list[tuple] = []
        self.makespan_s = 0.0
        self.offered = 0

    def engine_ready_at(self) -> float:
        """When the earliest coding lane frees up (0.0 = idle/unbounded);
        the scatter/gather planner uses this to prefer idle engines."""
        return min(self.engine_lanes) if self.engine_lanes else 0.0

    def submit(self, kind: str, service_s: float,
               busy: dict[str, float] | None = None,
               engine_s: float = 0.0,
               detail_out: dict | None = None,
               optional: dict[str, float] | None = None) -> float:
        """Schedule one request; returns its latency incl. queue wait.

        ``optional`` maps endpoint -> occupancy seconds the request put
        on the wire but did NOT wait for (redundant race legs that lost
        the k-th-arrival race).  An endpoint whose demand is entirely
        optional doesn't gate this request's start and contributes no
        endpoint queue-wait attribution — but its link clock still
        advances by the full occupancy, so *subsequent* requests queue
        behind the dropped traffic (the bytes are real).

        ``detail_out`` (tracing only): filled in place with the event's
        arrival/start/completion and per-resource ready times, plus the
        occupying endpoint (the busiest link clock among the request's
        endpoints) and the engine lane taken.
        """
        arrival = self.arrival.next_arrival()
        slot = min(range(len(self.slots)), key=self.slots.__getitem__)
        admit_ready = self.slots[slot]
        busy = busy or {}
        optional = optional or {}
        # endpoints the request actually waited on: any with demand
        # beyond what its own dropped race legs put there
        gating = [ep for ep, occ in busy.items()
                  if occ - optional.get(ep, 0.0) > 1e-18]
        link_ready = max((self.link_free[ep] for ep in gating), default=0.0)
        lane = -1
        engine_ready = 0.0
        if engine_s > 0.0 and self.engine_lanes:
            lane = min(range(len(self.engine_lanes)),
                       key=self.engine_lanes.__getitem__)
            engine_ready = self.engine_lanes[lane]
        start = max(arrival, admit_ready, link_ready, engine_ready)
        if detail_out is not None:
            endpoint = (max(gating, key=lambda ep: self.link_free[ep])
                        if gating else "")
            detail_out.update(arrival=arrival, start=start,
                              completion=start + service_s,
                              admit_ready=admit_ready,
                              link_ready=link_ready,
                              engine_ready=engine_ready,
                              endpoint=endpoint, lane=lane)
        completion = start + service_s
        self.slots[slot] = completion
        for ep, occ in busy.items():
            # gating endpoints have link_free <= start (they set
            # link_ready), so this is start + occ as before; a purely
            # optional endpoint may still be draining earlier traffic,
            # and its dropped bytes append behind that queue instead of
            # rewinding the clock
            self.link_free[ep] = max(self.link_free[ep], start) + occ
        if lane >= 0:
            self.engine_lanes[lane] = start + engine_s
        wait = start - arrival
        self.waits.record(kind, wait)
        self.wait_s_by_resource["admission"] += min(
            wait, max(0.0, admit_ready - arrival))
        self.wait_s_by_resource["endpoint"] += min(
            wait, max(0.0, link_ready - arrival))
        self.wait_s_by_resource["engine"] += min(
            wait, max(0.0, engine_ready - arrival))
        self.events.append((self.offered, kind, arrival, start, completion))
        self.offered += 1
        self.makespan_s = max(self.makespan_s, completion)
        return completion - arrival

    def snapshot(self) -> dict:
        return {
            "arrival": self.arrival.describe(),
            "offered": self.offered,
            "makespan_s": self.makespan_s,
            "queue_wait_s": self.waits.total_recorded_s,
            "queue_wait_s_by_kind": {
                k: sum(xs) for k, xs in sorted(self.waits.latencies.items())},
            "queue_wait_s_by_resource": dict(self.wait_s_by_resource),
        }


class NetSim:
    """Accumulates modeled time and byte counters."""

    def __init__(self, cost: CostModel | None = None, arrival=None,
                 trace=None):
        from .trace import resolve_trace
        self.cost = cost or CostModel()
        # per-request span tracer (None when off — the zero-cost default)
        self.tracer = resolve_trace(trace)
        self.bytes_by_kind: dict[str, int] = defaultdict(int)
        self.msgs_by_kind: dict[str, int] = defaultdict(int)
        self.bytes_by_endpoint: dict[str, int] = defaultdict(int)
        # modeled link-occupancy seconds (wire bytes over bandwidth) per
        # endpoint — the per-server serialization floor for concurrent
        # lanes.  Occupancy only: RTT/processing pipeline across legs, so
        # they don't serialize; draining bytes through one NIC does.
        self.time_by_endpoint: dict[str, float] = defaultdict(float)
        # recorded request latencies (incl. queue wait in event mode);
        # `latencies`/`ops_by_kind` alias the recorder's dicts so legacy
        # readers keep working, and `total_recorded_s` (monotonic sum,
        # survives reset) is a property over the recorder
        self.recorder = LatencyRecorder()
        self.latencies = self.recorder.latencies
        self.ops_by_kind = self.recorder.ops_by_kind
        # pure phase-algebra service times (== recorder in closed mode;
        # in event mode the queue-free component of each latency)
        self.service = LatencyRecorder()
        self.arrival = resolve_arrival(arrival)
        self.events = (EventRuntime(self.cost, self.arrival)
                       if self.arrival.open_loop else None)
        self._event_busy_mark: dict[str, float] = {}
        self._pending_coding_s = 0.0
        # slow-server injection: endpoint -> latency/occupancy multiplier
        # (the straggler axis — a server that is slow, not failed).
        # Persists across reset(), like injected failures do.
        self.inflation: dict[str, float] = {}
        # occupancy put on the wire by race legs that lost the
        # k-of-(k+Δ) race since the last record() — the request did not
        # wait for it, so the event runtime must not gate on it
        self._pending_optional: dict[str, float] = defaultdict(float)

    @property
    def total_recorded_s(self) -> float:
        return self.recorder.total_recorded_s

    # -- slow-server injection (straggler axis) -------------------------
    def inflate(self, endpoint: str, factor: float):
        """Latency-inflate one endpoint by ``factor`` (e.g. 10.0 = a
        server answering 10x slower).  Every leg touching the endpoint
        has both its modeled cost and its link occupancy multiplied —
        a straggler is slow on the wire, not just far away.  ``factor
        == 1.0`` removes the injection; the axis survives ``reset()``
        (like injected failures) so a measurement window keeps it."""
        if not (factor > 0.0):
            raise ValueError(f"inflate factor must be > 0, got {factor!r}")
        if factor == 1.0:
            self.inflation.pop(endpoint, None)
        else:
            self.inflation[endpoint] = float(factor)

    def _inflation_of(self, leg: Leg) -> float:
        if not self.inflation:
            return 1.0
        return max(self.inflation.get(leg.src, 1.0),
                   self.inflation.get(leg.dst, 1.0))

    # -- request construction ------------------------------------------
    def _account_leg(self, leg: Leg) -> float:
        """Byte/message/occupancy accounting shared by every phase
        flavor; returns the leg's modeled cost."""
        wire = leg.nbytes + self.cost.header_bytes
        self.bytes_by_kind[leg.kind] += wire
        self.msgs_by_kind[leg.kind] += 1
        factor = self._inflation_of(leg)
        occupancy = wire / self.cost.bw_Bps * factor
        if leg.src:
            self.bytes_by_endpoint[leg.src] += wire
            self.time_by_endpoint[leg.src] += occupancy
        if leg.dst:
            self.bytes_by_endpoint[leg.dst] += wire
            self.time_by_endpoint[leg.dst] += occupancy
        return self.cost.leg(leg.nbytes, leg.to_failed) * factor

    def phase(self, legs: list[Leg]) -> float:
        if self.tracer is None:
            worst = 0.0
            for leg in legs:
                worst = max(worst, self._account_leg(leg))
            return worst
        pairs = [(leg, self._account_leg(leg)) for leg in legs]
        worst = max((c for _, c in pairs), default=0.0)
        self.tracer.phase(worst, pairs)
        return worst

    def race_phase(self, groups: list[tuple[str, list[Leg]]],
                   need: int) -> tuple[float, list[int], list[int]]:
        """k-of-(k+Δ) fan-out: complete at the ``need``-th arrival.

        Each group is one candidate responder's full round trip
        (request leg + response leg); its arrival time is the sum of its
        leg costs.  The phase completes when ``need`` groups have
        arrived — the slowest Δ are *dropped*: their bytes, messages and
        link occupancy are all accounted (redundant traffic is real and
        future requests queue behind it), but they do not contribute to
        this request's latency, and in event mode their occupancy is
        flagged optional so the EventRuntime doesn't gate on it.

        Returns ``(t, winner_idxs, dropped_idxs)`` with deterministic
        (cost, index) tie-breaking.  Identical ``t`` with tracing on or
        off.
        """
        need = min(need, len(groups))
        entries = []   # (cost, idx, label, legs)
        for idx, (label, legs) in enumerate(groups):
            cost = sum(self._account_leg(leg) for leg in legs)
            entries.append((cost, idx, label, legs))
        ranked = sorted(entries, key=lambda e: (e[0], e[1]))
        t = ranked[need - 1][0] if need > 0 else 0.0
        winners = sorted(idx for _, idx, _, _ in ranked[:need])
        dropped = sorted(idx for _, idx, _, _ in ranked[need:])
        for cost, idx, label, legs in ranked[need:]:
            for leg in legs:
                wire = leg.nbytes + self.cost.header_bytes
                occ = wire / self.cost.bw_Bps * self._inflation_of(leg)
                if leg.src:
                    self._pending_optional[leg.src] += occ
                if leg.dst:
                    self._pending_optional[leg.dst] += occ
        if self.tracer is not None:
            won = set(winners)
            self.tracer.race(
                t, [(label, cost, idx in won)
                    for cost, idx, label, _ in sorted(entries,
                                                      key=lambda e: e[1])])
        return t, winners, dropped

    def serialized_phase(self, legs: list[Leg]) -> float:
        """Bulk-transfer phase: each destination drains its inbound legs
        sequentially (link-limited), destinations proceed in parallel —
        max over dst of sum(leg costs).  Use where volume, not a single
        RTT, dominates (e.g. batched recovery); `phase` would report the
        max single leg regardless of how much data moves."""
        per_dst: dict[str, float] = defaultdict(float)
        if self.tracer is None:
            for leg in legs:
                per_dst[leg.dst] += self._account_leg(leg)
            return max(per_dst.values()) if per_dst else 0.0
        pairs = []
        for leg in legs:
            cost = self._account_leg(leg)
            per_dst[leg.dst] += cost
            pairs.append((leg, cost))
        worst = max(per_dst.values()) if per_dst else 0.0
        self.tracer.drain(worst, dict(per_dst), pairs)
        return worst

    # -- concurrent lanes (cross-proxy pipelining) ----------------------
    def busy_snapshot(self) -> dict[str, float]:
        """Copy of per-endpoint busy seconds; diff two snapshots around a
        lane's execution to get that lane's endpoint occupancy."""
        return dict(self.time_by_endpoint)

    @staticmethod
    def busy_delta(before: dict[str, float],
                   after: dict[str, float]) -> dict[str, float]:
        return {ep: t - before.get(ep, 0.0) for ep, t in after.items()
                if t - before.get(ep, 0.0) > 0.0}

    @staticmethod
    def merge_lanes(lane_durations: list[float],
                    lane_busys: list[dict[str, float]]) -> float:
        """Merged duration of concurrently executing lanes.

        Lanes overlap freely (independent proxies driving disjoint
        sub-batches), but any endpoint shared by several lanes serializes
        its own legs: the merged time is the slowest lane or the busiest
        endpoint's total occupancy, whichever is larger — and never worse
        than running the lanes back to back."""
        if not lane_durations:
            return 0.0
        serial = sum(lane_durations)
        busy: dict[str, float] = defaultdict(float)
        for b in lane_busys:
            for ep, t in b.items():
                busy[ep] += t
        floor = max(busy.values(), default=0.0)
        return min(serial, max(max(lane_durations), floor))

    def note_coding(self, coding_s: float):
        """Event-mode demand capture: modeled engine-busy seconds charged
        to the request currently executing (no-op in closed-loop mode —
        the phase algebra already merged them into the latency)."""
        if self.events is not None and coding_s > 0.0:
            self._pending_coding_s += coding_s

    def record(self, req_kind: str, latency_s: float) -> float:
        """Record one finished request.

        Closed loop: the phase-algebra latency is recorded verbatim (the
        historical numbers, bit-identical).  Open loop: the request is
        additionally submitted to the EventRuntime — its endpoint demand
        is the ``time_by_endpoint`` delta since the previous record, its
        engine demand the coding seconds noted via ``note_coding`` — and
        the recorded latency includes the FCFS queue wait."""
        if self.events is None:
            self._pending_optional.clear()
            if self.tracer is not None:
                self.tracer.finish(req_kind, latency_s)
            self.recorder.record(req_kind, latency_s)
            return latency_s
        busy = self.busy_delta(self._event_busy_mark, self.time_by_endpoint)
        self._event_busy_mark = self.busy_snapshot()
        engine_s, self._pending_coding_s = self._pending_coding_s, 0.0
        optional = (dict(self._pending_optional)
                    if self._pending_optional else None)
        self._pending_optional.clear()
        self.service.record(req_kind, latency_s)
        detail = {} if self.tracer is not None else None
        lat = self.events.submit(req_kind, latency_s, busy, engine_s,
                                 detail_out=detail, optional=optional)
        if self.tracer is not None:
            detail["service"] = latency_s
            self.tracer.finish(req_kind, lat, detail=detail)
        self.recorder.record(req_kind, lat)
        return lat

    # -- reporting -------------------------------------------------------
    def percentile(self, req_kind: str, q: float) -> float:
        return self.recorder.percentile(req_kind, q)

    def mean(self, req_kind: str) -> float:
        return self.recorder.mean(req_kind)

    def latency_summary(self) -> dict:
        """Per-kind count/mean/p50/p99/p999 plus, in event mode, the
        per-kind queue-wait share and the per-resource breakdown."""
        out = self.recorder.summary()
        if self.events is not None:
            for kind, s in out.items():
                ws = self.events.waits.latencies.get(kind, [])
                s["queue_wait_s"] = sum(ws)
                s["queue_wait_p99_s"] = LatencyRecorder.percentile_of(ws, 99.0)
        return out

    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def bottleneck_throughput(self, total_ops: int, endpoints: list[str] | None = None) -> float:
        """ops/s bound by the busiest endpoint's traffic over link bw
        (pessimistic under Zipf hot keys — see mean_throughput)."""
        pool = (self.bytes_by_endpoint if endpoints is None
                else {e: self.bytes_by_endpoint.get(e, 0) for e in endpoints})
        if not pool or total_ops == 0:
            return float("nan")
        worst = max(pool.values())
        if worst == 0:
            return float("inf")
        return total_ops / (worst / self.cost.bw_Bps)

    def mean_throughput(self, total_ops: int, endpoints: list[str] | None = None) -> float:
        """ops/s bound by aggregate endpoint traffic over aggregate bw —
        models a cluster that load-balances over time (the paper's long
        YCSB runs smooth Zipf hot spots across 20M requests)."""
        pool = (self.bytes_by_endpoint if endpoints is None
                else {e: self.bytes_by_endpoint.get(e, 0) for e in endpoints})
        if not pool or total_ops == 0:
            return float("nan")
        total = sum(pool.values())
        if total == 0:
            return float("inf")
        return total_ops / (total / (len(pool) * self.cost.bw_Bps))

    def reset(self):
        self.bytes_by_kind.clear()
        self.msgs_by_kind.clear()
        self.bytes_by_endpoint.clear()
        self.time_by_endpoint.clear()
        self.recorder.clear()
        self.service.clear()
        self._event_busy_mark = {}
        self._pending_coding_s = 0.0
        self._pending_optional.clear()
        if self.tracer is not None:
            self.tracer.reset()
        if self.events is not None:
            self.arrival.reset()
            self.events = EventRuntime(self.cost, self.arrival)

    def snapshot(self) -> dict:
        out = {
            "bytes_by_kind": dict(self.bytes_by_kind),
            "msgs_by_kind": dict(self.msgs_by_kind),
            "bytes_by_endpoint": dict(self.bytes_by_endpoint),
        }
        if self.events is not None:
            out["event"] = self.events.snapshot()
        return out
