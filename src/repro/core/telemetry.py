"""Structured telemetry snapshots for MemEC clusters.

One versioned dict schema for everything an external consumer (the
benchmark harness, ``BENCH_ci.json``, a future dashboard) needs to read
off a running cluster, instead of each caller picking fields out of
``stats`` / ``net`` ad hoc.  The shape is stable under the
``(schema, version)`` pair — add fields freely, bump ``VERSION`` on any
rename/removal so consumers can gate.

Snapshot layout (version 1)::

    {
      "schema":   "memec/telemetry",
      "version":  1,
      "arrival":  {kind, inflight[, rate, seed, trace_len]},
      "open_loop": bool,
      "latency":  {KIND: {count, mean_s, p50_s, p99_s, p999_s
                          [, queue_wait_s, queue_wait_p99_s]}},
      "counters": {...},            # every numeric stats entry
      "engines":  [{engine, path, device_dispatches, modeled_busy_s,
                    ...}, ...],     # one per shard engine
      "event":    {offered, makespan_s, queue_wait_s,
                   queue_wait_s_by_kind, queue_wait_s_by_resource,
                   arrival}         # open-loop mode only
    }

Works duck-typed for both ``MemECCluster`` (``net`` is a ``NetSim``) and
``ShardedCluster`` (``net`` is the ``ShardedNet`` facade view).
"""
from __future__ import annotations

SCHEMA = "memec/telemetry"
VERSION = 1

#: keys every snapshot must carry, whatever the mode
REQUIRED_KEYS = ("schema", "version", "arrival", "open_loop", "latency",
                 "counters", "engines")


def snapshot(cluster) -> dict:
    """Versioned telemetry snapshot of a cluster (sharded or not)."""
    net = cluster.net
    stats = cluster.stats
    engines = getattr(cluster, "engines", None) or [cluster.engine]
    snap = {
        "schema": SCHEMA,
        "version": VERSION,
        "arrival": net.arrival.describe(),
        "open_loop": net.events is not None,
        "latency": net.latency_summary(),
        "counters": {k: v for k, v in stats.items()
                     if isinstance(v, (int, float))},
        "engines": [dict(e.stats(), engine=e.name) for e in engines],
    }
    if net.events is not None:
        snap["event"] = net.events.snapshot()
    return snap


def validate(snap: dict) -> dict:
    """Assert ``snap`` is a consumable version-1 snapshot; returns it.

    Consumers (benchmarks/common.py, the verify.sh CI smoke) call this
    before reading fields so a schema drift fails loudly at the seam
    instead of as a KeyError three layers down.
    """
    if snap.get("schema") != SCHEMA:
        raise ValueError(f"not a {SCHEMA} snapshot: {snap.get('schema')!r}")
    if snap.get("version") != VERSION:
        raise ValueError(f"telemetry version {snap.get('version')!r} != "
                         f"supported {VERSION}")
    missing = [k for k in REQUIRED_KEYS if k not in snap]
    if missing:
        raise ValueError(f"telemetry snapshot missing keys: {missing}")
    if snap["open_loop"] and "event" not in snap:
        raise ValueError("open-loop snapshot without an 'event' section")
    for kind, s in snap["latency"].items():
        for field in ("count", "mean_s", "p50_s", "p99_s", "p999_s"):
            if field not in s:
                raise ValueError(f"latency[{kind!r}] missing {field}")
    return snap
