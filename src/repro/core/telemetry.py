"""Structured telemetry snapshots for MemEC clusters.

One versioned dict schema for everything an external consumer (the
benchmark harness, ``BENCH_ci.json``, a future dashboard) needs to read
off a running cluster, instead of each caller picking fields out of
``stats`` / ``net`` ad hoc.  The shape is stable under the
``(schema, version)`` pair — add fields freely, bump ``VERSION`` on any
rename/removal so consumers can gate.

Snapshot layout (version 2)::

    {
      "schema":   "memec/telemetry",
      "version":  2,
      "arrival":  {kind, inflight[, rate, seed, trace_len]},
      "open_loop": bool,
      "latency":  {KIND: {count, mean_s, p50_s, p99_s, p999_s
                          [, queue_wait_s, queue_wait_p99_s]}},
      "counters": {...},            # every numeric stats entry
      "engines":  [{engine, path, device_dispatches, modeled_busy_s,
                    ...}, ...],     # one per shard engine
      "trace":    {enabled, requests, spans},  # tracer summary (always)
      "critical_path": {KIND: {count, p50: {latency_s, components},
                               p99: {...}, p999: {...}}},  # {} when off
      "event":    {offered, makespan_s, queue_wait_s,
                   queue_wait_s_by_kind, queue_wait_s_by_resource,
                   arrival},        # open-loop mode only
      "hot_tier": {buffered_updates, flushes, flushed_keys,
                   flushed_versions, saved_parity_rounds,
                   saved_parity_bytes, evictions, barrier_flushes,
                   buffered_keys, tracked_keys}  # only when tier enabled
    }

Version 2 adds the always-present ``trace`` summary and the
``critical_path`` decomposition (populated only when tracing is on —
see ``core/trace.py``).  Version-1 readers gate on ``version`` and fail
loudly in :func:`validate` rather than KeyError-ing on the new shape.

Works duck-typed for both ``MemECCluster`` (``net`` is a ``NetSim``) and
``ShardedCluster`` (``net`` is the ``ShardedNet`` facade view).
"""
from __future__ import annotations

from . import trace as _trace

SCHEMA = "memec/telemetry"
VERSION = 2

#: keys every snapshot must carry, whatever the mode
REQUIRED_KEYS = ("schema", "version", "arrival", "open_loop", "latency",
                 "counters", "engines", "trace", "critical_path")


def snapshot(cluster) -> dict:
    """Versioned telemetry snapshot of a cluster (sharded or not)."""
    net = cluster.net
    stats = cluster.stats
    engines = getattr(cluster, "engines", None) or [cluster.engine]
    snap = {
        "schema": SCHEMA,
        "version": VERSION,
        "arrival": net.arrival.describe(),
        "open_loop": net.events is not None,
        "latency": net.latency_summary(),
        "counters": {k: v for k, v in stats.items()
                     if isinstance(v, (int, float))},
        "engines": [dict(e.stats(), engine=e.name) for e in engines],
    }
    # hot-key tier (optional — present only when the tier is enabled;
    # additive field, so the schema stays at version 2)
    if "hot_tier" in stats:
        snap["hot_tier"] = dict(stats["hot_tier"])
    tracers = _trace._cluster_tracers(cluster)
    if tracers:
        snap["trace"] = {
            "enabled": True,
            "requests": sum(len(tr.requests) for _, _, tr in tracers),
            "spans": sum(tr.span_count() for _, _, tr in tracers),
        }
        snap["critical_path"] = _trace.critical_paths(cluster)
    else:
        snap["trace"] = {"enabled": False, "requests": 0, "spans": 0}
        snap["critical_path"] = {}
    if net.events is not None:
        snap["event"] = net.events.snapshot()
    return snap


def validate(snap: dict) -> dict:
    """Assert ``snap`` is a consumable version-2 snapshot; returns it.

    Consumers (benchmarks/common.py, the verify.sh CI smoke) call this
    before reading fields so a schema drift fails loudly at the seam
    instead of as a KeyError three layers down.  Version-1 snapshots are
    rejected here by the version gate.
    """
    if snap.get("schema") != SCHEMA:
        raise ValueError(f"not a {SCHEMA} snapshot: {snap.get('schema')!r}")
    if snap.get("version") != VERSION:
        raise ValueError(f"telemetry version {snap.get('version')!r} != "
                         f"supported {VERSION}")
    missing = [k for k in REQUIRED_KEYS if k not in snap]
    if missing:
        raise ValueError(f"telemetry snapshot missing keys: {missing}")
    if snap["open_loop"] and "event" not in snap:
        raise ValueError("open-loop snapshot without an 'event' section")
    for kind, s in snap["latency"].items():
        for field in ("count", "mean_s", "p50_s", "p99_s", "p999_s"):
            if field not in s:
                raise ValueError(f"latency[{kind!r}] missing {field}")
    tr = snap["trace"]
    for field in ("enabled", "requests", "spans"):
        if field not in tr:
            raise ValueError(f"trace summary missing {field}")
    if not tr["enabled"] and snap["critical_path"]:
        raise ValueError("critical_path populated with tracing disabled")
    for kind, row in snap["critical_path"].items():
        for field in ("count", "p50", "p99", "p999"):
            if field not in row:
                raise ValueError(f"critical_path[{kind!r}] missing {field}")
    return snap
