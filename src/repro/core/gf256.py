"""GF(2^8) arithmetic for erasure coding (MemEC §2).

The field is GF(2^8) with the standard primitive polynomial
x^8 + x^4 + x^3 + x^2 + 1 (0x11D), the same field used by Reed-Solomon
deployments (ISA-L, jerasure).  Host-side (numpy) paths build tables and
invert small matrices; device-side (jnp) paths do vectorized mul/matmul.

Two device formulations are provided:

* table-based (log/exp lookups) — the classic CPU formulation; used as the
  reference oracle (`kernels/ref.py` builds on these).
* bit-plane (GF(2) linear algebra) — multiplication by a constant c is an
  8x8 binary matrix M_c; this is the TPU-native formulation used by the
  Pallas kernels (`gf_mul_matrix` below builds M_c).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

POLY = 0x11D  # x^8 + x^4 + x^3 + x^2 + 1
ORDER = 256


def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    """Return (exp, log) tables. exp has 512 entries to avoid mod-255."""
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= POLY
    exp[255:510] = exp[0:255]  # wraparound copies
    return exp, log


EXP_TABLE, LOG_TABLE = _build_tables()
# Full 256x256 multiplication table (64KB) — handy for oracles and the
# one-hot/MXU formulation.
_a = np.arange(256)
MUL_TABLE = np.zeros((256, 256), dtype=np.uint8)
_nz = _a[1:]
MUL_TABLE[1:, 1:] = EXP_TABLE[(LOG_TABLE[_nz][:, None] + LOG_TABLE[_nz][None, :]) % 255]

# Device-resident copies (created lazily to keep import cheap on workers).
@functools.lru_cache(maxsize=None)
def _device_tables():
    # ensure_compile_time_eval: the first call may happen inside a jit
    # trace (e.g. the table-strategy matmul); without it the cache would
    # capture trace-local constants and leak tracers into later traces
    with jax.ensure_compile_time_eval():
        return (jnp.asarray(EXP_TABLE), jnp.asarray(LOG_TABLE),
                jnp.asarray(MUL_TABLE))


# ---------------------------------------------------------------------------
# host (numpy) scalar/array ops — used by control plane + decode inversion
# ---------------------------------------------------------------------------

def gf_mul_np(a, b):
    """Elementwise GF(2^8) product of two uint8 numpy arrays/scalars."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    out = MUL_TABLE[a, b]
    return out


def gf_inv_np(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("GF(2^8) inverse of 0")
    return int(EXP_TABLE[255 - LOG_TABLE[a]])


def gf_div_np(a: int, b: int) -> int:
    if b == 0:
        raise ZeroDivisionError
    if a == 0:
        return 0
    return int(EXP_TABLE[(LOG_TABLE[a] - LOG_TABLE[b]) % 255])


def gf_matmul_np(A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """GF(2^8) matrix product (XOR-accumulate) of uint8 matrices."""
    A = np.asarray(A, dtype=np.uint8)
    B = np.asarray(B, dtype=np.uint8)
    assert A.shape[-1] == B.shape[0]
    out = np.zeros(A.shape[:-1] + B.shape[1:], dtype=np.uint8)
    for i in range(A.shape[-1]):
        out ^= MUL_TABLE[A[..., i, None], B[i]] if B.ndim > 1 else MUL_TABLE[A[..., i], B[i]]
    return out


def gf_mat_inv(M: np.ndarray) -> np.ndarray:
    """Invert a small square matrix over GF(2^8) by Gauss-Jordan."""
    M = np.array(M, dtype=np.uint8)
    n = M.shape[0]
    assert M.shape == (n, n)
    aug = np.concatenate([M, np.eye(n, dtype=np.uint8)], axis=1)
    for col in range(n):
        piv = next((r for r in range(col, n) if aug[r, col] != 0), None)
        if piv is None:
            raise np.linalg.LinAlgError("singular matrix over GF(2^8)")
        if piv != col:
            aug[[col, piv]] = aug[[piv, col]]
        aug[col] = MUL_TABLE[aug[col], gf_inv_np(int(aug[col, col]))]
        for r in range(n):
            if r != col and aug[r, col] != 0:
                aug[r] ^= MUL_TABLE[aug[r, col], aug[col]]
    return aug[:, n:]


gf_mat_inv_np = gf_mat_inv  # canonical name used elsewhere


# ---------------------------------------------------------------------------
# bit-plane lift: multiplication-by-c as an 8x8 GF(2) matrix
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def gf_mul_matrix(c: int) -> np.ndarray:
    """8x8 binary matrix M such that (c * x) bits = M @ x bits (GF(2)).

    Bit convention: bit j of a byte is (byte >> j) & 1 (LSB first).
    M[j, i] = bit j of (c * 2^i).
    """
    M = np.zeros((8, 8), dtype=np.uint8)
    for i in range(8):
        prod = int(MUL_TABLE[c, 1 << i])
        for j in range(8):
            M[j, i] = (prod >> j) & 1
    return M


def lift_matrix(A: np.ndarray) -> np.ndarray:
    """Lift an (m,k) GF(2^8) matrix to its (m,8,k,8) binary bit-plane form.

    out[r, j, i, b] = bit j of (A[r,i] * 2^b): the GF(2) matrix applied to
    input bit-planes b of operand i producing output bit-plane j of row r.
    """
    A = np.asarray(A, dtype=np.uint8)
    m, k = A.shape
    out = np.zeros((m, 8, k, 8), dtype=np.uint8)
    for r in range(m):
        for i in range(k):
            out[r, :, i, :] = gf_mul_matrix(int(A[r, i]))
    return out


# ---------------------------------------------------------------------------
# device (jnp) ops — table formulation (reference / oracle path)
# ---------------------------------------------------------------------------

def gf_mul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise GF(2^8) product (uint8 in, uint8 out), table-based."""
    exp, log, _ = _device_tables()
    a = a.astype(jnp.uint8)
    b = b.astype(jnp.uint8)
    la = log[a.astype(jnp.int32)]
    lb = log[b.astype(jnp.int32)]
    prod = exp[(la + lb) % 255]
    zero = (a == 0) | (b == 0)
    return jnp.where(zero, jnp.uint8(0), prod)


def gf_scale(c, x: jax.Array) -> jax.Array:
    """Multiply every byte of x by scalar c (c may be traced uint8)."""
    c_arr = jnp.asarray(c, dtype=jnp.uint8)
    return gf_mul(jnp.broadcast_to(c_arr, x.shape), x)


def gf_matmul(A: jax.Array, B: jax.Array) -> jax.Array:
    """GF(2^8) matmul: (m,k) x (k, ...) -> (m, ...) with XOR accumulation.

    Table-based jnp formulation; k is expected to be small (<= 32) so the
    XOR fold is unrolled.
    """
    A = jnp.asarray(A, dtype=jnp.uint8)
    B = jnp.asarray(B, dtype=jnp.uint8)
    m, k = A.shape
    out = None
    for i in range(k):
        term = gf_mul(A[:, i].reshape((m,) + (1,) * (B.ndim - 1)), B[i][None])
        out = term if out is None else out ^ term
    return out


def bytes_view(x: jax.Array) -> jax.Array:
    """Bit-cast any array to its raw uint8 byte view (flat)."""
    return jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint8).reshape(-1)


def from_bytes_view(b: jax.Array, dtype, shape) -> jax.Array:
    """Inverse of bytes_view."""
    nbytes = jnp.dtype(dtype).itemsize
    return jax.lax.bitcast_convert_type(
        b.reshape(-1, nbytes), jnp.dtype(dtype)).reshape(shape)
