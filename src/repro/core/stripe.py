"""Stripe-list generation and two-stage hashing (paper §4.3).

A *stripe list* names the k data servers and n-k parity servers of a stripe.
Because every data write fans out to all n-k parity servers, a parity server
absorbs k× the write load of a data server; the generator below greedily
balances aggregate write load: per iteration pick the n-k least-loaded
servers as parity (+k load each) and the next k as data (+1 load each).

Proxies map a key to a server with two-stage hashing:
    key -> stripe list (hash % c) -> data server within the list.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .index import fnv1a


@dataclasses.dataclass(frozen=True)
class StripeList:
    list_id: int
    data_servers: tuple[int, ...]    # k server ids
    parity_servers: tuple[int, ...]  # n-k server ids

    @property
    def servers(self) -> tuple[int, ...]:
        return self.data_servers + self.parity_servers

    @property
    def n(self) -> int:
        return len(self.servers)

    @property
    def k(self) -> int:
        return len(self.data_servers)

    def position_of(self, server_id: int) -> int:
        return self.servers.index(server_id)


def generate_stripe_lists(num_servers: int, n: int, k: int, c: int) -> list[StripeList]:
    """Greedy write-load-balanced stripe-list generation (paper §4.3)."""
    if num_servers < n:
        raise ValueError(f"need >= n={n} servers, got {num_servers}")
    load = np.zeros(num_servers, dtype=np.int64)
    out: list[StripeList] = []
    for i in range(c):
        # stable sort by (load, server id) — ties broken by smaller id
        order = np.lexsort((np.arange(num_servers), load))
        parity = tuple(int(s) for s in order[: n - k])
        data = tuple(int(s) for s in order[n - k: n])
        for s in parity:
            load[s] += k
        for s in data:
            load[s] += 1
        out.append(StripeList(list_id=i, data_servers=data, parity_servers=parity))
    return out


def write_loads(lists: list[StripeList], num_servers: int) -> np.ndarray:
    load = np.zeros(num_servers, dtype=np.int64)
    for sl in lists:
        for s in sl.parity_servers:
            load[s] += sl.k
        for s in sl.data_servers:
            load[s] += 1
    return load


class StripeMapper:
    """Two-stage hashing used by proxies in normal mode (decentralized)."""

    def __init__(self, lists: list[StripeList]):
        self.lists = lists

    def stripe_list_for(self, key: bytes) -> StripeList:
        h = fnv1a(key, seed=0x5BD1E995)
        return self.lists[h % len(self.lists)]

    def data_server_for(self, key: bytes) -> tuple[StripeList, int]:
        sl = self.stripe_list_for(key)
        h = fnv1a(key, seed=0xC2B2AE3D)
        ds = sl.data_servers[h % len(sl.data_servers)]
        return sl, ds
