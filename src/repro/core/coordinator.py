"""MemEC coordinator (paper §4.1, §5.2): server states + transitions.

The coordinator is outside the I/O path in normal mode.  On failure it
drives the state machine of Figure 4:

    NORMAL -> INTERMEDIATE -> DEGRADED -> COORDINATED_NORMAL -> NORMAL

broadcasting each state change atomically to all proxies and working
servers (the Spread toolkit in the prototype; a synchronous broadcast in
this simulation — strictly stronger ordering).  It also stores the periodic
key->chunk-ID mapping checkpoints (§5.3) and picks redirected servers for
degraded requests (§5.4).
"""
from __future__ import annotations

import enum
from collections import defaultdict

from .chunk import ChunkId
from .stripe import StripeList


class ServerState(enum.Enum):
    NORMAL = "normal"
    INTERMEDIATE = "intermediate"
    DEGRADED = "degraded"
    COORDINATED_NORMAL = "coordinated_normal"


class Coordinator:
    def __init__(self, num_servers: int, stripe_lists: list[StripeList],
                 shard_id: int | None = None):
        self.num_servers = num_servers
        self.stripe_lists = stripe_lists
        self.shard_id = shard_id  # None for the unsharded cluster
        self.states: dict[int, ServerState] = {
            s: ServerState.NORMAL for s in range(num_servers)}
        # key -> (chunk-ID, instance seq) mapping checkpoints, per server
        # (§5.3); the instance seq orders re-SETs of the same key so the
        # recovery merge below can never resurrect a superseded mapping
        self.mapping_ckpt: dict[int, dict[bytes, tuple[ChunkId, int | None]]] = \
            defaultdict(dict)
        # merged (checkpoint + proxy buffers) view built at failure time
        self.recovery_mappings: dict[int, dict[bytes, tuple[ChunkId, int | None]]] = {}
        # (state name, server, shard, logical step) — deterministic audit
        # trail for the transition tests; no wall clock on purpose
        self.transition_log: list[tuple[str, int, int | None, int]] = []
        self._step = 0
        # sticky degraded-routing choices: (failed sid, list id) -> server.
        # Without stickiness, restoring an unrelated server could silently
        # re-rank `redirected_server` and strand degraded state (temp
        # objects, reconstructed chunks) at the previous target.
        self.redirect_assignments: dict[tuple[int, int], int] = {}

    # -- state machine -----------------------------------------------------
    def state_of(self, sid: int) -> ServerState:
        return self.states[sid]

    def failed_servers(self) -> list[int]:
        return [s for s, st in self.states.items()
                if st in (ServerState.INTERMEDIATE, ServerState.DEGRADED)]

    def is_available(self, sid: int) -> bool:
        return self.states[sid] == ServerState.NORMAL or \
            self.states[sid] == ServerState.COORDINATED_NORMAL

    def set_state(self, sid: int, state: ServerState):
        self.states[sid] = state
        self._step += 1
        self.transition_log.append((state.value, sid, self.shard_id,
                                    self._step))

    def any_failure(self) -> bool:
        return any(st != ServerState.NORMAL for st in self.states.values())

    # -- mapping checkpoints -------------------------------------------------
    @staticmethod
    def _newer(cur: tuple[ChunkId, int | None] | None,
               iseq: int | None) -> bool:
        """Does a mapping with instance seq ``iseq`` supersede ``cur``?
        Unversioned entries (None) never beat a versioned one."""
        if cur is None:
            return True
        cur_iseq = cur[1]
        if cur_iseq is None:
            return True
        return iseq is not None and iseq >= cur_iseq

    def store_checkpoint(self, sid: int,
                         mappings: list[tuple[bytes, ChunkId, int | None]]):
        d = self.mapping_ckpt[sid]
        for key, cid, iseq in mappings:
            if self._newer(d.get(key), iseq):
                d[key] = (cid, iseq)

    def merge_proxy_mappings(self, sid: int,
                             proxy_maps: list[list[tuple[bytes, ChunkId, int | None]]]):
        """Merge checkpointed + proxy-buffered mappings at failure time.
        Different proxies may buffer mappings for *different instances*
        of the same re-SET key; the instance seq, not merge order,
        decides which chunk the degraded path should resolve to."""
        merged = dict(self.mapping_ckpt.get(sid, {}))
        for pm in proxy_maps:
            for key, cid, iseq in pm:
                if self._newer(merged.get(key), iseq):
                    merged[key] = (cid, iseq)
        self.recovery_mappings[sid] = merged

    def chunk_id_for(self, sid: int, key: bytes) -> ChunkId | None:
        ent = self.recovery_mappings.get(sid, {}).get(key)
        return ent[0] if ent is not None else None

    # -- degraded routing (§5.4) ---------------------------------------------
    def redirected_server(self, sl: StripeList, failed_sid: int) -> int:
        """Sticky, deterministic choice of a working server in the list.

        The first call for a (failed server, stripe list) pair picks the
        first available server and records it; later calls return the same
        target while it stays available, so degraded state accumulated
        there remains reachable even as *other* servers fail or recover.
        A target that itself fails triggers a reassignment (the cluster
        hands its redirect state off, see ``MemECCluster.fail_server``).
        """
        akey = (failed_sid, sl.list_id)
        cur = self.redirect_assignments.get(akey)
        if cur is not None and self.is_available(cur):
            return cur
        for s in sl.servers:
            if s != failed_sid and self.is_available(s):
                self.redirect_assignments[akey] = s
                return s
        raise RuntimeError("no working server available in stripe list")

    def clear_redirects(self, restored_sid: int):
        """Drop sticky assignments for a server that came back (§5.5)."""
        for akey in [a for a in self.redirect_assignments
                     if a[0] == restored_sid]:
            del self.redirect_assignments[akey]
