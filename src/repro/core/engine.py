"""Unified batched coding data plane: one pluggable engine, kernels → cluster.

Every layer of the reproduction used to drive coding through one-chunk-at-
a-time ``codes.Code`` calls while the Pallas kernels sat in benchmarks.
``CodingEngine`` is the single seam they now share:

    encode_batch((B, k, C))                 -> (B, m, C) parity
    decode_batch([avail...], [wanted...])   -> [{pos: chunk}, ...]
    delta_batch((B,), (B, C))               -> (B, m, C) parity deltas
    apply_delta_batch((B, m, C), ...)       -> (B, m, C) updated parity

Backends (all byte-identical, cross-validated in ``tests/test_engine.py``):

* ``NumpyEngine``  — wraps the ``codes.Code`` classes one item at a time;
  the reference oracle and the default for the CPU-only simulation.
* ``JaxEngine``    — pure-jnp batched path (``kernels/ref.py`` idiom).
* ``PallasEngine`` — batched Pallas grids over ``gf256_matmul`` /
  ``delta_update``; block-structured codes (RDP) route through the same
  ``gf256_matmul_batched`` entry natively (column-loop kernels, with
  0/1 matrices on a bit-plane-free XOR-select body).

The device backends share a *block-linear representation* of the code: any
systematic code here (RS, RDP, XOR, none) is GF(2^8)-linear over sub-block
rows — a chunk is ``r`` sub-blocks (r=1 for RS/XOR, r=p-1 for RDP) and
encode is one (m*r, k*r) matrix over GF(2^8), probed generically from the
numpy oracle with basis vectors.  Decode inverts k available chunk-row
groups of the systematic generator (host-side, cached per erasure
pattern); deltas are column slices of the encode matrix.

Selection: ``make_engine(name, code)``; ``name=None`` reads the
``MEMEC_ENGINE`` env var (``numpy`` | ``jax`` | ``pallas``), defaulting to
``numpy``.  ``configs/memec.py`` carries the same knob for the cluster.

Async submission (PR 4): ``submit_encode`` / ``submit_decode`` /
``submit_delta`` return lightweight ``EngineFuture`` handles so the
cluster can issue coding work while the same shard's netsim legs are
modeled in flight (``async_engine=True`` / ``$MEMEC_ASYNC``).  The numpy
backend resolves lazily (the work runs at ``result()``); the jax and
pallas backends *dispatch* on-device at submit time — XLA's async
dispatch does the real overlapping — and call ``jax.block_until_ready``
only at resolution.  Every future carries a deterministic ``work_bytes``
figure (GF(2^8) multiply-accumulate bytes) that ``CostModel.coding_s``
turns into modeled time; results are byte-identical to the blocking
calls by construction.

Plan/execute decode (PR 5): decode is split into a ``DecodePlan`` built
at submit time from host *metadata only* — erasure-pattern signatures,
the cached ``(k*r, k*r)`` inversions (a bounded LRU, ``inv_cache_size``
/ ``$MEMEC_INV_CACHE``), per-pattern group layout, and the output
scatter map — and an execute stage that issues ONE batched device
matmul per pattern group (plus one for re-encoded parity rows).  On the
device backends ``submit_decode`` therefore dispatches at submit like
encode/delta, instead of deferring the group-by to ``result()``; the
``device_dispatches`` counter is the probe the tests assert this with.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from collections import OrderedDict

import numpy as np

from . import gf256
from .codes import Code, RDPCode


# ---------------------------------------------------------------------------
# Block-linear representation (shared by the device backends)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockRep:
    """A code as one GF(2^8) matrix over sub-block rows.

    ``r`` sub-blocks per chunk; ``encode``: (m*r, k*r) uint8 with
    parity_blocks = encode ∘ data_blocks, where chunk (C,) reshapes to
    (r, C//r) sub-block rows.
    """
    r: int
    encode: np.ndarray  # (m*r, k*r) uint8, read-only

    @property
    def generator(self) -> np.ndarray:
        """(n*r, k*r) systematic generator [I ; encode]."""
        kr = self.encode.shape[1]
        return np.concatenate([np.eye(kr, dtype=np.uint8), self.encode])


@functools.lru_cache(maxsize=None)
def block_rep(code: Code) -> BlockRep:
    """The code's block-linear matrix, analytic where available.

    Codes exposing ``block_matrix()`` (RDP) hand over their matrix
    directly; anything else is probed from the numpy oracle with basis
    vectors — all codes here are XOR-linear maps with GF(2^8)
    coefficients, so k*r single-byte probes at chunk width r fully
    determine the encode matrix (``tests/test_codes.py`` cross-checks
    the analytic form against the probe).
    """
    r = (code.p - 1) if isinstance(code, RDPCode) else 1
    k, m = code.k, code.m
    if hasattr(code, "block_matrix"):
        E = np.asarray(code.block_matrix(), dtype=np.uint8)
        assert E.shape == (m * r, k * r), (E.shape, m, k, r)
    else:
        E = np.zeros((m * r, k * r), dtype=np.uint8)
        for j in range(k * r):
            probe = np.zeros((k, r), dtype=np.uint8)
            probe[j // r, j % r] = 1
            E[:, j] = code.encode(probe).reshape(m * r)
    E.setflags(write=False)
    return BlockRep(r=r, encode=E)


# ---------------------------------------------------------------------------
# Decode plan (host metadata only — no chunk bytes)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeGroup:
    """One erasure-pattern group of a batched decode.

    ``idxs``: batch items sharing the pattern; ``use``: the chunk
    positions feeding the inverse (sorted availability, first k);
    ``inv``: the cached (k*r, k*r) inverse; ``need_par``/``par_rows``:
    parity positions to re-encode and their generator rows.
    """
    idxs: tuple[int, ...]
    use: tuple[int, ...]
    inv: np.ndarray
    wanted: tuple[int, ...]
    need_par: tuple[int, ...]
    par_rows: np.ndarray | None


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """Everything a decode needs besides the chunk bytes: the pattern
    group-by, per-group inverses, and the output scatter map.  Built
    from host metadata at submit time so device backends can dispatch
    the per-group matmuls immediately."""
    n_items: int
    chunk_size: int
    groups: tuple[DecodeGroup, ...]


# ---------------------------------------------------------------------------
# Async submission handles
# ---------------------------------------------------------------------------

class EngineFuture:
    """Handle to a submitted coding op.

    ``result()`` returns host numpy arrays, computing (numpy backend) or
    blocking on the already-dispatched device work (jax/pallas) on first
    call; resolution is idempotent.  ``work_bytes`` is the deterministic
    modeled-cost input for ``CostModel.coding_s`` — identical whether the
    op ran sync or async, so latency accounting can't drift between the
    two modes.
    """

    __slots__ = ("_thunk", "_value", "_done", "work_bytes", "kind")

    def __init__(self, thunk, work_bytes: int = 0, kind: str = ""):
        self._thunk = thunk
        self._value = None
        self._done = False
        self.work_bytes = work_bytes
        self.kind = kind

    @classmethod
    def wrap(cls, value, work_bytes: int = 0, kind: str = "") -> "EngineFuture":
        """An already-resolved future (empty batches, degenerate codes)."""
        fut = cls(None, work_bytes, kind)
        fut._value = value
        fut._done = True
        return fut

    @property
    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            self._value = self._thunk()
            self._done = True
            self._thunk = None
        return self._value


# ---------------------------------------------------------------------------
# Engine interface
# ---------------------------------------------------------------------------

class CodingEngine:
    """Batched encode/decode/delta over a fixed ``Code``.

    All arrays are host numpy uint8 at the interface (the cluster
    simulation lives on host); device backends convert internally.
    """

    name = "base"

    #: default bound for the decode-inverse LRU (see ``inv_cache_size``)
    DEFAULT_INV_CACHE = 256

    def __init__(self, code: Code, inv_cache_size: int | None = None):
        self.code = code
        self.rep = block_rep(code)
        # decode-matrix cache: erasure patterns recur per failed server,
        # but rolling failures across many patterns must not grow it
        # without bound — bounded LRU (knob: ctor arg or $MEMEC_INV_CACHE)
        if inv_cache_size is None:
            inv_cache_size = int(os.environ.get("MEMEC_INV_CACHE",
                                                self.DEFAULT_INV_CACHE))
        self.inv_cache_size = max(1, int(inv_cache_size))
        self._inv_cache: OrderedDict[tuple[int, ...],
                                     tuple[tuple[int, ...], np.ndarray]] = \
            OrderedDict()
        # fused decode matrices: [inv ; par_rows ∘ inv] per (use, need_par)
        # — lets the execute stage issue ONE matmul per pattern group
        # instead of matmul + re-encode pass (same LRU bound as _inv_cache)
        self._fused_cache: OrderedDict[tuple, np.ndarray] = OrderedDict()
        # device-dispatch probe: device backends bump this every time a
        # kernel/jit call is issued — tests assert submit_* dispatches
        # at submit (counter moves before result()), numpy stays at 0
        self.device_dispatches = 0
        # cumulative modeled engine-busy seconds (CostModel.coding_s of
        # every call merged into a request); the sharded scatter planner
        # sorts shard groups by this clock to drain idle engines first
        self.modeled_busy_s = 0.0
        # distinct (available-set, wanted) decode patterns submitted per
        # call, cumulatively — straggler races turn "which Δ dropped"
        # into per-request erasure sets, so this counter (vs inv_cache
        # occupancy) shows the pattern diversity they induce
        self.decode_patterns_submitted = 0
        # per-op dispatch provenance: every device hook records which
        # path actually ran it ("pallas-compiled" / "xla-compiled" /
        # "interpret" / "jnp-fallback") — a silent jnp fallback used to
        # be invisible in describe(), which claimed the dispatch path
        # unconditionally; tests now assert on this map
        self.op_paths: dict[str, str] = {}

    def note_modeled_busy(self, coding_s: float):
        """Charge modeled busy seconds against this engine's clock."""
        if coding_s > 0.0:
            self.modeled_busy_s += coding_s

    def _note_decode_patterns(self, available, wanted):
        """Count the distinct (sorted available keys, wanted) patterns
        of one submit_decode call into ``decode_patterns_submitted``."""
        self.decode_patterns_submitted += len(
            {(tuple(sorted(a.keys())), tuple(w))
             for a, w in zip(available, wanted)})

    # -- core batched ops (implemented by backends) ---------------------
    def encode_batch(self, data: np.ndarray) -> np.ndarray:
        """(B, k, C) data chunks -> (B, m, C) parity chunks."""
        raise NotImplementedError

    def decode_batch(self, available, wanted, chunk_size: int) -> list[dict]:
        """Reconstruct stripe positions for a batch of stripes.

        ``available``: sequence of {position: chunk (C,)} dicts;
        ``wanted``: sequence of position lists.  Returns one
        {position: chunk} dict per stripe.  Items sharing an erasure
        pattern are decoded together (one matrix inversion + one batched
        matmul per pattern).
        """
        raise NotImplementedError

    def delta_batch(self, data_indices, xors: np.ndarray) -> np.ndarray:
        """Parity deltas for B independent chunk mutations.

        ``data_indices``: (B,) stripe data positions; ``xors``: (B, C)
        full-chunk D ⊕ D' per item.  Returns (B, m, C); apply with
        ``parity ^= delta``.
        """
        raise NotImplementedError

    def apply_delta_batch(self, parity: np.ndarray, data_indices,
                          xors: np.ndarray) -> np.ndarray:
        """(B, m, C) parity ⊕ delta_batch(data_indices, xors)."""
        parity = np.asarray(parity, dtype=np.uint8)
        if parity.shape[1] == 0 or parity.shape[0] == 0:
            return parity.copy()
        return parity ^ self.delta_batch(data_indices, xors)

    # -- introspection ---------------------------------------------------
    def describe(self) -> dict:
        """Engine identity + the kernel dispatch path actually in use —
        the answer to "did I actually compile?" (base: host numpy)."""
        return {
            "engine": self.name,
            "code": type(self.code).__name__,
            "n": self.code.n, "k": self.code.k, "r": self.rep.r,
            "backend": "host",
            "path": "numpy-host",
            "op_paths": dict(self.op_paths),
        }

    def stats(self) -> dict:
        """Run counters: device dispatches and plan-cache occupancy."""
        return {
            "path": self.describe()["path"],
            "op_paths": dict(self.op_paths),
            "device_dispatches": self.device_dispatches,
            "inv_cache": len(self._inv_cache),
            "fused_cache": len(self._fused_cache),
            "modeled_busy_s": self.modeled_busy_s,
            "decode_patterns_submitted": self.decode_patterns_submitted,
        }

    # -- modeled work (GF(2^8) multiply-accumulate bytes per batch) -----
    def encode_work_bytes(self, batch: int, chunk_size: int) -> int:
        """(m*r, k*r) matrix times (k*r, C/r) blocks, B times."""
        return batch * self.code.m * self.code.k * self.rep.r * chunk_size

    def decode_work_bytes(self, batch: int, chunk_size: int) -> int:
        """(k*r, k*r) inverse times the available blocks, B times (the
        per-pattern inversion amortizes across the batch)."""
        return batch * self.code.k * self.code.k * self.rep.r * chunk_size

    def delta_work_bytes(self, batch: int, chunk_size: int) -> int:
        """m*r parity rows from one chunk's xor, B times."""
        return batch * self.code.m * self.rep.r * chunk_size

    # -- async submission (overridden by device backends to dispatch
    # eagerly; the base implementation defers the work to result()) -----
    def submit_encode(self, data: np.ndarray) -> EngineFuture:
        data = np.asarray(data, dtype=np.uint8)
        B, _, C = data.shape
        return EngineFuture(lambda: self.encode_batch(data),
                            self.encode_work_bytes(B, C), "encode")

    def submit_decode(self, available, wanted, chunk_size: int) -> EngineFuture:
        available = [dict(a) for a in available]
        wanted = [list(w) for w in wanted]
        self._note_decode_patterns(available, wanted)
        return EngineFuture(
            lambda: self.decode_batch(available, wanted, chunk_size),
            self.decode_work_bytes(len(available), chunk_size), "decode")

    def submit_delta(self, data_indices, xors: np.ndarray) -> EngineFuture:
        xors = np.asarray(xors, dtype=np.uint8)
        B, C = xors.shape
        return EngineFuture(lambda: self.delta_batch(data_indices, xors),
                            self.delta_work_bytes(B, C), "delta")

    def submit_fold_rows(self, data_indices, xors: np.ndarray, row_indices,
                         parity_rows: np.ndarray) -> EngineFuture:
        """Fused encode + seal-fold: per item, one parity *row*.

        Item i mutates the data chunk at stripe position
        ``data_indices[i]`` by ``xors[i]`` (B, C) and folds the resulting
        delta for parity row ``row_indices[i]`` into ``parity_rows[i]``
        (B, C) — the ``Server.submit_fold_seals`` shape, where each
        parity server folds only its own row.  Returns (B, C) updated
        rows.  Base implementation is the two-call composition (full
        delta, then row pick) the fused device kernels are byte-checked
        against; work models the single row actually produced.
        """
        xors = np.asarray(xors, dtype=np.uint8)
        parity_rows = np.asarray(parity_rows, dtype=np.uint8)
        B, C = xors.shape
        wb = B * self.rep.r * C
        if B == 0 or self.code.m == 0:
            return EngineFuture.wrap(parity_rows.copy(), wb, "fold")
        rows = np.asarray(row_indices, dtype=np.int64)
        idxs = list(data_indices)

        def thunk():
            delta = self.delta_batch(idxs, xors)          # (B, m, C)
            return parity_rows ^ delta[np.arange(B), rows]
        return EngineFuture(thunk, wb, "fold")

    def submit_apply_delta(self, parity: np.ndarray, data_indices,
                           xors: np.ndarray) -> EngineFuture:
        """Fused delta + parity apply: (B, m, C) updated parity.

        The async spelling of ``apply_delta_batch`` — device backends
        fold the delta into the parity inside one kernel instead of
        materializing (B, m, C) deltas and XORing on the host.
        """
        parity = np.asarray(parity, dtype=np.uint8)
        xors = np.asarray(xors, dtype=np.uint8)
        B, C = xors.shape
        wb = self.delta_work_bytes(B, C)
        if B == 0 or parity.shape[1] == 0:
            return EngineFuture.wrap(parity.copy(), wb, "apply_delta")
        idxs = list(data_indices)
        return EngineFuture(
            lambda: self.apply_delta_batch(parity, idxs, xors),
            wb, "apply_delta")

    def collapse_work_bytes(self, versions, chunk_size: int) -> int:
        """Modeled cost of a version-collapse flush: one delta round
        plus the XOR pass over every buffered version's bytes.  Shared
        by all backends so hot-tier latency accounting can't drift."""
        return (self.delta_work_bytes(len(versions), chunk_size)
                + sum(int(np.asarray(v).size) for v in versions))

    def submit_delta_collapse(self, parity: np.ndarray, data_indices,
                              version_xors) -> EngineFuture:
        """Fold V buffered versions per item into parity in ONE round.

        ``version_xors``: per item, a (V_i, C) uint8 array of successive
        version deltas (each XOR against the then-current chunk bytes);
        their XOR-fold is the collapsed base→latest delta, so N buffered
        updates to a hot key cost one parity round instead of N.
        ``parity`` (B, m, C); returns a future of updated parity.  The
        collapse is pure XOR (associative, byte-exact), so every backend
        is byte-identical to applying the versions one at a time.
        """
        parity = np.asarray(parity, dtype=np.uint8)
        versions = [np.asarray(v, dtype=np.uint8) for v in version_xors]
        B, C = len(versions), parity.shape[2]
        wb = self.collapse_work_bytes(versions, C)
        if B == 0 or parity.shape[1] == 0:
            return EngineFuture.wrap(parity.copy(), wb, "delta_collapse")
        idxs = list(data_indices)

        def thunk():
            collapsed = np.stack(
                [np.bitwise_xor.reduce(v, axis=0) for v in versions])
            return self.apply_delta_batch(parity, idxs, collapsed)
        return EngineFuture(thunk, wb, "delta_collapse")

    # -- shared decode plumbing -----------------------------------------
    def _decode_inverse(self, avail_sig: tuple[int, ...]
                        ) -> tuple[tuple[int, ...], np.ndarray]:
        """(positions used, (k*r, k*r) inverse) for an availability set.

        Mirrors ``RSCode.decode_matrix``: sorted positions, first k.  For
        an MDS code, restricting to any k available chunks is equivalent
        to erasing the rest — within tolerance, hence invertible.
        """
        hit = self._inv_cache.get(avail_sig)
        if hit is not None:
            self._inv_cache.move_to_end(avail_sig)
            return hit
        k, r = self.code.k, self.rep.r
        if len(avail_sig) < k:
            raise ValueError(
                f"need {k} chunks, got {len(avail_sig)} — beyond erasure "
                f"tolerance of {type(self.code).__name__}"
                f"({self.code.n},{k})")
        use = avail_sig[:k]
        G = self.rep.generator
        rows = np.concatenate([G[p * r:(p + 1) * r] for p in use])
        inv = gf256.gf_mat_inv(rows)
        self._inv_cache[avail_sig] = (use, inv)
        while len(self._inv_cache) > self.inv_cache_size:
            self._inv_cache.popitem(last=False)
        return use, inv

    def plan_decode(self, avail_sigs, wanted, chunk_size: int) -> DecodePlan:
        """Build a ``DecodePlan`` from host metadata only.

        ``avail_sigs``: per item, the available stripe positions (any
        iterable — sorted here); ``wanted``: per item, the positions to
        reconstruct.  Items sharing (pattern, wanted) decode together:
        one cached inversion, one batched matmul, one scatter group.
        """
        k, r = self.code.k, self.rep.r
        G = self.rep.generator
        sigs = [tuple(sorted(s)) for s in avail_sigs]
        wsigs = [tuple(w) for w in wanted]
        by_pattern: dict[tuple, list[int]] = {}
        for i, key in enumerate(zip(sigs, wsigs)):
            by_pattern.setdefault(key, []).append(i)
        groups = []
        for (sig, wsig), idxs in by_pattern.items():
            use, inv = self._decode_inverse(sig)
            need_par = tuple(w for w in wsig if w >= k)
            par_rows = None
            if need_par:
                par_rows = np.concatenate(
                    [G[p * r:(p + 1) * r] for p in need_par])
            groups.append(DecodeGroup(tuple(idxs), use, inv, wsig,
                                      need_par, par_rows))
        return DecodePlan(len(sigs), chunk_size, tuple(groups))

    def _fused_decode_matrix(self, g: DecodeGroup) -> np.ndarray:
        """[inv ; par_rows ∘ inv] — one matrix so a group's data recovery
        AND parity re-encode are a single device matmul instead of two
        chained ones.  The composition runs on host once per (use,
        need_par) pattern and is LRU-cached like the inversions."""
        key = (g.use, g.need_par)
        hit = self._fused_cache.get(key)
        if hit is not None:
            self._fused_cache.move_to_end(key)
            return hit
        M = g.inv if g.par_rows is None else np.concatenate(
            [g.inv, gf256.gf_matmul_np(g.par_rows, g.inv)])
        self._fused_cache[key] = M
        while len(self._fused_cache) > self.inv_cache_size:
            self._fused_cache.popitem(last=False)
        return M


class NumpyEngine(CodingEngine):
    """Reference oracle: loops the host ``codes.Code`` implementation."""

    name = "numpy"

    def encode_batch(self, data):
        data = np.asarray(data, dtype=np.uint8)
        B, k, C = data.shape
        if B == 0:
            return np.zeros((0, self.code.m, C), np.uint8)
        return np.stack([self.code.encode(d) for d in data])

    def decode_batch(self, available, wanted, chunk_size):
        return [self.code.decode(dict(a), list(w), chunk_size)
                for a, w in zip(available, wanted)]

    def delta_batch(self, data_indices, xors):
        xors = np.asarray(xors, dtype=np.uint8)
        B, C = xors.shape
        if B == 0:
            return np.zeros((0, self.code.m, C), np.uint8)
        return np.stack([self.code.xor_delta(int(i), x)
                         for i, x in zip(data_indices, xors)])


# ---------------------------------------------------------------------------
# Device backends
# ---------------------------------------------------------------------------

def _jax():
    import jax
    import jax.numpy as jnp
    return jax, jnp


@functools.lru_cache(maxsize=None)
def _jnp_block_matmuls():
    """jit'd (O,J)x(B,J,Cb) and per-item (B,O,J)x(B,J,Cb) GF(2^8) matmuls,
    plus the parity-folding per-item variant (fused delta + apply)."""
    jax, jnp = _jax()
    from repro.kernels import ref as kref

    @jax.jit
    def shared(M, D):
        prod = kref.gf256_mul_ref(M[None, :, :, None], D[:, None, :, :])
        return jax.lax.reduce(prod, np.uint8(0), jax.lax.bitwise_xor, (2,))

    @jax.jit
    def per_item(Ms, D):
        prod = kref.gf256_mul_ref(Ms[..., None], D[:, None, :, :])
        return jax.lax.reduce(prod, np.uint8(0), jax.lax.bitwise_xor, (2,))

    @jax.jit
    def per_item_fold(Ms, D, P):
        prod = kref.gf256_mul_ref(Ms[..., None], D[:, None, :, :])
        return P ^ jax.lax.reduce(prod, np.uint8(0), jax.lax.bitwise_xor,
                                  (2,))

    return shared, per_item, per_item_fold


@functools.lru_cache(maxsize=None)
def _jnp_xor_collapse():
    """jit'd (B, V, C) -> (B, C) XOR-fold over the version axis (the
    device half of ``submit_delta_collapse`` on the jax/pallas paths)."""
    jax, _ = _jax()

    @jax.jit
    def collapse(stacked):
        return jax.lax.reduce(stacked, np.uint8(0), jax.lax.bitwise_xor,
                              (1,))
    return collapse


class JaxEngine(CodingEngine):
    """Pure-jnp batched backend over the block-linear representation."""

    name = "jax"

    # -- device matmul hooks (PallasEngine overrides the dense case).
    # The `_dev` variants return device arrays without blocking — XLA
    # dispatches asynchronously, so submit_* can issue work and only
    # synchronize at EngineFuture.result().
    def _matmul_dev(self, M: np.ndarray, blocks: np.ndarray):
        """(O, J) ∘ (B, J, Cb) -> (B, O, Cb) over GF(2^8), device-side."""
        _, jnp = _jax()
        shared, _, _ = _jnp_block_matmuls()
        self.device_dispatches += 1
        self.op_paths["matmul"] = "jnp-fallback"
        return shared(jnp.asarray(M), jnp.asarray(blocks))

    def _matmul_per_item_dev(self, Ms: np.ndarray, blocks: np.ndarray,
                             parity: np.ndarray | None = None):
        """(B, O, J) ∘ (B, J, Cb) -> (B, O, Cb), one matrix per item;
        ``parity`` (B, O, Cb), when given, is folded in the same jit."""
        _, jnp = _jax()
        _, per_item, per_item_fold = _jnp_block_matmuls()
        self.device_dispatches += 1
        self.op_paths["delta_per_item"] = "jnp-fallback"
        if parity is None:
            return per_item(jnp.asarray(Ms), jnp.asarray(blocks))
        return per_item_fold(jnp.asarray(Ms), jnp.asarray(blocks),
                             jnp.asarray(parity))

    def describe(self) -> dict:
        from repro.kernels import dispatch
        d = super().describe()
        d.update(backend=dispatch.backend(), path=dispatch.XLA,
                 interpret_forced=dispatch.interpret_forced())
        return d

    @staticmethod
    def _resolve_dev(dev, shape):
        """Blocking resolution of a dispatched device array (the only
        place the async path waits on the device)."""
        jax, _ = _jax()
        return np.asarray(jax.block_until_ready(dev)).reshape(shape)

    def submit_encode(self, data):
        data = np.asarray(data, dtype=np.uint8)
        B, k, C = data.shape
        m = self.code.m
        wb = self.encode_work_bytes(B, C)
        if B == 0 or m == 0:
            return EngineFuture.wrap(np.zeros((B, m, C), np.uint8), wb,
                                     "encode")
        dev = self._matmul_dev(self.rep.encode, self._blocks(data))
        return EngineFuture(lambda: self._resolve_dev(dev, (B, m, C)),
                            wb, "encode")

    def submit_delta(self, data_indices, xors):
        xors = np.asarray(xors, dtype=np.uint8)
        B, C = xors.shape
        m, k, r = self.code.m, self.code.k, self.rep.r
        wb = self.delta_work_bytes(B, C)
        if B == 0 or m == 0:
            return EngineFuture.wrap(np.zeros((B, m, C), np.uint8), wb,
                                     "delta")
        idx = np.asarray(data_indices, dtype=np.int64)
        cols = self.rep.encode.reshape(m * r, k, r)[:, idx, :]
        Ms = np.ascontiguousarray(np.transpose(cols, (1, 0, 2)))
        dev = self._matmul_per_item_dev(Ms, xors.reshape(B, r, C // r))
        return EngineFuture(lambda: self._resolve_dev(dev, (B, m, C)),
                            wb, "delta")

    def submit_fold_rows(self, data_indices, xors, row_indices, parity_rows):
        """Fused: per item, the (r, r) sub-system for ONE parity row is
        multiplied against the xor blocks and folded into the row inside
        a single device call — m× less delta work than ``submit_delta``
        and no host-side XOR pass."""
        xors = np.asarray(xors, dtype=np.uint8)
        parity_rows = np.asarray(parity_rows, dtype=np.uint8)
        B, C = xors.shape
        m, k, r = self.code.m, self.code.k, self.rep.r
        wb = B * r * C
        if B == 0 or m == 0:
            return EngineFuture.wrap(parity_rows.copy(), wb, "fold")
        idx = np.asarray(data_indices, dtype=np.int64)
        rows = np.asarray(row_indices, dtype=np.int64)
        # E reshaped (m, r, k, r): item i's system is E4[row_i, :, pos_i, :]
        E4 = self.rep.encode.reshape(m, r, k, r)
        Ms = np.ascontiguousarray(E4[rows, :, idx, :])    # (B, r, r)
        dev = self._matmul_per_item_dev(Ms, xors.reshape(B, r, C // r),
                                        parity_rows.reshape(B, r, C // r))
        return EngineFuture(lambda: self._resolve_dev(dev, (B, C)),
                            wb, "fold")

    def submit_apply_delta(self, parity, data_indices, xors):
        """Fused delta + parity apply in one per-item device call (the
        old path materialized (B, m, C) deltas, round-tripped them to
        host, and XORed there)."""
        parity = np.asarray(parity, dtype=np.uint8)
        xors = np.asarray(xors, dtype=np.uint8)
        B, C = xors.shape
        m, k, r = self.code.m, self.code.k, self.rep.r
        wb = self.delta_work_bytes(B, C)
        if B == 0 or m == 0:
            return EngineFuture.wrap(parity.copy(), wb, "apply_delta")
        idx = np.asarray(data_indices, dtype=np.int64)
        cols = self.rep.encode.reshape(m * r, k, r)[:, idx, :]
        Ms = np.ascontiguousarray(np.transpose(cols, (1, 0, 2)))
        dev = self._matmul_per_item_dev(Ms, xors.reshape(B, r, C // r),
                                        parity.reshape(B, m * r, C // r))
        return EngineFuture(lambda: self._resolve_dev(dev, (B, m, C)),
                            wb, "apply_delta")

    def apply_delta_batch(self, parity, data_indices, xors):
        return self.submit_apply_delta(parity, data_indices, xors).result()

    def submit_delta_collapse(self, parity, data_indices, version_xors):
        """Device-side collapse: pad-stack the versions (B, Vmax, C)
        (zeros are XOR-identity), XOR-reduce on device, and feed the
        fused per-item delta+apply — dispatched at submit like the other
        device ops.  Byte-identical to the host collapse by XOR
        associativity."""
        parity = np.asarray(parity, dtype=np.uint8)
        versions = [np.asarray(v, dtype=np.uint8) for v in version_xors]
        B, C = len(versions), parity.shape[2]
        m, k, r = self.code.m, self.code.k, self.rep.r
        wb = self.collapse_work_bytes(versions, C)
        if B == 0 or m == 0:
            return EngineFuture.wrap(parity.copy(), wb, "delta_collapse")
        _, jnp = _jax()
        vmax = max(v.shape[0] for v in versions)
        stacked = np.zeros((B, vmax, C), dtype=np.uint8)
        for i, v in enumerate(versions):
            stacked[i, :v.shape[0]] = v
        self.device_dispatches += 1
        collapsed = _jnp_xor_collapse()(jnp.asarray(stacked))      # (B, C)
        idx = np.asarray(data_indices, dtype=np.int64)
        cols = self.rep.encode.reshape(m * r, k, r)[:, idx, :]
        Ms = np.ascontiguousarray(np.transpose(cols, (1, 0, 2)))
        dev = self._matmul_per_item_dev(
            Ms, collapsed.reshape(B, r, C // r),
            parity.reshape(B, m * r, C // r))
        return EngineFuture(lambda: self._resolve_dev(dev, (B, m, C)),
                            wb, "delta_collapse")

    def _blocks(self, chunks: np.ndarray) -> np.ndarray:
        """(B, x, C) -> (B, x*r, C//r) sub-block rows."""
        B, x, C = chunks.shape
        r = self.rep.r
        if C % r:
            raise ValueError(f"chunk size {C} not divisible by r={r}")
        return chunks.reshape(B, x * r, C // r)

    def encode_batch(self, data):
        # the blocking call IS the submitted future resolved on the spot
        # — one dispatch body for both paths keeps sync/async
        # byte-identity true by construction
        return self.submit_encode(data).result()

    def submit_decode(self, available, wanted, chunk_size):
        """Plan on host metadata, dispatch the per-group matmuls NOW.

        The plan's group-by and cached inversions need no chunk bytes,
        so the device work is issued at submit — like encode/delta —
        and ``result()`` only blocks on it and scatters the output."""
        available = [dict(a) for a in available]
        wb = self.decode_work_bytes(len(available), chunk_size)
        if not available:
            return EngineFuture.wrap([], wb, "decode")
        self._note_decode_patterns(available, wanted)
        plan = self.plan_decode([a.keys() for a in available], wanted,
                                chunk_size)
        devs = self._execute_decode_dev(plan, available)
        return EngineFuture(lambda: self._scatter_decode(plan, devs),
                            wb, "decode")

    def _execute_decode_dev(self, plan: DecodePlan, available) -> list:
        """Execute stage: ONE batched device matmul per pattern group.

        The group's inverse and its re-encoded-parity rows are fused into
        a single host-composed matrix (``_fused_decode_matrix``), so the
        old matmul -> parity-re-encode chain collapses to one kernel —
        byte-checked against the two-call composition in
        ``tests/test_dispatch_tune.py``."""
        devs = []
        for g in plan.groups:
            stacked = np.stack(
                [np.stack([np.asarray(available[i][p], np.uint8)
                           for p in g.use]) for i in g.idxs])  # (Bg, k, C)
            M = self._fused_decode_matrix(g)
            devs.append(self._matmul_dev(M, self._blocks(stacked)))
        return devs

    def _scatter_decode(self, plan: DecodePlan, devs) -> list[dict]:
        """Resolution: block on the dispatched groups and scatter each
        item's wanted positions back into per-stripe dicts.  The fused
        matmul output is (Bg, k + n_par, C): data rows then the
        re-encoded parity rows."""
        k, C = self.code.k, plan.chunk_size
        results: list[dict | None] = [None] * plan.n_items
        for g, dev in zip(plan.groups, devs):
            Bg, npar = len(g.idxs), len(g.need_par)
            out = self._resolve_dev(dev, (Bg, k + npar, C))
            for bi, i in enumerate(g.idxs):
                results[i] = {w: (out[bi, w] if w < k
                                  else out[bi, k + g.need_par.index(w)])
                              for w in g.wanted}
        return results

    def decode_batch(self, available, wanted, chunk_size):
        # same plan/execute body as the submitted path, resolved on the
        # spot — sync/async byte-identity true by construction
        return self.submit_decode(available, wanted, chunk_size).result()

    def delta_batch(self, data_indices, xors):
        return self.submit_delta(data_indices, xors).result()


class PallasEngine(JaxEngine):
    """Batched Pallas grids for every block-linear code.

    Dense codes (RS, XOR; r == 1) hit the fully-unrolled
    `gf256_matmul`/`delta_update` kernel bodies with a (batch, C-tile)
    grid.  Block-structured codes (RDP; r = p-1) route through the SAME
    `gf256_matmul_batched` entry point natively: its column-loop kernels
    handle the (m*r, k*r) block matrix — pure-XOR 0/1 matrices drop the
    bit-plane loop entirely — so RDP encode/decode no longer falls back
    to the jnp path (ROADMAP "batching RDP natively in Pallas").
    Per-item delta matrices (r > 1) run `gf256_matmul_per_item_batched`
    — the same batched grid with one matrix tile per item — so RDP
    updates no longer drop to the jnp per-item matmul either.

    How the kernels actually run comes from ``kernels.dispatch``:
    compiled Pallas on TPU/GPU, the XLA-jitted ``xla_gf256`` twins on
    CPU, interpret mode only under ``$MEMEC_INTERPRET=1`` —
    ``describe()`` reports the resolved path.
    """

    name = "pallas"

    def _matmul_dev(self, M, blocks):
        from repro.kernels import dispatch
        from repro.kernels.gf256_matmul import gf256_matmul_batched
        self.device_dispatches += 1
        self.op_paths["matmul"] = dispatch.decide().path
        return gf256_matmul_batched(M, blocks)

    def _matmul_per_item_dev(self, Ms, blocks, parity=None):
        from repro.kernels import dispatch
        from repro.kernels.delta_update import delta_apply_per_item_batched
        self.device_dispatches += 1
        self.op_paths["delta_per_item"] = dispatch.decide().path
        return delta_apply_per_item_batched(parity, Ms, blocks)

    def describe(self) -> dict:
        from repro.kernels import dispatch
        d = CodingEngine.describe(self)
        d.update(dispatch.describe())
        return d

    def _gammas(self, data_indices) -> np.ndarray:
        idx = np.asarray(data_indices, dtype=np.int64)
        return np.ascontiguousarray(
            self.rep.encode[:, idx].T).astype(np.int32)   # (B, m)

    def delta_batch(self, data_indices, xors):
        if self.rep.r != 1 or self.code.m == 0:
            return super().delta_batch(data_indices, xors)
        xors = np.asarray(xors, dtype=np.uint8)
        B, C = xors.shape
        if B == 0:
            return np.zeros((B, self.code.m, C), np.uint8)
        from repro.kernels import dispatch
        from repro.kernels.delta_update import delta_apply_batched
        # parity=None: delta-only kernel — no dead parity streams
        self.device_dispatches += 1
        self.op_paths["delta"] = dispatch.decide().path
        return np.asarray(delta_apply_batched(
            None, self._gammas(data_indices), xors))

    def submit_delta(self, data_indices, xors):
        if self.rep.r != 1 or self.code.m == 0:
            return super().submit_delta(data_indices, xors)
        xors = np.asarray(xors, dtype=np.uint8)
        B, C = xors.shape
        wb = self.delta_work_bytes(B, C)
        if B == 0:
            return EngineFuture.wrap(np.zeros((B, self.code.m, C), np.uint8),
                                     wb, "delta")
        from repro.kernels import dispatch
        from repro.kernels.delta_update import delta_apply_batched
        self.device_dispatches += 1
        self.op_paths["delta"] = dispatch.decide().path
        dev = delta_apply_batched(None, self._gammas(data_indices), xors)
        return EngineFuture(
            lambda: self._resolve_dev(dev, (B, self.code.m, C)), wb, "delta")

    def submit_apply_delta(self, parity, data_indices, xors):
        if self.rep.r != 1 or self.code.m == 0:
            # r > 1: the per-item Pallas grid with in-kernel parity fold
            return super().submit_apply_delta(parity, data_indices, xors)
        parity = np.asarray(parity, dtype=np.uint8)
        xors = np.asarray(xors, dtype=np.uint8)
        B, C = xors.shape
        wb = self.delta_work_bytes(B, C)
        if B == 0 or parity.shape[1] == 0:
            return EngineFuture.wrap(parity.copy(), wb, "apply_delta")
        from repro.kernels import dispatch
        from repro.kernels.delta_update import delta_apply_batched
        self.device_dispatches += 1
        self.op_paths["delta"] = dispatch.decide().path
        dev = delta_apply_batched(parity, self._gammas(data_indices), xors)
        return EngineFuture(
            lambda: self._resolve_dev(dev, parity.shape), wb, "apply_delta")


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------

ENGINES = {
    "numpy": NumpyEngine,
    "jax": JaxEngine,
    "pallas": PallasEngine,
}


def make_engine(name: str | None, code: Code) -> CodingEngine:
    """Build a backend for ``code``.

    ``name=None`` falls back to ``$MEMEC_ENGINE`` then ``"numpy"``.  A
    comma-separated list (the per-shard spelling, e.g. ``pallas,numpy``)
    collapses to its first entry when a single engine is requested.
    """
    if isinstance(name, CodingEngine):
        return name
    name = (name or os.environ.get("MEMEC_ENGINE") or "numpy").lower()
    if "," in name:
        name = name.split(",")[0].strip()
    try:
        cls = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown coding engine {name!r}; pick from {sorted(ENGINES)}")
    return cls(code)


def resolve_async(async_engine=None) -> bool:
    """Async-pipeline knob: the argument, else ``$MEMEC_ASYNC`` (truthy
    spellings: 1/true/yes/on), defaulting to the synchronous pipeline."""
    if async_engine is None:
        return os.environ.get("MEMEC_ASYNC", "").strip().lower() in (
            "1", "true", "yes", "on")
    return bool(async_engine)


def engine_specs(spec, num_shards: int) -> list:
    """Expand an engine spec into one entry per shard.

    ``spec`` may be None (defer to ``$MEMEC_ENGINE``, itself possibly a
    comma list), a single backend name, a comma-separated string, a
    list/tuple of names, or a ``CodingEngine`` instance; shorter lists
    cycle (e.g. ``"pallas,numpy"`` over 4 shards -> pallas/numpy/pallas/
    numpy — pallas for hot shards, numpy elsewhere)."""
    if spec is None:
        spec = os.environ.get("MEMEC_ENGINE")
    if isinstance(spec, str) and "," in spec:
        spec = [s.strip() for s in spec.split(",") if s.strip()]
    if isinstance(spec, (list, tuple)):
        if not spec:
            raise ValueError("empty engine spec list")
        return [spec[i % len(spec)] for i in range(num_shards)]
    return [spec] * num_shards
