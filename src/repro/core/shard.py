"""Sharded MemEC: hash-partitioned shard stores, pipelined cross-shard batches.

Scaling seam on top of the unsharded cluster (ROADMAP: "sharded stores
driving per-shard engines").  A ``ShardedCluster`` partitions the key space
(FNV-1a hash of key -> shard) across S independent ``MemECCluster`` shard
stores.  Each shard owns its own stripe lists, servers, proxies,
coordinator state, netsim accounting, and ``CodingEngine`` instance —
mixed backends per shard are allowed (e.g. ``engine="pallas,numpy"`` puts
Pallas on hot shards and numpy elsewhere; see ``engine_specs``).

Batched multi-key requests go through a cross-shard scatter/gather
planner: keys are grouped per shard in request order, the per-shard
engine+network batches execute concurrently (one worker per shard — real
wall-clock overlap of coding with other shards' in-flight netsim legs,
the ROADMAP's async seam), and results merge back in request order.  The
merged request's modeled latency is the *slowest shard's* batch time
(full pipeline overlap across disjoint shard hardware); the facade tracks
how much modeled time the overlap saved versus sequential shard execution
(``stats["pipeline_overlap_saved_s"]``).

Failures are shard-scoped: ``fail_server``/``restore_server`` take a
global server id (``shard * servers_per_shard + local``) or an explicit
``shard=`` kwarg, and recovery of one shard never blocks traffic on the
others — non-failed shards keep serving decentralized normal-mode
requests throughout.

The unsharded cluster is the S=1 special case: ``make_cluster`` returns a
plain ``MemECCluster`` for one shard, so every existing call site keeps
working; ``shards=`` / ``$MEMEC_SHARDS`` opt in to S>1.
"""
from __future__ import annotations

import os
from collections import defaultdict
from concurrent.futures import ThreadPoolExecutor

from .index import fnv1a
from .netsim import NetSim
from .store import MemECCluster

# dedicated hash seed: shard routing must stay independent of the
# per-shard two-stage stripe hashing (stripe.py)
SHARD_SEED = 0x01000193

# batch request kinds the facade re-records with pipelined latencies;
# the per-shard components are excluded from aggregate latency views
BATCH_KINDS = ("MGET", "MSET", "MUPDATE")


def shard_for_key(key: bytes, num_shards: int) -> int:
    """Hash-partition the key space across shards."""
    if num_shards <= 1:
        return 0
    return fnv1a(key, seed=SHARD_SEED) % num_shards


def resolve_shards(shards=None) -> int:
    """Shard count from the argument or ``$MEMEC_SHARDS`` (default 1)."""
    if shards is None:
        shards = os.environ.get("MEMEC_SHARDS")
    s = 1 if shards in (None, "") else int(shards)
    if s < 1:
        raise ValueError(f"shards must be >= 1, got {s}")
    return s


class ShardedNet:
    """NetSim-shaped aggregate view over per-shard netsims.

    Single-key request latencies and all byte/message counters come from
    the shards; the facade's own records (pipelined MGET/MSET/MUPDATE
    latencies) live in ``local`` and replace the shards' per-shard batch
    entries in merged views.  Endpoints are namespaced ``sh{i}:s{j}`` for
    S>1 (each shard is separate hardware) and left bare for S=1 so the
    view is a drop-in for the unsharded net.
    """

    def __init__(self, cluster: "ShardedCluster"):
        self._cl = cluster
        self.local = NetSim(cluster.shards[0].net.cost)
        self.cost = self.local.cost

    def _shard_nets(self):
        return [sh.net for sh in self._cl.shards]

    def _prefix(self, i: int, ep: str) -> str:
        return ep if self._cl.num_shards == 1 else f"sh{i}:{ep}"

    # -- recording (facade-level merged batches) ------------------------
    def record(self, req_kind: str, latency_s: float):
        self.local.record(req_kind, latency_s)

    # -- merged views ----------------------------------------------------
    @property
    def latencies(self) -> dict:
        out = defaultdict(list)
        for net in self._shard_nets():
            for kind, xs in net.latencies.items():
                if kind in BATCH_KINDS:
                    continue  # subsumed by the facade's pipelined record
                out[kind].extend(xs)
        for kind, xs in self.local.latencies.items():
            out[kind].extend(xs)
        return dict(out)

    @property
    def ops_by_kind(self) -> dict:
        out = defaultdict(int)
        for net in self._shard_nets():
            for kind, n in net.ops_by_kind.items():
                if kind in BATCH_KINDS:
                    continue
                out[kind] += n
        for kind, n in self.local.ops_by_kind.items():
            out[kind] += n
        return dict(out)

    @property
    def bytes_by_kind(self) -> dict:
        out = defaultdict(int)
        for net in self._shard_nets():
            for kind, n in net.bytes_by_kind.items():
                out[kind] += n
        return dict(out)

    @property
    def msgs_by_kind(self) -> dict:
        out = defaultdict(int)
        for net in self._shard_nets():
            for kind, n in net.msgs_by_kind.items():
                out[kind] += n
        return dict(out)

    @property
    def bytes_by_endpoint(self) -> dict:
        out = {}
        for i, net in enumerate(self._shard_nets()):
            for ep, n in net.bytes_by_endpoint.items():
                out[self._prefix(i, ep)] = n
        return out

    # -- reporting (same formulas as NetSim) ----------------------------
    def percentile(self, req_kind: str, q: float) -> float:
        import numpy as np
        xs = self.latencies.get(req_kind, [])
        return float(np.percentile(xs, q)) if xs else float("nan")

    def mean(self, req_kind: str) -> float:
        xs = self.latencies.get(req_kind, [])
        return sum(xs) / len(xs) if xs else float("nan")

    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def _endpoint_pool(self, endpoints):
        pool = self.bytes_by_endpoint
        if endpoints is not None:
            pool = {e: pool.get(e, 0) for e in endpoints}
        return pool

    def bottleneck_throughput(self, total_ops: int,
                              endpoints: list[str] | None = None) -> float:
        pool = self._endpoint_pool(endpoints)
        if not pool or total_ops == 0:
            return float("nan")
        worst = max(pool.values())
        if worst == 0:
            return float("inf")
        return total_ops / (worst / self.cost.bw_Bps)

    def mean_throughput(self, total_ops: int,
                        endpoints: list[str] | None = None) -> float:
        pool = self._endpoint_pool(endpoints)
        if not pool or total_ops == 0:
            return float("nan")
        total = sum(pool.values())
        if total == 0:
            return float("inf")
        return total_ops / (total / (len(pool) * self.cost.bw_Bps))

    def reset(self):
        for net in self._shard_nets():
            net.reset()
        self.local.reset()

    def snapshot(self) -> dict:
        return {
            "bytes_by_kind": self.bytes_by_kind,
            "msgs_by_kind": self.msgs_by_kind,
            "bytes_by_endpoint": self.bytes_by_endpoint,
        }


class ShardedCluster:
    """Facade over S independent ``MemECCluster`` shard stores.

    Exposes the full cluster request API (single-key + multi-key), with
    multi-key requests planned across shards and pipelined.  Constructor
    keywords other than ``shards``/``engine``/``pipeline`` are forwarded
    verbatim to every shard store.
    """

    def __init__(self, shards=None, engine=None, pipeline: bool = True,
                 **cluster_kw):
        from .engine import engine_specs
        self.num_shards = resolve_shards(shards)
        specs = engine_specs(engine, self.num_shards)
        self.shards = [MemECCluster(engine=specs[i], shard_id=i, **cluster_kw)
                       for i in range(self.num_shards)]
        s0 = self.shards[0]
        self.servers_per_shard = len(s0.servers)
        self.num_proxies = s0.num_proxies
        self.code, self.n, self.k = s0.code, s0.n, s0.k
        self.chunk_size = s0.chunk_size
        self.degraded_enabled = s0.degraded_enabled
        self.engines = [sh.engine for sh in self.shards]
        self.engine = self.engines[0]
        self.pipeline = bool(pipeline) and self.num_shards > 1
        self._stats = {"cross_shard_batches": 0, "pipelined_batches": 0,
                       "pipeline_overlap_saved_s": 0.0}
        self.net = ShardedNet(self)

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def shard_of(self, key: bytes) -> int:
        return shard_for_key(key, self.num_shards)

    def _shard_for(self, key: bytes) -> MemECCluster:
        return self.shards[self.shard_of(key)]

    def locate(self, key: bytes):
        """(shard id, stripe list, data server) for a key."""
        si = self.shard_of(key)
        sl, ds = self.shards[si].mapper.data_server_for(key)
        return si, sl, ds

    def global_sid(self, shard: int, local_sid: int) -> int:
        return shard * self.servers_per_shard + local_sid

    def _resolve_server(self, sid: int, shard: int | None) -> tuple[int, int]:
        if shard is None:
            shard, sid = divmod(sid, self.servers_per_shard)
        if not 0 <= shard < self.num_shards:
            raise ValueError(f"no shard {shard}")
        return shard, sid

    @property
    def failed(self) -> set[int]:
        """Global ids of every transiently-failed server across shards."""
        return {self.global_sid(i, s)
                for i, sh in enumerate(self.shards) for s in sh.failed}

    @property
    def stats(self) -> dict:
        out = dict(self._stats)
        for sh in self.shards:
            for k, v in sh.stats.items():
                out[k] = out.get(k, 0) + v
        return out

    def server_endpoint_names(self) -> list[str]:
        return [self.net._prefix(i, ep)
                for i, sh in enumerate(self.shards)
                for ep in sh.server_endpoint_names()]

    # ------------------------------------------------------------------
    # single-key API — decentralized, shard-local
    # ------------------------------------------------------------------
    def set(self, key: bytes, value: bytes, proxy_id: int = 0):
        return self._shard_for(key).set(key, value, proxy_id)

    def get(self, key: bytes, proxy_id: int = 0):
        return self._shard_for(key).get(key, proxy_id)

    def update(self, key: bytes, value: bytes, proxy_id: int = 0) -> bool:
        return self._shard_for(key).update(key, value, proxy_id)

    def delete(self, key: bytes, proxy_id: int = 0) -> bool:
        return self._shard_for(key).delete(key, proxy_id)

    # ------------------------------------------------------------------
    # multi-key API — cross-shard scatter/gather planner
    # ------------------------------------------------------------------
    def _plan(self, keys) -> dict[int, list[int]]:
        """Group request indices per shard, preserving request order."""
        groups: dict[int, list[int]] = {}
        for i, key in enumerate(keys):
            groups.setdefault(self.shard_of(key), []).append(i)
        return groups

    def _scatter(self, fn, groups: dict[int, list[int]]):
        """Run ``fn(shard_index, request_indices)`` for every shard group.

        With pipelining, groups execute on one worker per shard (each
        worker touches only its own shard's state, so this is safe and
        deterministic); results return in shard order either way.
        """
        items = sorted(groups.items())
        if self.pipeline and len(items) > 1:
            # per-call pool: workers release with the call (no idle
            # threads outliving the batch), spawn cost is negligible
            # next to the per-shard engine + store work
            with ThreadPoolExecutor(max_workers=len(items)) as pool:
                futures = [(si, idxs, pool.submit(fn, si, idxs))
                           for si, idxs in items]
                return [(si, idxs, f.result()) for si, idxs, f in futures]
        return [(si, idxs, fn(si, idxs)) for si, idxs in items]

    def _record_batch(self, kind: str, dts: list[float]):
        """Merged-request latency under pipelining: the per-shard batches
        overlap fully (disjoint servers/proxies/engines), so the request
        completes when the slowest shard does."""
        if not dts:
            return
        self.net.record(kind, max(dts))
        self._stats["cross_shard_batches"] += 1
        if len(dts) > 1:
            self._stats["pipelined_batches"] += 1
            self._stats["pipeline_overlap_saved_s"] += sum(dts) - max(dts)

    def multi_get(self, keys, proxy_id: int = 0) -> list:
        keys = list(keys)
        groups = self._plan(keys)
        out: list = [None] * len(keys)

        def run(si, idxs):
            sh = self.shards[si]
            t0 = sh.net.total_recorded_s
            vals = sh.multi_get([keys[i] for i in idxs], proxy_id)
            return vals, sh.net.total_recorded_s - t0

        dts = []
        for si, idxs, (vals, dt) in self._scatter(run, groups):
            for i, v in zip(idxs, vals):
                out[i] = v
            dts.append(dt)
        self._record_batch("MGET", dts)
        return out

    def multi_set(self, items, proxy_id: int = 0) -> list[bool]:
        items = list(items)
        groups = self._plan([k for k, _ in items])
        ok = [False] * len(items)

        def run(si, idxs):
            sh = self.shards[si]
            t0 = sh.net.total_recorded_s
            oks = sh.multi_set([items[i] for i in idxs], proxy_id)
            return oks, sh.net.total_recorded_s - t0

        dts = []
        for si, idxs, (oks, dt) in self._scatter(run, groups):
            for i, o in zip(idxs, oks):
                ok[i] = o
            dts.append(dt)
        self._record_batch("MSET", dts)
        return ok

    def multi_update(self, items, proxy_id: int = 0) -> list[bool]:
        items = list(items)
        groups = self._plan([k for k, _ in items])
        ok = [False] * len(items)

        def run(si, idxs):
            sh = self.shards[si]
            t0 = sh.net.total_recorded_s
            oks = sh.multi_update([items[i] for i in idxs], proxy_id)
            return oks, sh.net.total_recorded_s - t0

        dts = []
        for si, idxs, (oks, dt) in self._scatter(run, groups):
            for i, o in zip(idxs, oks):
                ok[i] = o
            dts.append(dt)
        self._record_batch("MUPDATE", dts)
        return ok

    # ------------------------------------------------------------------
    # shard-scoped failure transitions — one shard's recovery never
    # blocks the others' traffic
    # ------------------------------------------------------------------
    def fail_server(self, sid: int, shard: int | None = None) -> dict:
        shard, local = self._resolve_server(sid, shard)
        timings = self.shards[shard].fail_server(local)
        timings["shard"] = shard
        return timings

    def restore_server(self, sid: int, shard: int | None = None) -> dict:
        shard, local = self._resolve_server(sid, shard)
        timings = self.shards[shard].restore_server(local)
        timings["shard"] = shard
        return timings

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def total_memory(self) -> dict:
        agg: dict[str, int] = {}
        for sh in self.shards:
            for k, v in sh.total_memory().items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def stored_payload_bytes(self) -> int:
        return sum(sh.stored_payload_bytes() for sh in self.shards)


def make_cluster(shards=None, engine=None, pipeline: bool = True,
                 **cluster_kw):
    """Cluster factory: plain ``MemECCluster`` for S=1 (the unsharded
    special case — byte- and latency-identical to the pre-sharding
    cluster), ``ShardedCluster`` for S>1.  ``shards=None`` reads
    ``$MEMEC_SHARDS``."""
    s = resolve_shards(shards)
    if s == 1:
        from .engine import engine_specs
        return MemECCluster(engine=engine_specs(engine, 1)[0], **cluster_kw)
    return ShardedCluster(shards=s, engine=engine, pipeline=pipeline,
                          **cluster_kw)
