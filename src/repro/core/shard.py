"""Sharded MemEC: hash-partitioned shard stores, pipelined cross-shard batches.

Scaling seam on top of the unsharded cluster (ROADMAP: "sharded stores
driving per-shard engines").  A ``ShardedCluster`` partitions the key space
(FNV-1a hash of key -> shard) across S independent ``MemECCluster`` shard
stores.  Each shard owns its own stripe lists, servers, proxies,
coordinator state, netsim accounting, and ``CodingEngine`` instance —
mixed backends per shard are allowed (e.g. ``engine="pallas,numpy"`` puts
Pallas on hot shards and numpy elsewhere; see ``engine_specs``).

Batched multi-key requests go through a cross-shard scatter/gather
planner: keys are grouped per shard in request order, the per-shard
engine+network batches execute concurrently (one worker per shard — real
wall-clock overlap of coding with other shards' in-flight netsim legs,
the ROADMAP's async seam), and results merge back in request order.  The
merged request's modeled latency is the *slowest shard's* batch time
(full pipeline overlap across disjoint shard hardware); the facade tracks
how much modeled time the overlap saved versus sequential shard execution
(``stats["pipeline_overlap_saved_s"]``).

Failures are shard-scoped: ``fail_server``/``restore_server`` take a
global server id (``shard * servers_per_shard + local``) or an explicit
``shard=`` kwarg, and recovery of one shard never blocks traffic on the
others — non-failed shards keep serving decentralized normal-mode
requests throughout.

Key routing goes through a pluggable ``Placement`` policy (core/ring.py):
the historical FNV-1a-mod map (default, ``placement="mod"``) or a
consistent-hash ring with virtual nodes and weights (``"ring"`` /
``$MEMEC_PLACEMENT``).  With a ring the cluster is *elastic*:
``add_shard``/``remove_shard`` grow or drain membership and
``rebalance()`` escapes load skew, all executing live stripe migrations
through ``core/rebalance.py`` — a forwarding table (``_pending``) keeps
every key readable and writable mid-migration, and per-shard load
counters (``shard_ops``/``load_skew``) feed both the skew decisions and
``stats()``/``net.snapshot()``.

The unsharded cluster is the S=1 special case: ``make_cluster`` returns a
plain ``MemECCluster`` for one shard, so every existing call site keeps
working; ``shards=`` / ``$MEMEC_SHARDS`` opt in to S>1.
"""
from __future__ import annotations

import os
from collections import defaultdict
from concurrent.futures import ThreadPoolExecutor

from .index import fnv1a
from .netsim import LatencyRecorder, NetSim, resolve_arrival
from .ring import make_placement
from .store import MemECCluster
from .trace import Span, _fill_seq, resolve_trace

# dedicated hash seed: shard routing must stay independent of the
# per-shard two-stage stripe hashing (stripe.py)
SHARD_SEED = 0x01000193

# batch request kinds the facade re-records with pipelined latencies;
# the per-shard components are excluded from aggregate latency views
BATCH_KINDS = ("MGET", "MSET", "MUPDATE")


def shard_for_key(key: bytes, num_shards: int) -> int:
    """The historical FNV-1a-mod partition (``ModPlacement``'s formula).

    Kept as the mod-policy primitive; cluster code must route through
    ``ShardedCluster.shard_of`` / a ``Placement`` (core/ring.py) instead
    of calling this directly, so elastic placements stay pluggable."""
    if num_shards <= 1:
        return 0
    return fnv1a(key, seed=SHARD_SEED) % num_shards


def resolve_shards(shards=None) -> int:
    """Shard count from the argument or ``$MEMEC_SHARDS`` (default 1)."""
    if shards is None:
        shards = os.environ.get("MEMEC_SHARDS")
    s = 1 if shards in (None, "") else int(shards)
    if s < 1:
        raise ValueError(f"shards must be >= 1, got {s}")
    return s


class ShardedNet:
    """NetSim-shaped aggregate view over per-shard netsims.

    Byte/message counters come from the shards; request latencies come
    from the facade's own records in ``local`` — every routed request,
    single-key and batched, is recorded there (per-shard records are
    that request's shard slice, not an independent client request).
    Endpoints are namespaced ``sh{i}:s{j}`` for S>1 (each shard is
    separate hardware) and left bare for S=1 so the view is a drop-in
    for the unsharded net.
    """

    def __init__(self, cluster: "ShardedCluster"):
        self._cl = cluster
        # the facade's own event runtime lives here: merged batches and
        # migration legs are submitted against per-shard "sh{i}" resource
        # clocks (shard nets stay closed-loop — their phase algebra is
        # the service time, the facade adds the queueing)
        self.local = NetSim(cluster.shards[0].net.cost,
                            arrival=cluster.arrival,
                            trace=cluster._facade_tracer or False)
        self.cost = self.local.cost

    @property
    def events(self):
        return self.local.events

    @property
    def arrival(self):
        return self.local.arrival

    def _shard_nets(self):
        return [sh.net for sh in self._cl.shards]

    def _prefix(self, i: int, ep: str) -> str:
        return ep if self._cl.num_shards == 1 else f"sh{i}:{ep}"

    # -- recording (facade-level merged batches) ------------------------
    def record(self, req_kind: str, latency_s: float):
        self.local.record(req_kind, latency_s)

    # -- merged views ----------------------------------------------------
    @property
    def latencies(self) -> dict:
        """Client-request latencies.  Every facade-routed request —
        single-key (since PR 8) and batched — records here; shard-level
        records are either subsumed by a facade record (the per-shard
        slice of a routed request) or shard-internal control-plane
        traffic (degraded replays inside fail/restore), still visible on
        ``shards[i].net.latencies``."""
        return {k: xs for k, xs in self.local.latencies.items() if xs}

    @property
    def ops_by_kind(self) -> dict:
        return {k: n for k, n in self.local.ops_by_kind.items() if n}

    @property
    def bytes_by_kind(self) -> dict:
        out = defaultdict(int)
        for net in self._shard_nets():
            for kind, n in net.bytes_by_kind.items():
                out[kind] += n
        for kind, n in self.local.bytes_by_kind.items():
            out[kind] += n      # facade-level traffic (stripe migration)
        return dict(out)

    @property
    def msgs_by_kind(self) -> dict:
        out = defaultdict(int)
        for net in self._shard_nets():
            for kind, n in net.msgs_by_kind.items():
                out[kind] += n
        for kind, n in self.local.msgs_by_kind.items():
            out[kind] += n
        return dict(out)

    @property
    def bytes_by_endpoint(self) -> dict:
        out = {}
        for i, net in enumerate(self._shard_nets()):
            for ep, n in net.bytes_by_endpoint.items():
                out[self._prefix(i, ep)] = n
        for ep, n in self.local.bytes_by_endpoint.items():
            # facade legs carry pre-namespaced endpoints (sh{i}:..., mig)
            out[ep] = out.get(ep, 0) + n
        return out

    # -- reporting (shared LatencyRecorder formulas — cannot diverge
    # from NetSim's) ----------------------------------------------------
    def percentile(self, req_kind: str, q: float) -> float:
        return LatencyRecorder.percentile_of(
            self.latencies.get(req_kind, []), q)

    def mean(self, req_kind: str) -> float:
        return LatencyRecorder.mean_of(self.latencies.get(req_kind, []))

    def latency_summary(self) -> dict:
        """Per-kind count/mean/p50/p99/p999 over the merged view (same
        shape as ``NetSim.latency_summary``), with facade-level queue
        waits attached in event mode."""
        out = {k: LatencyRecorder.summary_of(xs)
               for k, xs in sorted(self.latencies.items())}
        if self.local.events is not None:
            for kind, s in out.items():
                ws = self.local.events.waits.latencies.get(kind, [])
                s["queue_wait_s"] = sum(ws)
                s["queue_wait_p99_s"] = LatencyRecorder.percentile_of(ws, 99.0)
        return out

    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def _endpoint_pool(self, endpoints):
        pool = self.bytes_by_endpoint
        if endpoints is not None:
            pool = {e: pool.get(e, 0) for e in endpoints}
        return pool

    def bottleneck_throughput(self, total_ops: int,
                              endpoints: list[str] | None = None) -> float:
        pool = self._endpoint_pool(endpoints)
        if not pool or total_ops == 0:
            return float("nan")
        worst = max(pool.values())
        if worst == 0:
            return float("inf")
        return total_ops / (worst / self.cost.bw_Bps)

    def mean_throughput(self, total_ops: int,
                        endpoints: list[str] | None = None) -> float:
        pool = self._endpoint_pool(endpoints)
        if not pool or total_ops == 0:
            return float("nan")
        total = sum(pool.values())
        if total == 0:
            return float("inf")
        return total_ops / (total / (len(pool) * self.cost.bw_Bps))

    def reset(self):
        for net in self._shard_nets():
            net.reset()
        self.local.reset()

    def snapshot(self) -> dict:
        # per-shard load + skew ride along so rebalancing decisions and
        # benchmarks read one source of truth
        out = {
            "bytes_by_kind": self.bytes_by_kind,
            "msgs_by_kind": self.msgs_by_kind,
            "bytes_by_endpoint": self.bytes_by_endpoint,
            "shard_ops": list(self._cl.shard_ops),
            "load_skew": self._cl.load_skew(),
        }
        if self.local.events is not None:
            out["event"] = self.local.events.snapshot()
        return out


class ShardedCluster:
    """Facade over S independent ``MemECCluster`` shard stores.

    Exposes the full cluster request API (single-key + multi-key), with
    multi-key requests planned across shards and pipelined.  Constructor
    keywords other than ``shards``/``engine``/``pipeline`` are forwarded
    verbatim to every shard store.
    """

    def __init__(self, shards=None, engine=None, pipeline: bool = True,
                 placement=None, arrival=None, trace=None, **cluster_kw):
        from .engine import engine_specs
        self.num_shards = resolve_shards(shards)
        self._engine_spec = engine
        # open-loop event mode runs at the facade (ShardedNet.local): the
        # shard stores are forced closed-loop so their phase algebra
        # stays the pure per-shard service time — the facade adds the
        # queueing against per-shard resource clocks.
        self.arrival = resolve_arrival(arrival)
        # span tracing mirrors that split: the facade tracer records the
        # client-visible requests; each shard gets its own tracer whose
        # request roots are grafted into the facade spans per shard slice
        self._facade_tracer = resolve_trace(trace)
        self._cluster_kw = dict(cluster_kw, arrival="closed",
                                trace=self._facade_tracer is not None)
        specs = engine_specs(engine, self.num_shards)
        self.shards = [MemECCluster(engine=specs[i], shard_id=i,
                                    **self._cluster_kw)
                       for i in range(self.num_shards)]
        s0 = self.shards[0]
        self.servers_per_shard = len(s0.servers)
        self.num_proxies = s0.num_proxies
        self.code, self.n, self.k = s0.code, s0.n, s0.k
        self.chunk_size = s0.chunk_size
        self.degraded_enabled = s0.degraded_enabled
        # intra-shard async pipeline (PR 4) — the per-shard stores resolve
        # the knob ($MEMEC_ASYNC / async_engine= in cluster_kw); exposed
        # here so drivers can pick proxy-spread batches (`proxy_id=None`)
        self.async_engine = s0.async_engine
        # straggler-tolerant read knob (resolved per shard store from
        # redundant_reads= in cluster_kw / $MEMEC_REDUNDANT_READS)
        self.redundant_reads = s0.redundant_reads
        self.engines = [sh.engine for sh in self.shards]
        self.engine = self.engines[0]
        self.pipeline = bool(pipeline) and self.num_shards > 1
        self._stats = {"cross_shard_batches": 0, "pipelined_batches": 0,
                       "pipeline_overlap_saved_s": 0.0,
                       "migrations": 0, "migrated_keys": 0,
                       "migration_bytes": 0, "migration_chunk_bytes": 0}
        # elastic placement: all key routing flows through the Placement
        # policy (core/ring.py); retired shard ids leave the policy but
        # keep their (drained) stores so global server ids stay stable
        self.placement = make_placement(placement, self.num_shards)
        self.retired: set[int] = set()
        # forwarding table for live migration: key -> shard that still
        # holds its bytes (supersedes the placement until the move lands)
        self._pending: dict[bytes, int] = {}
        # per-shard request counters (facade-routed ops) feeding the
        # load-skew metric and skew-aware rebalancing
        self.shard_ops: list[int] = [0] * self.num_shards
        self.net = ShardedNet(self)

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def shard_of(self, key: bytes) -> int:
        if self._pending:
            si = self._pending.get(key)
            if si is not None:
                return si
        return self.placement.shard_for(key)

    def _shard_for(self, key: bytes) -> MemECCluster:
        si = self.shard_of(key)
        self.shard_ops[si] += 1
        return self.shards[si]

    def locate(self, key: bytes):
        """(shard id, stripe list, data server) for a key."""
        si = self.shard_of(key)
        sl, ds = self.shards[si].mapper.data_server_for(key)
        return si, sl, ds

    def global_sid(self, shard: int, local_sid: int) -> int:
        return shard * self.servers_per_shard + local_sid

    def _resolve_server(self, sid: int, shard: int | None) -> tuple[int, int]:
        if shard is None:
            shard, sid = divmod(sid, self.servers_per_shard)
        if not 0 <= shard < self.num_shards:
            raise ValueError(f"no shard {shard}")
        return shard, sid

    @property
    def failed(self) -> set[int]:
        """Global ids of every transiently-failed server across shards."""
        return {self.global_sid(i, s)
                for i, sh in enumerate(self.shards) for s in sh.failed}

    @property
    def stats(self) -> dict:
        out = dict(self._stats)
        for sh in self.shards:
            for k, v in sh.stats.items():
                if not isinstance(v, (int, float)):
                    continue  # nested summaries are rebuilt facade-level
                out[k] = out.get(k, 0) + v
        hot = [sh.stats["hot_tier"] for sh in self.shards
               if "hot_tier" in sh.stats]
        if hot:
            # nested hot-tier summaries are skipped by the numeric merge
            # above — rebuild them facade-level (counter-wise sum)
            merged: dict = {}
            for h in hot:
                for k, v in h.items():
                    merged[k] = merged.get(k, 0) + v
            out["hot_tier"] = merged
        out["shard_ops"] = list(self.shard_ops)
        out["load_skew"] = self.load_skew()
        # merged-view latency percentiles (shared LatencyRecorder
        # formulas) + facade queue-wait breakdown in event mode
        out["latency"] = self.net.latency_summary()
        if self.net.events is not None:
            ev = self.net.events.snapshot()
            out["arrival"] = ev["arrival"]
            out["queue_wait_s"] = ev["queue_wait_s"]
            out["queue_wait_s_by_kind"] = ev["queue_wait_s_by_kind"]
            out["queue_wait_s_by_resource"] = ev["queue_wait_s_by_resource"]
            out["event_makespan_s"] = ev["makespan_s"]
        return out

    def load_skew(self) -> float:
        """Max/mean facade-routed ops across *active* shards (1.0 =
        perfectly balanced; the metric skew-aware rebalancing watches)."""
        loads = [self.shard_ops[s] for s in self.placement.shard_ids
                 if s < len(self.shard_ops)]
        total = sum(loads)
        if not loads or total == 0:
            return 1.0
        return max(loads) / (total / len(loads))

    def reset_load(self):
        """Zero the per-shard op counters (start a fresh skew window)."""
        self.shard_ops = [0] * len(self.shards)

    def server_endpoint_names(self) -> list[str]:
        return [self.net._prefix(i, ep)
                for i, sh in enumerate(self.shards)
                for ep in sh.server_endpoint_names()]

    @property
    def tracer(self):
        """The facade span tracer (None when tracing is off)."""
        return self.net.local.tracer

    def _shard_window(self, sh):
        """Start-of-request snapshot of one shard's recorded time,
        tracer position, and degraded counter."""
        str_ = sh.tracer
        return (sh.net.total_recorded_s,
                len(str_.requests) if str_ is not None else 0,
                sh._stats["degraded_requests"])

    def _shard_slice(self, sh, window):
        """Close a window: (modeled seconds, grafted shard request spans
        — moved out of the shard tracer, degraded?)."""
        t0, n0, d0 = window
        spans = None
        if sh.tracer is not None:
            spans = sh.tracer.requests[n0:]
            del sh.tracer.requests[n0:]
        return (sh.net.total_recorded_s - t0, spans,
                sh._stats["degraded_requests"] > d0)

    # ------------------------------------------------------------------
    # single-key API — shard-local execution, facade-level recording:
    # each op is one facade request (one event in open-loop mode, one
    # span tree when tracing), closing the ROADMAP gap where sharded
    # single-key traffic bypassed the facade EventRuntime.
    # ------------------------------------------------------------------
    def _single(self, kind: str, key: bytes, op):
        si = self.shard_of(key)
        self.shard_ops[si] += 1
        sh = self.shards[si]
        win = self._shard_window(sh)
        out = op(sh)
        dt, spans, degraded = self._shard_slice(sh, win)
        if dt > 0.0 or spans:
            if degraded:
                kind += "_DEG"
            self._record_facade(kind, [(si, dt, spans)])
        return out

    def set(self, key: bytes, value: bytes, proxy_id: int = 0):
        return self._single("SET", key,
                            lambda sh: sh.set(key, value, proxy_id))

    def get(self, key: bytes, proxy_id: int = 0):
        return self._single("GET", key, lambda sh: sh.get(key, proxy_id))

    def update(self, key: bytes, value: bytes, proxy_id: int = 0) -> bool:
        return self._single("UPDATE", key,
                            lambda sh: sh.update(key, value, proxy_id))

    def delete(self, key: bytes, proxy_id: int = 0) -> bool:
        return self._single("DELETE", key,
                            lambda sh: sh.delete(key, proxy_id))

    # ------------------------------------------------------------------
    # multi-key API — cross-shard scatter/gather planner
    # ------------------------------------------------------------------
    def _plan(self, keys) -> dict[int, list[int]]:
        """Group request indices per shard, preserving request order."""
        groups: dict[int, list[int]] = {}
        for i, key in enumerate(keys):
            groups.setdefault(self.shard_of(key), []).append(i)
        return groups

    def _scatter(self, fn, groups: dict[int, list[int]]):
        """Run ``fn(shard_index, request_indices)`` for every shard group.

        Groups are issued idle-engines-first: shards are ordered by their
        engine's cumulative modeled-busy clock (``modeled_busy_s``, fed
        by every coding call), shard id as the deterministic tie-break —
        the serial path drains idle engines before queueing behind busy
        ones, and the thread pool submits them first.  With pipelining,
        groups execute on one worker per shard (each worker touches only
        its own shard's state, so this is safe and deterministic);
        results return in issue order either way.
        """
        items = sorted(groups.items(),
                       key=lambda kv: (self.engines[kv[0]].modeled_busy_s,
                                       kv[0]))
        for si, idxs in items:
            self.shard_ops[si] += len(idxs)
        if self.pipeline and len(items) > 1:
            # per-call pool: workers release with the call (no idle
            # threads outliving the batch), spawn cost is negligible
            # next to the per-shard engine + store work
            with ThreadPoolExecutor(max_workers=len(items)) as pool:
                futures = [(si, idxs, pool.submit(fn, si, idxs))
                           for si, idxs in items]
                return [(si, idxs, f.result()) for si, idxs, f in futures]
        return [(si, idxs, fn(si, idxs)) for si, idxs in items]

    def _record_facade(self, kind: str, entries) -> float:
        """Record one facade request from its per-shard slices
        (``entries``: (shard id, modeled seconds, grafted spans)).

        The merged latency is the slowest shard's slice (full pipeline
        overlap across disjoint shard hardware).  In open-loop event mode
        the request is one event against the facade runtime — each
        involved shard's "sh{i}" resource clock is held for that shard's
        share, so back-to-back requests hitting the same hot shard queue
        there while disjoint shards overlap.  When tracing, the grafted
        shard span trees become per-shard groups under the facade root
        (one Chrome-trace pid per shard)."""
        service = max(dt for _, dt, _ in entries)
        net = self.net.local
        tr = net.tracer
        if tr is not None:
            tr.push()
            groups = []
            for si, dt, spans in entries:
                g = Span(f"sh{si}", "shard", dt, "seq",
                         children=list(spans or []), meta={"shard": si})
                _fill_seq(g)
                groups.append(g)
            if len(groups) == 1:
                tr.add(groups[0])
            else:
                tr.add(Span("scatter", "merge", service, "par",
                            children=groups))
        if net.events is not None:
            busy = {}
            for si, dt, _ in entries:
                busy[f"sh{si}"] = busy.get(f"sh{si}", 0.0) + dt
            net.service.record(kind, service)
            detail = {} if tr is not None else None
            lat = net.events.submit(kind, service, busy, detail_out=detail)
            if tr is not None:
                detail["service"] = service
                tr.finish(kind, lat, detail=detail)
            net.recorder.record(kind, lat)
        else:
            # closed loop: NetSim.record finishes the open span frame
            net.record(kind, service)
        return service

    def _record_batch(self, kind: str, dts):
        """Facade record for one scatter/gathered batch; ``dts``:
        (shard id, modeled seconds, grafted spans) triples."""
        if not dts:
            return
        service = self._record_facade(kind, dts)
        self._stats["cross_shard_batches"] += 1
        if len(dts) > 1:
            self._stats["pipelined_batches"] += 1
            self._stats["pipeline_overlap_saved_s"] += \
                sum(dt for _, dt, _ in dts) - service

    def multi_get(self, keys, proxy_id: int | None = 0) -> list:
        keys = list(keys)
        groups = self._plan(keys)
        out: list = [None] * len(keys)

        def run(si, idxs):
            sh = self.shards[si]
            win = self._shard_window(sh)
            vals = sh.multi_get([keys[i] for i in idxs], proxy_id)
            dt, spans, _ = self._shard_slice(sh, win)
            return vals, dt, spans

        dts = []
        for si, idxs, (vals, dt, spans) in self._scatter(run, groups):
            for i, v in zip(idxs, vals):
                out[i] = v
            dts.append((si, dt, spans))
        self._record_batch("MGET", dts)
        return out

    def multi_set(self, items, proxy_id: int | None = 0) -> list[bool]:
        items = list(items)
        groups = self._plan([k for k, _ in items])
        ok = [False] * len(items)

        def run(si, idxs):
            sh = self.shards[si]
            win = self._shard_window(sh)
            oks = sh.multi_set([items[i] for i in idxs], proxy_id)
            dt, spans, _ = self._shard_slice(sh, win)
            return oks, dt, spans

        dts = []
        for si, idxs, (oks, dt, spans) in self._scatter(run, groups):
            for i, o in zip(idxs, oks):
                ok[i] = o
            dts.append((si, dt, spans))
        self._record_batch("MSET", dts)
        return ok

    def multi_update(self, items, proxy_id: int | None = 0) -> list[bool]:
        items = list(items)
        groups = self._plan([k for k, _ in items])
        ok = [False] * len(items)

        def run(si, idxs):
            sh = self.shards[si]
            win = self._shard_window(sh)
            oks = sh.multi_update([items[i] for i in idxs], proxy_id)
            dt, spans, _ = self._shard_slice(sh, win)
            return oks, dt, spans

        dts = []
        for si, idxs, (oks, dt, spans) in self._scatter(run, groups):
            for i, o in zip(idxs, oks):
                ok[i] = o
            dts.append((si, dt, spans))
        self._record_batch("MUPDATE", dts)
        return ok

    # ------------------------------------------------------------------
    # elasticity — membership changes + skew-aware rebalancing, executed
    # as live stripe migrations (core/rebalance.py)
    # ------------------------------------------------------------------
    def add_shard(self, weight: float = 1.0, engine=None, migrate: bool = True,
                  max_moves: int | None = None, batch_size: int = 64,
                  step_cb=None) -> dict:
        """Grow the cluster by one shard store and (by default) migrate
        the key ranges the new placement assigns to it — live: client
        requests interleave at every ``step_cb`` batch boundary.  Returns
        the migration report (``moved_keys``/``moved_bytes``/...)."""
        from .engine import engine_specs
        from .rebalance import Rebalancer
        new_id = len(self.shards)
        if engine is None:
            # extend the construction-time spec's cycle to the new slot
            engine = engine_specs(self._engine_spec, new_id + 1)[new_id]
        sh = MemECCluster(engine=engine, shard_id=new_id, **self._cluster_kw)
        self.shards.append(sh)
        self.engines.append(sh.engine)
        self.shard_ops.append(0)
        self.num_shards = len(self.shards)
        self.placement.add_shard(new_id, weight=weight)
        report = {"shard": new_id, "moved_keys": 0, "moved_bytes": 0}
        rb = Rebalancer(self, batch_size=batch_size)
        if migrate:
            report.update(rb.run(max_moves=max_moves, step_cb=step_cb))
            report["shard"] = new_id
        else:
            # no data moves yet, but the forwarding table must still be
            # installed — the new placement already routes ~1/S of keys
            # to the (empty) new shard, and they'd read as missing
            plan = rb.plan()
            report["mismatched"] = plan.mismatched
            report["pending_left"] = len(self._pending)
        return report

    def remove_shard(self, shard: int, batch_size: int = 64,
                     step_cb=None) -> dict:
        """Retire a shard: drop it from the placement, then drain every
        resident key to its new home (always a full drain — a retired
        store must end empty).  The store object stays in ``shards`` so
        global server ids and netsim endpoint names remain stable."""
        from .rebalance import Rebalancer
        if shard in self.retired or shard not in self.placement.shard_ids:
            raise ValueError(f"no active shard {shard}")
        self.placement.remove_shard(shard)
        self.retired.add(shard)
        rb = Rebalancer(self, batch_size=batch_size)
        report = rb.run(step_cb=step_cb)
        report["shard"] = shard
        return report

    def rebalance(self, max_moves: int | None = None,
                  skew_threshold: float = 1.25, batch_size: int = 64,
                  step_cb=None, reset_load: bool = True) -> dict:
        """Skew-aware rebalancing: when the per-shard load skew
        (max/mean ``shard_ops``) crosses ``skew_threshold``, shift ring
        weights inversely to observed load and migrate — capped at
        ``max_moves`` keys (the rest stays forwarded until a later pass).
        Requires a weighted placement (ring); the mod placement reports
        itself unsupported rather than reshuffling everything."""
        from .rebalance import Rebalancer, skewed_weights
        skew = self.load_skew()
        report = {"skew_before": skew, "moved_keys": 0, "moved_bytes": 0}
        if skew <= skew_threshold:
            report["skipped"] = "skew below threshold"
            return report
        if not self.placement.supports_weights:
            report["skipped"] = (f"{self.placement.kind} placement does not "
                                 "support weighted rebalancing")
            return report
        loads = {s: float(self.shard_ops[s])
                 for s in self.placement.shard_ids}
        weights = skewed_weights(self.placement, loads)
        for s, w in weights.items():
            self.placement.set_weight(s, w)
        rb = Rebalancer(self, batch_size=batch_size)
        report.update(rb.run(max_moves=max_moves, step_cb=step_cb))
        report["skew_before"] = skew
        report["weights"] = weights
        if reset_load:
            self.reset_load()
        return report

    # ------------------------------------------------------------------
    # shard-scoped failure transitions — one shard's recovery never
    # blocks the others' traffic
    # ------------------------------------------------------------------
    def fail_server(self, sid: int, shard: int | None = None,
                    recover: bool = True) -> dict:
        shard, local = self._resolve_server(sid, shard)
        timings = self.shards[shard].fail_server(local, recover=recover)
        timings["shard"] = shard
        return timings

    def restore_server(self, sid: int, shard: int | None = None) -> dict:
        shard, local = self._resolve_server(sid, shard)
        timings = self.shards[shard].restore_server(local)
        timings["shard"] = shard
        return timings

    def flush_hot_buffers(self) -> int:
        """Drain every shard's hot-key version buffer; returns the total
        number of buffered entries folded back into their stripes."""
        return sum(sh.flush_hot_buffers() for sh in self.shards)

    def inflate_server(self, sid: int, factor: float,
                       shard: int | None = None) -> dict:
        """Slow-server injection (straggler axis): latency-inflate one
        server's legs by ``factor`` inside its shard; ``factor=1.0``
        restores.  Facade event gating stays whole-shard (``sh{i}``)
        granularity — the inflation lands in the shard's phase algebra
        and therefore in the facade-recorded latency."""
        shard, local = self._resolve_server(sid, shard)
        self.shards[shard].inflate_server(local, factor)
        return {"shard": shard, "server": local, "factor": factor}

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def total_memory(self) -> dict:
        agg: dict[str, int] = {}
        for sh in self.shards:
            for k, v in sh.total_memory().items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def stored_payload_bytes(self) -> int:
        return sum(sh.stored_payload_bytes() for sh in self.shards)


def make_cluster(shards=None, engine=None, pipeline: bool = True,
                 placement=None, **cluster_kw):
    """Cluster factory: plain ``MemECCluster`` for S=1 (the unsharded
    special case — byte- and latency-identical to the pre-sharding
    cluster, no placement machinery attached), ``ShardedCluster`` for
    S>1.  ``shards=None`` reads ``$MEMEC_SHARDS``; ``placement=None``
    reads ``$MEMEC_PLACEMENT`` (``mod`` | ``ring`` | ``ring:<vnodes>``,
    default ``mod``)."""
    s = resolve_shards(shards)
    if s == 1:
        from .engine import engine_specs
        return MemECCluster(engine=engine_specs(engine, 1)[0], **cluster_kw)
    return ShardedCluster(shards=s, engine=engine, pipeline=pipeline,
                          placement=placement, **cluster_kw)
