"""Redundancy analysis of the three data models (paper §3.3, Figure 2).

Redundancy := (actual storage per object incl. fault-tolerance redundancy)
              / (K + V + M).

Defaults mirror the paper: M=4, R=8, C=4096, I=8, O=0.9.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AnalysisParams:
    K: float          # key size
    V: float          # value size
    n: int
    k: int
    M: float = 4.0    # metadata size
    R: float = 8.0    # reference size
    C: float = 4096.0  # chunk size
    I: float = 8.0    # chunk-ID size
    O: float = 0.9    # cuckoo-hash occupancy

    @property
    def object_size(self) -> float:
        return self.K + self.V + self.M


def redundancy_all_replication(p: AnalysisParams) -> float:
    """(n-k+1) full copies of (object + reference)."""
    copies = p.n - p.k + 1
    return copies * (p.K + p.V + p.M + p.R) / p.object_size


def redundancy_hybrid_encoding(p: AnalysisParams) -> float:
    """Replicate key+metadata+reference (n-k+1)x; erasure-code the value."""
    copies = p.n - p.k + 1
    return (copies * (p.K + p.M + p.R) + p.n * p.V / p.k) / p.object_size


def redundancy_all_encoding(p: AnalysisParams) -> float:
    """Erasure-code the whole object; local-only indexes (paper eq. §3.3)."""
    obj = p.object_size
    coded = p.n * obj / p.k
    obj_index = p.R / p.O
    objs_per_stripe = p.k * p.C / obj
    chunk_over = p.n * (p.I + p.R / p.O) / objs_per_stripe
    return (coded + obj_index + chunk_over) / obj


MODELS = {
    "all-replication": redundancy_all_replication,
    "hybrid-encoding": redundancy_hybrid_encoding,
    "all-encoding": redundancy_all_encoding,
}


def figure2_table(K: float, nk: tuple[int, int], values=None) -> dict:
    """Reproduce one panel of Figure 2: redundancy vs value size."""
    n, k = nk
    values = values if values is not None else [2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
    rows = {}
    for name, fn in MODELS.items():
        rows[name] = [fn(AnalysisParams(K=K, V=v, n=n, k=k)) for v in values]
    rows["V"] = list(values)
    return rows


def crossover_value(K: float, nk: tuple[int, int], target: float, model: str = "all-encoding",
                    vmax: int = 100000) -> int:
    """Smallest V at which `model` redundancy drops below `target`."""
    n, k = nk
    fn = MODELS[model]
    for v in range(1, vmax):
        if fn(AnalysisParams(K=K, V=v, n=n, k=k)) <= target:
            return v
    return -1


def xla_cost_analysis(compiled) -> dict:
    """Version-tolerant ``compiled.cost_analysis()``.

    jax <= 0.4.x returns a *list* of per-program dicts (one entry for a
    single-device program), newer jax returns the dict directly, and
    either may return None/empty for trivial programs.  Always hands
    back a plain dict so callers can ``.get("flops", 0)`` regardless of
    the installed jax.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return dict(cost) if cost else {}
