"""Hot-key update tier: EWMA hot-set detection + bounded version buffers.

MemEC pays a full parity-delta round (engine call + m parity legs) on
every sealed-object UPDATE; under a Zipf workload the few hottest keys
dominate that cost.  The multi-version coding line of work (Ali &
Cadambe, PAPERS.md) shows update traffic can scale with delta entropy
across versions instead of object size.  This module is the host-side
state for that tier:

* ``HotKeyTracker`` — per-key EWMA-decayed update counters (the PR 3
  ``shard_ops`` idiom applied per key); a key is *hot* once its decayed
  score reaches ``threshold``.
* ``VersionBuffer`` — bounded map of hot sealed objects to their
  buffered version deltas (trimmed XOR segments against the then-current
  chunk bytes).  Successive versions XOR-chain: their fold is the
  collapsed base→latest delta, so N buffered updates cost ONE parity
  round at flush (``CodingEngine.submit_delta_collapse``).
* ``HotTier`` — the two plus the ``stats["hot_tier"]`` counters.

Everything here is deterministic (decay depends only on the op sequence)
and pure host bookkeeping — the flush/merge/barrier logic lives in
``core/store.py``, the collapse math in ``core/engine.py``.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np


def resolve_hot_keys(hot_key_threshold=None, env: str = "MEMEC_HOT_KEYS"
                     ) -> float:
    """Hot-tier knob: the ctor argument, else ``$MEMEC_HOT_KEYS``,
    defaulting to 0.0 (tier off — byte-identical baseline, zero state)."""
    if hot_key_threshold is None:
        raw = os.environ.get(env, "").strip()
        if not raw:
            return 0.0
        hot_key_threshold = float(raw)
    return max(0.0, float(hot_key_threshold))


class HotKeyTracker:
    """EWMA-decayed per-key update counters.

    ``touch(key)`` bumps the key's score by 1 after decaying it by
    ``0.5 ** (ops_since_last / HALFLIFE_OPS)`` — a steady updater's
    score converges near ``1 / (1 - 0.5**(gap/HALFLIFE_OPS))``, so the
    threshold is roughly "sustained share of the update stream".  Decay
    is a pure function of the op counter: replaying the same op sequence
    reproduces the same hot set exactly.
    """

    HALFLIFE_OPS = 64
    MAX_TRACKED = 4096

    def __init__(self, threshold: float):
        self.threshold = float(threshold)
        self.op = 0
        self._score: dict[bytes, tuple[float, int]] = {}

    def touch(self, key: bytes) -> bool:
        """Count one update to ``key``; True when the key is now hot."""
        self.op += 1
        s, last = self._score.get(key, (0.0, self.op))
        s = s * 0.5 ** ((self.op - last) / self.HALFLIFE_OPS) + 1.0
        self._score[key] = (s, self.op)
        if len(self._score) > self.MAX_TRACKED:
            self._prune()
        return s >= self.threshold

    def _prune(self) -> None:
        """Drop entries whose decayed score fell below 1 (i.e. colder
        than a single fresh touch); if everything is warm, keep the top
        half by (score, key) — deterministic tie-break."""
        op = self.op
        decayed = {k: sv * 0.5 ** ((op - lo) / self.HALFLIFE_OPS)
                   for k, (sv, lo) in self._score.items()}
        keep = [k for k, s in decayed.items() if s >= 1.0]
        if len(keep) > self.MAX_TRACKED // 2:
            keep = sorted(keep, key=lambda k: (-decayed[k], k))
            keep = keep[:self.MAX_TRACKED // 2]
        self._score = {k: (decayed[k], op) for k in keep}


@dataclasses.dataclass
class BufferedKey:
    """One hot sealed object's pending version deltas.

    ``versions`` holds trimmed XOR segments ``(chunk_off, seg)`` against
    the then-current chunk bytes (the data server mutated immediately;
    only the parity round was deferred), so XOR-folding them yields the
    collapsed base→latest delta.  ``sl``/``cid`` pin the stripe the
    deltas are owed to — they stay valid even if the key is later
    deleted or re-SET elsewhere (the obligation is per chunk region,
    not per key).
    """
    key: bytes
    sl: object
    cid: object
    versions: list[tuple[int, np.ndarray]]

    def extent(self) -> tuple[int, int]:
        """(min_off, max_end) union extent across buffered versions."""
        lo = min(off for off, _ in self.versions)
        hi = max(off + len(seg) for off, seg in self.versions)
        return lo, hi


class VersionBuffer:
    """Bounded, insertion-ordered map of buffered hot keys.

    ``append`` records one more version; exceeding ``max_keys`` evicts
    the oldest entry (returned so the caller can flush it).  A stripe
    index ``(list_id, stripe_id) -> keys`` backs the read barrier: any
    sealed-chunk race/decode on a stripe flushes that stripe's buffered
    keys first.
    """

    def __init__(self, max_keys: int = 64, max_versions: int = 8):
        self.max_keys = max(1, int(max_keys))
        self.max_versions = max(1, int(max_versions))
        self.entries: dict[bytes, BufferedKey] = {}
        self._by_stripe: dict[tuple, set[bytes]] = {}

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: bytes) -> bool:
        return key in self.entries

    def get(self, key: bytes) -> BufferedKey | None:
        return self.entries.get(key)

    @staticmethod
    def stripe_of(sl, cid) -> tuple:
        return (id(sl), cid.stripe_id)

    def append(self, key: bytes, sl, cid, chunk_off: int, seg: np.ndarray
               ) -> tuple[BufferedKey, BufferedKey | None]:
        """Buffer one version delta; returns (entry, evicted-or-None)."""
        e = self.entries.get(key)
        if e is None:
            e = BufferedKey(key=key, sl=sl, cid=cid, versions=[])
            self.entries[key] = e
            self._by_stripe.setdefault(self.stripe_of(sl, cid),
                                       set()).add(key)
        e.versions.append((int(chunk_off), np.array(seg, dtype=np.uint8)))
        evicted = None
        if len(self.entries) > self.max_keys:
            oldest = next(iter(self.entries))
            if oldest != key:
                evicted = self.pop(oldest)
        return e, evicted

    def full(self, entry: BufferedKey) -> bool:
        return len(entry.versions) >= self.max_versions

    def pop(self, key: bytes) -> BufferedKey | None:
        e = self.entries.pop(key, None)
        if e is not None:
            sk = self.stripe_of(e.sl, e.cid)
            members = self._by_stripe.get(sk)
            if members is not None:
                members.discard(key)
                if not members:
                    del self._by_stripe[sk]
        return e

    def pop_stripe(self, sl, cid) -> list[BufferedKey]:
        """Drain every buffered key owing deltas to (sl, stripe) — the
        read-barrier drain, in insertion order for determinism."""
        members = self._by_stripe.get(self.stripe_of(sl, cid))
        if not members:
            return []
        keys = [k for k in self.entries if k in members]
        return [self.pop(k) for k in keys]

    def pop_all(self) -> list[BufferedKey]:
        out = [self.entries[k] for k in list(self.entries)]
        self.entries.clear()
        self._by_stripe.clear()
        return out


class HotTier:
    """Tracker + buffer + the ``stats["hot_tier"]`` counters."""

    def __init__(self, threshold: float, *, max_keys: int = 64,
                 max_versions: int = 8):
        self.tracker = HotKeyTracker(threshold)
        self.buffer = VersionBuffer(max_keys=max_keys,
                                    max_versions=max_versions)
        self.stats = {
            "buffered_updates": 0,      # sealed updates absorbed by the tier
            "flushes": 0,               # flush rounds (batched collapse calls)
            "flushed_keys": 0,          # entries folded back into stripes
            "flushed_versions": 0,      # versions collapsed across all flushes
            "saved_parity_rounds": 0,   # parity rounds avoided (N-1 per flush)
            "saved_parity_bytes": 0,    # modeled delta-leg bytes avoided
            "evictions": 0,             # capacity-evicted entries (flushed)
            "barrier_flushes": 0,       # read-barrier / failure-driven drains
        }

    def snapshot(self) -> dict:
        return dict(self.stats, buffered_keys=len(self.buffer),
                    tracked_keys=len(self.tracker._score))
