"""Serving engine: batched prefill + decode with EC-protected cache pages.

The decode path is the `serve_step` the dry-run lowers for decode_32k /
long_500k cells.  KV/SSM cache pages can be erasure-coded across the data
axis exactly like checkpoint pages (`protect_cache`): losing a host then
costs a decode-from-k reconstruction instead of recomputing every live
session's prefill — the paper's degraded GET (on-demand, chunk-granular)
applied to serving state.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.distributed.ecstore import ECConfig, ECStateStore


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray
    steps: int


class ServeEngine:
    def __init__(self, model: Model, params, *, max_len: int,
                 batch_size: int, cache_dtype=jnp.bfloat16, rng_seed: int = 0):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self.cache = model.init_cache(batch_size, max_len, dtype=cache_dtype)
        self.cur_len = 0
        self._decode = jax.jit(model.decode_step)
        self._apply = jax.jit(model.apply)
        self.rng = jax.random.PRNGKey(rng_seed)
        self.ec_store: ECStateStore | None = None
        self.ec_parity = None

    # -- serving ---------------------------------------------------------
    def prefill(self, batch: dict) -> jax.Array:
        """Run the prompt through the model token-by-token into the cache
        (simple reference path; production path fuses via model.apply)."""
        toks = batch["tokens"]
        B, S = toks.shape
        logits = None
        for t in range(S):
            logits, self.cache = self._decode(
                self.params, self.cache, toks[:, t], jnp.int32(self.cur_len))
            self.cur_len += 1
        return logits

    def decode(self, steps: int, temperature: float = 0.0,
               first_tokens=None) -> GenerationResult:
        out = []
        tok = first_tokens
        for _ in range(steps):
            logits, self.cache = self._decode(
                self.params, self.cache, tok, jnp.int32(self.cur_len))
            if temperature > 0:
                self.rng, k = jax.random.split(self.rng)
                tok = jax.random.categorical(k, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            tok = tok.astype(jnp.int32)
            out.append(np.asarray(tok))
            self.cur_len += 1
        return GenerationResult(np.stack(out, axis=1), steps)

    # -- EC protection of serving state -----------------------------------
    def protect_cache(self, mesh, cache_specs, ec_cfg: ECConfig | None = None):
        self.ec_store = ECStateStore(mesh, cache_specs, ec_cfg)
        self.ec_parity = self.ec_store.encode(self.cache)
        return self.ec_parity

    def refresh_cache_parity(self, old_cache):
        assert self.ec_store is not None
        self.ec_parity = self.ec_store.delta_update(
            old_cache, self.cache, self.ec_parity)

    def recover_cache_pages(self, failed_data_index: int):
        assert self.ec_store is not None
        return self.ec_store.reconstruct(self.cache, self.ec_parity,
                                         failed_data_index)


def greedy_generate(model: Model, params, prompt_tokens, steps: int,
                    max_len: int | None = None):
    """One-shot convenience used by examples/tests."""
    B, S = prompt_tokens.shape
    eng = ServeEngine(model, params, max_len=max_len or (S + steps),
                      batch_size=B)
    logits = eng.prefill({"tokens": prompt_tokens})
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    res = eng.decode(steps - 1, first_tokens=first) if steps > 1 else \
        GenerationResult(np.asarray(first)[:, None], 1)
    toks = np.concatenate([np.asarray(first)[:, None], res.tokens], axis=1) \
        if steps > 1 else res.tokens
    return toks[:, :steps]
