"""serve subpackage."""
