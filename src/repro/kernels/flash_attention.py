"""Pallas TPU kernel: fused causal flash attention (QK^T -> online
softmax -> PV in one VMEM pass).

§Perf cell B identified the fp32 score round-trips of the pure-JAX
blockwise attention as the top memory lever for the dense train cells
(est. −35 % t_memory): XLA materializes the (bq, bkv) scores and the
online-softmax carries through HBM between scan steps, where a fused
kernel keeps them in VMEM scratch.

Grid (BH, nq, nkv), iterated kv-fastest; scratch (acc, m, l) persists
across the kv axis and the output tile is written on the last kv step —
the standard TPU flash-attention schedule.  Causal masking by absolute
positions; GQA is handled by the caller expanding KV heads (the wrapper
does it lazily per head group).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import dispatch

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bkv: int, nkv: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                    # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if causal:
        qp = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kp = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(qp >= kp, s, NEG_INF)
    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == nkv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal",
                                             "interpret"))
def _flash_call(q, k, v, *, bq, bkv, causal, interpret):
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    nq, nkv = Sq // bq, Skv // bkv
    scale = 1.0 / np.sqrt(hd)
    kernel = functools.partial(_flash_kernel, bq=bq, bkv=bkv, nkv=nkv,
                               scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: bool | None = None):
    """q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd).  Returns (B, Sq, H, hd).

    GQA: each group of H//KV query heads shares a KV head; the wrapper
    expands by indexing (no materialized repeat).

    Dispatch: compiled Pallas flash schedule on TPU/GPU; on CPU a jitted
    dense-softmax attention (XLA CPU has no flash win to fuse for, and
    interpret-mode Pallas would just simulate the grid serially).
    """
    dec = dispatch.decide(interpret)
    if dec.path == dispatch.XLA:
        kv_idx = np.arange(q.shape[2]) // (q.shape[2] // k.shape[2])
        return _attention_xla(jnp.asarray(q), jnp.asarray(k),
                              jnp.asarray(v), jnp.asarray(kv_idx),
                              causal=causal)
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    # pad to tile multiples (padded kv masked by causal vs real positions)
    Sq_p = -(-Sq // bq) * bq
    Skv_p = -(-Skv // bkv) * bkv
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    # (B, H, S, hd) flat over batch*heads; kv expanded by head-group index
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq_p, hd)
    kv_idx = np.arange(H) // G
    kf = k.transpose(0, 2, 1, 3)[:, kv_idx].reshape(B * H, Skv_p, hd)
    vf = v.transpose(0, 2, 1, 3)[:, kv_idx].reshape(B * H, Skv_p, hd)
    out = _flash_call(qf, kf, vf, bq=bq, bkv=bkv, causal=causal,
                      interpret=dec.interpret)
    out = out.reshape(B, H, Sq_p, hd).transpose(0, 2, 1, 3)
    return out[:, :Sq]


@functools.partial(jax.jit, static_argnames=("causal",))
def _attention_xla(q, k, v, kv_idx, *, causal):
    """Dense-softmax attention in fp32, GQA by KV-head indexing — the
    compiled CPU twin of the flash kernel (same math, no tiling)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    kf = k[:, :, kv_idx]                                  # (B, Skv, H, hd)
    vf = v[:, :, kv_idx]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)
