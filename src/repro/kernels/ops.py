"""jit'd public wrappers around the Pallas kernels.

Every op dispatches to the Pallas kernel (interpret-mode on CPU, compiled
on TPU); `use_ref=True` routes to the pure-jnp oracle instead — benchmarks
use this to compare, tests to cross-validate.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import gf256
from repro.core.codes import RSCode

from . import ref as _ref
from .cuckoo_lookup import cuckoo_lookup
from .delta_update import delta_update
from .gf256_matmul import gf256_matmul


def encode_stripe(code: RSCode, data: jax.Array, *, use_ref: bool = False,
                  interpret: bool | None = None) -> jax.Array:
    """(k, C) data chunks -> (m, C) parity chunks."""
    A = code.parity_matrix
    if use_ref:
        return _ref.rs_encode_ref(A, data)
    return gf256_matmul(A, data, interpret=interpret)


def decode_stripe(code: RSCode, available: dict[int, jax.Array],
                  wanted: list[int], chunk_size: int, *,
                  use_ref: bool = False,
                  interpret: bool | None = None) -> dict[int, jax.Array]:
    """Reconstruct stripe positions from any k available chunks.

    The (k,k) decode-matrix inversion runs on the host (failure sets are
    concrete coordinator events); the (k,k)x(k,C) products run on device.
    """
    inv, idx = code.decode_matrix(list(available.keys()))
    stacked = jnp.stack([jnp.asarray(available[i], jnp.uint8) for i in idx])
    mm = _ref.rs_decode_ref if use_ref else (
        lambda M, D: gf256_matmul(np.asarray(M), D, interpret=interpret))
    data = mm(inv, stacked)
    out = {}
    G = code.generator
    need_par = [w for w in wanted if w >= code.k]
    for w in wanted:
        if w < code.k:
            out[w] = data[w]
    if need_par:
        par = mm(G[need_par], data)
        for r, w in enumerate(need_par):
            out[w] = par[r]
    return out


def apply_parity_delta(code: RSCode, parity: jax.Array, data_index: int,
                       old: jax.Array, new: jax.Array, *,
                       use_ref: bool = False,
                       interpret: bool | None = None) -> jax.Array:
    """Fused P' = P ⊕ gamma_i (old ⊕ new) for all m parity rows."""
    gammas = code.parity_matrix[:, data_index].astype(np.int32)
    if use_ref:
        return _ref.delta_update_ref(parity, jnp.asarray(gammas), old, new)
    return delta_update(parity, jnp.asarray(gammas), old, new,
                        interpret=interpret)


def batched_index_lookup(index, keys: list[bytes], *, use_ref: bool = False,
                         interpret: bool | None = None):
    """Probe a CuckooIndex for many keys at once on device.

    Returns (found bool (Q,), slot int32 (Q,)).  Fingerprint equality is
    exact at the table level; callers resolve the slot to the stored entry.
    """
    from repro.core.index import hash_pair
    fps, occ = index.bucket_arrays()
    h1s, h2s, qs = [], [], []
    for key in keys:
        h1, h2 = hash_pair(key)
        h1s.append(h1)
        h2s.append(h2)
        qs.append(h1 if h1 != 0 else 1)
    h1a = np.array(h1s, dtype=np.uint64)
    h2a = np.array(h2s, dtype=np.uint64)
    fpa = np.array(qs, dtype=np.uint64)
    if use_ref:
        B = fps.shape[0]
        flo = jnp.asarray((fps & np.uint64(0xFFFFFFFF)).astype(np.uint32))
        fhi = jnp.asarray((fps >> np.uint64(32)).astype(np.uint32))
        found, slot = _ref.cuckoo_lookup_ref(
            flo, fhi, jnp.asarray(occ, dtype=jnp.int32),
            jnp.asarray((h1a % B).astype(np.int32)),
            jnp.asarray((h2a % B).astype(np.int32)),
            jnp.asarray((fpa & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
            jnp.asarray((fpa >> np.uint64(32)).astype(np.uint32)))
        return found, slot
    return cuckoo_lookup(fps, occ, h1a, h2a, fpa, interpret=interpret)


def bytes_of(x: jax.Array) -> jax.Array:
    """Bit-cast any tensor to its flat uint8 byte view (for EC over params)."""
    return gf256.bytes_view(x)


def from_bytes(b: jax.Array, dtype, shape) -> jax.Array:
    return gf256.from_bytes_view(b, dtype, shape)
