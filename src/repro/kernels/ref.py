"""Pure-jnp oracles for the Pallas kernels.

These mirror the numpy host data plane (`repro.core.gf256/codes`) in JAX so
every kernel has an in-framework reference implementation to sweep against.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import gf256


def _tables():
    return jnp.asarray(gf256.EXP_TABLE), jnp.asarray(gf256.LOG_TABLE)


def gf256_mul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise GF(2^8) product via log/exp tables."""
    exp, log = _tables()
    a = a.astype(jnp.uint8)
    b = b.astype(jnp.uint8)
    prod = exp[(log[a.astype(jnp.int32)] + log[b.astype(jnp.int32)]) % 255]
    return jnp.where((a == 0) | (b == 0), jnp.uint8(0), prod)


def gf256_matmul_ref(A: jax.Array, D: jax.Array) -> jax.Array:
    """GF(2^8) matmul (m,k) x (k,C) -> (m,C) with XOR accumulation."""
    A = jnp.asarray(A, dtype=jnp.uint8)
    D = jnp.asarray(D, dtype=jnp.uint8)
    m, k = A.shape
    out = jnp.zeros((m,) + D.shape[1:], dtype=jnp.uint8)
    for i in range(k):
        out = out ^ gf256_mul_ref(
            jnp.broadcast_to(A[:, i][:, None], (m,) + D.shape[1:]), D[i][None])
    return out


def delta_update_ref(parity: jax.Array, gammas: jax.Array,
                     old: jax.Array, new: jax.Array) -> jax.Array:
    """P_j' = P_j ⊕ gamma_j * (old ⊕ new)   (paper §2 linearity).

    parity: (m, C); gammas: (m,); old/new: (C,).
    """
    xor = old.astype(jnp.uint8) ^ new.astype(jnp.uint8)
    m = parity.shape[0]
    scaled = gf256_mul_ref(
        jnp.broadcast_to(gammas.astype(jnp.uint8)[:, None], (m, xor.shape[-1])),
        jnp.broadcast_to(xor[None], (m, xor.shape[-1])))
    return parity ^ scaled


def cuckoo_lookup_ref(flo: jax.Array, fhi: jax.Array, occupied: jax.Array,
                      b1: jax.Array, b2: jax.Array,
                      qlo: jax.Array, qhi: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Batched 2-bucket x 4-slot probe.

    Fingerprints are carried as (lo, hi) uint32 pairs (JAX defaults to
    32-bit; TPUs have no 64-bit lanes).  flo/fhi: (B,4) tables;
    occupied: (B,4); b1/b2: (Q,) int32 bucket indices; qlo/qhi: (Q,).
    Returns (found: (Q,) bool, slot: (Q,) int32 = bucket*4+slot or -1).
    """
    hit1 = (occupied[b1] != 0) & (flo[b1] == qlo[:, None]) & (fhi[b1] == qhi[:, None])
    hit2 = (occupied[b2] != 0) & (flo[b2] == qlo[:, None]) & (fhi[b2] == qhi[:, None])
    slot_ids = jnp.arange(4, dtype=jnp.int32)[None, :]
    big = jnp.int32(2 ** 30)
    s1 = jnp.min(jnp.where(hit1, b1[:, None] * 4 + slot_ids, big), axis=1)
    s2 = jnp.min(jnp.where(hit2, b2[:, None] * 4 + slot_ids, big), axis=1)
    slot = jnp.minimum(s1, s2)
    found = slot < big
    return found, jnp.where(found, slot, -1)


def rs_encode_ref(parity_matrix: np.ndarray, data: jax.Array) -> jax.Array:
    """Stripe encode: (k, C) data -> (m, C) parity."""
    return gf256_matmul_ref(jnp.asarray(parity_matrix), data)


def rs_decode_ref(inv_matrix: np.ndarray, available: jax.Array) -> jax.Array:
    """Data reconstruction given host-inverted decode matrix (k,k)x(k,C)."""
    return gf256_matmul_ref(jnp.asarray(inv_matrix), available)
