"""Pallas TPU kernels for MemEC's compute hot spots.

* gf256_matmul — stripe encode/decode as bit-plane GF(2^8) matmul;
* delta_update — fused P' = P ⊕ gamma·(D ⊕ D') parity maintenance;
* cuckoo_lookup — batched 2x4 index probe via scalar-prefetch row gather;
* flash_attention — fused causal QK^T->softmax->PV with VMEM scratch
  (the §Perf cell-B memory lever for dense training/prefill).

`ops` holds the jit'd public wrappers; `ref` the pure-jnp oracles.
`dispatch` picks the path per backend: compiled Pallas on TPU/GPU, the
XLA-jitted GF(2^8) twins (`xla_gf256`) on CPU, interpret-mode Pallas
only behind `$MEMEC_INTERPRET=1`; `tune` autotunes strategy/block_c per
shape into a persisted cache.
"""
from . import ops, ref
from .cuckoo_lookup import cuckoo_lookup
from .delta_update import delta_update
from .flash_attention import flash_attention
from .gf256_matmul import build_apow, gf256_matmul

__all__ = ["ops", "ref", "cuckoo_lookup", "delta_update", "flash_attention",
           "gf256_matmul", "build_apow"]
