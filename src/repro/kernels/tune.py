"""Shape autotuner for the GF(2^8) kernel data plane.

The kernels have real strategy choices — per-element unroll vs column
loop vs 0/1 XOR-select on the Pallas grids, packed bit-plane vs log/exp
table on the XLA CPU path — plus a ``block_c`` tile knob, and the right
answer depends on ``(k, m, chunk_size, batch)`` and the dispatch path.
The old code hard-wired one threshold (``MAX_UNROLL_OPS = 1024``); this
module turns that into a measured, persisted decision:

* ``lookup(op, path, ...)`` — consult the tuning cache; returns
  ``{"strategy": ..., "block_c": ...}`` or None (callers then use their
  built-in heuristic, so a missing/corrupt cache can never break
  dispatch — regression-tested).
* ``autotune(...)`` — time every valid (strategy, block_c) candidate for
  one shape and record the winner.
* ``autotune_ci_shapes()`` — the sweep behind ``python -m
  benchmarks.kernels_bench --tune``: tunes the CI bench shapes and
  persists the cache.

Cache file: ``$MEMEC_TUNE_CACHE`` when set, else the committed defaults
``kernels/tune_defaults.json`` (tuned on the CI runner class).  The JSON
is a flat ``{key: entry}`` map with keys like
``matmul/xla-compiled/gf/k8m2c4096b16``.
"""
from __future__ import annotations

import json
import os
import time
import warnings

import numpy as np

DEFAULTS_PATH = os.path.join(os.path.dirname(__file__), "tune_defaults.json")

# block_c candidates for the Pallas grids (lane-aligned); the XLA path
# has no tile knob, so its entries carry block_c = 0
BLOCK_C_CANDIDATES = (512, 1024, 2048, 4096)

_cache: dict | None = None
_cache_src: str | None = None          # path the cache was loaded from
_warned: set = set()


def cache_path() -> str:
    """Active cache file: ``$MEMEC_TUNE_CACHE`` or the committed defaults."""
    return os.environ.get("MEMEC_TUNE_CACHE") or DEFAULTS_PATH


def _warn_once(msg: str) -> None:
    if msg not in _warned:
        _warned.add(msg)
        warnings.warn(msg, stacklevel=3)


def load_cache(reload: bool = False) -> dict:
    """The tuning map (lazily loaded; reloaded when the env path moves).

    A missing or corrupt cache degrades to ``{}`` — dispatch falls back
    to the built-in heuristics, it never crashes."""
    global _cache, _cache_src
    path = cache_path()
    if _cache is not None and _cache_src == path and not reload:
        return _cache
    entries: dict = {}
    try:
        with open(path) as f:
            raw = json.load(f)
        body = raw.get("entries", raw) if isinstance(raw, dict) else None
        if isinstance(body, dict):
            entries = {k: v for k, v in body.items()
                       if isinstance(v, dict) and "strategy" in v}
        else:
            _warn_once(f"tune cache {path}: not a JSON object; ignoring")
    except FileNotFoundError:
        if path != DEFAULTS_PATH:
            _warn_once(f"tune cache {path}: not found; using heuristics")
    except (json.JSONDecodeError, OSError) as e:
        _warn_once(f"tune cache {path}: unreadable ({e}); using heuristics")
    _cache, _cache_src = entries, path
    return entries


def key(op: str, path: str, *, k: int, m: int, chunk: int, batch: int,
        cls: str = "gf") -> str:
    """Cache key: op + dispatch path + matrix class (``01`` matrices have
    strategies dense ones can't use) + the shape tuple."""
    return f"{op}/{path}/{cls}/k{k}m{m}c{chunk}b{batch}"


def matrix_cls(A) -> str:
    return "01" if int(np.asarray(A).max(initial=0)) <= 1 else "gf"


def lookup(op: str, path: str, *, k: int, m: int, chunk: int, batch: int,
           cls: str = "gf") -> dict | None:
    """Tuned entry for a shape, or None (caller heuristic applies)."""
    return load_cache().get(key(op, path, k=k, m=m, chunk=chunk,
                                batch=batch, cls=cls))


def record(entry_key: str, entry: dict) -> None:
    cache = load_cache()
    cache[entry_key] = entry


def save(path: str | None = None) -> str:
    """Persist the in-memory cache (sorted, versioned) and return the path."""
    path = path or cache_path()
    cache = load_cache()
    with open(path, "w") as f:
        json.dump({"version": 1,
                   "entries": {k: cache[k] for k in sorted(cache)}},
                  f, indent=2, sort_keys=True)
        f.write("\n")
    return path


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def _time_call(fn, reps: int = 5) -> float:
    """Median-of-reps wall time in us (each rep blocks on the device)."""
    import jax
    jax.block_until_ready(fn())          # warmup / compile
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def candidates(op: str, path: str, *, ops: int, is01: bool) -> list[dict]:
    """Valid (strategy, block_c) combinations for one op on one path."""
    from . import dispatch, xla_gf256
    out = []
    if path == dispatch.XLA:
        strategies = [xla_gf256.BITPLANE32, xla_gf256.TABLE]
        if is01:
            strategies.append(xla_gf256.SELECT32)
        return [{"strategy": s, "block_c": 0} for s in strategies]
    # pallas-shaped paths: strategy x block_c grid
    strategies = ["cols"]
    if op == "matmul" and ops <= 8192:   # unroll trace blows up past this
        strategies.append("unroll")
    if is01:
        strategies.append("gf01")
    for s in strategies:
        for bc in BLOCK_C_CANDIDATES:
            out.append({"strategy": s, "block_c": bc})
    return out


def autotune_matmul(A: np.ndarray, *, chunk: int, batch: int,
                    path: str | None = None, reps: int = 5,
                    verbose: bool = False) -> dict:
    """Tune the shared-matrix batched matmul for one (A, chunk, batch).

    ``chunk`` is the *chunk size* at the engine interface; the matrix's
    block width (k*r columns) determines the device-side block width.
    Records and returns the winning entry."""
    from . import dispatch, xla_gf256
    from .gf256_matmul import gf256_matmul_batched
    path = path or dispatch.decide().path
    A = np.asarray(A, dtype=np.uint8)
    O, J = A.shape
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, (max(batch, 1), J, chunk), dtype=np.uint8)
    is01 = int(A.max(initial=0)) <= 1
    best = None
    for cand in candidates("matmul", path, ops=O * J * 8, is01=is01):
        if batch == 1 and path == dispatch.XLA:
            # batch=1 entries feed the single-stripe call, which has its
            # own 2D jits — time the path the entry will actually steer
            fn = (lambda cand=cand: xla_gf256.matmul(
                A, data[0], strategy=cand["strategy"]))
        else:
            fn = (lambda cand=cand: gf256_matmul_batched(
                A, data, strategy=cand["strategy"],
                block_c=cand["block_c"] or 2048,
                interpret=(True if path == dispatch.INTERPRET else None)))
        try:
            us = _time_call(fn, reps=reps)
        except Exception as e:     # a candidate failing to lower is data
            if verbose:
                print(f"  {cand} failed: {type(e).__name__}")
            continue
        if verbose:
            print(f"  matmul k{J}m{O}c{chunk}b{batch} {cand} -> {us:.1f}us")
        if best is None or us < best["us"]:
            best = dict(cand, us=round(us, 2))
    assert best is not None, "no tuning candidate succeeded"
    entry_key = key("matmul", path, k=J, m=O, chunk=chunk, batch=batch,
                    cls="01" if is01 else "gf")
    record(entry_key, best)
    return best


def autotune_delta_per_item(M: np.ndarray, *, chunk: int, batch: int,
                            path: str | None = None, reps: int = 5,
                            verbose: bool = False) -> dict:
    """Tune the per-item-matrix delta fold (the r > 1 / RDP update shape
    and the hot-tier flush collapse).

    ``M`` is one (O, J) per-item system prototype (replicated across the
    batch for timing — real calls vary the matrix per item, which
    changes nothing about strategy/tile choice); ``chunk`` is the
    device-side block width C of the call, i.e. engine chunk_size / r.
    Records and returns the winning entry."""
    from . import dispatch
    from .delta_update import delta_apply_per_item_batched
    path = path or dispatch.decide().path
    M = np.asarray(M, dtype=np.uint8)
    O, J = M.shape
    rng = np.random.default_rng(0)
    Ms = np.ascontiguousarray(
        np.broadcast_to(M, (max(batch, 1), O, J)))
    blocks = rng.integers(0, 256, (max(batch, 1), J, chunk), dtype=np.uint8)
    parity = rng.integers(0, 256, (max(batch, 1), O, chunk), dtype=np.uint8)
    is01 = int(M.max(initial=0)) <= 1
    best = None
    for cand in candidates("delta_per_item", path, ops=O * J * 8, is01=is01):
        fn = (lambda cand=cand: delta_apply_per_item_batched(
            parity, Ms, blocks, strategy=cand["strategy"],
            block_c=cand["block_c"] or None,
            interpret=(True if path == dispatch.INTERPRET else None)))
        try:
            us = _time_call(fn, reps=reps)
        except Exception as e:     # a candidate failing to lower is data
            if verbose:
                print(f"  {cand} failed: {type(e).__name__}")
            continue
        if verbose:
            print(f"  delta_per_item k{J}m{O}c{chunk}b{batch} {cand} "
                  f"-> {us:.1f}us")
        if best is None or us < best["us"]:
            best = dict(cand, us=round(us, 2))
    assert best is not None, "no tuning candidate succeeded"
    entry_key = key("delta_per_item", path, k=J, m=O, chunk=chunk,
                    batch=batch, cls="01" if is01 else "gf")
    record(entry_key, best)
    return best


def autotune_ci_shapes(verbose: bool = True) -> dict:
    """Tune the shapes the CI bench smoke exercises; returns the cache.

    Called by ``python -m benchmarks.kernels_bench --tune``; commit the
    refreshed ``tune_defaults.json`` when the runner class changes."""
    from repro.core.codes import RSCode, make_code
    from repro.core.engine import block_rep
    rs = RSCode(n=10, k=8)
    rdp = make_code("rdp", 10, 8)
    rep = block_rep(rdp)
    shapes = [
        # (matrix, chunk at the matmul interface, batch)
        (rs.parity_matrix, 4096, 1),        # bench encode row
        (rs.parity_matrix, 4096, 16),       # batched engine row
        (rs.parity_matrix, 65536, 1),       # slow-sweep encode row
        (rep.encode, 4096 // rep.r, 4),     # RDP block encode (0/1)
    ]
    for A, chunk, batch in shapes:
        if verbose:
            O, J = A.shape
            print(f"tuning matmul k={J} m={O} chunk={chunk} batch={batch}")
        autotune_matmul(np.asarray(A), chunk=chunk, batch=batch,
                        verbose=verbose)
    # per-item-matrix delta shapes (r > 1 RDP updates + hot-tier flush):
    # the RDP per-item system is the (m*r, r) column slice of the block
    # matrix (0/1), at device width chunk/r; the RS hot-tier collapse is
    # the (m, 1) parity-matrix column at full chunk width (dense gf).
    E4 = np.asarray(rep.encode).reshape(rdp.m * rep.r, rdp.k, rep.r)
    Mi = np.ascontiguousarray(E4[:, 0, :])            # (m*r, r), 0/1
    for batch in (4, 16):
        if verbose:
            print(f"tuning delta_per_item k={rep.r} m={rdp.m * rep.r} "
                  f"chunk={4096 // rep.r} batch={batch}")
        autotune_delta_per_item(Mi, chunk=4096 // rep.r, batch=batch,
                                verbose=verbose)
    Mrs = np.ascontiguousarray(
        np.asarray(rs.parity_matrix)[:, :1])          # (m, 1), dense
    if verbose:
        print(f"tuning delta_per_item k=1 m={rs.m} chunk=512 batch=4")
    autotune_delta_per_item(Mrs, chunk=512, batch=4, verbose=verbose)
    return load_cache()
