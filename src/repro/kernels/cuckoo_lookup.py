"""Pallas TPU kernel: batched cuckoo-hash probe (data-plane GET path).

Each query probes its two candidate buckets (4 slots each) of the object
index (paper §3.2).  The TPU-idiomatic form of this gather is *scalar
prefetch*: the bucket ids are prefetched into SMEM and consumed by the
BlockSpec index maps, so each grid step DMAs exactly the two (1,4) bucket
rows it needs from HBM — the Pallas equivalent of a row gather.

64-bit fingerprints are carried as (lo, hi) uint32 pairs: TPUs have no
64-bit integer lanes, so the comparison is done as two 32-bit equalities.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dispatch

try:  # TPU grid spec with scalar prefetch
    from jax.experimental.pallas import tpu as pltpu
    _HAVE_PLTPU = True
except Exception:  # pragma: no cover
    _HAVE_PLTPU = False


def _probe_kernel(b1_ref, b2_ref, f1lo, f1hi, o1, f2lo, f2hi, o2,
                  qlo_ref, qhi_ref, found_ref, slot_ref):
    q = pl.program_id(0)
    qlo = qlo_ref[0]
    qhi = qhi_ref[0]
    slot_ids = jax.lax.iota(jnp.int32, 4)
    big = jnp.int32(2 ** 30)
    hit1 = (f1lo[0] == qlo) & (f1hi[0] == qhi) & (o1[0] != 0)
    hit2 = (f2lo[0] == qlo) & (f2hi[0] == qhi) & (o2[0] != 0)
    s1 = jnp.min(jnp.where(hit1, b1_ref[q] * 4 + slot_ids, big))
    s2 = jnp.min(jnp.where(hit2, b2_ref[q] * 4 + slot_ids, big))
    s = jnp.minimum(s1, s2)
    found_ref[0] = (s < big).astype(jnp.int32)
    slot_ref[0] = jnp.where(s < big, s, -1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _probe_call(b1, b2, flo, fhi, occ, qlo, qhi, *, interpret):
    Q = b1.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Q,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda q, b1, b2: (b1[q], 0)),
            pl.BlockSpec((1, 4), lambda q, b1, b2: (b1[q], 0)),
            pl.BlockSpec((1, 4), lambda q, b1, b2: (b1[q], 0)),
            pl.BlockSpec((1, 4), lambda q, b1, b2: (b2[q], 0)),
            pl.BlockSpec((1, 4), lambda q, b1, b2: (b2[q], 0)),
            pl.BlockSpec((1, 4), lambda q, b1, b2: (b2[q], 0)),
            pl.BlockSpec((1,), lambda q, b1, b2: (q,)),
            pl.BlockSpec((1,), lambda q, b1, b2: (q,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda q, b1, b2: (q,)),
            pl.BlockSpec((1,), lambda q, b1, b2: (q,)),
        ],
    )
    return pl.pallas_call(
        _probe_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Q,), jnp.int32),
                   jax.ShapeDtypeStruct((Q,), jnp.int32)],
        interpret=interpret,
    )(b1, b2, flo, fhi, occ, flo, fhi, occ, qlo, qhi)


def cuckoo_lookup(fingerprints, occupied, h1, h2, fp, *,
                  interpret: bool | None = None):
    """Batched probe.

    fingerprints: (B,4) uint64 (numpy or jnp); occupied: (B,4) bool;
    h1/h2: (Q,) uint64 hashes; fp: (Q,) uint64 fingerprints.
    Returns (found bool (Q,), slot int32 (Q,) = bucket*4+slot or -1).

    Dispatch: compiled Pallas scalar-prefetch grid on TPU/GPU; on CPU the
    jitted jnp probe (``ref.cuckoo_lookup_ref`` — the gather vectorizes
    fine under XLA CPU, no interpret tax).
    """
    import numpy as np
    dec = dispatch.decide(interpret)
    fingerprints = np.asarray(fingerprints, dtype=np.uint64)
    B = fingerprints.shape[0]
    flo = jnp.asarray((fingerprints & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    fhi = jnp.asarray((fingerprints >> np.uint64(32)).astype(np.uint32))
    occ = jnp.asarray(np.asarray(occupied), dtype=jnp.int32)
    h1 = np.asarray(h1, dtype=np.uint64)
    h2 = np.asarray(h2, dtype=np.uint64)
    fp = np.asarray(fp, dtype=np.uint64)
    b1 = jnp.asarray((h1 % B).astype(np.int32))
    b2 = jnp.asarray((h2 % B).astype(np.int32))
    qlo = jnp.asarray((fp & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    qhi = jnp.asarray((fp >> np.uint64(32)).astype(np.uint32))
    if dec.path == dispatch.XLA:
        found, slot = _probe_xla(flo, fhi, occ, b1, b2, qlo, qhi)
    else:
        found, slot = _probe_call(b1, b2, flo, fhi, occ, qlo, qhi,
                                  interpret=dec.interpret)
    return found.astype(bool), slot


@jax.jit
def _probe_xla(flo, fhi, occ, b1, b2, qlo, qhi):
    from repro.kernels.ref import cuckoo_lookup_ref
    return cuckoo_lookup_ref(flo, fhi, occ, b1, b2, qlo, qhi)
