"""Pallas TPU kernel: fused delta parity update  P' = P ⊕ gamma·(D ⊕ D').

This is the paper's UPDATE hot path (§2/§4.2) and the inner loop of the
EC-checkpoint maintenance in training: every step the optimizer's byte
delta is folded into the m parity rows.  Fusing XOR + GF-scale + XOR into
one kernel reads old/new/parity once from HBM and writes parity once —
3 reads + 1 write per byte, the bandwidth floor for this op.

gamma powers (gamma * 2^b) are computed *in-kernel* from the scalar gamma
via 8 xtime steps (shift + conditional reduction by the field polynomial
0x11D), so the kernel accepts traced per-row coefficients — no host table
needed, which matters when the stripe position (and hence gamma) is picked
dynamically by the stripe mapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dispatch

DEFAULT_BLOCK_C = 2048


def _delta_kernel(g_ref, p_ref, old_ref, new_ref, o_ref, *, m: int):
    x = (old_ref[...] ^ new_ref[...]).astype(jnp.int32)       # (BC,)
    outs = []
    for r in range(m):
        g = g_ref[r].astype(jnp.int32)                        # scalar gamma
        acc = jnp.zeros_like(x)
        for b in range(8):
            acc = acc ^ (((x >> b) & 1) * g)
            # xtime: g <- g*2 in GF(2^8) / 0x11D
            g = ((g << 1) ^ jnp.where((g & 0x80) != 0, 0x11D, 0)) & 0xFF
        outs.append(p_ref[r] ^ acc.astype(jnp.uint8))
    o_ref[...] = jnp.stack(outs)


@functools.partial(jax.jit, static_argnames=("m", "block_c", "interpret"))
def _delta_call(gammas, parity, old, new, *, m, block_c, interpret):
    C = parity.shape[1]
    grid = (C // block_c,)
    return pl.pallas_call(
        functools.partial(_delta_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda c: (0,)),
            pl.BlockSpec((m, block_c), lambda c: (0, c)),
            pl.BlockSpec((block_c,), lambda c: (c,)),
            pl.BlockSpec((block_c,), lambda c: (c,)),
        ],
        out_specs=pl.BlockSpec((m, block_c), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((m, C), jnp.uint8),
        interpret=interpret,
    )(gammas, parity, old, new)


def _scaled_rows(g_ref, x, m: int):
    """rows[r] = gamma_r * x over GF(2^8) via in-kernel xtime powers."""
    rows = []
    for r in range(m):
        g = g_ref[0, r].astype(jnp.int32)
        acc = jnp.zeros_like(x)
        for b in range(8):
            acc = acc ^ (((x >> b) & 1) * g)
            g = ((g << 1) ^ jnp.where((g & 0x80) != 0, 0x11D, 0)) & 0xFF
        rows.append(acc.astype(jnp.uint8))
    return rows


def _delta_apply_batched_kernel(g_ref, p_ref, x_ref, o_ref, *, m: int):
    x = x_ref[0].astype(jnp.int32)                        # (BC,)
    rows = _scaled_rows(g_ref, x, m)
    o_ref[0] = jnp.stack([p_ref[0, r] ^ rows[r] for r in range(m)])


def _delta_only_batched_kernel(g_ref, x_ref, o_ref, *, m: int):
    x = x_ref[0].astype(jnp.int32)                        # (BC,)
    o_ref[0] = jnp.stack(_scaled_rows(g_ref, x, m))


@functools.partial(jax.jit, static_argnames=("m", "block_c", "interpret"))
def _delta_apply_batched_call(gammas, parity, xor, *, m, block_c, interpret):
    B, _, C = parity.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_delta_apply_batched_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m), lambda b, c: (b, 0)),
            pl.BlockSpec((1, m, block_c), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, block_c), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, m, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, m, C), jnp.uint8),
        interpret=interpret,
    )(gammas, parity, xor)


@functools.partial(jax.jit, static_argnames=("m", "block_c", "interpret"))
def _delta_only_batched_call(gammas, xor, *, m, block_c, interpret):
    B, C = xor.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_delta_only_batched_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m), lambda b, c: (b, 0)),
            pl.BlockSpec((1, block_c), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, m, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, m, C), jnp.uint8),
        interpret=interpret,
    )(gammas, xor)


def delta_apply_batched(parity: jax.Array | None, gammas: jax.Array,
                        xor: jax.Array, *, block_c: int = DEFAULT_BLOCK_C,
                        interpret: bool | None = None) -> jax.Array:
    """Batched fused delta fold with per-item coefficients.

    parity: (B, m, C); gammas: (B, m) — each batch item may update a
    different stripe position, hence per-item gamma rows; xor: (B, C) is
    D ⊕ D' per item.  Returns (B, m, C) updated parity.  This is the
    batched analogue of `delta_update` (grid = batch x C-tiles).

    ``parity=None`` returns the bare deltas gamma_r·xor — same kernel
    minus the parity read/write streams, for callers that fold the delta
    into host-side buffers themselves.
    """
    dec = dispatch.decide(interpret)
    if dec.path == dispatch.XLA:
        from repro.kernels import xla_gf256
        return xla_gf256.delta_batched(gammas, xor, parity)
    interpret = dec.interpret
    xor = jnp.asarray(xor, dtype=jnp.uint8)
    gammas = jnp.asarray(gammas, dtype=jnp.int32)
    B, m = gammas.shape
    C = xor.shape[1]
    if B == 0 or m == 0:
        return jnp.zeros((B, m, C), jnp.uint8)
    block_c = min(block_c, _round_up(C, 128))
    Cp = _round_up(C, block_c)
    if Cp != C:
        xor = jnp.pad(xor, ((0, 0), (0, Cp - C)))
    if parity is None:
        out = _delta_only_batched_call(gammas, xor, m=m, block_c=block_c,
                                       interpret=interpret)
        return out[:, :, :C]
    parity = jnp.asarray(parity, dtype=jnp.uint8)
    if Cp != C:
        parity = jnp.pad(parity, ((0, 0), (0, 0), (0, Cp - C)))
    out = _delta_apply_batched_call(gammas, parity, xor, m=m,
                                    block_c=block_c, interpret=interpret)
    return out[:, :, :C]


def delta_apply_per_item_batched(parity: jax.Array | None, Ms, blocks, *,
                                 block_c: int | None = None,
                                 strategy: str | None = None,
                                 interpret: bool | None = None) -> jax.Array:
    """Per-item-matrix delta fold — the r > 1 (RDP) update shape.

    ``Ms`` (B, O, J): one sub-block system per item (O = m*r rows,
    J = r columns for a single-chunk mutation); ``blocks`` (B, J, Cb)
    the xor sub-blocks; ``parity`` (B, O, Cb), when given, is folded in
    the same kernel.  This is the dispatch-routed, tune-aware front door
    for ``gf256_matmul_per_item_batched`` — the engines' r > 1 delta
    path goes through here so RDP updates hit the compiled per-item
    grid (Pallas on TPU/GPU, the ``xla_gf256`` twin on CPU) instead of
    the jnp per-item matmul, and the ``(op=delta_per_item, ...)`` tuning
    entries steer strategy × block_c when the caller doesn't.
    """
    from repro.kernels import tune
    from repro.kernels.gf256_matmul import gf256_matmul_per_item_batched
    import numpy as np
    Ms = np.asarray(Ms, dtype=np.uint8)
    B, O, J = Ms.shape
    C = blocks.shape[2]
    if strategy is None and block_c is None and B and O:
        dec = dispatch.decide(interpret)
        tuned = tune.lookup("delta_per_item", dec.path, k=J, m=O, chunk=C,
                            batch=B, cls=tune.matrix_cls(Ms))
        if tuned is not None:
            strategy = tuned.get("strategy")
            block_c = tuned.get("block_c") or None
    return gf256_matmul_per_item_batched(Ms, blocks, parity,
                                         block_c=block_c, strategy=strategy,
                                         interpret=interpret)


def delta_update(parity: jax.Array, gammas: jax.Array, old: jax.Array,
                 new: jax.Array, *, block_c: int = DEFAULT_BLOCK_C,
                 interpret: bool | None = None) -> jax.Array:
    """parity (m,C), gammas (m,), old/new (C,) -> new parity (m,C)."""
    dec = dispatch.decide(interpret)
    if dec.path == dispatch.XLA:
        from repro.kernels import xla_gf256
        return xla_gf256.delta_single(parity, gammas, old, new)
    interpret = dec.interpret
    parity = jnp.asarray(parity, dtype=jnp.uint8)
    old = jnp.asarray(old, dtype=jnp.uint8)
    new = jnp.asarray(new, dtype=jnp.uint8)
    gammas = jnp.asarray(gammas, dtype=jnp.int32)
    m, C = parity.shape
    block_c = min(block_c, _round_up(C, 128))
    Cp = _round_up(C, block_c)
    if Cp != C:
        parity = jnp.pad(parity, ((0, 0), (0, Cp - C)))
        old = jnp.pad(old, (0, Cp - C))
        new = jnp.pad(new, (0, Cp - C))
    out = _delta_call(gammas, parity, old, new, m=m, block_c=block_c,
                      interpret=interpret)
    return out[:, :C]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult
